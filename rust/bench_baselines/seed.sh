#!/usr/bin/env bash
# Seed (or refresh) the committed perf-trajectory baselines from a real
# bench run on a quiet machine. Run from rust/:
#
#   ./bench_baselines/seed.sh
#
# Keep BENCH_QUICK consistent with CI (which exports BENCH_QUICK=1) —
# quick-mode and full-mode numbers are not comparable.
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_QUICK="${BENCH_QUICK:-1}"
for b in bench_scheduler bench_round_engine bench_slice_cache bench_multitenant bench_obs; do
    cargo bench --bench "$b"
done
cp BENCH_*.json bench_baselines/
echo "seeded: $(ls bench_baselines/BENCH_*.json | tr '\n' ' ')"
echo "review the numbers, then commit bench_baselines/"

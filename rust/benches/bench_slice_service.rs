//! Slice-service bench: Option 1 vs 2 vs 3 fetch cost + byte ledgers across
//! (K, m, cohort), a threaded cohort-slicing sweep on a transformer-sized
//! store (the scale axis the session API exists for), plus the §6
//! PIR-overhead trade-off table. This is the systems ablation behind the
//! paper's §3.2/§6 discussion.

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use fedselect::cdn::pir::{client_down_bytes, PirScheme};
use fedselect::fedselect::{ClientKeys, RoundSession, SliceImpl, SliceService};
use fedselect::metrics::human_bytes;
use fedselect::model::ModelArch;
use fedselect::tensor::rng::Rng;

fn main() {
    let mut b = harness::Bench::new();
    let cohort = if b.quick { 8 } else { 32 };

    for &(vocab, m) in &[(2048usize, 64usize), (8192, 256), (8192, 2048)] {
        let arch = ModelArch::logreg(vocab);
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        // per-client distinct key sets (realistic overlap via zipf-ish reuse)
        let mut rng = Rng::new(7, 1);
        let keysets: Vec<ClientKeys> = (0..cohort)
            .map(|_| {
                vec![rng
                    .sample_without_replacement(vocab, m)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()]
            })
            .collect();

        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let name = format!("fetch/{imp:?}/K={vocab},m={m},cohort={cohort}");
            let mut svc = imp.build();
            b.run(&name, 10, || {
                let session = svc.begin_round(&store, &spec).unwrap();
                for ks in &keysets {
                    let out = session.fetch(ks).unwrap();
                    std::hint::black_box(&out);
                }
                let ledger = session.finish();
                std::hint::black_box(ledger);
            });
        }
        // ledger comparison (single round)
        println!("-- ledger K={vocab} m={m} cohort={cohort} --");
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            for ks in &keysets {
                session.fetch(ks).unwrap();
            }
            let l = session.finish();
            println!(
                "  {:>10?}: down={} up_keys={} psi={} memo_hits={} pregen={} cdn_q={} service_us={}",
                imp,
                human_bytes(l.down_bytes),
                human_bytes(l.up_key_bytes),
                l.psi_evals,
                l.memo_hits,
                l.pregen_slices,
                l.cdn_queries,
                l.service_us
            );
        }
    }

    // threaded cohort slicing on a transformer-sized store: the session API's
    // scale axis. Wall time covers fetch_batch only (pre-generation is
    // charged to begin_round, outside the timer, for every impl equally).
    {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let cohort_n = if b.quick { 16 } else { 64 };
        let (mv, mh) = (256usize, 128usize);
        let mut rng = Rng::new(11, 2);
        let batch: Vec<ClientKeys> = (0..cohort_n)
            .map(|_| {
                vec![
                    rng.sample_without_replacement(2048, mv)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect(),
                    rng.sample_without_replacement(512, mh)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect(),
                ]
            })
            .collect();
        println!(
            "-- cohort slicing throughput (transformer store, cohort={cohort_n}, m=({mv},{mh})) --"
        );
        let iters = if b.quick { 3 } else { 8 };
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut base_cps = 0.0f64;
            for &threads in &[1usize, 2, 4, 8] {
                let mut svc = imp.build();
                // warmup round
                {
                    let session = svc.begin_round(&store, &spec).unwrap();
                    std::hint::black_box(session.fetch_batch(&batch, threads).unwrap());
                    session.finish();
                }
                let mut elapsed = Duration::ZERO;
                let mut bytes = 0u64;
                for _ in 0..iters {
                    let session = svc.begin_round(&store, &spec).unwrap();
                    let t0 = Instant::now();
                    let out = session.fetch_batch(&batch, threads).unwrap();
                    elapsed += t0.elapsed();
                    bytes += out.iter().map(|s| s.bytes()).sum::<u64>();
                    std::hint::black_box(&out);
                    session.finish();
                }
                let secs = elapsed.as_secs_f64().max(1e-9);
                let cps = (cohort_n * iters) as f64 / secs;
                let mbps = bytes as f64 / 1e6 / secs;
                if threads == 1 {
                    base_cps = cps;
                }
                println!(
                    "  {imp} x{threads}: {cps:>8.0} clients/s  {mbps:>8.0} MB/s  ({:.2}x vs 1 thread)",
                    cps / base_cps.max(1e-9)
                );
                let name = format!("cohort_slicing/{imp}/threads={threads}");
                b.metric(&name, "clients_per_s", cps);
                b.metric(&name, "mb_per_s", mbps);
                b.metric(&name, "speedup_vs_1thread", cps / base_cps.max(1e-9));
            }
        }
    }

    // PIR trade-off: private selection vs plain broadcast (paper §6)
    println!("-- PIR overhead (per client, K records of B bytes, m queries) --");
    for &(k, rec_bytes, m) in &[
        (1usize << 13, 200usize, 256usize),
        (1 << 16, 200, 256),
        (1 << 20, 512, 100),
    ] {
        let full = (k * rec_bytes) as u64;
        for scheme in [PirScheme::Trivial, PirScheme::SqrtComm, PirScheme::LogComm] {
            let down = client_down_bytes(scheme, m, k, rec_bytes);
            println!(
                "  K=2^{:<2} B={rec_bytes:<4} m={m:<4} {scheme:?}: down={} vs broadcast={} -> {}",
                (k as f64).log2() as u32,
                human_bytes(down),
                human_bytes(full),
                if down < full { "PIR still saves" } else { "broadcast cheaper" }
            );
        }
    }
    if let Some(r) = b.ratio(
        "fetch/Broadcast/K=8192,m=256,cohort=8",
        "fetch/PregenCdn/K=8192,m=256,cohort=8",
    ) {
        b.note(&format!("broadcast/pregen wall ratio at K=8192,m=256: {r:.2}x"));
    }
    b.write_json("BENCH_slice_service.json");
}

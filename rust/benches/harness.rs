//! Minimal benchmark harness (offline substitute for criterion).
//!
//! Each measurement warms up, then runs timed iterations and reports
//! mean / p50 / p95 wall time. `--quick` (or BENCH_QUICK=1) cuts iteration
//! counts for CI. Output is line-oriented: `bench <name>: mean=… p50=… p95=…`.

use std::time::Instant;

pub struct Bench {
    pub quick: bool,
    results: Vec<(String, f64)>,
}

impl Bench {
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("BENCH_QUICK").is_ok();
        Bench {
            quick,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `iters` is scaled down by 4 in quick mode.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        let iters = if self.quick { (iters / 4).max(3) } else { iters.max(5) };
        // warmup
        for _ in 0..iters.min(3) {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
        println!(
            "bench {name}: mean={mean:.3}ms p50={p50:.3}ms p95={p95:.3}ms (n={})",
            samples.len()
        );
        self.results.push((name.to_string(), mean));
    }

    /// Report a derived ratio between two recorded benches.
    pub fn ratio(&self, num: &str, den: &str) -> Option<f64> {
        let find = |n: &str| {
            self.results
                .iter()
                .find(|(name, _)| name == n)
                .map(|(_, v)| *v)
        };
        Some(find(num)? / find(den)?)
    }

    pub fn note(&self, s: &str) {
        println!("note: {s}");
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

//! Minimal benchmark harness (offline substitute for criterion).
//!
//! Each measurement warms up, then runs timed iterations and reports
//! mean / p50 / p95 wall time. `--quick` (or BENCH_QUICK=1) cuts iteration
//! counts for CI. Output is line-oriented: `bench <name>: mean=… p50=… p95=…`.
//!
//! Besides wall times, a bench can record named throughput/derived metrics
//! via [`Bench::metric`]; [`Bench::write_json`] dumps everything as a
//! machine-readable `BENCH_<name>.json` (schema `fedselect-bench-v1`) so
//! runs can be diffed across commits — the repo's perf trajectory.

// shared across all benches via `#[path]`; not every bench uses every helper
#![allow(dead_code)]

use std::collections::BTreeMap;
use std::time::Instant;

use fedselect::util::json::Json;

#[derive(Clone, Copy, Debug)]
struct Wall {
    mean_ms: f64,
    p50_ms: f64,
    p95_ms: f64,
    n: usize,
}

pub struct Bench {
    pub quick: bool,
    results: Vec<(String, Wall)>,
    metrics: Vec<(String, BTreeMap<String, f64>)>,
}

impl Bench {
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("BENCH_QUICK").is_ok();
        Bench {
            quick,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `iters` is scaled down by 4 in quick mode.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        let iters = if self.quick { (iters / 4).max(3) } else { iters.max(5) };
        // warmup
        for _ in 0..iters.min(3) {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
        println!(
            "bench {name}: mean={mean:.3}ms p50={p50:.3}ms p95={p95:.3}ms (n={})",
            samples.len()
        );
        self.results.push((
            name.to_string(),
            Wall {
                mean_ms: mean,
                p50_ms: p50,
                p95_ms: p95,
                n: samples.len(),
            },
        ));
    }

    /// Record one derived metric (clients/s, MB/s, sim seconds, …) under a
    /// measurement name; repeated calls with the same name merge keys.
    pub fn metric(&mut self, name: &str, key: &str, value: f64) {
        if let Some((_, m)) = self.metrics.iter_mut().find(|(n, _)| n == name) {
            m.insert(key.to_string(), value);
        } else {
            let mut m = BTreeMap::new();
            m.insert(key.to_string(), value);
            self.metrics.push((name.to_string(), m));
        }
    }

    /// Fold a [`fedselect::obs::MetricsRegistry`] snapshot into one
    /// measurement's derived metrics: counters and gauges under their
    /// registry names, histograms as `<name>_mean`. Registry names use
    /// dots (`comm.down_bytes`), so they never collide with the
    /// `*_per_s` / `sim_*` families the perf gate thresholds — they ride
    /// along as informational trajectory.
    pub fn record_registry(&mut self, name: &str, reg: &fedselect::obs::MetricsRegistry) {
        let entries: Vec<(String, f64)> = reg
            .counters()
            .map(|(k, v)| (k.to_string(), v as f64))
            .chain(reg.gauges().map(|(k, v)| (k.to_string(), v)))
            .chain(reg.hists().map(|(k, h)| (format!("{k}_mean"), h.mean())))
            .collect();
        for (k, v) in entries {
            self.metric(name, &k, v);
        }
    }

    /// Report a derived ratio between two recorded benches.
    pub fn ratio(&self, num: &str, den: &str) -> Option<f64> {
        let find = |n: &str| {
            self.results
                .iter()
                .find(|(name, _)| name == n)
                .map(|(_, v)| v.mean_ms)
        };
        Some(find(num)? / find(den)?)
    }

    pub fn note(&self, s: &str) {
        println!("note: {s}");
    }

    /// Write everything recorded so far as machine-readable JSON
    /// (`fedselect-bench-v1`): wall times under `"wall_ms"`, derived
    /// metrics under `"metrics"`.
    pub fn write_json(&self, path: &str) {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str("fedselect-bench-v1".into()));
        root.insert("quick".to_string(), Json::Bool(self.quick));
        let walls: Vec<Json> = self
            .results
            .iter()
            .map(|(name, w)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("mean_ms".to_string(), Json::Num(w.mean_ms));
                o.insert("p50_ms".to_string(), Json::Num(w.p50_ms));
                o.insert("p95_ms".to_string(), Json::Num(w.p95_ms));
                o.insert("n".to_string(), Json::Num(w.n as f64));
                Json::Obj(o)
            })
            .collect();
        root.insert("wall_ms".to_string(), Json::Arr(walls));
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|(name, m)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(name.clone()));
                for (k, v) in m {
                    o.insert(k.clone(), Json::Num(*v));
                }
                Json::Obj(o)
            })
            .collect();
        root.insert("metrics".to_string(), Json::Arr(metrics));
        match std::fs::write(path, Json::Obj(root).dump()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

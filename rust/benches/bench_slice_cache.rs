//! Cross-round slice-cache bench: the repeated-selection workload of
//! `experiment --id cache`, run cache-off (baseline) and cache-on per
//! eviction policy. Emits `BENCH_slice_cache.json` (schema
//! `fedselect-bench-v1`) with the hit rate and the *effective saved
//! bandwidth* — wire MB the cache kept off the downlink per simulated
//! second (`saved_mb_per_s`, deterministic, gated by `perf_diff`) — the
//! repo's delta-fetch perf trajectory.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::cache::EvictPolicy;
use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::scheduler::{FleetKind, SchedPolicy};

fn main() {
    let mut b = harness::Bench::new();
    let (vocab, m) = (1024usize, 128usize);
    let (rounds, cohort, n_clients) = if b.quick { (6, 8, 32) } else { (12, 12, 60) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let make = |cache: Option<EvictPolicy>| {
        let mut cfg = TrainConfig::logreg_default(vocab, m);
        cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
        cfg.rounds = rounds;
        cfg.cohort = cohort;
        cfg.eval.every = 0;
        cfg.eval.max_examples = 256;
        cfg.fleet = FleetKind::Tiered3;
        cfg.sched_policy = SchedPolicy::StalenessFair;
        cfg.dropout_rate = 0.3;
        cfg.seed = 1000;
        if let Some(evict) = cache {
            cfg.cache = true;
            cfg.cache_evict = evict;
            cfg.cache_budget_frac = 0.5;
        }
        cfg
    };

    // cache-off baseline (identical trajectory at the same seed)
    let mut base = Trainer::with_dataset(make(None), dataset.clone()).unwrap();
    let mut base_down = 0u64;
    for _ in 0..rounds {
        base_down += base.run_round().unwrap().comm.down_bytes;
    }
    let base_sim = base.scheduler().sim_total_s();
    println!(
        "baseline: down={:.2}MB sim_total={base_sim:.1}s",
        base_down as f64 / 1e6
    );

    for evict in EvictPolicy::ALL {
        let name = format!("cache/{evict}");
        let t0 = Instant::now();
        let mut tr = Trainer::with_dataset(make(Some(evict)), dataset.clone()).unwrap();
        let mut down = 0u64;
        let mut hits = 0u64;
        let mut lookups = 0u64;
        let mut completed = 0usize;
        for _ in 0..rounds {
            let rec = tr.run_round().unwrap();
            down += rec.comm.down_bytes;
            hits += rec.comm.client_cache_hits;
            lookups += rec.tier_cache_lookups.iter().sum::<u64>();
            completed += rec.completed;
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let sim_total = tr.scheduler().sim_total_s();
        let hit_rate = if lookups > 0 {
            100.0 * hits as f64 / lookups as f64
        } else {
            0.0
        };
        let saved_mb = (base_down.saturating_sub(down)) as f64 / 1e6;
        // deterministic: wire MB kept off the downlink per simulated second
        let saved_mb_per_s = saved_mb / sim_total.max(1e-9);
        println!(
            "{name}: hit_rate={hit_rate:.1}%  saved={saved_mb:.2}MB  \
             ({saved_mb_per_s:.4} MB/sim-s)  sim_total={sim_total:.1}s  \
             {:.1} clients/s",
            completed as f64 / secs
        );
        b.metric(&name, "hit_rate_pct", hit_rate);
        b.metric(&name, "saved_mb", saved_mb);
        b.metric(&name, "saved_mb_per_s", saved_mb_per_s);
        b.metric(&name, "sim_total_s", sim_total);
        b.metric(&name, "clients_per_s", completed as f64 / secs);

        // per-round wall-time distribution (delta planning + commits
        // included) on a fresh trainer
        let mut timed = Trainer::with_dataset(make(Some(evict)), dataset.clone()).unwrap();
        b.run(&format!("round_wall/cache/{evict}"), 8, || {
            let rec = timed.run_round().unwrap();
            std::hint::black_box(rec.comm.client_cache_hits);
        });
    }

    b.write_json("BENCH_slice_cache.json");
}

//! Pipelined-executor throughput: the same merge-heavy round driven
//! sequentially (`--exec strict --exec-workers 1`), strictly over the pool
//! (`--exec-workers 4`), and in fast mode (completion-order merge over the
//! key-striped accumulator). Emits `BENCH_exec.json` (schema
//! `fedselect-bench-v1`) with rounds/s, mean merge-stall ms, and pool
//! utilization per variant — `perf_diff` gates the trajectory
//! (`*_per_s` higher-is-better, `*_stall_ms` lower-is-better).
//!
//! Outside quick mode the bench also *asserts* the tentpole claim: fast
//! throughput strictly above strict at 4 workers.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::bow::BowConfig;
use fedselect::exec::ExecMode;

/// Merge-heavy shape: a wide logreg (409.6k params) with big slices and a
/// large cohort, so the close-phase accumulator work is a visible slice of
/// the round.
fn bench_cfg(exec: ExecMode, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(8192, 2048);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(8192, 50).with_clients(60, 0, 10));
    cfg.cohort = 24;
    cfg.rounds = 1;
    cfg.exec = exec;
    cfg.exec_workers = workers;
    cfg
}

fn main() {
    let mut b = harness::Bench::new();
    let rounds = if b.quick { 4usize } else { 12 };

    let variants = [
        ("strict_w1", ExecMode::Strict, 1usize),
        ("strict_w4", ExecMode::Strict, 4),
        ("fast_w4", ExecMode::Fast, 4),
        ("fast_w8", ExecMode::Fast, 8),
    ];
    let mut rounds_per_s = Vec::new();
    for (tag, exec, workers) in variants {
        let mut tr = Trainer::new(bench_cfg(exec, workers)).unwrap();
        // one untimed round to warm caches/allocations
        std::hint::black_box(tr.run_round().unwrap());
        let mut stall = 0.0f64;
        let mut util = 0.0f64;
        let t0 = Instant::now();
        for _ in 0..rounds {
            let rec = tr.run_round().unwrap();
            stall += rec.merge_stall_ms;
            util += rec.exec_util;
            std::hint::black_box(rec);
        }
        let rps = rounds as f64 / t0.elapsed().as_secs_f64();
        rounds_per_s.push((tag, rps));
        let name = format!("exec/{tag}");
        b.metric(&name, "rounds_per_s", rps);
        b.metric(&name, "merge_stall_ms", stall / rounds as f64);
        b.metric(&name, "worker_util", util / rounds as f64);
        println!(
            "bench {name}: {rps:.2} rounds/s | merge stall {:.3}ms | util {:.2}",
            stall / rounds as f64,
            util / rounds as f64
        );
        // wall-time distribution of a single round, same shape
        let mut tr = Trainer::new(bench_cfg(exec, workers)).unwrap();
        b.run(&name, 8, || {
            std::hint::black_box(tr.run_round().unwrap());
        });
    }

    let rps = |tag: &str| {
        rounds_per_s
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, v)| *v)
            .unwrap()
    };
    b.note(&format!(
        "fast_w4 / strict_w4 throughput: {:.2}x",
        rps("fast_w4") / rps("strict_w4")
    ));
    if !b.quick {
        // the tentpole contract: completion-order merging over the sharded
        // accumulator must out-run the strict cohort-order replay once the
        // pool is wide enough
        assert!(
            rps("fast_w4") > rps("strict_w4"),
            "fast ({:.2} rounds/s) did not beat strict ({:.2} rounds/s) at 4 workers",
            rps("fast_w4"),
            rps("strict_w4")
        );
    }
    b.write_json("BENCH_exec.json");
}

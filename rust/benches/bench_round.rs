//! End-to-end round latency: the full Algorithm-2 loop (sample cohort,
//! pre-generate, fetch, client updates, deselect-aggregate, server step),
//! native vs PJRT engines. L3 overhead is isolated by comparing against the
//! pure client-update cost.

#[path = "harness.rs"]
mod harness;

use fedselect::config::{DatasetConfig, EngineKind, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::bow::BowConfig;
use fedselect::data::images::ImageConfig;

fn main() {
    let mut b = harness::Bench::new();

    // logreg round: native engine across m
    for &m in &[64usize, 256, 1024] {
        let mut cfg = TrainConfig::logreg_default(2048, m);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(2048, 50).with_clients(60, 0, 10));
        cfg.cohort = 20;
        cfg.rounds = 1;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run(&format!("round/logreg/native/m={m}"), 10, || {
            let rec = tr.run_round().unwrap();
            std::hint::black_box(rec);
        });
    }

    // mlp round: native engine
    for &m in &[50usize, 200] {
        let mut cfg = TrainConfig::mlp_default(m);
        cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(40, 8));
        cfg.cohort = 10;
        cfg.rounds = 1;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run(&format!("round/mlp/native/m={m}"), 5, || {
            let rec = tr.run_round().unwrap();
            std::hint::black_box(rec);
        });
    }

    // PJRT rounds when artifacts are present
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for &m in &[64usize, 1024] {
            let mut cfg = TrainConfig::logreg_default(2048, m);
            cfg.dataset = DatasetConfig::Bow(BowConfig::new(2048, 50).with_clients(60, 0, 10));
            cfg.cohort = 20;
            cfg.rounds = 1;
            cfg.engine = EngineKind::pjrt_default();
            let mut tr = Trainer::new(cfg).unwrap();
            b.run(&format!("round/logreg/pjrt/m={m}"), 10, || {
                let rec = tr.run_round().unwrap();
                std::hint::black_box(rec);
            });
        }
        let mut cfg = TrainConfig::cnn_default(16);
        cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(40, 8));
        cfg.cohort = 10;
        cfg.rounds = 1;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run("round/cnn/pjrt/m=16", 5, || {
            let rec = tr.run_round().unwrap();
            std::hint::black_box(rec);
        });
        if let Some(r) = b.ratio("round/logreg/pjrt/m=64", "round/logreg/native/m=64") {
            b.note(&format!("pjrt/native round ratio (logreg m=64): {r:.2}x"));
        }
    } else {
        b.note("artifacts missing: skipping PJRT round benches (run `make artifacts`)");
    }
}

//! Million-client fleet bench: `plan_round` throughput and resident
//! scheduler state across fleet sizes 10k / 1M / 10M, for every selection
//! policy, on the lazy tiered fleet. Emits `BENCH_fleet.json` (schema
//! `fedselect-bench-v1`) with planned clients/s and resident MB per size —
//! the repo's fleet-scale perf trajectory.
//!
//! Quick mode (`--quick` / BENCH_QUICK) drops the 10M tier so the CI smoke
//! stays fast; the derived metrics keep their names, so `perf_diff`
//! compares like against like.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::config::TrainConfig;
use fedselect::scheduler::{FleetKind, SchedPolicy, Scheduler, SliceGeometry};
use fedselect::tensor::rng::Rng;

fn main() {
    let mut b = harness::Bench::new();
    let sizes: &[usize] = if b.quick {
        &[10_000, 1_000_000]
    } else {
        &[10_000, 1_000_000, 10_000_000]
    };
    let geom = SliceGeometry {
        base_ms: vec![512],
        per_key_floats: vec![64],
        broadcast_floats: 64,
        server_floats: 4096 * 64 + 64,
    };
    let plan_rounds = if b.quick { 5 } else { 20 };

    for &n in sizes {
        let label = if n >= 1_000_000 {
            format!("{}m", n / 1_000_000)
        } else {
            format!("{}k", n / 1_000)
        };
        for policy in SchedPolicy::ALL {
            let mut cfg = TrainConfig::logreg_default(256, 64);
            cfg.fleet = FleetKind::Tiered3;
            cfg.fleet_size = n;
            cfg.sched_policy = policy;
            cfg.cohort = 100;
            cfg.mem_cap_frac = 0.25;
            cfg.seed = 7;
            let mut sched = Scheduler::new(&cfg, 100).unwrap();
            let mut rng = Rng::new(cfg.seed, 0x5CA1E);
            let name = format!("plan/{label}/{policy}");
            let t0 = Instant::now();
            for round in 1..=plan_rounds {
                let plan = sched.plan_round(round, cfg.cohort, &geom, &mut rng, &[]);
                std::hint::black_box(plan.cohort.len());
            }
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            let clients_per_s = n as f64 * plan_rounds as f64 / secs;
            let plan_ms = 1e3 * secs / plan_rounds as f64;
            let resident_mb = sched.resident_state_bytes() as f64 / 1e6;
            println!(
                "{name}: plan={plan_ms:.3}ms  {clients_per_s:.3e} clients/s  \
                 touched={}  resident={resident_mb:.3}MB",
                sched.clients_touched()
            );
            b.metric(&name, "plan_ms", plan_ms);
            b.metric(&name, "clients_per_s", clients_per_s);
            b.metric(&name, "resident_mb", resident_mb);
            b.metric(&name, "clients_touched", sched.clients_touched() as f64);
        }

        // wall-time distribution for the uniform policy (the floor every
        // other policy builds on)
        let mut cfg = TrainConfig::logreg_default(256, 64);
        cfg.fleet = FleetKind::Tiered3;
        cfg.fleet_size = n;
        cfg.sched_policy = SchedPolicy::Uniform;
        cfg.cohort = 100;
        cfg.mem_cap_frac = 0.25;
        cfg.seed = 7;
        let mut sched = Scheduler::new(&cfg, 100).unwrap();
        let mut rng = Rng::new(cfg.seed, 0x5CA1E);
        let mut round = 0usize;
        b.run(&format!("plan_wall/{label}/uniform"), 10, || {
            round += 1;
            let plan = sched.plan_round(round, 100, &geom, &mut rng, &[]);
            std::hint::black_box(plan.cohort.len());
        });
    }

    b.write_json("BENCH_fleet.json");
}

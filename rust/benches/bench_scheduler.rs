//! Cohort-scheduler bench: host wall time and simulated round time for
//! every selection policy on a tiered fleet, plus the uniform-fleet
//! baseline. Emits `BENCH_scheduler.json` (schema `fedselect-bench-v1`)
//! with clients/s, MB/s, and simulated round seconds per policy — the
//! repo's scheduler perf trajectory.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::scheduler::{FleetKind, SchedPolicy};

fn main() {
    let mut b = harness::Bench::new();
    let (vocab, m) = (2048usize, 256usize);
    let (rounds, cohort, n_clients) = if b.quick { (3, 10, 60) } else { (8, 25, 150) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    for (fleet, policy) in std::iter::once((FleetKind::Uniform, SchedPolicy::Uniform)).chain(
        SchedPolicy::ALL
            .into_iter()
            .map(|p| (FleetKind::Tiered3, p)),
    ) {
        let name = format!("round/{fleet}/{policy}");
        let make = || {
            let mut cfg = TrainConfig::logreg_default(vocab, m);
            cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = 256;
            cfg.fleet = fleet.clone();
            cfg.sched_policy = policy;
            cfg.seed = 1000;
            cfg
        };
        // timed: full training rounds through the scheduler
        let t0 = Instant::now();
        let mut trainer = Trainer::with_dataset(make(), dataset.clone()).unwrap();
        let mut completed = 0usize;
        let mut down_bytes = 0u64;
        let mut sim_round_sum = 0.0f64;
        for _ in 0..rounds {
            let rec = trainer.run_round().unwrap();
            completed += rec.completed;
            down_bytes += rec.comm.down_bytes;
            sim_round_sum += rec.sim_round_s;
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let clients_per_s = completed as f64 / secs;
        let mbps = down_bytes as f64 / 1e6 / secs;
        let sim_round_s = sim_round_sum / rounds as f64;
        println!(
            "{name}: {clients_per_s:>8.1} clients/s  {mbps:>7.1} MB/s  \
             sim_round={sim_round_s:.2}s  sim_total={:.1}s",
            trainer.scheduler().sim_total_s()
        );
        b.metric(&name, "clients_per_s", clients_per_s);
        b.metric(&name, "mb_per_s", mbps);
        b.metric(&name, "sim_round_s", sim_round_s);
        b.metric(&name, "sim_total_s", trainer.scheduler().sim_total_s());

        // per-round wall-time distribution (scheduling included)
        let mut timed = Trainer::with_dataset(make(), dataset.clone()).unwrap();
        b.run(&format!("round_wall/{fleet}/{policy}"), 10, || {
            let rec = timed.run_round().unwrap();
            std::hint::black_box(rec.sim_round_s);
        });
    }

    b.write_json("BENCH_scheduler.json");
}

//! Multi-tenant coordinator bench: N heterogeneous jobs arbitrated over
//! one shared tiered fleet, per arbiter policy. Emits
//! `BENCH_multitenant.json` (schema `fedselect-bench-v1`) with coordinator
//! throughput (`jobs_per_s`, `arbiter_ticks_per_s` — gated by `perf_diff`)
//! and the deterministic `fleet_utilization` rollup (informational).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::cache::CacheShare;
use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::SliceImpl;
use fedselect::scheduler::{FleetKind, SchedPolicy};
use fedselect::tenancy::{ArbiterPolicy, Coordinator, JobRegistry, JobSpec};

fn main() {
    let mut b = harness::Bench::new();
    let (rounds, n_clients) = if b.quick { (3, 30) } else { (8, 60) };

    let make = |vocab: usize, m: usize, cohort: usize, imp: SliceImpl, cache: bool| {
        let mut cfg = TrainConfig::logreg_default(vocab, m);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(n_clients, 6, 8));
        cfg.rounds = rounds;
        cfg.cohort = cohort;
        cfg.eval.every = 0;
        cfg.eval.max_examples = 256;
        cfg.fleet = FleetKind::Tiered3;
        cfg.sched_policy = SchedPolicy::StalenessFair;
        cfg.dropout_rate = 0.2;
        cfg.seed = 4242;
        cfg.slice_impl = imp;
        cfg.cache = cache;
        cfg
    };
    let roster = || {
        vec![
            JobSpec::new(1, "narrow", make(256, 32, 6, SliceImpl::OnDemand, false)),
            JobSpec::new(2, "wide", make(512, 64, 8, SliceImpl::PregenCdn, true)).with_weight(2.0),
            JobSpec::new(3, "bcast", make(256, 48, 6, SliceImpl::Broadcast, false))
                .with_priority(5),
        ]
    };
    let n_jobs = roster().len();

    for policy in ArbiterPolicy::ALL {
        let name = format!("coordinator/{policy}");
        let t0 = Instant::now();
        let reg = JobRegistry::new(roster(), CacheShare::Partitioned).unwrap();
        let mut coord = Coordinator::new(reg, policy).unwrap();
        let report = coord.run().unwrap();
        let secs = t0.elapsed().as_secs_f64().max(1e-9);

        let jobs_per_s = n_jobs as f64 / secs;
        let ticks_per_s = report.ticks as f64 / secs;
        let util_pct = 100.0 * report.fleet_utilization;
        println!(
            "{name}: {n_jobs} jobs / {} ticks in {secs:.2}s  \
             ({jobs_per_s:.2} jobs/s, {ticks_per_s:.2} ticks/s)  \
             sim={:.1}s util={util_pct:.1}%",
            report.ticks, report.total_sim_s
        );
        b.metric(&name, "jobs_per_s", jobs_per_s);
        b.metric(&name, "arbiter_ticks_per_s", ticks_per_s);
        // deterministic rollup, informational (no _per_s suffix => ungated)
        b.metric(&name, "fleet_utilization", util_pct);
        b.metric(&name, "sim_total_s", report.total_sim_s);

        // tick wall-time distribution on a fresh coordinator; rebuild when
        // the run completes so every sample measures a live tick
        let reg = JobRegistry::new(roster(), CacheShare::Partitioned).unwrap();
        let mut live = Coordinator::new(reg, policy).unwrap();
        let mut done = 0usize;
        b.run(&format!("tick_wall/{policy}"), 8, || {
            if done >= rounds {
                let reg = JobRegistry::new(roster(), CacheShare::Partitioned).unwrap();
                live = Coordinator::new(reg, policy).unwrap();
                done = 0;
            }
            live.tick().unwrap();
            done += 1;
        });
    }

    b.write_json("BENCH_multitenant.json");
}

//! Observability overhead bench: events/s through the recorder sinks and
//! ops/s through the metrics registry. Emits `BENCH_obs.json` (schema
//! `fedselect-bench-v1`). `null_events_per_s` is the unconditional-dispatch
//! worst case of the always-on path — real call sites gate on
//! `Recorder::enabled()` and skip event construction entirely — and
//! `jsonl_events_per_s` is the cost of tracing to disk; both are gated by
//! `perf_diff` as the observability perf trajectory.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::coordinator::{AggregationMode, RoundRecord};
use fedselect::fedselect::RoundComm;
use fedselect::obs::trace::JsonlRecorder;
use fedselect::obs::{
    ClientStage, HealthConfig, HealthMonitor, MetricsRegistry, NullRecorder, Phase, Recorder,
    SloRule, TraceEvent,
};

/// Emit a representative round's event mix: 1 round_start, 4 spans, 4
/// client lifecycle events, 1 round_close — 10 events per call.
fn pump_round(rec: &dyn Recorder, round: usize) {
    rec.record(&TraceEvent::RoundStart {
        ns: 0,
        round,
        sim_start_s: round as f64,
    });
    for (i, phase) in [Phase::Plan, Phase::Fetch, Phase::Compute, Phase::Close]
        .into_iter()
        .enumerate()
    {
        rec.record(&TraceEvent::Span {
            ns: 0,
            round,
            phase,
            wall_ms: i as f64,
            sim_s: i as f64 * 0.5,
        });
    }
    let client = round % 64;
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Selected,
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Fetched {
            down_bytes: 4096,
            cache_hit_pieces: 3,
        },
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Computed { up_bytes: 2048 },
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Merged {
            staleness: 0,
            weight: 1.0,
        },
    });
    rec.record(&TraceEvent::RoundClose {
        ns: 0,
        round,
        completed: 1,
        dropped: 0,
        discarded: 0,
        deferred: 0,
        committees: 0,
        close_s: 1.0,
        sim_round_s: 1.5,
        sim_total_s: round as f64 * 1.5,
        down_bytes: 4096,
        up_bytes: 2048,
        eligible: 100,
        arrivals: 0,
        departures: 0,
        outage_excluded: 0,
        clients_touched: 10,
        resident_bytes: 1024,
    });
}

const EVENTS_PER_ROUND: usize = 10;

/// Synthetic round ledger for the health-monitor overhead measurement:
/// deterministic per-round jitter plus a level step at `round >= 64` so
/// both detector paths (EWMA update + window shift) do real work.
fn synth_record(round: usize) -> RoundRecord {
    let jitter = (round % 7) as f64 * 0.01;
    let eligible = if round >= 64 { 500 } else { 950 + round % 13 };
    RoundRecord {
        round,
        completed: 9 + round % 2,
        dropped: round % 2,
        mode: AggregationMode::Synchronous,
        discarded_clients: 0,
        mean_staleness: 0.0,
        committees: 0,
        mean_committee_size: 0.0,
        min_committee_size: 0,
        comm: RoundComm::default(),
        up_bytes: 2048,
        max_client_mem: 0,
        wall_ms: 0.0,
        merge_stall_ms: 0.0,
        exec_util: 1.0,
        sim_round_s: 1.5 + jitter,
        tier_completed: vec![10],
        tier_dropped: vec![0],
        tier_discarded: vec![0],
        tier_down_bytes: vec![4096],
        tier_cache_hits: vec![3],
        tier_cache_lookups: vec![4],
        cache_evictions: 0,
        cache_stale_refreshes: 0,
        deferrals: 0,
        eligible,
        arrivals: 0,
        departures: 0,
        outage_excluded: 0,
        clients_touched: 10,
        resident_bytes: 1024,
    }
}

fn main() {
    let mut b = harness::Bench::new();
    let rounds = if b.quick { 2_000 } else { 20_000 };
    let events = rounds * EVENTS_PER_ROUND;

    let null = NullRecorder;
    b.run("obs/null_sink", 10, || {
        for r in 0..rounds {
            pump_round(&null, r);
        }
    });
    let t0 = Instant::now();
    for r in 0..rounds {
        pump_round(&null, r);
    }
    b.metric(
        "obs",
        "null_events_per_s",
        events as f64 / t0.elapsed().as_secs_f64(),
    );

    let path = std::env::temp_dir().join("fedselect_bench_obs.jsonl");
    let path = path.to_string_lossy().to_string();
    b.run("obs/jsonl_sink", 10, || {
        let jsonl = JsonlRecorder::create(&path).unwrap();
        for r in 0..rounds {
            pump_round(&jsonl, r);
        }
        jsonl.flush();
    });
    let jsonl = JsonlRecorder::create(&path).unwrap();
    let t0 = Instant::now();
    for r in 0..rounds {
        pump_round(&jsonl, r);
    }
    jsonl.flush();
    b.metric(
        "obs",
        "jsonl_events_per_s",
        events as f64 / t0.elapsed().as_secs_f64(),
    );
    let _ = std::fs::remove_file(&path);

    // registry hot path: one counter, one counter-vec slot, one histogram
    // observation per op — the shape of the trainer's per-event updates
    let ops = if b.quick { 50_000 } else { 500_000 };
    let mut reg = MetricsRegistry::new();
    b.run("obs/registry", 10, || {
        for i in 0..ops {
            reg.counter_add("clients.completed", 1);
            reg.counter_vec_add("tier.completed", i % 3, 1);
            reg.observe("fetch_latency_s.t0", (i % 100) as f64 * 0.01);
        }
    });
    let mut reg = MetricsRegistry::new();
    let t0 = Instant::now();
    for i in 0..ops {
        reg.counter_add("clients.completed", 1);
        reg.counter_vec_add("tier.completed", i % 3, 1);
        reg.observe("fetch_latency_s.t0", (i % 100) as f64 * 0.01);
    }
    b.metric(
        "obs",
        "registry_ops_per_s",
        (3 * ops) as f64 / t0.elapsed().as_secs_f64(),
    );
    // snapshot the registry into the bench JSON via the harness helper
    // (informational: dotted names sit outside the gated metric families)
    b.record_registry("obs/registry_snapshot", &reg);

    // health-monitor overhead: the same synthetic round stream folded
    // through 2 SLO rules + both anomaly detectors, vs the monitor-free
    // baseline (reading the same fields the monitor samples)
    let health_rounds = if b.quick { 20_000 } else { 200_000 };
    let records: Vec<RoundRecord> = (0..health_rounds).map(synth_record).collect();
    let cfg = HealthConfig {
        slos: SloRule::parse_list("eligible_frac:ge:0.7,dropped_frac:le:0.5").unwrap(),
        detectors: true,
        ..HealthConfig::default()
    };
    b.run("obs/health_monitor", 5, || {
        let mut mon = HealthMonitor::new(&cfg, 1_000, 10).unwrap();
        for rec in &records {
            let _ = mon.observe_round(rec);
        }
        let _ = mon.finish();
    });
    let mut mon = HealthMonitor::new(&cfg, 1_000, 10).unwrap();
    let t0 = Instant::now();
    for rec in &records {
        let _ = mon.observe_round(rec);
    }
    let ledger = mon.finish();
    b.metric(
        "obs",
        "monitor_on_rounds_per_s",
        health_rounds as f64 / t0.elapsed().as_secs_f64(),
    );
    let t0 = Instant::now();
    let mut acc = 0.0f64;
    for rec in &records {
        acc += rec.sim_round_s + rec.eligible as f64 + rec.dropped as f64;
    }
    b.metric(
        "obs",
        "monitor_off_rounds_per_s",
        health_rounds as f64 / t0.elapsed().as_secs_f64().max(1e-9),
    );
    assert!(acc > 0.0 && ledger.total() > 0, "monitor bench must do real work");

    b.note(&format!(
        "{rounds} rounds x {EVENTS_PER_ROUND} events; registry ops x{ops}; \
         monitor x{health_rounds} rounds"
    ));
    b.write_json("BENCH_obs.json");
}

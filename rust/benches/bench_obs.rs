//! Observability overhead bench: events/s through the recorder sinks and
//! ops/s through the metrics registry. Emits `BENCH_obs.json` (schema
//! `fedselect-bench-v1`). `null_events_per_s` is the unconditional-dispatch
//! worst case of the always-on path — real call sites gate on
//! `Recorder::enabled()` and skip event construction entirely — and
//! `jsonl_events_per_s` is the cost of tracing to disk; both are gated by
//! `perf_diff` as the observability perf trajectory.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fedselect::obs::trace::JsonlRecorder;
use fedselect::obs::{ClientStage, MetricsRegistry, NullRecorder, Phase, Recorder, TraceEvent};

/// Emit a representative round's event mix: 1 round_start, 4 spans, 4
/// client lifecycle events, 1 round_close — 10 events per call.
fn pump_round(rec: &dyn Recorder, round: usize) {
    rec.record(&TraceEvent::RoundStart {
        ns: 0,
        round,
        sim_start_s: round as f64,
    });
    for (i, phase) in [Phase::Plan, Phase::Fetch, Phase::Compute, Phase::Close]
        .into_iter()
        .enumerate()
    {
        rec.record(&TraceEvent::Span {
            ns: 0,
            round,
            phase,
            wall_ms: i as f64,
            sim_s: i as f64 * 0.5,
        });
    }
    let client = round % 64;
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Selected,
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Fetched {
            down_bytes: 4096,
            cache_hit_pieces: 3,
        },
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Computed { up_bytes: 2048 },
    });
    rec.record(&TraceEvent::Client {
        ns: 0,
        round,
        client,
        tier: Some(client % 3),
        stage: ClientStage::Merged {
            staleness: 0,
            weight: 1.0,
        },
    });
    rec.record(&TraceEvent::RoundClose {
        ns: 0,
        round,
        completed: 1,
        dropped: 0,
        discarded: 0,
        deferred: 0,
        committees: 0,
        close_s: 1.0,
        sim_round_s: 1.5,
        sim_total_s: round as f64 * 1.5,
        down_bytes: 4096,
        up_bytes: 2048,
        eligible: 100,
        arrivals: 0,
        departures: 0,
        outage_excluded: 0,
        clients_touched: 10,
        resident_bytes: 1024,
    });
}

const EVENTS_PER_ROUND: usize = 10;

fn main() {
    let mut b = harness::Bench::new();
    let rounds = if b.quick { 2_000 } else { 20_000 };
    let events = rounds * EVENTS_PER_ROUND;

    let null = NullRecorder;
    b.run("obs/null_sink", 10, || {
        for r in 0..rounds {
            pump_round(&null, r);
        }
    });
    let t0 = Instant::now();
    for r in 0..rounds {
        pump_round(&null, r);
    }
    b.metric(
        "obs",
        "null_events_per_s",
        events as f64 / t0.elapsed().as_secs_f64(),
    );

    let path = std::env::temp_dir().join("fedselect_bench_obs.jsonl");
    let path = path.to_string_lossy().to_string();
    b.run("obs/jsonl_sink", 10, || {
        let jsonl = JsonlRecorder::create(&path).unwrap();
        for r in 0..rounds {
            pump_round(&jsonl, r);
        }
        jsonl.flush();
    });
    let jsonl = JsonlRecorder::create(&path).unwrap();
    let t0 = Instant::now();
    for r in 0..rounds {
        pump_round(&jsonl, r);
    }
    jsonl.flush();
    b.metric(
        "obs",
        "jsonl_events_per_s",
        events as f64 / t0.elapsed().as_secs_f64(),
    );
    let _ = std::fs::remove_file(&path);

    // registry hot path: one counter, one counter-vec slot, one histogram
    // observation per op — the shape of the trainer's per-event updates
    let ops = if b.quick { 50_000 } else { 500_000 };
    let mut reg = MetricsRegistry::new();
    b.run("obs/registry", 10, || {
        for i in 0..ops {
            reg.counter_add("clients.completed", 1);
            reg.counter_vec_add("tier.completed", i % 3, 1);
            reg.observe("fetch_latency_s.t0", (i % 100) as f64 * 0.01);
        }
    });
    let mut reg = MetricsRegistry::new();
    let t0 = Instant::now();
    for i in 0..ops {
        reg.counter_add("clients.completed", 1);
        reg.counter_vec_add("tier.completed", i % 3, 1);
        reg.observe("fetch_latency_s.t0", (i % 100) as f64 * 0.01);
    }
    b.metric(
        "obs",
        "registry_ops_per_s",
        (3 * ops) as f64 / t0.elapsed().as_secs_f64(),
    );
    // snapshot the registry into the bench JSON via the harness helper
    // (informational: dotted names sit outside the gated metric families)
    b.record_registry("obs/registry_snapshot", &reg);

    b.note(&format!(
        "{rounds} rounds x {EVENTS_PER_ROUND} events; registry ops x{ops}"
    ));
    b.write_json("BENCH_obs.json");
}

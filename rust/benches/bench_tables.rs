//! One bench per paper table/figure workload: times a representative round
//! of each experiment's configuration (the regeneration itself runs via
//! `fedselect experiment --id …`; this bench tracks the *cost* of each
//! workload so perf regressions in any figure path are visible).

#[path = "harness.rs"]
mod harness;

use fedselect::config::{DatasetConfig, EngineKind, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::bow::BowConfig;
use fedselect::data::images::ImageConfig;
use fedselect::data::text::TextConfig;
use fedselect::fedselect::KeyPolicy;

fn main() {
    let mut b = harness::Bench::new();
    let artifacts = std::path::Path::new("artifacts/manifest.json").exists();

    // fig2/fig3: tag prediction, structured keys
    {
        let mut cfg = TrainConfig::logreg_default(8192, 1024);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(8192, 50).with_clients(80, 8, 10));
        cfg.cohort = 30;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run("table/fig2_fig3 tag-prediction round (n=8192, m=1024)", 8, || {
            std::hint::black_box(tr.run_round().unwrap());
        });
        b.run("table/fig2 eval pass (2048 examples, n=8192)", 5, || {
            std::hint::black_box(tr.evaluate().unwrap());
        });
    }

    // fig4: key strategy ablation — RandomLocal arm
    {
        let mut cfg = TrainConfig::logreg_default(2048, 256);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(2048, 50).with_clients(60, 6, 8));
        cfg.policies = vec![KeyPolicy::RandomLocal { m: 256 }];
        cfg.cohort = 30;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run("table/fig4 random-local round (n=2048, m=256)", 8, || {
            std::hint::black_box(tr.run_round().unwrap());
        });
    }

    // table3 / fig5 (2NN arm): random neuron keys
    {
        let mut cfg = TrainConfig::mlp_default(100);
        cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(40, 8));
        cfg.cohort = 15;
        let mut tr = Trainer::new(cfg).unwrap();
        b.run("table/table3_fig5 2NN round (m=100)", 5, || {
            std::hint::black_box(tr.run_round().unwrap());
        });
    }

    if artifacts {
        // table2 / fig5 (CNN arm) + fig6: random filter keys
        {
            let mut cfg = TrainConfig::cnn_default(32);
            cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(40, 8));
            cfg.cohort = 10;
            let mut tr = Trainer::new(cfg).unwrap();
            b.run("table/table2_fig5_fig6 CNN round (m=32, pjrt)", 5, || {
                std::hint::black_box(tr.run_round().unwrap());
            });
        }
        // fig7: transformer mixed selection
        {
            let mut cfg = TrainConfig::transformer_default(512, 128);
            cfg.dataset = DatasetConfig::Text(TextConfig::new(2048, 20).with_clients(30, 4, 6));
            cfg.cohort = 6;
            cfg.engine = EngineKind::pjrt_default();
            let mut tr = Trainer::new(cfg).unwrap();
            b.run("table/fig7 transformer round (mv=512, dh=128, pjrt)", 5, || {
                std::hint::black_box(tr.run_round().unwrap());
            });
        }
        // end-to-end driver round (large server model)
        {
            use fedselect::model::ModelArch;
            let arch = ModelArch::transformer_e2e();
            let (vocab, seq) = match &arch {
                ModelArch::Transformer { shape, .. } => (shape.vocab, shape.seq),
                _ => unreachable!(),
            };
            let mut cfg = TrainConfig::transformer_default(1024, 256);
            cfg.arch = arch;
            cfg.dataset =
                DatasetConfig::Text(TextConfig::new(vocab, seq).with_clients(30, 0, 6));
            cfg.policies = vec![
                KeyPolicy::TopFreq { m: 1024 },
                KeyPolicy::RandomGlobal { m: 256 },
            ];
            cfg.cohort = 4;
            cfg.engine = EngineKind::pjrt_default();
            let mut tr = Trainer::new(cfg).unwrap();
            b.run("table/e2e 40M-param transformer round (pjrt)", 3, || {
                std::hint::black_box(tr.run_round().unwrap());
            });
        }
    } else {
        b.note("artifacts missing: CNN/transformer table benches skipped (run `make artifacts`)");
    }
}

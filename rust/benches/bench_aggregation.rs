//! Aggregation bench (§4.2 ablation): plain sparse deselect vs client-side-φ
//! + dense secure aggregation vs IBLT sparse encoding — wall time and upload
//! bytes per client.

#[path = "harness.rs"]
mod harness;

use fedselect::aggregation::iblt::Iblt;
use fedselect::aggregation::{AggMode, Aggregator, SecureAggSim, SparseAccumulator};
use fedselect::metrics::human_bytes;
use fedselect::model::ModelArch;
use fedselect::tensor::rng::Rng;

fn main() {
    let mut b = harness::Bench::new();
    let cohort = if b.quick { 6 } else { 20 };
    let vocab = 4096;
    let m = 256;
    let arch = ModelArch::logreg(vocab);
    let store = arch.init_store(&mut Rng::new(2, 0));
    let spec = arch.select_spec();
    let t = 50usize;

    let mut rng = Rng::new(11, 1);
    let clients: Vec<(Vec<Vec<u32>>, Vec<Vec<f32>>)> = (0..cohort)
        .map(|_| {
            let keys = vec![rng
                .sample_without_replacement(vocab, m)
                .into_iter()
                .map(|x| x as u32)
                .collect::<Vec<u32>>()];
            let ups = vec![
                (0..m * t).map(|_| rng.normal()).collect::<Vec<f32>>(),
                (0..t).map(|_| rng.normal()).collect::<Vec<f32>>(),
            ];
            (keys, ups)
        })
        .collect();

    b.run(&format!("sparse_deselect/cohort={cohort},m={m}"), 20, || {
        let mut agg = Box::new(SparseAccumulator::new(&store));
        for (keys, ups) in &clients {
            agg.add_client(&spec, keys, ups).unwrap();
        }
        let (u, _) = agg.finalize(AggMode::CohortMean);
        std::hint::black_box(u);
    });

    b.run(&format!("secure_agg/cohort={cohort},m={m}"), 5, || {
        let ids: Vec<u64> = (0..cohort as u64).collect();
        let mut agg = Box::new(SecureAggSim::new(&store, ids, 77));
        for (keys, ups) in &clients {
            agg.add_client(&spec, keys, ups).unwrap();
        }
        let (u, _) = agg.finalize(AggMode::CohortMean);
        std::hint::black_box(u);
    });

    // IBLT path: per-key rows as values, capacity sized for distinct keys
    b.run(&format!("iblt_encode_merge_decode/cohort={cohort},m={m}"), 5, || {
        let mut total = Iblt::new(cohort * m, t, 3);
        for (keys, ups) in &clients {
            let mut tab = Iblt::new(cohort * m, t, 3);
            for (j, &k) in keys[0].iter().enumerate() {
                tab.insert(k as u64, &ups[0][j * t..(j + 1) * t]);
            }
            total.merge(&tab);
        }
        let decoded = total.decode().expect("decode");
        std::hint::black_box(decoded);
    });

    // upload-byte comparison (the paper's §4.2 communication argument)
    let plain_up = (m * t + t + m) * 4;
    let secure_up = store.bytes();
    let iblt_up = Iblt::new(cohort * m, t, 3).wire_bytes();
    println!("-- per-client upload --");
    println!("  sparse (update+keys): {}", human_bytes(plain_up as u64));
    println!("  secure dense (φ at client): {}", human_bytes(secure_up as u64));
    println!("  IBLT table: {}", human_bytes(iblt_up));
    println!(
        "  dense/sparse = {:.1}x",
        secure_up as f64 / plain_up as f64
    );
    if let Some(r) = b.ratio(
        &format!("secure_agg/cohort={cohort},m={m}"),
        &format!("sparse_deselect/cohort={cohort},m={m}"),
    ) {
        b.note(&format!("secure/sparse wall ratio: {r:.1}x"));
    }
}

//! Pipelined round-executor contracts, end to end through the trainer:
//!
//! 1. `--exec strict` is **byte-identical** to the legacy sequential round
//!    — model bits and every deterministic `RoundRecord` field — at worker
//!    counts {1, 4, 8}, across all three slice implementations, with the
//!    cross-round cache on;
//! 2. the same identity holds under the over-select and buffered
//!    aggregation modes (and with the cache off);
//! 3. `--exec fast` is run-to-run deterministic: two same-seed runs agree
//!    bit for bit;
//! 4. `--exec fast` preserves the ledger: every byte/count/sim field of
//!    every round matches the strict run (merge *order* is the only
//!    difference), and the final loss lands within float-reassociation
//!    distance of strict;
//! 5. `wall_ms` is the span *union*: once fetch and compute overlap under
//!    the pooled executor, each round's `wall_ms` is bounded by the sum of
//!    its four traced phase spans.

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{AggregationMode, RoundRecord, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::exec::ExecMode;
use fedselect::fedselect::SliceImpl;
use fedselect::model::ParamStore;
use fedselect::scheduler::{FleetKind, SchedPolicy};
use fedselect::util::json::Json;

/// Small tiered workload with hazards (dropped slots), cache commits, and
/// staleness-fair cycling — every side effect the executor must replay.
fn exec_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(512, 64);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
    cfg.rounds = 5;
    cfg.cohort = 6;
    cfg.eval.every = 5;
    cfg.eval.max_examples = 128;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::StalenessFair;
    cfg.dropout_rate = 0.3;
    cfg.cache = true;
    cfg.seed = seed;
    cfg
}

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("fedselect_exec_{name}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .to_string()
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

/// Every `RoundRecord` field except the host-clock trio (`wall_ms`,
/// `merge_stall_ms`, `exec_util`).
fn assert_records_identical(a: &RoundRecord, b: &RoundRecord, label: &str) {
    assert_eq!(a.round, b.round, "{label}");
    assert_eq!(a.completed, b.completed, "{label}");
    assert_eq!(a.dropped, b.dropped, "{label}");
    assert_eq!(a.mode, b.mode, "{label}");
    assert_eq!(a.discarded_clients, b.discarded_clients, "{label}");
    assert_eq!(a.mean_staleness.to_bits(), b.mean_staleness.to_bits(), "{label}");
    assert_eq!(a.committees, b.committees, "{label}");
    assert_eq!(
        a.mean_committee_size.to_bits(),
        b.mean_committee_size.to_bits(),
        "{label}"
    );
    assert_eq!(a.min_committee_size, b.min_committee_size, "{label}");
    assert_eq!(a.comm, b.comm, "{label}");
    assert_eq!(a.up_bytes, b.up_bytes, "{label}");
    assert_eq!(a.max_client_mem, b.max_client_mem, "{label}");
    assert_eq!(a.sim_round_s.to_bits(), b.sim_round_s.to_bits(), "{label}");
    assert_eq!(a.tier_completed, b.tier_completed, "{label}");
    assert_eq!(a.tier_dropped, b.tier_dropped, "{label}");
    assert_eq!(a.tier_discarded, b.tier_discarded, "{label}");
    assert_eq!(a.tier_down_bytes, b.tier_down_bytes, "{label}");
    assert_eq!(a.tier_cache_hits, b.tier_cache_hits, "{label}");
    assert_eq!(a.tier_cache_lookups, b.tier_cache_lookups, "{label}");
    assert_eq!(a.cache_evictions, b.cache_evictions, "{label}");
    assert_eq!(a.cache_stale_refreshes, b.cache_stale_refreshes, "{label}");
    assert_eq!(a.deferrals, b.deferrals, "{label}");
    assert_eq!(a.eligible, b.eligible, "{label}");
    assert_eq!(a.arrivals, b.arrivals, "{label}");
    assert_eq!(a.departures, b.departures, "{label}");
    assert_eq!(a.outage_excluded, b.outage_excluded, "{label}");
    assert_eq!(a.clients_touched, b.clients_touched, "{label}");
    assert_eq!(a.resident_bytes, b.resident_bytes, "{label}");
}

fn run(cfg: TrainConfig) -> (Trainer, fedselect::coordinator::TrainReport) {
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr.run().unwrap();
    (tr, report)
}

fn assert_runs_identical(base_cfg: TrainConfig, var_cfg: TrainConfig, label: &str) {
    let (t_base, base) = run(base_cfg);
    let (t_var, var) = run(var_cfg);
    assert_eq!(base.rounds.len(), var.rounds.len(), "{label}");
    for (a, b) in base.rounds.iter().zip(var.rounds.iter()) {
        assert_records_identical(a, b, &format!("{label} round {}", a.round));
    }
    assert_eq!(base.evals.len(), var.evals.len(), "{label}");
    for (a, b) in base.evals.iter().zip(var.evals.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{label} eval {}", a.round);
        assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{label} eval {}", a.round);
    }
    assert_stores_bit_identical(t_base.store(), t_var.store(), label);
}

#[test]
fn strict_is_byte_identical_to_sequential_across_impls_and_workers() {
    for impl_ in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
        let mut base_cfg = exec_cfg(4040);
        base_cfg.slice_impl = impl_;
        for workers in [1usize, 4, 8] {
            let mut cfg = base_cfg.clone();
            cfg.exec = ExecMode::Strict;
            cfg.exec_workers = workers;
            assert_runs_identical(
                base_cfg.clone(),
                cfg,
                &format!("{impl_:?} workers={workers}"),
            );
        }
    }
}

#[test]
fn strict_identity_holds_under_over_select_and_buffered_closes() {
    let modes = [
        AggregationMode::OverSelect { extra_frac: 0.5 },
        AggregationMode::Buffered { goal_count: 4, max_staleness: 2 },
    ];
    for mode in modes {
        let mut base_cfg = exec_cfg(4141);
        base_cfg.agg_mode = mode;
        base_cfg.cache = false; // also covers the no-version-clock path
        let mut cfg = base_cfg.clone();
        cfg.exec_workers = 4;
        assert_runs_identical(base_cfg, cfg, &format!("{mode:?} workers=4"));
    }
}

#[test]
fn fast_is_run_to_run_deterministic() {
    let mut cfg = exec_cfg(4242);
    cfg.exec = ExecMode::Fast;
    cfg.exec_workers = 4;
    assert_runs_identical(cfg.clone(), cfg, "fast workers=4 repeat");
}

#[test]
fn fast_preserves_the_ledger_and_stays_near_strict_loss() {
    // cache off: with the version clock disabled the ledger is a pure
    // function of plans and timing, so merge *order* (the one thing fast
    // changes) cannot move a single byte of it
    let mut strict_cfg = exec_cfg(4343);
    strict_cfg.cache = false;
    strict_cfg.exec_workers = 4;
    let mut fast_cfg = strict_cfg.clone();
    fast_cfg.exec = ExecMode::Fast;

    let (_, strict) = run(strict_cfg);
    let (_, fast) = run(fast_cfg);
    assert_eq!(strict.rounds.len(), fast.rounds.len());
    for (a, b) in strict.rounds.iter().zip(fast.rounds.iter()) {
        // everything but the float-order-sensitive staleness means must
        // match exactly; under sync they are identical too
        assert_records_identical(a, b, &format!("fast-vs-strict round {}", a.round));
    }
    let (a, b) = (
        strict.evals.last().expect("eval ran").loss as f64,
        fast.evals.last().expect("eval ran").loss as f64,
    );
    assert!(
        (a - b).abs() <= 1e-3 * a.abs().max(1.0),
        "fast loss {b} strayed from strict {a}"
    );
}

#[test]
fn wall_ms_is_bounded_by_the_sum_of_phase_spans_under_fast() {
    let path = tmp_path("spans");
    let mut cfg = exec_cfg(4444);
    cfg.exec = ExecMode::Fast;
    cfg.exec_workers = 4;
    cfg.obs.trace_out = Some(path.clone());
    let (_, report) = run(cfg);

    // sum the four phase spans per round from the trace
    let text = std::fs::read_to_string(&path).unwrap();
    let mut span_sum = vec![0.0f64; report.rounds.len() + 1];
    let mut task_count = 0usize;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let ev = Json::parse(line).unwrap();
        match ev.get("t").and_then(Json::as_str) {
            Some("span") => {
                let phase = ev.get("phase").and_then(Json::as_str).unwrap();
                if phase == "eval" {
                    continue;
                }
                let round = ev.get("round").and_then(Json::as_f64).unwrap() as usize;
                span_sum[round] += ev.get("wall_ms").and_then(Json::as_f64).unwrap();
            }
            Some("task") => task_count += 1,
            _ => {}
        }
    }
    for rec in &report.rounds {
        // tiny epsilon for the clock reads between span boundaries
        assert!(
            rec.wall_ms <= span_sum[rec.round] * (1.0 + 1e-6) + 0.5,
            "round {}: wall_ms {} exceeds span sum {}",
            rec.round,
            rec.wall_ms,
            span_sum[rec.round]
        );
        assert!(rec.exec_util > 0.0 && rec.exec_util <= 1.0, "round {}", rec.round);
        assert!(rec.merge_stall_ms >= 0.0, "round {}", rec.round);
    }
    // one task span per surviving (non-dropped) slot
    let survived: usize = report.rounds.iter().map(|r| r.completed + r.discarded_clients).sum();
    assert_eq!(task_count, survived, "task spans cover every surviving slot");
    std::fs::remove_file(&path).unwrap();
}

//! Integration tests across modules: end-to-end tiny training runs on the
//! native engine, slice-service interchangeability at the Trainer level,
//! failure injection, baselines, and the experiment harness's quick paths.

use fedselect::baselines::{federated_dropout, full_broadcast};
use fedselect::config::{DatasetConfig, EngineKind, TrainConfig};
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::data::images::ImageConfig;
use fedselect::fedselect::{KeyPolicy, SliceImpl};
use fedselect::optim::ServerOpt;

fn logreg_cfg(vocab: usize, m: usize) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(vocab, m);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(40, 6, 10));
    cfg.rounds = 8;
    cfg.cohort = 10;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 512;
    cfg
}

#[test]
fn logreg_fedselect_learns() {
    let mut tr = Trainer::new(logreg_cfg(512, 64)).unwrap();
    let before = tr.evaluate().unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_eval.metric > before.metric + 0.05);
    assert!(report.final_eval.loss < before.loss);
}

#[test]
fn mlp_random_keys_learn() {
    let mut cfg = TrainConfig::mlp_default(50);
    cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(30, 8));
    cfg.rounds = 10;
    cfg.cohort = 8;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 512;
    let mut tr = Trainer::new(cfg).unwrap();
    let before = tr.evaluate().unwrap();
    let report = tr.run().unwrap();
    assert!(
        report.final_eval.metric > before.metric,
        "{} !> {}",
        report.final_eval.metric,
        before.metric
    );
}

#[test]
fn slice_impls_identical_training_through_trainer() {
    let mut finals = Vec::new();
    for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
        let mut cfg = logreg_cfg(256, 32);
        cfg.rounds = 3;
        cfg.slice_impl = imp;
        let report = Trainer::new(cfg).unwrap().run().unwrap();
        finals.push(report.final_eval.loss);
    }
    assert!((finals[0] - finals[1]).abs() < 1e-9);
    assert!((finals[1] - finals[2]).abs() < 1e-9);
}

#[test]
fn parallel_cohort_slicing_trains_byte_identically() {
    // --fetch-threads is a pure throughput knob: same trajectory, same bytes
    let mut cfg = logreg_cfg(256, 32);
    cfg.rounds = 3;
    cfg.slice_impl = SliceImpl::OnDemand;
    let serial = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    cfg.fetch_threads = 4;
    let parallel = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(
        serial.final_eval.loss.to_bits(),
        parallel.final_eval.loss.to_bits()
    );
    assert_eq!(serial.final_eval.metric.to_bits(), parallel.final_eval.metric.to_bits());
    assert_eq!(serial.total_down_bytes, parallel.total_down_bytes);
    assert_eq!(serial.total_up_bytes, parallel.total_up_bytes);
}

#[test]
fn broadcast_downloads_more_than_selection() {
    let mut sel = logreg_cfg(512, 32);
    sel.rounds = 2;
    let rep_sel = Trainer::new(sel.clone()).unwrap().run().unwrap();
    let rep_bc = Trainer::new(full_broadcast(sel)).unwrap().run().unwrap();
    assert!(rep_bc.total_down_bytes > 4 * rep_sel.total_down_bytes);
    assert!((rep_bc.rel_model_size - 1.0).abs() < 1e-9);
}

#[test]
fn federated_dropout_baseline_runs() {
    let mut cfg = TrainConfig::mlp_default(50);
    cfg.dataset = DatasetConfig::Image(ImageConfig::new(62).with_clients(16, 4));
    cfg.rounds = 3;
    cfg.cohort = 5;
    cfg.eval.every = 0;
    let report = Trainer::new(federated_dropout(cfg)).unwrap().run().unwrap();
    assert!(report.final_eval.metric >= 0.0);
}

#[test]
fn dropout_injection_still_converges() {
    let mut cfg = logreg_cfg(256, 32);
    cfg.dropout_rate = 0.3;
    cfg.rounds = 8;
    let mut tr = Trainer::new(cfg).unwrap();
    let before = tr.evaluate().unwrap();
    let report = tr.run().unwrap();
    let total_dropped: usize = report.rounds.iter().map(|r| r.dropped).sum();
    assert!(total_dropped > 0, "no dropouts injected");
    assert!(report.final_eval.loss < before.loss);
}

#[test]
fn per_coord_mean_also_learns() {
    let mut cfg = logreg_cfg(256, 32);
    cfg.agg = fedselect::aggregation::AggMode::PerCoordMean;
    cfg.server_opt = ServerOpt::fedadagrad(0.05);
    let mut tr = Trainer::new(cfg).unwrap();
    let before = tr.evaluate().unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_eval.loss < before.loss);
}

#[test]
fn key_policy_top_beats_random_local_early() {
    // the Fig. 4 shape: Top strictly dominates Random in early rounds
    let ds = BowConfig::new(1024, 50).with_clients(60, 6, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds.clone()));
    let mut run_with = |pol: KeyPolicy| {
        let mut cfg = logreg_cfg(1024, 64);
        cfg.dataset = DatasetConfig::Bow(ds.clone());
        cfg.policies = vec![pol];
        cfg.rounds = 6;
        Trainer::with_dataset(cfg, dataset.clone())
            .unwrap()
            .run()
            .unwrap()
            .final_eval
            .metric
    };
    let top = run_with(KeyPolicy::TopFreq { m: 64 });
    let rand = run_with(KeyPolicy::RandomLocal { m: 64 });
    assert!(
        top >= rand - 0.02,
        "Top ({top}) should not lose to RandomLocal ({rand}) early"
    );
}

#[test]
fn pregen_ledger_shows_amortization() {
    let mut cfg = logreg_cfg(512, 64);
    cfg.rounds = 1;
    cfg.cohort = 12;
    cfg.slice_impl = SliceImpl::PregenCdn;
    let mut tr = Trainer::new(cfg.clone()).unwrap();
    let rec = tr.run_round().unwrap();
    // pre-generation computed each key exactly once...
    assert_eq!(rec.comm.pregen_slices, 512);
    assert_eq!(rec.comm.psi_evals, 512);
    // ...while on-demand computes at most (distinct keys requested)
    cfg.slice_impl = SliceImpl::OnDemand;
    let mut tr2 = Trainer::new(cfg).unwrap();
    let rec2 = tr2.run_round().unwrap();
    assert!(rec2.comm.psi_evals + rec2.comm.memo_hits >= 12 * 64 - 64);
    assert!(rec2.comm.psi_evals <= 512);
}

#[test]
fn trainer_rejects_invalid_configs() {
    let mut cfg = logreg_cfg(256, 32);
    cfg.rounds = 0;
    assert!(Trainer::new(cfg).is_err());
    let mut cfg = TrainConfig::cnn_default(16);
    cfg.engine = EngineKind::Native;
    assert!(Trainer::new(cfg).is_err());
}

#[test]
fn experiments_quick_native_subset() {
    use fedselect::experiments::{run, ExpOptions};
    let mut opts = ExpOptions::new(true, EngineKind::Native);
    opts.out_dir = std::env::temp_dir()
        .join("fedselect_it_results")
        .to_string_lossy()
        .into_owned();
    // native-only quick experiments (CNN/transformer arms need artifacts and
    // are covered by pjrt_parity.rs when available)
    for id in ["table1", "fig4", "table3"] {
        let tables = run(id, &opts).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!tables.is_empty(), "{id}");
        assert!(!tables[0].rows.is_empty(), "{id}");
    }
}

//! PJRT <-> native parity: the cross-language numeric contract.
//!
//! These tests load the AOT artifacts (HLO text lowered from the JAX models,
//! with the Pallas kernels inside) and assert that, on identical inputs, the
//! compiled XLA executables and the pure-Rust mirrors produce the same
//! client-update deltas and eval metrics to float tolerance.
//!
//! All tests skip (pass trivially, with a stderr note) when `artifacts/`
//! has not been built — run `make artifacts` for full coverage.

use fedselect::clients::{build_cu_batch, build_eval_batches, Engine};
use fedselect::config::{DatasetConfig, EngineKind, TrainConfig};
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::KeyPolicy;
use fedselect::model::ModelArch;
use fedselect::native::Buf;
use fedselect::runtime::PjrtRuntime;
use fedselect::tensor::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("FEDSELECT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[pjrt_parity] {dir}/manifest.json missing — skipping (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_covers_every_experiment_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    for name in [
        "logreg_cu_m64",
        "logreg_cu_m1024",
        "logreg_eval_n512",
        "logreg_eval_n8192",
        "mlp_cu_m10",
        "mlp_cu_m200",
        "mlp_eval",
        "cnn_cu_m4",
        "cnn_cu_m64",
        "cnn_eval",
        "tf_cu_v2048_h512",
        "tf_eval",
        "e2e_cu",
        "e2e_eval",
    ] {
        rt.artifact(name).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn logreg_client_update_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(31, 0);
    let arch = ModelArch::logreg(512);
    let store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let ds = build_dataset(&DatasetConfig::Bow(
        BowConfig::new(512, 50).with_clients(4, 0, 0),
    ));
    let client = &ds.train[0];
    let keys = vec![KeyPolicy::TopFreq { m: 64 }.keys_for(client, 512, &mut rng, None, false)];
    let slices = spec.slice(&store, &keys).unwrap();
    let (batch, _) = build_cu_batch(&arch, client, &keys, &mut rng).unwrap();

    let mut native = Engine::Native;
    let d_native = native
        .client_update(&arch, &[64], slices.clone(), &batch, 0.3)
        .unwrap();
    let mut pjrt = Engine::Pjrt(Box::new(PjrtRuntime::load(&dir).unwrap()));
    let d_pjrt = pjrt
        .client_update(&arch, &[64], slices, &batch, 0.3)
        .unwrap();

    assert_eq!(d_native.len(), d_pjrt.len());
    for (i, (a, b)) in d_native.iter().zip(d_pjrt.iter()).enumerate() {
        assert_eq!(a.len(), b.len(), "output {i} len");
        for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() < 1e-4 + 1e-3 * x.abs(),
                "output {i}[{j}]: native {x} vs pjrt {y}"
            );
        }
    }
}

#[test]
fn logreg_eval_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(33, 0);
    let arch = ModelArch::logreg(512);
    let store = arch.init_store(&mut rng);
    let ds = build_dataset(&DatasetConfig::Bow(
        BowConfig::new(512, 50).with_clients(4, 0, 4),
    ));
    let pool: Vec<&fedselect::data::Example> = ds
        .test
        .iter()
        .flat_map(|c| c.examples.iter())
        .take(200)
        .collect();
    let batches = build_eval_batches(&arch, &pool).unwrap();

    let mut native = Engine::Native;
    let mut pjrt = Engine::Pjrt(Box::new(PjrtRuntime::load(&dir).unwrap()));
    for b in &batches {
        let (l1, m1, w1) = native.eval(&arch, &store, b).unwrap();
        let (l2, m2, w2) = pjrt.eval(&arch, &store, b).unwrap();
        assert!((w1 - w2).abs() < 1e-6);
        assert!((l1 - l2).abs() < 1e-2 * (1.0 + l1.abs()), "loss {l1} vs {l2}");
        assert!((m1 - m2).abs() < 1e-3 * w1.max(1.0), "recall {m1} vs {m2}");
    }
}

#[test]
fn mlp_client_update_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(37, 0);
    let arch = ModelArch::mlp2nn();
    let store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let m = 50;
    let keys = vec![Rng::new(5, 5)
        .sample_without_replacement(200, m)
        .into_iter()
        .map(|x| x as u32)
        .collect::<Vec<u32>>()];
    let slices = spec.slice(&store, &keys).unwrap();
    // synthetic image batch
    let bs = arch.cu_batch();
    let cap = bs.capacity();
    let x: Vec<f32> = (0..cap * 784).map(|_| rng.f32()).collect();
    let y: Vec<i32> = (0..cap).map(|_| rng.below(62) as i32).collect();
    let wgt: Vec<f32> = (0..cap).map(|i| if i < cap - 3 { 1.0 } else { 0.0 }).collect();
    let batch = vec![Buf::F32(x), Buf::I32(y), Buf::F32(wgt)];

    let mut native = Engine::Native;
    let d_native = native
        .client_update(&arch, &[m], slices.clone(), &batch, 0.05)
        .unwrap();
    let mut pjrt = Engine::Pjrt(Box::new(PjrtRuntime::load(&dir).unwrap()));
    let d_pjrt = pjrt.client_update(&arch, &[m], slices, &batch, 0.05).unwrap();
    for (i, (a, b)) in d_native.iter().zip(d_pjrt.iter()).enumerate() {
        let max_diff = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-4, "output {i}: max diff {max_diff}");
    }
}

#[test]
fn cnn_client_update_executes_and_is_finite() {
    // No native CNN mirror (by design); validate execution + sanity.
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(41, 0);
    let arch = ModelArch::cnn();
    let store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let m = 16;
    let keys = vec![(0..m as u32).collect::<Vec<u32>>()];
    let slices = spec.slice(&store, &keys).unwrap();
    let bs = arch.cu_batch();
    let cap = bs.capacity();
    let x: Vec<f32> = (0..cap * 784).map(|_| rng.f32()).collect();
    let y: Vec<i32> = (0..cap).map(|_| rng.below(62) as i32).collect();
    let batch = vec![Buf::F32(x), Buf::I32(y), Buf::F32(vec![1.0; cap])];
    let mut pjrt = Engine::Pjrt(Box::new(PjrtRuntime::load(&dir).unwrap()));
    let d0 = pjrt
        .client_update(&arch, &[m], slices.clone(), &batch, 0.0)
        .unwrap();
    assert!(d0.iter().all(|t| t.iter().all(|&v| v == 0.0)), "lr=0 => zero delta");
    let d = pjrt.client_update(&arch, &[m], slices, &batch, 0.05).unwrap();
    assert_eq!(d.len(), 8);
    let total: f32 = d.iter().flat_map(|t| t.iter()).map(|v| v.abs()).sum();
    assert!(total.is_finite() && total > 0.0);
}

#[test]
fn transformer_client_update_executes_and_learns_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(43, 0);
    let arch = ModelArch::transformer();
    let store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let keys = vec![
        {
            let mut k: Vec<u32> = (0..512).collect();
            k[0] = 0;
            k
        },
        (0..128u32).collect::<Vec<u32>>(),
    ];
    let slices = spec.slice(&store, &keys).unwrap();
    let bs = arch.cu_batch();
    let cap = bs.capacity();
    let seq = 20;
    let x: Vec<i32> = (0..cap * seq).map(|_| rng.below(512) as i32).collect();
    let y: Vec<i32> = (0..cap * seq).map(|_| rng.below(512) as i32).collect();
    let batch = vec![
        Buf::I32(x),
        Buf::I32(y),
        Buf::F32(vec![1.0; cap * seq]),
    ];
    let mut pjrt = Engine::Pjrt(Box::new(PjrtRuntime::load(&dir).unwrap()));
    let ms = [512usize, 128usize];
    let d = pjrt
        .client_update(&arch, &ms, slices, &batch, 0.1)
        .unwrap();
    assert_eq!(d.len(), store.segments.len());
    // the embedding delta only touches rows whose local ids appeared
    let demb = &d[0];
    assert!(demb.iter().any(|&v| v != 0.0));
    assert!(demb.iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_end_to_end_training_improves_logreg() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = TrainConfig::logreg_default(512, 64);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
    cfg.rounds = 4;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 256;
    cfg.engine = EngineKind::Pjrt {
        artifacts_dir: dir,
    };
    let mut tr = Trainer::new(cfg).unwrap();
    let before = tr.evaluate().unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_eval.loss < before.loss);
}

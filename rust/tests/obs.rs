//! Observability contracts, end to end through the trainer:
//!
//! 1. telemetry is **trajectory-neutral**: a run tracing every event to a
//!    JSONL sink is byte-identical — model bits and every deterministic
//!    `RoundRecord` field (the host-clock `wall_ms`/`merge_stall_ms`/
//!    `exec_util` trio is the only exclusion) — to the default
//!    `NullRecorder` run, at fetch thread counts {1, 4} and under the
//!    pipelined executor at 8 workers;
//! 2. the emitted trace validates line by line against the versioned
//!    schema (`fedselect-trace-v1`);
//! 3. two same-seed traces agree on their sim-time content
//!    (`diff_traces` → clean), and an injected divergence is flagged;
//! 4. the fleet summary rendered from the trainer's live metrics registry
//!    is byte-identical to the ledger-walking path over the report.

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{RoundRecord, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::metrics::{fleet_summary, fleet_summary_from, keys};
use fedselect::model::ParamStore;
use fedselect::obs::trace::{diff_traces, validate_trace_line, TRACE_SCHEMA};
use fedselect::scheduler::{FleetKind, SchedPolicy};

/// Small tiered workload exercising every event family: hazards (dropped),
/// cache (fetched with hits), staleness-fair cycling, periodic eval.
fn obs_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(512, 64);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
    cfg.rounds = 6;
    cfg.cohort = 6;
    cfg.eval.every = 3;
    cfg.eval.max_examples = 128;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::StalenessFair;
    cfg.dropout_rate = 0.3;
    cfg.cache = true;
    cfg.seed = seed;
    cfg
}

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("fedselect_obs_{name}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .to_string()
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

/// Every `RoundRecord` field except the host-clock trio (`wall_ms`,
/// `merge_stall_ms`, `exec_util`).
fn assert_records_identical(a: &RoundRecord, b: &RoundRecord, label: &str) {
    assert_eq!(a.round, b.round, "{label}");
    assert_eq!(a.completed, b.completed, "{label}");
    assert_eq!(a.dropped, b.dropped, "{label}");
    assert_eq!(a.mode, b.mode, "{label}");
    assert_eq!(a.discarded_clients, b.discarded_clients, "{label}");
    assert_eq!(a.mean_staleness.to_bits(), b.mean_staleness.to_bits(), "{label}");
    assert_eq!(a.committees, b.committees, "{label}");
    assert_eq!(
        a.mean_committee_size.to_bits(),
        b.mean_committee_size.to_bits(),
        "{label}"
    );
    assert_eq!(a.min_committee_size, b.min_committee_size, "{label}");
    // the whole comm ledger, including the *modeled* (deterministic)
    // service_us latency
    assert_eq!(a.comm, b.comm, "{label}");
    assert_eq!(a.up_bytes, b.up_bytes, "{label}");
    assert_eq!(a.max_client_mem, b.max_client_mem, "{label}");
    assert_eq!(a.sim_round_s.to_bits(), b.sim_round_s.to_bits(), "{label}");
    assert_eq!(a.tier_completed, b.tier_completed, "{label}");
    assert_eq!(a.tier_dropped, b.tier_dropped, "{label}");
    assert_eq!(a.tier_discarded, b.tier_discarded, "{label}");
    assert_eq!(a.tier_down_bytes, b.tier_down_bytes, "{label}");
    assert_eq!(a.tier_cache_hits, b.tier_cache_hits, "{label}");
    assert_eq!(a.tier_cache_lookups, b.tier_cache_lookups, "{label}");
    assert_eq!(a.cache_evictions, b.cache_evictions, "{label}");
    assert_eq!(a.cache_stale_refreshes, b.cache_stale_refreshes, "{label}");
    assert_eq!(a.deferrals, b.deferrals, "{label}");
    assert_eq!(a.eligible, b.eligible, "{label}");
    assert_eq!(a.arrivals, b.arrivals, "{label}");
    assert_eq!(a.departures, b.departures, "{label}");
    assert_eq!(a.outage_excluded, b.outage_excluded, "{label}");
    assert_eq!(a.clients_touched, b.clients_touched, "{label}");
    assert_eq!(a.resident_bytes, b.resident_bytes, "{label}");
}

#[test]
fn tracing_is_byte_identical_to_null_recorder() {
    // (fetch_threads, exec_workers): serial, threaded batch fetch, and the
    // pipelined executor (which replaces the batch fetch phase entirely)
    for (threads, workers) in [(1usize, 1usize), (4, 1), (1, 8)] {
        let label = format!("threads={threads} workers={workers}");
        let mut off_cfg = obs_cfg(5050);
        off_cfg.fetch_threads = threads;
        off_cfg.exec_workers = workers;
        let mut on_cfg = off_cfg.clone();
        let path = tmp_path(&format!("identity_{threads}_{workers}"));
        on_cfg.obs.trace_out = Some(path.clone());

        let mut t_off = Trainer::new(off_cfg).unwrap();
        let mut t_on = Trainer::new(on_cfg).unwrap();
        assert!(!t_off.recorder().enabled(), "{label}: default is the null sink");
        assert!(t_on.recorder().enabled(), "{label}: tracing sink installed");

        let off = t_off.run().unwrap();
        let on = t_on.run().unwrap();
        assert_eq!(off.rounds.len(), on.rounds.len(), "{label}");
        for (a, b) in off.rounds.iter().zip(on.rounds.iter()) {
            assert_records_identical(a, b, &format!("{label} round {}", a.round));
        }
        assert_eq!(off.evals.len(), on.evals.len(), "{label}");
        for (a, b) in off.evals.iter().zip(on.evals.iter()) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{label} eval {}", a.round);
            assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{label} eval {}", a.round);
        }
        assert_stores_bit_identical(t_off.store(), t_on.store(), &label);
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn trace_validates_against_schema_and_covers_event_families() {
    let path = tmp_path("schema");
    let mut cfg = obs_cfg(6060);
    cfg.obs.trace_out = Some(path.clone());
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr.run().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(lines[0].contains(TRACE_SCHEMA), "header carries the schema tag");
    for (i, line) in lines.iter().enumerate() {
        validate_trace_line(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
    }
    let count = |tag: &str| {
        lines
            .iter()
            .filter(|l| l.contains(&format!("\"t\":\"{tag}\"")))
            .count()
    };
    assert_eq!(count("run_start"), 1);
    assert_eq!(count("run_end"), 1);
    assert_eq!(count("round_close"), report.rounds.len());
    // 4 phase spans per round + 1 eval span per evaluation
    assert_eq!(count("span"), 4 * report.rounds.len() + report.evals.len());
    // one executor task span per surviving (non-dropped) slot
    let survived: usize = report.rounds.iter().map(|r| r.completed).sum();
    assert_eq!(count("task"), survived);
    assert_eq!(count("eval"), report.evals.len());
    assert!(count("client") > 0, "client lifecycle events present");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn same_seed_fast_exec_traces_diff_clean() {
    // completion-order merging must still be run-to-run deterministic on
    // the sim clock: two same-seed `--exec fast` pooled runs diff clean
    let (path_a, path_b) = (tmp_path("fast_a"), tmp_path("fast_b"));
    for path in [&path_a, &path_b] {
        let mut cfg = obs_cfg(9090);
        cfg.exec = fedselect::exec::ExecMode::Fast;
        cfg.exec_workers = 4;
        cfg.obs.trace_out = Some(path.clone());
        Trainer::new(cfg).unwrap().run().unwrap();
    }
    let a = std::fs::read_to_string(&path_a).unwrap();
    let b = std::fs::read_to_string(&path_b).unwrap();
    assert!(
        diff_traces(&a, &b).is_none(),
        "same-seed fast traces diverged: {:?}",
        diff_traces(&a, &b)
    );
    std::fs::remove_file(&path_a).unwrap();
    std::fs::remove_file(&path_b).unwrap();
}

#[test]
fn same_seed_traces_diff_clean_and_divergence_is_flagged() {
    let (path_a, path_b) = (tmp_path("diff_a"), tmp_path("diff_b"));
    for path in [&path_a, &path_b] {
        let mut cfg = obs_cfg(7070);
        cfg.obs.trace_out = Some(path.clone());
        Trainer::new(cfg).unwrap().run().unwrap();
    }
    let a = std::fs::read_to_string(&path_a).unwrap();
    let b = std::fs::read_to_string(&path_b).unwrap();
    // the raw bytes differ (wall_ms is host noise) but the sim-time
    // content must not
    assert!(diff_traces(&a, &b).is_none(), "same-seed traces diverged");

    // inject a sim-field divergence: prepend a digit to a sim_round_s
    // value (always changes the number, stays valid JSON)
    let needle = "\"sim_round_s\":";
    let pos = b.find(needle).expect("round_close present") + needle.len();
    let mut mutated = b.clone();
    mutated.insert(pos, '9');
    let msg = diff_traces(&a, &mutated).expect("divergence must be flagged");
    assert!(msg.contains("line"), "diff names the diverging line: {msg}");

    std::fs::remove_file(&path_a).unwrap();
    std::fs::remove_file(&path_b).unwrap();
}

#[test]
fn live_registry_summary_matches_ledger_walking_path() {
    let mut tr = Trainer::new(obs_cfg(8080)).unwrap();
    let report = tr.run().unwrap();
    let fleet = tr.scheduler().fleet();
    let from_ledgers = fleet_summary(fleet, &report.rounds);
    let from_registry = fleet_summary_from(fleet, tr.metrics());
    assert_eq!(from_ledgers.to_pretty(), from_registry.to_pretty());
    assert_eq!(tr.metrics().counter(keys::ROUNDS) as usize, report.rounds.len());
    // per-tier fetch-latency histograms saw every completion event: under
    // the sync barrier that is exactly the merged (non-dropped) clients
    let observed: u64 = (0..fleet.num_tiers())
        .filter_map(|t| tr.metrics().hist(&fedselect::coordinator::fetch_latency_key(t)))
        .map(|h| h.count())
        .sum();
    let expected: usize = report.rounds.iter().map(|r| r.completed).sum();
    assert_eq!(observed as usize, expected);
}

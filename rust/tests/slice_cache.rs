//! Cross-round slice-cache properties, end to end through the trainer:
//!
//! 1. cache-on is **byte-identical** to cache-off — model trajectory and
//!    every non-downlink ledger field — for all three slice
//!    implementations at fetch thread counts {1, 4}, while the keyed
//!    implementations strictly save down-bytes;
//! 2. eviction is deterministic at a fixed seed, even under a budget tight
//!    enough to churn every round;
//! 3. `max_stale_rounds` forces refresh exactly at the boundary: with the
//!    staleness-fair scheduler's exact re-selection gap of 4 rounds, a
//!    bound of 3 turns every would-be hit into a stale refresh and a bound
//!    of 4 reproduces the unbounded hit count bit for bit;
//! 4. version bumps cover only aggregator-written rows — the clock's
//!    touched set stays a strict subset of the keyspace on a small-cohort
//!    workload.

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::SliceImpl;
use fedselect::model::ParamStore;
use fedselect::scheduler::{FleetKind, SchedPolicy};

/// Repeated-selection workload: stable TopFreq keys, staleness-fair
/// cycling (24 clients / cohort 6 = an exact 4-round re-selection gap),
/// tiered hazards + a 0.4 dropout floor so fetched-but-never-merged key
/// sets stay version-fresh, and a 512 vocab so cohorts cannot write the
/// whole keyspace.
fn cache_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(512, 64);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
    cfg.rounds = 8;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 256;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::StalenessFair;
    cfg.dropout_rate = 0.4;
    cfg.seed = seed;
    cfg
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

#[test]
fn cache_on_is_byte_identical_to_cache_off_across_impls_and_threads() {
    for imp in [SliceImpl::PregenCdn, SliceImpl::OnDemand, SliceImpl::Broadcast] {
        for threads in [1usize, 4] {
            let mut base = cache_cfg(4040);
            base.slice_impl = imp;
            base.fetch_threads = threads;
            let mut cached = base.clone();
            cached.cache = true;
            let label = format!("{imp}/threads={threads}");

            let mut t_off = Trainer::new(base).unwrap();
            let mut t_on = Trainer::new(cached).unwrap();
            let mut down_off = 0u64;
            let mut down_on = 0u64;
            let mut hits = 0u64;
            for round in 0..8 {
                let a = t_off.run_round().unwrap();
                let b = t_on.run_round().unwrap();
                let rl = format!("{label} round {}", round + 1);
                // every non-downlink ledger field agrees exactly
                assert_eq!(a.completed, b.completed, "{rl}");
                assert_eq!(a.dropped, b.dropped, "{rl}");
                assert_eq!(a.discarded_clients, b.discarded_clients, "{rl}");
                if !(imp == SliceImpl::OnDemand && threads > 1) {
                    // on-demand ψ/memo splits are race-dependent across
                    // threads (two workers may both pay a ψ), so exact
                    // equality between two independent runs only holds
                    // serially; the cache changes none of it either way
                    assert_eq!(a.comm.psi_evals, b.comm.psi_evals, "{rl}");
                    assert_eq!(a.comm.memo_hits, b.comm.memo_hits, "{rl}");
                    assert_eq!(a.comm.service_us, b.comm.service_us, "{rl}");
                }
                assert_eq!(a.comm.pregen_slices, b.comm.pregen_slices, "{rl}");
                assert_eq!(a.comm.cdn_queries, b.comm.cdn_queries, "{rl}");
                assert_eq!(a.comm.up_key_bytes, b.comm.up_key_bytes, "{rl}");
                assert_eq!(a.up_bytes, b.up_bytes, "{rl}");
                assert_eq!(a.max_client_mem, b.max_client_mem, "{rl}");
                // only the wire can shrink, and the tier ledger tracks it
                assert!(b.comm.down_bytes <= a.comm.down_bytes, "{rl}");
                assert!(b.sim_round_s <= a.sim_round_s + 1e-9, "{rl}");
                assert_eq!(
                    b.tier_down_bytes.iter().sum::<u64>(),
                    b.comm.down_bytes,
                    "{rl}: tier ledger must equal the wire ledger post-cache"
                );
                assert_eq!(a.comm.client_cache_hits, 0, "{rl}: cache-off has no hits");
                down_off += a.comm.down_bytes;
                down_on += b.comm.down_bytes;
                hits += b.comm.client_cache_hits;
            }
            assert_stores_bit_identical(t_off.store(), t_on.store(), &label);
            if imp != SliceImpl::Broadcast {
                // keyed pieces re-select across rounds: strict savings
                assert!(hits > 0, "{label}: no client-cache hits at all");
                assert!(
                    down_on < down_off,
                    "{label}: cache-on {down_on} !< cache-off {down_off}"
                );
            }
        }
    }
}

#[test]
fn eviction_is_deterministic_under_a_fixed_seed() {
    // a budget tight enough that low-tier caches churn every commit
    let make = || {
        let mut cfg = cache_cfg(777);
        cfg.cache = true;
        cfg.cache_budget_frac = 0.05;
        cfg
    };
    let mut a = Trainer::new(make()).unwrap();
    let mut b = Trainer::new(make()).unwrap();
    let mut evictions = 0u64;
    let mut a_down: Vec<u64> = Vec::with_capacity(8);
    for round in 0..8 {
        let ra = a.run_round().unwrap();
        let rb = b.run_round().unwrap();
        let key = |r: &fedselect::coordinator::RoundRecord| {
            (
                r.comm.down_bytes,
                r.comm.client_cache_hits,
                r.cache_evictions,
                r.cache_stale_refreshes,
                r.tier_cache_hits.clone(),
                r.tier_cache_lookups.clone(),
            )
        };
        assert_eq!(key(&ra), key(&rb), "round {}", round + 1);
        evictions += ra.cache_evictions;
        a_down.push(ra.comm.down_bytes);
    }
    assert!(evictions > 0, "the tight budget never evicted anything");
    assert_stores_bit_identical(a.store(), b.store(), "evict determinism");
    // threads don't change cache behavior either
    let mut c_cfg = make();
    c_cfg.fetch_threads = 4;
    let c = Trainer::new(c_cfg).unwrap().run().unwrap();
    let c_down: Vec<u64> = c.rounds.iter().map(|r| r.comm.down_bytes).collect();
    assert_eq!(a_down, c_down, "fetch_threads changed the cache ledger");
}

#[test]
fn max_stale_rounds_forces_refresh_exactly_at_the_boundary() {
    // staleness-fair on 24 clients / cohort 6 re-selects every client after
    // exactly 4 rounds, so the age of every cached piece at its next lookup
    // is exactly 4: a bound of 3 refuses every would-be hit (turning it
    // into a stale refresh), a bound of 4 is indistinguishable from
    // unbounded.
    let run = |max_stale: usize| {
        let mut cfg = cache_cfg(909);
        cfg.cache = true;
        cfg.max_stale_rounds = max_stale;
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let unbounded = run(0);
    let at_gap = run(4);
    let below_gap = run(3);
    let hits = |r: &fedselect::coordinator::TrainReport| {
        r.rounds.iter().map(|x| x.comm.client_cache_hits).sum::<u64>()
    };
    let stale = |r: &fedselect::coordinator::TrainReport| {
        r.rounds.iter().map(|x| x.cache_stale_refreshes).sum::<u64>()
    };
    assert!(hits(&unbounded) > 0, "workload produced no reuse at all");
    assert_eq!(hits(&at_gap), hits(&unbounded), "bound == gap must not refuse");
    assert_eq!(stale(&at_gap), 0);
    assert_eq!(hits(&below_gap), 0, "bound < gap must refuse every hit");
    assert_eq!(
        stale(&below_gap),
        hits(&unbounded),
        "every refused hit is ledgered as a stale refresh"
    );
    // refreshes move bytes but never change them: identical trajectories
    assert_eq!(
        unbounded.final_eval.loss.to_bits(),
        below_gap.final_eval.loss.to_bits()
    );
    assert!(below_gap.total_down_bytes > at_gap.total_down_bytes);
}

#[test]
fn version_bumps_cover_only_written_rows() {
    let mut cfg = cache_cfg(123);
    cfg.cache = true;
    let mut t = Trainer::new(cfg).unwrap();
    for _ in 0..3 {
        t.run_round().unwrap();
    }
    let clock = t.versions().expect("cache run has a version clock");
    let touched = clock.touched_rows();
    // something merged, so something was written...
    assert!(touched > 0, "no rows ever bumped");
    // ...but only rows merged updates wrote: 3 rounds x cohort 6 x m 64
    // bounds the selected union at 18*64 << 512, and zero-aggregate rows
    // (dropouts, padded keys) keep even that bound loose
    assert!(
        touched < 512,
        "touched {touched} rows — the whole keyspace was invalidated"
    );
}

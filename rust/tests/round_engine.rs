//! Round-engine properties (driven by the crate's own PCG, like
//! tests/proptests.rs — every failing case reports its seed):
//!
//! 1. `--agg-mode sync` through the event-driven engine reproduces the
//!    pre-engine coordinator **byte for byte** — model state and every
//!    `RoundRecord` ledger field — against a faithful replica of the old
//!    barrier loop (plan -> keys -> slice -> dropout coin -> update ->
//!    cohort-order aggregate -> server step -> straggler close), at fetch
//!    thread counts {1, 4}, with per-client key budgets and hazards on;
//! 2. buffered merge order is deterministic given the SimClock seed: two
//!    identical runs agree bit-for-bit on the trajectory, the per-round
//!    merge tallies, staleness, and simulated time;
//! 3. over-selection ledgers the discarded stragglers' download bytes.

use fedselect::aggregation::{AggMode, Aggregator, SparseAccumulator};
use fedselect::clients::{build_cu_batch, client_memory_bytes, Engine};
use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{build_dataset, AggregationMode, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::ClientKeys;
use fedselect::model::ParamStore;
use fedselect::optim::Optimizer;
use fedselect::scheduler::{ClientRoundStats, FleetKind, SchedPolicy, Scheduler, SliceGeometry};
use fedselect::tensor::rng::Rng;

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(128, 32);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(24, 4, 8));
    cfg.rounds = 3;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 128;
    cfg.seed = seed;
    cfg
}

/// One round's ledger as the pre-engine coordinator reported it.
#[derive(Debug, PartialEq)]
struct LegacyRound {
    completed: usize,
    dropped: usize,
    down_bytes: u64,
    up_bytes: u64,
    max_client_mem: usize,
    sim_round_s: u64, // f64 bits
    tier_completed: Vec<usize>,
    tier_dropped: Vec<usize>,
    tier_down_bytes: Vec<u64>,
}

/// Faithful replica of the pre-engine `Trainer::run_round`: scheduler
/// phase 0, per-client key forks, parallel slicing, the post-fetch dropout
/// coin, sequential cohort-order aggregation behind a synchronous barrier,
/// and the straggler-bound `complete_round` close.
fn legacy_trajectory(cfg: &TrainConfig, threads: usize) -> (ParamStore, Vec<LegacyRound>) {
    let arch = cfg.arch.clone();
    let dataset = build_dataset(&cfg.dataset);
    let mut rng = Rng::new(cfg.seed, 100);
    let mut store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let mut service = cfg.slice_impl.build();
    let mut optimizer = Optimizer::new(cfg.server_opt, &store);
    let mut engine = Engine::Native;
    let geom = SliceGeometry {
        base_ms: spec
            .keyspaces
            .iter()
            .zip(cfg.policies.iter())
            .map(|(ks, p)| p.m(ks.size))
            .collect(),
        per_key_floats: (0..spec.keyspaces.len())
            .map(|ks| spec.per_key_floats(ks))
            .collect(),
        broadcast_floats: spec.broadcast_floats(&store),
        server_floats: spec.server_floats(&store),
    };
    let mut scheduler = Scheduler::new(cfg, dataset.train.len()).unwrap();
    let mut records = Vec::with_capacity(cfg.rounds);
    for round in 1..=cfg.rounds {
        let mut round_rng = rng.fork(round as u64);
        let plan = scheduler.plan_round(round, cfg.cohort, &geom, &mut round_rng, &[]);
        let cohort = plan.cohort.clone();
        let shared: Vec<Option<Vec<u32>>> = cfg
            .policies
            .iter()
            .zip(spec.keyspaces.iter())
            .map(|(p, ks)| p.round_keys(ks.size, &mut round_rng))
            .collect();
        let mut client_keys: Vec<ClientKeys> = Vec::new();
        let mut client_rngs: Vec<Rng> = Vec::new();
        for (slot, &ci) in cohort.iter().enumerate() {
            let client = &dataset.train[ci];
            let mut crng = round_rng.fork(client.id ^ 0xC11E47);
            let keys: ClientKeys = cfg
                .policies
                .iter()
                .enumerate()
                .map(|(ksi, p)| {
                    let p = match &plan.key_budgets {
                        Some(budgets) => p.with_m(budgets[slot][ksi]),
                        None => *p,
                    };
                    p.keys_for(
                        client,
                        spec.keyspaces[ksi].size,
                        &mut crng,
                        shared[ksi].as_deref(),
                        false,
                    )
                })
                .collect();
            client_keys.push(keys);
            client_rngs.push(crng);
        }
        let (bundles, comm) = {
            let session = service.begin_round(&store, &spec).unwrap();
            let bundles = session.fetch_batch(&client_keys, threads).unwrap();
            (bundles, session.finish())
        };
        let mut agg = SparseAccumulator::new(&store);
        let mut completed = 0usize;
        let mut dropped = 0usize;
        let mut up_bytes = 0u64;
        let mut max_mem = 0usize;
        let mut stats: Vec<ClientRoundStats> = Vec::with_capacity(cohort.len());
        for (i, bundle) in bundles.into_iter().enumerate() {
            let client = &dataset.train[cohort[i]];
            let crng = &mut client_rngs[i];
            let keys = &client_keys[i];
            let down_bytes = bundle.bytes();
            let slice_floats = bundle.total_floats();
            if plan.hazards[i] > 0.0 && crng.f32() < plan.hazards[i] {
                dropped += 1;
                stats.push(ClientRoundStats {
                    down_bytes,
                    dropped: true,
                    ..ClientRoundStats::default()
                });
                continue;
            }
            let (batch, _) = build_cu_batch(&arch, client, keys, crng).unwrap();
            max_mem = max_mem.max(client_memory_bytes(slice_floats, &batch));
            let ms: Vec<usize> = keys.iter().map(|k| k.len()).collect();
            let deltas = engine
                .client_update(&arch, &ms, bundle.into_vecs(), &batch, cfg.client_lr)
                .unwrap();
            let plain_up = deltas.iter().map(|d| d.len() as u64 * 4).sum::<u64>()
                + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
            up_bytes += plain_up;
            agg.add_client(&spec, keys, &deltas).unwrap();
            completed += 1;
            stats.push(ClientRoundStats {
                down_bytes,
                up_bytes: plain_up,
                compute_units: slice_floats as f64 * client.num_examples() as f64,
                dropped: false,
                ..ClientRoundStats::default()
            });
        }
        if completed > 0 {
            let (update, _) = Box::new(agg).finalize(AggMode::CohortMean);
            optimizer.step(&mut store, &update);
        }
        let sim = scheduler.complete_round(&plan, &stats);
        records.push(LegacyRound {
            completed,
            dropped,
            down_bytes: comm.down_bytes,
            up_bytes,
            max_client_mem: max_mem,
            sim_round_s: sim.sim_round_s.to_bits(),
            tier_completed: sim.tier_completed,
            tier_dropped: sim.tier_dropped,
            tier_down_bytes: sim.tier_down_bytes,
        });
    }
    (store, records)
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

#[test]
fn sync_mode_is_byte_identical_to_the_legacy_loop() {
    // fleets/policies chosen to exercise hazards (dropout coins), per-client
    // key budgets, and multi-tier timing; threads {1, 4} per the contract
    let scenarios: [(FleetKind, SchedPolicy, f32); 3] = [
        (FleetKind::Uniform, SchedPolicy::Uniform, 0.0),
        (FleetKind::Tiered3, SchedPolicy::MemoryCapped, 0.0),
        (FleetKind::FlakyEdge, SchedPolicy::Uniform, 0.3),
    ];
    for (fleet, policy, dropout) in scenarios {
        for threads in [1usize, 4] {
            let mut cfg = base_cfg(1009);
            cfg.fleet = fleet.clone();
            cfg.sched_policy = policy;
            cfg.dropout_rate = dropout;
            cfg.fetch_threads = threads;
            cfg.mem_cap_frac = 0.2;
            let label = format!("{fleet}/{policy}/threads={threads}");
            let (legacy_store, legacy_rounds) = legacy_trajectory(&cfg, threads);
            assert_eq!(cfg.agg_mode, AggregationMode::Synchronous, "{label}");
            let mut tr = Trainer::new(cfg).unwrap();
            for (r, legacy) in legacy_rounds.iter().enumerate() {
                let rec = tr.run_round().unwrap();
                let engine_round = LegacyRound {
                    completed: rec.completed,
                    dropped: rec.dropped,
                    down_bytes: rec.comm.down_bytes,
                    up_bytes: rec.up_bytes,
                    max_client_mem: rec.max_client_mem,
                    sim_round_s: rec.sim_round_s.to_bits(),
                    tier_completed: rec.tier_completed,
                    tier_dropped: rec.tier_dropped,
                    tier_down_bytes: rec.tier_down_bytes,
                };
                assert_eq!(&engine_round, legacy, "{label} round {}", r + 1);
                assert_eq!(rec.discarded_clients, 0, "{label}");
                assert_eq!(rec.mean_staleness, 0.0, "{label}");
            }
            assert_stores_bit_identical(&legacy_store, tr.store(), &label);
        }
    }
}

#[test]
fn prop_buffered_merge_order_is_deterministic_in_the_seed() {
    const CASES: usize = 8;
    for case in 0..CASES {
        let seed = 0xB0FF + case as u64;
        let mut cfg = base_cfg(seed);
        cfg.fleet = if case % 2 == 0 {
            FleetKind::Tiered3
        } else {
            FleetKind::FlakyEdge
        };
        cfg.rounds = 4;
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: (case % 5) + 1,
            max_staleness: case % 3,
        };
        let mut a = Trainer::new(cfg.clone()).unwrap();
        let mut b = Trainer::new(cfg).unwrap();
        for round in 0..4 {
            let ra = a.run_round().unwrap();
            let rb = b.run_round().unwrap();
            let key = |r: &fedselect::coordinator::RoundRecord| {
                (
                    r.completed,
                    r.dropped,
                    r.discarded_clients,
                    r.mean_staleness.to_bits(),
                    r.sim_round_s.to_bits(),
                    r.up_bytes,
                    r.comm.down_bytes,
                )
            };
            assert_eq!(key(&ra), key(&rb), "case {case} round {round}");
        }
        // merge *order* affects float accumulation: bit-identical stores
        // prove the order itself was reproduced
        assert_stores_bit_identical(a.store(), b.store(), &format!("case {case}"));
        assert_eq!(a.round_engine().in_flight(), b.round_engine().in_flight());
    }
}

#[test]
fn over_select_ledgers_discarded_downloads() {
    let mut sync_cfg = base_cfg(77);
    sync_cfg.fleet = FleetKind::Tiered3;
    sync_cfg.rounds = 2;
    let mut over_cfg = sync_cfg.clone();
    over_cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.5 };

    let sync = Trainer::new(sync_cfg).unwrap().run().unwrap();
    let over = Trainer::new(over_cfg).unwrap().run().unwrap();

    assert!(over.total_discarded > 0, "no stragglers were ever discarded");
    // discarded stragglers' downloads stay on both ledgers: the slice
    // session charged every fetch, and the tier tallies cover the whole
    // (inflated) cohort — so over-selection downloads strictly more than
    // the barrier at the same goal count
    for rec in &over.rounds {
        assert_eq!(
            rec.tier_down_bytes.iter().sum::<u64>(),
            rec.comm.down_bytes,
            "tier ledger must include discarded clients' downloads"
        );
        assert_eq!(
            rec.completed + rec.dropped + rec.discarded_clients,
            9, // 6 requested + ceil(6 * 0.5) over-selected
            "every selected client is accounted for"
        );
        assert!(rec.completed <= 6, "rounds close at the original goal");
    }
    assert!(
        over.total_down_bytes > sync.total_down_bytes,
        "over-selection must pay extra download bytes ({} !> {})",
        over.total_down_bytes,
        sync.total_down_bytes
    );
}

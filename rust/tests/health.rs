//! Fleet-health-monitor contracts, end to end through the trainer:
//!
//! 1. the monitor is **trajectory-neutral**: a run with SLOs + detectors
//!    enabled produces byte-identical model bits and round ledgers
//!    (host-clock fields excluded) to the monitor-off run — and with the
//!    monitor off, the report's ledger is exactly the default;
//! 2. two same-seed monitored runs emit byte-identical incident streams
//!    (`diff_traces` → clean), and a mutated incident field is flagged —
//!    incident lines are sim-time *content*, not log noise;
//! 3. the end-of-run [`HealthReport`] agrees with the trace's incident
//!    lifecycle events.

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{RoundRecord, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::metrics::keys;
use fedselect::obs::trace::diff_traces;
use fedselect::obs::SloRule;
use fedselect::scheduler::{FleetKind, SchedPolicy};

/// Same tiered workload as `tests/obs.rs`: hazards, cache traffic,
/// staleness-fair cycling — plenty of series for the monitor to watch.
fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(512, 64);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
    cfg.rounds = 6;
    cfg.cohort = 6;
    cfg.eval.every = 3;
    cfg.eval.max_examples = 128;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::StalenessFair;
    cfg.dropout_rate = 0.3;
    cfg.cache = true;
    cfg.seed = seed;
    cfg
}

/// An SLO set the 30%-hazard workload violates from round one (dropped
/// ceiling) alongside one it satisfies (round-time ceiling), plus the
/// anomaly detectors.
fn monitored_cfg(seed: u64) -> TrainConfig {
    let mut cfg = base_cfg(seed);
    cfg.obs.health.slos =
        SloRule::parse_list("dropped_frac:le:0.05,sim_round_s:le:1e9").unwrap();
    cfg.obs.health.detectors = true;
    cfg.obs.health.warmup = 3;
    cfg
}

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("fedselect_health_{name}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .to_string()
}

/// A round ledger with its host-clock fields zeroed: everything left must
/// be byte-identical across same-seed runs.
fn sim_only(rec: &RoundRecord) -> String {
    let mut r = rec.clone();
    r.merge_stall_ms = 0.0;
    r.exec_util = 0.0;
    format!("{r:?}")
}

#[test]
fn monitor_is_trajectory_neutral_and_off_means_off() {
    let mut t_off = Trainer::new(base_cfg(4242)).unwrap();
    let mut t_on = Trainer::new(monitored_cfg(4242)).unwrap();
    let off = t_off.run().unwrap();
    let on = t_on.run().unwrap();

    // off = fully off: no monitor ran, the report carries the default
    assert_eq!(off.health.total(), 0);
    assert_eq!(off.health.rules, 0);
    assert!(!off.health.detectors);
    assert_eq!(t_off.metrics().counter(keys::HEALTH_INCIDENTS), 0);

    // on: the dropped_frac ceiling burns, but the trajectory is untouched
    assert!(on.health.total() > 0, "30% hazard must violate dropped_frac:le:0.05");
    assert!(on.health.critical_count() > 0, "SLO incidents are critical");
    assert_eq!(on.health.rules, 2);
    assert!(on.health.detectors);
    assert!(t_on.metrics().counter(keys::HEALTH_INCIDENTS) > 0);

    assert_eq!(off.rounds.len(), on.rounds.len());
    for (a, b) in off.rounds.iter().zip(on.rounds.iter()) {
        assert_eq!(sim_only(a), sim_only(b), "round {} diverged", a.round);
    }
    for (a, b) in off.evals.iter().zip(on.evals.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "eval {}", a.round);
        assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "eval {}", a.round);
    }
    // model bits
    for (sa, sb) in t_off.store().segments.iter().zip(t_on.store().segments.iter()) {
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "segment {} diverges at {i}", sa.name);
        }
    }
}

#[test]
fn same_seed_incident_ledgers_are_byte_identical_and_mutations_flagged() {
    let (path_a, path_b) = (tmp_path("ledger_a"), tmp_path("ledger_b"));
    let mut reports = Vec::new();
    for path in [&path_a, &path_b] {
        let mut cfg = monitored_cfg(1717);
        cfg.obs.trace_out = Some(path.clone());
        let mut tr = Trainer::new(cfg).unwrap();
        reports.push(tr.run().unwrap());
    }
    assert_eq!(reports[0].health, reports[1].health, "in-memory ledgers agree");
    assert!(reports[0].health.total() > 0, "workload must open incidents");

    let a = std::fs::read_to_string(&path_a).unwrap();
    let b = std::fs::read_to_string(&path_b).unwrap();
    let opens = a
        .lines()
        .filter(|l| l.contains("\"t\":\"incident\"") && l.contains("\"action\":\"open\""))
        .count();
    assert_eq!(opens, reports[0].health.total(), "one open line per ledger incident");
    assert!(diff_traces(&a, &b).is_none(), "same-seed incident streams diverged");

    // incident lines are content: mutate one observed value → flagged
    let needle = "\"t\":\"incident\"";
    let line_start = b.find(needle).expect("incident line present");
    let obs_pos = b[line_start..].find("\"observed\":").unwrap() + line_start + 11;
    let mut mutated = b.clone();
    mutated.insert(obs_pos, '9');
    let msg = diff_traces(&a, &mutated).expect("mutated incident must be flagged");
    assert!(msg.contains("line"), "diff names the diverging line: {msg}");

    std::fs::remove_file(&path_a).unwrap();
    std::fs::remove_file(&path_b).unwrap();
}

#[test]
fn trace_lifecycle_agrees_with_the_final_report() {
    let path = tmp_path("lifecycle");
    let mut cfg = monitored_cfg(2525);
    cfg.obs.trace_out = Some(path.clone());
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr.run().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let count = |frag: &str| {
        text.lines()
            .filter(|l| l.contains("\"t\":\"incident\"") && l.contains(frag))
            .count()
    };
    assert_eq!(count("\"action\":\"open\""), report.health.total());
    let resolved = report
        .health
        .incidents
        .iter()
        .filter(|i| i.resolved_round.is_some())
        .count();
    assert_eq!(count("\"action\":\"resolve\""), resolved);
    assert_eq!(
        tr.metrics().counter(keys::HEALTH_RESOLVED) as usize,
        resolved,
        "registry resolve counter tracks the ledger"
    );
    std::fs::remove_file(&path).unwrap();
}

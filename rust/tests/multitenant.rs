//! Multi-tenant coordinator contracts:
//!
//! 1. a **single-job coordinator is byte-identical to the plain trainer**
//!    — model trajectory (final eval bits) and every `RoundRecord` ledger
//!    field, at fetch thread counts {1, 4}, with caching, a tiered fleet
//!    and dropout on (the job's id is pinned to 0: namespace 0 hashes
//!    identically to an untagged run);
//! 2. **cross-job isolation**: under the fair-share arbiter with
//!    partitioned cache budgets, every job's trajectory matches its
//!    isolated run bit for bit, with any mix of slice implementations;
//! 3. the **contended** cache share never changes a trajectory either
//!    (fresh cache entries are exact copies wherever the bytes live);
//! 4. coordinator runs are **deterministic**: same registry, same grants,
//!    same clocks, bit for bit.

use fedselect::cache::CacheShare;
use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{RoundRecord, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::SliceImpl;
use fedselect::scheduler::{FleetKind, SchedPolicy};
use fedselect::tenancy::{ArbiterPolicy, Coordinator, JobRegistry, JobSpec};

fn base_cfg(vocab: usize, m: usize) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(vocab, m);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(24, 4, 8));
    cfg.rounds = 5;
    cfg.cohort = 6;
    cfg.eval.every = 2;
    cfg.eval.max_examples = 256;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::StalenessFair;
    cfg.dropout_rate = 0.3;
    cfg.seed = 77;
    cfg
}

/// Every ledger field of two RoundRecords, compared exactly (floats by
/// bits — the contract is byte-identity, not approximation).
fn assert_rounds_identical(a: &RoundRecord, b: &RoundRecord, label: &str) {
    assert_eq!(a.round, b.round, "{label}: round");
    assert_eq!(a.completed, b.completed, "{label}: completed");
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(a.mode.name(), b.mode.name(), "{label}: mode");
    assert_eq!(a.discarded_clients, b.discarded_clients, "{label}: discarded");
    assert_eq!(
        a.mean_staleness.to_bits(),
        b.mean_staleness.to_bits(),
        "{label}: staleness"
    );
    assert_eq!(a.committees, b.committees, "{label}: committees");
    assert_eq!(
        a.mean_committee_size.to_bits(),
        b.mean_committee_size.to_bits(),
        "{label}: committee size"
    );
    assert_eq!(a.min_committee_size, b.min_committee_size, "{label}: floor");
    assert_eq!(a.comm.down_bytes, b.comm.down_bytes, "{label}: down");
    assert_eq!(a.comm.up_key_bytes, b.comm.up_key_bytes, "{label}: key bytes");
    assert_eq!(a.comm.psi_evals, b.comm.psi_evals, "{label}: psi");
    assert_eq!(a.comm.memo_hits, b.comm.memo_hits, "{label}: memo hits");
    assert_eq!(a.comm.pregen_slices, b.comm.pregen_slices, "{label}: pregen");
    assert_eq!(a.comm.cdn_queries, b.comm.cdn_queries, "{label}: cdn queries");
    assert_eq!(a.comm.service_us, b.comm.service_us, "{label}: service time");
    assert_eq!(
        a.comm.client_cache_hits, b.comm.client_cache_hits,
        "{label}: cache hits"
    );
    assert_eq!(a.up_bytes, b.up_bytes, "{label}: up");
    assert_eq!(a.max_client_mem, b.max_client_mem, "{label}: mem");
    assert_eq!(
        a.sim_round_s.to_bits(),
        b.sim_round_s.to_bits(),
        "{label}: sim_round_s"
    );
    assert_eq!(a.tier_completed, b.tier_completed, "{label}: tier completed");
    assert_eq!(a.tier_dropped, b.tier_dropped, "{label}: tier dropped");
    assert_eq!(a.tier_discarded, b.tier_discarded, "{label}: tier discarded");
    assert_eq!(a.tier_down_bytes, b.tier_down_bytes, "{label}: tier down");
    assert_eq!(a.tier_cache_hits, b.tier_cache_hits, "{label}: tier hits");
    assert_eq!(
        a.tier_cache_lookups, b.tier_cache_lookups,
        "{label}: tier lookups"
    );
    assert_eq!(a.cache_evictions, b.cache_evictions, "{label}: evictions");
    assert_eq!(
        a.cache_stale_refreshes, b.cache_stale_refreshes,
        "{label}: stale refreshes"
    );
    assert_eq!(a.deferrals, b.deferrals, "{label}: deferrals");
}

#[test]
fn single_job_coordinator_is_byte_identical_to_the_trainer() {
    for threads in [1usize, 4] {
        for share in [CacheShare::Partitioned, CacheShare::Contended] {
            let mut cfg = base_cfg(512, 64);
            cfg.cache = true;
            cfg.slice_impl = SliceImpl::PregenCdn;
            cfg.fetch_threads = threads;

            let legacy = Trainer::new(cfg.clone()).unwrap().run().unwrap();

            // id 0 => tenancy namespace 0, byte-identical addressing
            let reg =
                JobRegistry::new(vec![JobSpec::new(0, "solo", cfg)], share).unwrap();
            let multi = Coordinator::new(reg, ArbiterPolicy::FairShare)
                .unwrap()
                .run()
                .unwrap();
            assert_eq!(multi.reports.len(), 1);
            let solo = &multi.reports[0];

            let label = format!("threads={threads} share={share:?}");
            assert_eq!(legacy.rounds.len(), solo.rounds.len(), "{label}");
            for (a, b) in legacy.rounds.iter().zip(&solo.rounds) {
                assert_rounds_identical(a, b, &label);
            }
            assert_eq!(
                legacy.final_eval.loss.to_bits(),
                solo.final_eval.loss.to_bits(),
                "{label}: final loss"
            );
            assert_eq!(
                legacy.final_eval.metric.to_bits(),
                solo.final_eval.metric.to_bits(),
                "{label}: final metric"
            );
            assert_eq!(legacy.evals.len(), solo.evals.len(), "{label}: eval cadence");
            for (a, b) in legacy.evals.iter().zip(&solo.evals) {
                assert_eq!(a.round, b.round, "{label}: eval round");
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{label}: eval loss");
            }
            assert_eq!(legacy.total_down_bytes, solo.total_down_bytes, "{label}");
            assert_eq!(legacy.total_up_bytes, solo.total_up_bytes, "{label}");
            assert_eq!(
                legacy.total_sim_s.to_bits(),
                solo.total_sim_s.to_bits(),
                "{label}: total sim"
            );
            assert_eq!(legacy.total_discarded, solo.total_discarded, "{label}");
        }
    }
}

#[test]
fn fair_share_jobs_match_their_isolated_runs_bit_for_bit() {
    // heterogeneous slice impls; job 2 caches — cross-job isolation means
    // every trajectory is exactly what the job alone would have produced
    let mut a = base_cfg(128, 32);
    a.slice_impl = SliceImpl::OnDemand;
    let mut b = base_cfg(512, 64);
    b.slice_impl = SliceImpl::PregenCdn;
    b.cache = true;
    b.rounds = 4;
    let mut c = base_cfg(256, 32);
    c.slice_impl = SliceImpl::Broadcast;
    c.cohort = 4;

    let isolated: Vec<_> = [a.clone(), b.clone(), c.clone()]
        .into_iter()
        .map(|cfg| Trainer::new(cfg).unwrap().run().unwrap())
        .collect();

    let reg = JobRegistry::new(
        vec![
            JobSpec::new(1, "on-demand", a),
            JobSpec::new(2, "cdn-cached", b),
            JobSpec::new(3, "broadcast", c),
        ],
        CacheShare::Partitioned,
    )
    .unwrap();
    let multi = Coordinator::new(reg, ArbiterPolicy::FairShare)
        .unwrap()
        .run()
        .unwrap();

    for (iso, shared) in isolated.iter().zip(&multi.reports) {
        assert_eq!(iso.rounds.len(), shared.rounds.len());
        assert_eq!(
            iso.final_eval.loss.to_bits(),
            shared.final_eval.loss.to_bits(),
            "trajectory diverged under multi-tenancy"
        );
        assert_eq!(iso.total_up_bytes, shared.total_up_bytes);
        for (ra, rb) in iso.rounds.iter().zip(&shared.rounds) {
            assert_eq!(ra.completed, rb.completed);
            assert_eq!(ra.dropped, rb.dropped);
        }
    }
    // the shared clock strictly beats queueing the three jobs
    let sequential: f64 = isolated.iter().map(|r| r.total_sim_s).sum();
    assert!(
        multi.total_sim_s < sequential,
        "shared {} !< sequential {}",
        multi.total_sim_s,
        sequential
    );
    // fair-share granted every active tick: 5, 4, 5 rounds over 5 ticks
    assert_eq!(multi.ticks, 5);
    assert_eq!(multi.grants, vec![5, 4, 5]);
}

#[test]
fn contended_cache_share_never_changes_trajectories() {
    let mut a = base_cfg(512, 64);
    a.slice_impl = SliceImpl::PregenCdn;
    a.cache = true;
    let mut b = base_cfg(512, 48);
    b.slice_impl = SliceImpl::OnDemand;
    b.cache = true;
    b.rounds = 4;

    let isolated: Vec<_> = [a.clone(), b.clone()]
        .into_iter()
        .map(|cfg| Trainer::new(cfg).unwrap().run().unwrap())
        .collect();

    let reg = JobRegistry::new(
        vec![JobSpec::new(1, "cdn", a), JobSpec::new(2, "od", b)],
        CacheShare::Contended,
    )
    .unwrap();
    let multi = Coordinator::new(reg, ArbiterPolicy::FairShare)
        .unwrap()
        .run()
        .unwrap();

    for (iso, shared) in isolated.iter().zip(&multi.reports) {
        // contention can change which bytes are cache-served (wire ledger),
        // never what the model computes
        assert_eq!(
            iso.final_eval.loss.to_bits(),
            shared.final_eval.loss.to_bits()
        );
        assert_eq!(iso.total_up_bytes, shared.total_up_bytes);
    }
}

#[test]
fn coordinator_runs_are_deterministic() {
    let build = || {
        let mut a = base_cfg(128, 32);
        a.slice_impl = SliceImpl::OnDemand;
        let b = base_cfg(256, 48);
        let reg = JobRegistry::new(
            vec![
                JobSpec::new(1, "a", a).with_weight(2.0),
                JobSpec::new(2, "b", b).with_priority(5),
            ],
            CacheShare::Partitioned,
        )
        .unwrap();
        Coordinator::new(reg, ArbiterPolicy::DeficitRoundRobin).unwrap()
    };
    let r1 = build().run().unwrap();
    let r2 = build().run().unwrap();
    assert_eq!(r1.ticks, r2.ticks);
    assert_eq!(r1.grants, r2.grants);
    assert_eq!(r1.total_sim_s.to_bits(), r2.total_sim_s.to_bits());
    for (a, b) in r1.reports.iter().zip(&r2.reports) {
        assert_eq!(a.final_eval.loss.to_bits(), b.final_eval.loss.to_bits());
        assert_eq!(a.total_down_bytes, b.total_down_bytes);
    }
}

#[test]
fn priority_arbiter_grants_disjoint_cohorts_per_tick() {
    let lo = base_cfg(128, 32);
    let hi = base_cfg(256, 32);
    let reg = JobRegistry::new(
        vec![
            JobSpec::new(1, "lo", lo).with_priority(0),
            JobSpec::new(2, "hi", hi).with_priority(9),
        ],
        CacheShare::Partitioned,
    )
    .unwrap();
    let mut coord = Coordinator::new(reg, ArbiterPolicy::Priority).unwrap();
    let multi = coord.run().unwrap();
    // both 5-round jobs fit the 24-client fleet each tick (6 + 6 <= 24)
    assert_eq!(multi.grants, vec![5, 5]);
    for rep in &multi.reports {
        assert_eq!(rep.rounds.len(), 5);
        for r in &rep.rounds {
            // full cohorts despite the exclusion — leftovers sufficed
            assert_eq!(r.completed + r.dropped + r.discarded_clients, 6);
        }
    }
}

//! Million-client fleet engine: end-to-end invariants.
//!
//! 1. **Lazy ≡ eager at seed sizes** — an explicit `--fleet-size` equal to
//!    the dataset population runs the identical trajectory (every
//!    `RoundRecord` field and every model bit) as the legacy dataset-sized
//!    fleet, at 1 and 4 fetch threads, for all four synthetic kinds and a
//!    trace fleet; and `Fleet::materialize` is definitionally the lazy
//!    generator.
//! 2. **Scenario determinism** — churn + outage runs of the same seed
//!    produce identical eligibility ledgers, cohort outcomes, and model
//!    bits; the horizon bound stops the run on the simulated clock.
//! 3. **Memory sparsity** — resident scheduler state scales with touched
//!    clients, not fleet size: a 100k-client fleet leaves only
//!    cohort-proportional bytes behind.

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{RoundRecord, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::fleet::{ChurnSpec, Fleet, OutageSpec};
use fedselect::model::ParamStore;
use fedselect::scheduler::{FleetKind, SchedPolicy};

const N_TRAIN: usize = 24;

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(128, 32);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(N_TRAIN, 4, 8));
    cfg.rounds = 3;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 128;
    cfg.seed = seed;
    cfg
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

/// Every `RoundRecord` field except the host-clock `wall_ms`.
fn assert_records_identical(a: &RoundRecord, b: &RoundRecord, label: &str) {
    assert_eq!(a.round, b.round, "{label}");
    assert_eq!(a.completed, b.completed, "{label}");
    assert_eq!(a.dropped, b.dropped, "{label}");
    assert_eq!(a.mode, b.mode, "{label}");
    assert_eq!(a.discarded_clients, b.discarded_clients, "{label}");
    assert_eq!(a.mean_staleness.to_bits(), b.mean_staleness.to_bits(), "{label}");
    assert_eq!(a.committees, b.committees, "{label}");
    assert_eq!(a.min_committee_size, b.min_committee_size, "{label}");
    assert_eq!(a.comm, b.comm, "{label}");
    assert_eq!(a.up_bytes, b.up_bytes, "{label}");
    assert_eq!(a.max_client_mem, b.max_client_mem, "{label}");
    assert_eq!(a.sim_round_s.to_bits(), b.sim_round_s.to_bits(), "{label}");
    assert_eq!(a.tier_completed, b.tier_completed, "{label}");
    assert_eq!(a.tier_dropped, b.tier_dropped, "{label}");
    assert_eq!(a.tier_discarded, b.tier_discarded, "{label}");
    assert_eq!(a.tier_down_bytes, b.tier_down_bytes, "{label}");
    assert_eq!(a.tier_cache_hits, b.tier_cache_hits, "{label}");
    assert_eq!(a.tier_cache_lookups, b.tier_cache_lookups, "{label}");
    assert_eq!(a.cache_evictions, b.cache_evictions, "{label}");
    assert_eq!(a.cache_stale_refreshes, b.cache_stale_refreshes, "{label}");
    assert_eq!(a.deferrals, b.deferrals, "{label}");
    assert_eq!(a.eligible, b.eligible, "{label}");
    assert_eq!(a.arrivals, b.arrivals, "{label}");
    assert_eq!(a.departures, b.departures, "{label}");
    assert_eq!(a.outage_excluded, b.outage_excluded, "{label}");
    assert_eq!(a.clients_touched, b.clients_touched, "{label}");
    assert_eq!(a.resident_bytes, b.resident_bytes, "{label}");
}

fn assert_same_trajectory(mut a_cfg: TrainConfig, mut b_cfg: TrainConfig, label: &str) {
    for threads in [1usize, 4] {
        a_cfg.fetch_threads = threads;
        b_cfg.fetch_threads = threads;
        let mut ta = Trainer::new(a_cfg.clone()).unwrap();
        let mut tb = Trainer::new(b_cfg.clone()).unwrap();
        let ra = ta.run().unwrap();
        let rb = tb.run().unwrap();
        let label = format!("{label} threads={threads}");
        assert_eq!(ra.rounds.len(), rb.rounds.len(), "{label}");
        for (x, y) in ra.rounds.iter().zip(rb.rounds.iter()) {
            assert_records_identical(x, y, &format!("{label} round {}", x.round));
        }
        assert_stores_bit_identical(ta.store(), tb.store(), &label);
    }
}

#[test]
fn explicit_fleet_size_at_seed_scale_is_byte_identical_to_the_legacy_path() {
    // `--fleet-size N_TRAIN` goes through the lazy fleet-size plumbing but
    // must reproduce the default dataset-sized run exactly — every ledger
    // field, every model bit — for every synthetic kind and a trace fleet.
    let kinds = [
        FleetKind::Uniform,
        FleetKind::Tiered3,
        FleetKind::Diurnal,
        FleetKind::FlakyEdge,
        FleetKind::Trace("../examples/fleet_trace_32.txt".to_string()),
    ];
    for kind in kinds {
        let mut legacy = base_cfg(4040);
        legacy.fleet = kind.clone();
        let mut sized = legacy.clone();
        sized.fleet_size = N_TRAIN;
        assert_same_trajectory(legacy, sized, &format!("{kind}"));
    }
}

#[test]
fn explicit_fleet_size_is_byte_identical_under_policies_and_cache() {
    // the same identity must hold when the budget-deriving policies and
    // the lazily-allocated client caches are in play
    for policy in [SchedPolicy::MemoryCapped, SchedPolicy::StalenessFair] {
        let mut legacy = base_cfg(4141);
        legacy.fleet = FleetKind::Tiered3;
        legacy.sched_policy = policy;
        legacy.mem_cap_frac = 0.25;
        legacy.cache = true;
        legacy.cache_budget_frac = 0.5;
        let mut sized = legacy.clone();
        sized.fleet_size = N_TRAIN;
        assert_same_trajectory(legacy, sized, &format!("cache+{policy}"));
    }
}

#[test]
fn materialize_matches_the_lazy_generator_end_to_end() {
    for kind in [FleetKind::Tiered3, FleetKind::Diurnal, FleetKind::FlakyEdge] {
        let fleet = Fleet::generate(kind.clone(), 300, 99, 0.25).unwrap();
        let eager = fleet.materialize();
        assert_eq!(eager.len(), 300, "{kind}");
        for (ci, p) in eager.iter().enumerate() {
            let lazy = fleet.profile(ci);
            assert_eq!(p.tier, lazy.tier, "{kind} client {ci}");
            assert_eq!(p.down_bps.to_bits(), lazy.down_bps.to_bits(), "{kind} client {ci}");
            assert_eq!(p.mem_frac.to_bits(), lazy.mem_frac.to_bits(), "{kind} client {ci}");
            assert_eq!(p.hazard.to_bits(), lazy.hazard.to_bits(), "{kind} client {ci}");
        }
    }
}

fn scenario_cfg(seed: u64) -> TrainConfig {
    let mut cfg = base_cfg(seed);
    cfg.rounds = 5;
    cfg.fleet = FleetKind::Tiered3;
    cfg.fleet_size = 500;
    cfg.scenario.churn = Some(ChurnSpec { rate_per_h: 40.0, width_frac: 0.5 });
    cfg.scenario.outage = Some(OutageSpec { start_h: 0.0, dur_h: 1e6, frac: 0.2 });
    cfg
}

#[test]
fn churn_and_outage_scenarios_are_deterministic_and_ledgered() {
    let ra = Trainer::new(scenario_cfg(2020)).unwrap().run().unwrap();
    let rb = Trainer::new(scenario_cfg(2020)).unwrap().run().unwrap();
    assert_eq!(ra.rounds.len(), rb.rounds.len());
    let mut saw_outage = false;
    let mut saw_churn_delta = false;
    for (a, b) in ra.rounds.iter().zip(rb.rounds.iter()) {
        assert_records_identical(a, b, &format!("scenario round {}", a.round));
        // the standing outage excludes a fifth of the fleet, the churn
        // window half of it — eligibility must be genuinely constrained
        assert!(a.eligible < 500, "round {}: eligible {}", a.round, a.eligible);
        assert!(a.eligible >= a.completed + a.dropped, "round {}", a.round);
        saw_outage |= a.outage_excluded > 0;
        saw_churn_delta |= a.arrivals > 0 || a.departures > 0;
    }
    assert!(saw_outage, "outage never excluded anyone");
    assert!(saw_churn_delta, "churn never rotated the window");
}

#[test]
fn horizon_stops_the_run_on_the_simulated_clock() {
    let mut cfg = base_cfg(3030);
    cfg.rounds = 10;
    // one simulated round of this workload takes far longer than 3.6
    // simulated milliseconds, so the bound fires right after round 1
    cfg.scenario.horizon_h = 1e-6;
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 1, "horizon must stop the run early");
}

#[test]
fn resident_state_scales_with_touched_clients_not_fleet_size() {
    let mut cfg = base_cfg(5050);
    cfg.rounds = 3;
    cfg.cohort = 10;
    cfg.fleet = FleetKind::Tiered3;
    cfg.fleet_size = 100_000;
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    let last = report.rounds.last().unwrap();
    // at most cohort × rounds distinct clients have ever been selected
    assert!(last.clients_touched > 0);
    assert!(
        last.clients_touched <= 30,
        "touched {} > selections made",
        last.clients_touched
    );
    // resident scheduler state is proportional to those ~30 clients; an
    // eager 100k-profile table alone would be megabytes
    assert!(
        last.resident_bytes < 64 * 1024,
        "resident bytes {} not sparse",
        last.resident_bytes
    );
}

#[test]
fn oversized_fleet_with_cache_allocates_caches_lazily() {
    let mut cfg = base_cfg(6060);
    cfg.rounds = 3;
    cfg.cohort = 8;
    cfg.fleet = FleetKind::Tiered3;
    cfg.fleet_size = 50_000;
    cfg.cache = true;
    cfg.cache_budget_frac = 0.5;
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr.run().unwrap();
    let caches = tr.scheduler().caches().expect("caches installed");
    assert!(caches.clients_cached() > 0, "committing clients got caches");
    assert!(
        caches.clients_cached() <= 24,
        "only ever-committing clients may hold a cache, got {}",
        caches.clients_cached()
    );
    let last = report.rounds.last().unwrap();
    assert!(last.resident_bytes > 0);
    assert!(
        last.clients_touched <= 24,
        "touched {} > selections made",
        last.clients_touched
    );
}

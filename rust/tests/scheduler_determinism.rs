//! Scheduler determinism properties (driven by the crate's own PCG, like
//! tests/proptests.rs — every failing case reports its seed):
//!
//! 1. the same seed yields the same cohort plan for *every* policy × fleet,
//!    across repeated scheduler instances and rounds;
//! 2. `Uniform` policy + `uniform` fleet reproduces the pre-scheduler
//!    coordinator trajectory byte-for-byte — verified against a faithful
//!    replica of the old inline round loop (sample cohort -> draw keys ->
//!    slice -> dropout coin -> update -> aggregate -> server step), with
//!    and without the deprecated scalar `dropout_rate`.

use fedselect::aggregation::{Aggregator, SparseAccumulator};
use fedselect::clients::{build_cu_batch, Engine};
use fedselect::config::TrainConfig;
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::config::DatasetConfig;
use fedselect::data::bow::BowConfig;
use fedselect::fedselect::ClientKeys;
use fedselect::model::ParamStore;
use fedselect::optim::Optimizer;
use fedselect::scheduler::{FleetKind, SchedPolicy, Scheduler, SliceGeometry};
use fedselect::tensor::rng::Rng;

const CASES: usize = 12;

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(128, 32);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(24, 4, 8));
    cfg.rounds = 3;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 128;
    cfg.seed = seed;
    cfg
}

fn geom() -> SliceGeometry {
    SliceGeometry {
        base_ms: vec![32],
        per_key_floats: vec![50],
        broadcast_floats: 50,
        server_floats: 128 * 50 + 50,
    }
}

#[test]
fn prop_same_seed_same_cohort_for_every_policy_and_fleet() {
    let fleets = [
        FleetKind::Uniform,
        FleetKind::Tiered3,
        FleetKind::Diurnal,
        FleetKind::FlakyEdge,
    ];
    for case in 0..CASES {
        let seed = 0x5C4ED + case as u64;
        for fleet in fleets {
            for policy in SchedPolicy::ALL {
                let mut cfg = base_cfg(seed);
                cfg.fleet = fleet.clone();
                cfg.sched_policy = policy;
                let g = geom();
                let mut a = Scheduler::new(&cfg, 24).unwrap();
                let mut b = Scheduler::new(&cfg, 24).unwrap();
                // drive both from identically forked round RNGs, as the
                // trainer does
                let mut rng_a = Rng::new(seed, 100);
                let mut rng_b = Rng::new(seed, 100);
                for round in 1..=4usize {
                    let mut ra = rng_a.fork(round as u64);
                    let mut rb = rng_b.fork(round as u64);
                    let pa = a.plan_round(round, 6, &g, &mut ra, &[]);
                    let pb = b.plan_round(round, 6, &g, &mut rb, &[]);
                    assert_eq!(
                        pa.cohort, pb.cohort,
                        "case {case} {fleet} {policy} round {round}"
                    );
                    assert_eq!(
                        pa.key_budgets, pb.key_budgets,
                        "case {case} {fleet} {policy} round {round}"
                    );
                    assert_eq!(
                        pa.hazards, pb.hazards,
                        "case {case} {fleet} {policy} round {round}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_full_training_is_deterministic_for_every_policy() {
    for (fleet, policy) in [
        (FleetKind::Tiered3, SchedPolicy::MemoryCapped),
        (FleetKind::Diurnal, SchedPolicy::AvailabilityAware),
        (FleetKind::FlakyEdge, SchedPolicy::StalenessFair),
        (FleetKind::Tiered3, SchedPolicy::LossWeighted),
        (FleetKind::Uniform, SchedPolicy::Uniform),
    ] {
        let mut cfg = base_cfg(11);
        cfg.fleet = fleet.clone();
        cfg.sched_policy = policy;
        let ra = Trainer::new(cfg.clone()).unwrap().run().unwrap();
        let rb = Trainer::new(cfg).unwrap().run().unwrap();
        assert_eq!(
            ra.final_eval.loss.to_bits(),
            rb.final_eval.loss.to_bits(),
            "{fleet} {policy}"
        );
        assert_eq!(ra.total_down_bytes, rb.total_down_bytes, "{fleet} {policy}");
        assert_eq!(
            ra.total_sim_s.to_bits(),
            rb.total_sim_s.to_bits(),
            "{fleet} {policy}"
        );
    }
}

/// Faithful replica of the pre-scheduler `Trainer::run_round` loop:
/// inline uniform cohort sampling, per-client RNG forks, the scalar
/// post-fetch dropout coin gated on `dropout_rate > 0`, sequential
/// updates, cohort-mean aggregation, server optimizer step.
fn legacy_trajectory(cfg: &TrainConfig) -> ParamStore {
    let arch = cfg.arch.clone();
    let dataset = build_dataset(&cfg.dataset);
    let mut rng = Rng::new(cfg.seed, 100);
    let mut store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let mut service = cfg.slice_impl.build();
    let mut optimizer = Optimizer::new(cfg.server_opt, &store);
    let mut engine = Engine::Native;
    for round in 1..=cfg.rounds {
        let mut round_rng = rng.fork(round as u64);
        let cohort = dataset.sample_cohort(&mut round_rng, cfg.cohort);
        let shared: Vec<Option<Vec<u32>>> = cfg
            .policies
            .iter()
            .zip(spec.keyspaces.iter())
            .map(|(p, ks)| p.round_keys(ks.size, &mut round_rng))
            .collect();
        let mut client_keys: Vec<ClientKeys> = Vec::new();
        let mut client_rngs: Vec<Rng> = Vec::new();
        for &ci in &cohort {
            let client = &dataset.train[ci];
            let mut crng = round_rng.fork(client.id ^ 0xC11E47);
            let keys: ClientKeys = cfg
                .policies
                .iter()
                .enumerate()
                .map(|(ksi, p)| {
                    p.keys_for(client, spec.keyspaces[ksi].size, &mut crng, shared[ksi].as_deref(), false)
                })
                .collect();
            client_keys.push(keys);
            client_rngs.push(crng);
        }
        let bundles = {
            let session = service.begin_round(&store, &spec).unwrap();
            let bundles = session.fetch_batch(&client_keys, cfg.fetch_threads).unwrap();
            session.finish();
            bundles
        };
        let mut agg = SparseAccumulator::new(&store);
        let mut completed = 0usize;
        for (i, bundle) in bundles.into_iter().enumerate() {
            let client = &dataset.train[cohort[i]];
            let crng = &mut client_rngs[i];
            let keys = &client_keys[i];
            if cfg.dropout_rate > 0.0 && crng.f32() < cfg.dropout_rate {
                continue;
            }
            let (batch, _) = build_cu_batch(&arch, client, keys, crng).unwrap();
            let ms: Vec<usize> = keys.iter().map(|k| k.len()).collect();
            let deltas = engine
                .client_update(&arch, &ms, bundle.into_vecs(), &batch, cfg.client_lr)
                .unwrap();
            agg.add_client(&spec, keys, &deltas).unwrap();
            completed += 1;
        }
        if completed > 0 {
            let (update, _) = Box::new(agg).finalize(cfg.agg);
            optimizer.step(&mut store, &update);
        }
    }
    store
}

fn assert_stores_bit_identical(a: &ParamStore, b: &ParamStore, label: &str) {
    assert_eq!(a.segments.len(), b.segments.len(), "{label}");
    for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
        assert_eq!(sa.name, sb.name, "{label}");
        assert_eq!(sa.data.len(), sb.data.len(), "{label} {}", sa.name);
        for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: segment {} diverges at {i}",
                sa.name
            );
        }
    }
}

#[test]
fn uniform_scheduler_reproduces_the_legacy_trajectory_byte_for_byte() {
    for seed in [7u64, 23, 1009] {
        let cfg = base_cfg(seed); // uniform fleet + Uniform policy defaults
        let legacy = legacy_trajectory(&cfg);
        let mut tr = Trainer::new(cfg).unwrap();
        for _ in 0..3 {
            tr.run_round().unwrap();
        }
        assert_stores_bit_identical(&legacy, tr.store(), &format!("seed {seed}"));
    }
}

#[test]
fn deprecated_dropout_rate_maps_onto_the_hazard_byte_for_byte() {
    let mut cfg = base_cfg(7);
    cfg.dropout_rate = 0.4;
    let legacy = legacy_trajectory(&cfg);
    let mut tr = Trainer::new(cfg).unwrap();
    let mut dropped = 0usize;
    for _ in 0..3 {
        dropped += tr.run_round().unwrap().dropped;
    }
    assert!(dropped > 0, "hazard floor never fired");
    assert_stores_bit_identical(&legacy, tr.store(), "dropout 0.4");
}

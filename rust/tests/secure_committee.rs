//! Close-group secure-aggregation committees (the §4.2 privacy strategy
//! composed with goal-count closes), end to end:
//!
//! 1. `secure_agg + buffered/over-select` passes `validate` with
//!    `secure_committee` and trains to (near-)plain model quality — the
//!    committee path only differs from plain aggregation by the fixed-point
//!    quantization and the committee-grouped summation order;
//! 2. over-selected stragglers are keyed into the committee and recovered
//!    via mask reconstruction rather than poisoning the sum;
//! 3. FedBuff-style concurrency control: a client with an update in flight
//!    is never re-selected, so the in-flight pool never holds two updates
//!    of one client (the planner-exclusion regression test).

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{AggregationMode, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::scheduler::FleetKind;

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(128, 32);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(24, 4, 8));
    cfg.rounds = 4;
    cfg.cohort = 6;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 256;
    cfg.seed = seed;
    cfg
}

#[test]
fn committee_secagg_trains_under_buffered_closes_near_plain_quality() {
    let mut plain = base_cfg(501);
    plain.fleet = FleetKind::Tiered3;
    plain.agg_mode = AggregationMode::Buffered {
        goal_count: 4,
        max_staleness: 3,
    };
    let mut secure = plain.clone();
    secure.secure_agg = true;
    secure.secure_committee = true;
    secure.validate().expect("committees lift the sync-only restriction");

    let rp = Trainer::new(plain).unwrap().run().unwrap();
    let rs = Trainer::new(secure).unwrap().run().unwrap();
    assert!(rs.final_eval.loss.is_finite());
    // the masked uploads are full-model-sized, which shifts completion
    // times and hence which updates land within the goal count — so the
    // comparison is near-matching model quality (the async sweep's bar),
    // not bit-identity
    let gap = (rp.final_eval.metric - rs.final_eval.metric).abs();
    assert!(
        gap < 0.05,
        "plain {} vs committee {}",
        rp.final_eval.metric,
        rs.final_eval.metric
    );
    // committee members upload full-model-sized masked update + count
    // vectors (u64 group elements), dwarfing the plain sliced uploads
    assert!(rs.total_up_bytes > rp.total_up_bytes);
    // committees were actually keyed, at most one per staleness class
    for rec in &rs.rounds {
        if rec.completed > 0 {
            assert!(rec.committees >= 1, "round {}: no committee keyed", rec.round);
            assert!(rec.mean_committee_size >= 1.0);
            assert!(
                rec.committees <= rec.completed,
                "more committees than merged updates"
            );
        }
    }
    // staleness carried across rounds still shows up under committees
    assert!(
        rs.rounds.iter().skip(1).any(|r| r.mean_staleness > 0.0),
        "no stale merge ever happened"
    );
}

#[test]
fn committee_secagg_recovers_over_selected_stragglers() {
    let mut cfg = base_cfg(733);
    cfg.fleet = FleetKind::Tiered3;
    cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.5 };
    cfg.secure_agg = true;
    cfg.secure_committee = true;
    cfg.validate().unwrap();
    let mut plain = base_cfg(733);
    plain.fleet = FleetKind::Tiered3;
    plain.agg_mode = AggregationMode::OverSelect { extra_frac: 0.5 };

    let rs = Trainer::new(cfg).unwrap().run().unwrap();
    let rp = Trainer::new(plain).unwrap().run().unwrap();
    assert!(rs.total_discarded > 0, "no straggler was ever discarded");
    // discarded stragglers were keyed into their close's committee: the mean
    // keyed size exceeds the merged count in the rounds that discarded
    let mut saw_reconstruction = false;
    for rec in &rs.rounds {
        if rec.completed == 0 {
            continue;
        }
        assert_eq!(rec.committees, 1, "over-select keys one committee per close");
        let keyed = (rec.completed + rec.discarded_clients) as f64;
        assert!(
            (rec.mean_committee_size - keyed).abs() < 1e-9,
            "round {}: committee size {} != merged {} + discarded {}",
            rec.round,
            rec.mean_committee_size,
            rec.completed,
            rec.discarded_clients
        );
        if rec.discarded_clients > 0 {
            saw_reconstruction = true;
        }
    }
    assert!(saw_reconstruction, "reconstruction path never exercised");
    // and the recovered sums train as well as plain over-selection (the
    // close set can differ — masked uploads shift completion times)
    let gap = (rp.final_eval.metric - rs.final_eval.metric).abs();
    assert!(
        gap < 0.05,
        "plain {} vs committee {}",
        rp.final_eval.metric,
        rs.final_eval.metric
    );
}

#[test]
fn whole_cohort_secure_agg_still_requires_sync() {
    let mut cfg = base_cfg(7);
    cfg.secure_agg = true;
    cfg.agg_mode = AggregationMode::Buffered {
        goal_count: 0,
        max_staleness: 4,
    };
    let err = cfg.validate().unwrap_err().to_string();
    assert!(err.contains("--secure-committee"), "{err}");
    cfg.secure_committee = true;
    cfg.validate().unwrap();
}

#[test]
fn buffered_planner_never_reselects_an_in_flight_client() {
    // tight population so re-selection would be near-certain without the
    // exclusion set: 6 of 12 clients selected per round, goal 2, so up to 4
    // updates stay in flight each round for up to 5 rounds
    let mut cfg = base_cfg(909);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(12, 2, 4));
    cfg.fleet = FleetKind::Tiered3;
    cfg.rounds = 6;
    cfg.cohort = 6;
    cfg.agg_mode = AggregationMode::Buffered {
        goal_count: 2,
        max_staleness: 5,
    };
    let mut tr = Trainer::new(cfg).unwrap();
    let mut saw_in_flight = false;
    for _ in 0..6 {
        tr.run_round().unwrap();
        let pool = tr.round_engine().in_flight();
        let distinct = tr.round_engine().in_flight_clients().len();
        assert_eq!(
            pool, distinct,
            "in-flight pool holds two updates of one client"
        );
        saw_in_flight |= pool > 0;
    }
    assert!(saw_in_flight, "config never left an update in flight");
}

//! Property-based tests on coordinator invariants.
//!
//! The offline build has no external proptest crate, so this file drives
//! randomized cases from the crate's own deterministic PCG — every failure
//! reports the case seed, and re-running with the same build reproduces it.
//!
//! Invariants covered (DESIGN.md §2):
//! 1. deselect(select(x)) is identity on selected coords, zero elsewhere
//! 2. FEDSELECT with all keys == BROADCAST (paper §3.3)
//! 3. all three slice-service implementations are byte-identical
//! 4. Aggregate* with all-keys clients == dense mean
//! 5. secure-agg masked sum == plain sum (mask cancellation), with dropouts
//! 6. IBLT merge/decode round-trips sparse (key, value) multisets
//! 7. merged keyspaces == separate FedSelects (paper §3.3 composition)
//! 8. key policies always yield m distinct in-range keys
//! 9. `fetch_batch` over N threads is byte-identical to sequential
//!    per-client `fetch`, for all three implementations

use fedselect::aggregation::{AggMode, Aggregator, SecureAggSim, SparseAccumulator};
use fedselect::aggregation::iblt::Iblt;
use fedselect::data::{ClientData, Example};
use fedselect::fedselect::{ClientKeys, KeyPolicy, RoundSession, SliceImpl, SliceService};
use fedselect::model::{Binding, KeyMap, Keyspace, ModelArch, ParamStore, Segment, SelectSpec};
use fedselect::tensor::rng::Rng;

const CASES: usize = 40;

fn rand_keys(rng: &mut Rng, k: usize, m: usize) -> Vec<u32> {
    rng.sample_without_replacement(k, m)
        .into_iter()
        .map(|x| x as u32)
        .collect()
}

fn rand_store_spec(rng: &mut Rng) -> (ParamStore, SelectSpec) {
    // random keyed segment geometry
    let k = 2 + rng.below(40);
    let row = 1 + rng.below(6);
    let groups = 1 + rng.below(5);
    let mut seg = Segment::zeros("w", &[groups * k, row]);
    for v in &mut seg.data {
        *v = rng.normal();
    }
    let mut bias = Segment::zeros("b", &[3]);
    for v in &mut bias.data {
        *v = rng.normal();
    }
    let store = ParamStore {
        segments: vec![seg, bias],
    };
    let spec = SelectSpec {
        bindings: vec![
            Binding::Keyed {
                seg: 0,
                keyspace: 0,
                map: KeyMap::grouped_rows(groups, k, row),
            },
            Binding::Full { seg: 1 },
        ],
        keyspaces: vec![Keyspace {
            name: "k".into(),
            size: k,
        }],
    };
    spec.validate(&store).unwrap();
    (store, spec)
}

#[test]
fn prop_select_then_deselect_is_partial_identity() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA11CE + case as u64, 1);
        let (store, spec) = rand_store_spec(&mut rng);
        let k = spec.keyspaces[0].size;
        let m = 1 + rng.below(k);
        let keys = vec![rand_keys(&mut rng, k, m)];
        let slices = spec.slice(&store, &keys).unwrap();
        let mut acc = store.zeros_like();
        let mut cnt = store.zeros_like();
        spec.deselect_add(&mut acc, &mut cnt, &keys, &slices).unwrap();
        for (si, (a, c)) in acc
            .segments
            .iter()
            .zip(cnt.segments.iter())
            .enumerate()
            .take(1)
        {
            for (i, ((&av, &cv), &orig)) in a
                .data
                .iter()
                .zip(c.data.iter())
                .zip(store.segments[si].data.iter())
                .enumerate()
            {
                if cv > 0.0 {
                    assert!(
                        (av - orig * cv).abs() < 1e-5,
                        "case {case} seg {si} idx {i}: {av} vs {orig}*{cv}"
                    );
                } else {
                    assert_eq!(av, 0.0, "case {case}: unselected coord nonzero");
                }
            }
        }
    }
}

#[test]
fn prop_all_keys_recovers_broadcast() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xB0B + case as u64, 2);
        let (store, spec) = rand_store_spec(&mut rng);
        let k = spec.keyspaces[0].size;
        let keys = vec![(0..k as u32).collect::<Vec<_>>()];
        let slices = spec.slice(&store, &keys).unwrap();
        assert_eq!(slices[0], store.segments[0].data, "case {case}");
        assert_eq!(slices[1], store.segments[1].data, "case {case}");
    }
}

#[test]
fn prop_slice_services_are_interchangeable() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0x5E1EC7 + case as u64, 3);
        let (store, spec) = rand_store_spec(&mut rng);
        let k = spec.keyspaces[0].size;
        let m = 1 + rng.below(k);
        let keys = vec![rand_keys(&mut rng, k, m)];
        let mut outs = Vec::new();
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            outs.push(session.fetch(&keys).unwrap().to_vecs());
        }
        assert_eq!(outs[0], outs[1], "case {case} broadcast vs on-demand");
        assert_eq!(outs[1], outs[2], "case {case} on-demand vs pregen");
    }
}

/// Two-keyspace geometry (transformer-shaped): row-keyed embedding over
/// keyspace 0, grouped-row dense over keyspace 1, plus a full bias.
fn rand_multi_store_spec(rng: &mut Rng) -> (ParamStore, SelectSpec) {
    let k0 = 2 + rng.below(24);
    let r0 = 1 + rng.below(6);
    let k1 = 2 + rng.below(16);
    let r1 = 1 + rng.below(4);
    let g = 1 + rng.below(4);
    let mut emb = Segment::zeros("emb", &[k0, r0]);
    for v in &mut emb.data {
        *v = rng.normal();
    }
    let mut w = Segment::zeros("w", &[g * k1, r1]);
    for v in &mut w.data {
        *v = rng.normal();
    }
    let mut bias = Segment::zeros("b", &[5]);
    for v in &mut bias.data {
        *v = rng.normal();
    }
    let store = ParamStore {
        segments: vec![emb, w, bias],
    };
    let spec = SelectSpec {
        bindings: vec![
            Binding::Keyed {
                seg: 0,
                keyspace: 0,
                map: KeyMap::rows(k0, r0),
            },
            Binding::Keyed {
                seg: 1,
                keyspace: 1,
                map: KeyMap::grouped_rows(g, k1, r1),
            },
            Binding::Full { seg: 2 },
        ],
        keyspaces: vec![
            Keyspace {
                name: "vocab".into(),
                size: k0,
            },
            Keyspace {
                name: "ffn".into(),
                size: k1,
            },
        ],
    };
    spec.validate(&store).unwrap();
    (store, spec)
}

#[test]
fn prop_parallel_fetch_batch_is_byte_identical_to_sequential() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0xBA7C4 + case as u64, 9);
        // alternate single-keyspace and transformer-shaped geometries
        let (store, spec) = if case % 2 == 0 {
            rand_store_spec(&mut rng)
        } else {
            rand_multi_store_spec(&mut rng)
        };
        let cohort = 1 + rng.below(10);
        let batch: Vec<ClientKeys> = (0..cohort)
            .map(|_| {
                spec.keyspaces
                    .iter()
                    .map(|ks| {
                        let m = 1 + rng.below(ks.size);
                        rand_keys(&mut rng, ks.size, m)
                    })
                    .collect()
            })
            .collect();
        let threads = 2 + rng.below(7); // 2..=8, may exceed the cohort
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            let seq: Vec<Vec<Vec<f32>>> = batch
                .iter()
                .map(|keys| session.fetch(keys).unwrap().to_vecs())
                .collect();
            let par: Vec<Vec<Vec<f32>>> = session
                .fetch_batch(&batch, threads)
                .unwrap()
                .into_iter()
                .map(|b| b.to_vecs())
                .collect();
            assert_eq!(seq, par, "case {case} {imp} threads={threads}");
            // and both equal the direct ψ of the spec
            for (i, keys) in batch.iter().enumerate() {
                assert_eq!(
                    par[i],
                    spec.slice(&store, keys).unwrap(),
                    "case {case} {imp} client {i}"
                );
            }
        }
    }
}

#[test]
fn prop_aggregate_star_with_all_keys_is_dense_mean() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0xA66u64.wrapping_add(case as u64), 4);
        let (store, spec) = rand_store_spec(&mut rng);
        let k = spec.keyspaces[0].size;
        let all: Vec<u32> = (0..k as u32).collect();
        let n_clients = 2 + rng.below(5);
        let mut agg = Box::new(SparseAccumulator::new(&store));
        let mut expect0 = vec![0.0f32; store.segments[0].len()];
        let mut expect1 = vec![0.0f32; store.segments[1].len()];
        for _ in 0..n_clients {
            let u0: Vec<f32> = (0..expect0.len()).map(|_| rng.normal()).collect();
            let u1: Vec<f32> = (0..expect1.len()).map(|_| rng.normal()).collect();
            for (e, &v) in expect0.iter_mut().zip(u0.iter()) {
                *e += v / n_clients as f32;
            }
            for (e, &v) in expect1.iter_mut().zip(u1.iter()) {
                *e += v / n_clients as f32;
            }
            agg.add_client(&spec, &[all.clone()], &[u0, u1]).unwrap();
        }
        let (u, _) = agg.finalize(AggMode::CohortMean);
        for (got, want) in u.segments[0].data.iter().zip(expect0.iter()) {
            assert!((got - want).abs() < 1e-4, "case {case}");
        }
        for (got, want) in u.segments[1].data.iter().zip(expect1.iter()) {
            assert!((got - want).abs() < 1e-4, "case {case}");
        }
    }
}

#[test]
fn prop_secure_agg_equals_plain_with_random_dropouts() {
    for case in 0..CASES / 2 {
        let mut rng = Rng::new(0x5EC + case as u64, 5);
        let (store, spec) = rand_store_spec(&mut rng);
        let k = spec.keyspaces[0].size;
        let n = 3 + rng.below(4);
        let cohort: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1).collect();
        let mut sec = SecureAggSim::new(&store, cohort.clone(), 0xFEED + case as u64);
        let mut plain = SparseAccumulator::new(&store);
        for &cid in cohort.iter().take(n - 1) {
            // last member drops
            let m = 1 + rng.below(k);
            let keys = vec![rand_keys(&mut rng, k, m)];
            let len0 = {
                let Binding::Keyed { map, .. } = &spec.bindings[0] else {
                    unreachable!()
                };
                map.sliced_len(m)
            };
            let ups = vec![
                (0..len0).map(|_| rng.normal()).collect::<Vec<f32>>(),
                (0..3).map(|_| rng.normal()).collect::<Vec<f32>>(),
            ];
            sec.submit(cid, &spec, &keys, &ups).unwrap();
            plain.add_client(&spec, &keys, &ups).unwrap();
        }
        sec.mark_dropped(cohort[n - 1]);
        let (ssum, scnt) = sec.unmask_sum();
        let (psum, pcnt) = plain.raw();
        for (a, b) in ssum.segments.iter().zip(psum.segments.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 5e-3, "case {case}: {x} vs {y}");
            }
        }
        for (a, b) in scnt.segments.iter().zip(pcnt.segments.iter()) {
            assert_eq!(a.data, b.data, "case {case} counts");
        }
    }
}

#[test]
fn prop_iblt_roundtrips_random_multisets() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x1B17 + case as u64, 6);
        let dim = 1 + rng.below(4);
        let n_clients = 1 + rng.below(6);
        let keys_per = 1 + rng.below(12);
        let keyspace = 64;
        let mut total = Iblt::new(keyspace, dim, 99);
        let mut expect: std::collections::HashMap<u64, Vec<f32>> = Default::default();
        for _ in 0..n_clients {
            let mut t = Iblt::new(keyspace, dim, 99);
            for _ in 0..keys_per {
                let key = rng.below(keyspace) as u64;
                let val: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                t.insert(key, &val);
                let e = expect.entry(key).or_insert_with(|| vec![0.0; dim]);
                for (a, b) in e.iter_mut().zip(val.iter()) {
                    *a += b;
                }
            }
            total.merge(&t);
        }
        let got = total.decode().unwrap_or_else(|r| {
            panic!("case {case}: decode stalled with {r} residual cells")
        });
        assert_eq!(got.len(), expect.len(), "case {case}");
        for (k, _, v) in got {
            for (a, b) in v.iter().zip(expect[&k].iter()) {
                assert!((a - b).abs() < 1e-3, "case {case} key {k}");
            }
        }
    }
}

#[test]
fn prop_merged_keyspaces_equal_separate_selects() {
    // paper §3.3: two FedSelects over [K1], [K2] == one over [K1]x[K2].
    // The transformer spec has exactly this structure (vocab + ffn).
    for case in 0..8 {
        let mut rng = Rng::new(0x333 + case as u64, 7);
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut rng);
        let spec = arch.select_spec();
        let k0 = spec.keyspaces[0].size;
        let k1 = spec.keyspaces[1].size;
        let keys = vec![rand_keys(&mut rng, k0, 16), rand_keys(&mut rng, k1, 8)];
        // merged: both keyspaces at once
        let merged = spec.slice(&store, &keys).unwrap();
        // separate: keyspace 0 with all of 1, then keyspace 1 with all of 0,
        // picking each binding from the run that sliced it.
        let all1: Vec<u32> = (0..k1 as u32).collect();
        let all0: Vec<u32> = (0..k0 as u32).collect();
        let only0 = spec
            .slice(&store, &[keys[0].clone(), all1])
            .unwrap();
        let only1 = spec
            .slice(&store, &[all0, keys[1].clone()])
            .unwrap();
        for (i, b) in spec.bindings.iter().enumerate() {
            match b {
                Binding::Keyed { keyspace: 0, .. } => {
                    assert_eq!(merged[i], only0[i], "case {case} binding {i}")
                }
                Binding::Keyed { keyspace: 1, .. } => {
                    assert_eq!(merged[i], only1[i], "case {case} binding {i}")
                }
                _ => assert_eq!(merged[i], only0[i], "case {case} binding {i}"),
            }
        }
    }
}

#[test]
fn prop_key_policies_yield_distinct_inrange_keys() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x4E1u64.wrapping_add(case as u64), 8);
        let k = 4 + rng.below(100);
        let m = 1 + rng.below(k);
        // synthetic client with a random feature profile
        let nw = 1 + rng.below(k);
        let words: Vec<u32> = rand_keys(&mut rng, k, nw);
        let examples = vec![Example::Bow {
            words: words.clone(),
            tags: vec![0],
        }];
        let feature_counts = ClientData::compute_feature_counts(&examples);
        let client = ClientData {
            id: case as u64,
            examples,
            feature_counts,
        };
        for pol in [
            KeyPolicy::TopFreq { m },
            KeyPolicy::RandomLocal { m },
            KeyPolicy::RandomTopLocal { m },
            KeyPolicy::RandomGlobal { m },
        ] {
            let keys = pol.keys_for(&client, k, &mut rng, None, case % 2 == 0);
            assert_eq!(keys.len(), m, "case {case} {pol:?}");
            let set: std::collections::HashSet<u32> = keys.iter().copied().collect();
            assert_eq!(set.len(), m, "case {case} {pol:?} dup keys");
            assert!(keys.iter().all(|&x| (x as usize) < k), "case {case} {pol:?}");
            if case % 2 == 0 {
                assert!(keys.contains(&0), "case {case} {pol:?} force_key_zero");
            }
        }
    }
}

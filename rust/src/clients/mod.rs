//! Client simulation: local batch assembly and the client-update engine.
//!
//! [`build_cu_batch`] turns a client's raw examples into the static-shape
//! `[steps, mb, ...]` tensors the AOT client-update artifacts expect —
//! including the FedSelect-specific parts: BOW features are *projected onto
//! the client's selected keys* (the π_A of §2.3) and transformer tokens are
//! remapped to slice-local ids (out-of-slice tokens hit the UNK key).
//! Variable-size client datasets are padded with zero-weight rows.
//!
//! [`Engine`] dispatches `ClientUpdate`/eval either to the PJRT runtime
//! (the compiled XLA artifacts — the production path) or to the native Rust
//! mirror (logreg/MLP only; the test oracle and artifact-free sweep path).

use std::collections::HashMap;

use crate::data::{ClientData, Example};
use crate::error::{Error, Result};
use crate::model::{ModelArch, ParamStore};
use crate::native::{self, Buf};
use crate::runtime::PjrtRuntime;
use crate::tensor::rng::Rng;

/// Client-update engine backend.
pub enum Engine {
    /// Pure-Rust mirror (logreg/MLP only).
    Native,
    /// Compiled AOT artifacts through PJRT.
    Pjrt(Box<PjrtRuntime>),
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Pjrt(_) => "pjrt",
        }
    }

    /// Run one local epoch; returns the model delta per binding.
    pub fn client_update(
        &mut self,
        arch: &ModelArch,
        ms: &[usize],
        slices: Vec<Vec<f32>>,
        batch: &[Buf],
        lr: f32,
    ) -> Result<Vec<Vec<f32>>> {
        match self {
            Engine::Native => native::client_update(arch, ms, &slices, batch, lr),
            Engine::Pjrt(rt) => {
                let name = arch.cu_name(ms);
                let mut inputs: Vec<Buf> = slices.into_iter().map(Buf::F32).collect();
                inputs.extend(batch.iter().cloned());
                inputs.push(Buf::F32(vec![lr]));
                rt.execute(&name, &inputs)
            }
        }
    }

    /// Evaluate the full server model on one padded eval batch.
    /// Returns (loss_sum, metric_sum, weight_sum).
    pub fn eval(
        &mut self,
        arch: &ModelArch,
        store: &ParamStore,
        batch: &[Buf],
    ) -> Result<(f64, f64, f64)> {
        match self {
            Engine::Native => {
                let params: Vec<Vec<f32>> =
                    store.segments.iter().map(|s| s.data.clone()).collect();
                native::eval(arch, &params, batch)
            }
            Engine::Pjrt(rt) => {
                let name = arch.eval_name();
                let mut inputs: Vec<Buf> = store
                    .segments
                    .iter()
                    .map(|s| Buf::F32(s.data.clone()))
                    .collect();
                inputs.extend(batch.iter().cloned());
                let out = rt.execute(&name, &inputs)?;
                Ok((out[0][0] as f64, out[1][0] as f64, out[2][0] as f64))
            }
        }
    }
}

/// Select up to `cap` example indices for a local epoch (shuffled, no
/// replacement; datasets smaller than `cap` are padded at batch build).
fn epoch_indices(n: usize, cap: usize, rng: &mut Rng) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.truncate(cap);
    idx
}

/// Build the `[steps, mb, ...]` client-update batch for one client.
///
/// Returns batch buffers in artifact order plus the number of real
/// (non-padding) examples used.
pub fn build_cu_batch(
    arch: &ModelArch,
    client: &ClientData,
    keys: &[Vec<u32>],
    rng: &mut Rng,
) -> Result<(Vec<Buf>, usize)> {
    let bs = arch.cu_batch();
    let cap = bs.capacity();
    let idx = epoch_indices(client.examples.len(), cap, rng);
    let used = idx.len();
    match arch {
        ModelArch::Logreg { tags, .. } => {
            let m = keys[0].len();
            let pos: HashMap<u32, usize> =
                keys[0].iter().enumerate().map(|(j, &k)| (k, j)).collect();
            let mut x = vec![0.0f32; cap * m];
            let mut y = vec![0.0f32; cap * tags];
            let mut wgt = vec![0.0f32; cap];
            for (row, &ei) in idx.iter().enumerate() {
                let Example::Bow { words, tags: tg } = &client.examples[ei] else {
                    return Err(Error::Data("logreg needs BOW examples".into()));
                };
                for w in words {
                    if let Some(&j) = pos.get(w) {
                        x[row * m + j] = 1.0;
                    }
                }
                for &t in tg {
                    y[row * tags + t as usize] = 1.0;
                }
                wgt[row] = 1.0;
            }
            Ok((vec![Buf::F32(x), Buf::F32(y), Buf::F32(wgt)], used))
        }
        ModelArch::Mlp { .. } | ModelArch::Cnn { .. } => {
            let mut x = vec![0.0f32; cap * 784];
            let mut y = vec![0i32; cap];
            let mut wgt = vec![0.0f32; cap];
            for (row, &ei) in idx.iter().enumerate() {
                let Example::Image { pixels, label } = &client.examples[ei] else {
                    return Err(Error::Data("image model needs image examples".into()));
                };
                x[row * 784..(row + 1) * 784].copy_from_slice(pixels);
                y[row] = *label as i32;
                wgt[row] = 1.0;
            }
            Ok((vec![Buf::F32(x), Buf::I32(y), Buf::F32(wgt)], used))
        }
        ModelArch::Transformer { shape, .. } => {
            let seq = shape.seq;
            let local: HashMap<u32, i32> = keys[0]
                .iter()
                .enumerate()
                .map(|(j, &k)| (k, j as i32))
                .collect();
            let unk = *local.get(&0).unwrap_or(&0);
            let mut x = vec![0i32; cap * seq];
            let mut y = vec![0i32; cap * seq];
            let mut wgt = vec![0.0f32; cap * seq];
            for (row, &ei) in idx.iter().enumerate() {
                let Example::Text { tokens } = &client.examples[ei] else {
                    return Err(Error::Data("transformer needs text examples".into()));
                };
                if tokens.len() < seq + 1 {
                    return Err(Error::Data(format!(
                        "text example too short: {} < {}",
                        tokens.len(),
                        seq + 1
                    )));
                }
                for p in 0..seq {
                    let xi = *local.get(&tokens[p]).unwrap_or(&unk);
                    let yi = *local.get(&tokens[p + 1]).unwrap_or(&unk);
                    x[row * seq + p] = xi;
                    y[row * seq + p] = yi;
                    wgt[row * seq + p] = 1.0;
                }
            }
            Ok((vec![Buf::I32(x), Buf::I32(y), Buf::F32(wgt)], used))
        }
    }
}

/// Build padded eval batches of the arch's eval batch size from a pool of
/// examples (full-model space: no key projection/remapping beyond vocab).
pub fn build_eval_batches(arch: &ModelArch, examples: &[&Example]) -> Result<Vec<Vec<Buf>>> {
    let b = arch.eval_batch();
    let mut out = Vec::new();
    for chunk in examples.chunks(b) {
        match arch {
            ModelArch::Logreg { vocab, tags } => {
                let mut x = vec![0.0f32; b * vocab];
                let mut y = vec![0.0f32; b * tags];
                let mut wgt = vec![0.0f32; b];
                for (row, ex) in chunk.iter().enumerate() {
                    let Example::Bow { words, tags: tg } = ex else {
                        return Err(Error::Data("logreg eval needs BOW".into()));
                    };
                    for &w in words {
                        if (w as usize) < *vocab {
                            x[row * vocab + w as usize] = 1.0;
                        }
                    }
                    for &t in tg {
                        y[row * tags + t as usize] = 1.0;
                    }
                    wgt[row] = 1.0;
                }
                out.push(vec![Buf::F32(x), Buf::F32(y), Buf::F32(wgt)]);
            }
            ModelArch::Mlp { .. } | ModelArch::Cnn { .. } => {
                let mut x = vec![0.0f32; b * 784];
                let mut y = vec![0i32; b];
                let mut wgt = vec![0.0f32; b];
                for (row, ex) in chunk.iter().enumerate() {
                    let Example::Image { pixels, label } = ex else {
                        return Err(Error::Data("image eval needs images".into()));
                    };
                    x[row * 784..(row + 1) * 784].copy_from_slice(pixels);
                    y[row] = *label as i32;
                    wgt[row] = 1.0;
                }
                out.push(vec![Buf::F32(x), Buf::I32(y), Buf::F32(wgt)]);
            }
            ModelArch::Transformer { shape, .. } => {
                let seq = shape.seq;
                let mut x = vec![0i32; b * seq];
                let mut y = vec![0i32; b * seq];
                let mut wgt = vec![0.0f32; b * seq];
                for (row, ex) in chunk.iter().enumerate() {
                    let Example::Text { tokens } = ex else {
                        return Err(Error::Data("transformer eval needs text".into()));
                    };
                    for p in 0..seq {
                        x[row * seq + p] = tokens[p] as i32;
                        y[row * seq + p] = tokens[p + 1] as i32;
                        wgt[row * seq + p] = 1.0;
                    }
                }
                out.push(vec![Buf::I32(x), Buf::I32(y), Buf::F32(wgt)]);
            }
        }
    }
    Ok(out)
}

/// Client-side peak memory estimate in bytes: sub-model + batch + one
/// gradient-sized buffer (what the paper's client memory argument counts).
pub fn client_memory_bytes(slice_floats: usize, batch: &[Buf]) -> usize {
    let batch_bytes: usize = batch.iter().map(|b| b.bytes()).sum();
    slice_floats * 4 * 2 + batch_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::bow::{generate, BowConfig};

    #[test]
    fn logreg_batch_projects_onto_keys() {
        let ds = generate(&BowConfig::new(64, 8).with_clients(2, 0, 0));
        let arch = ModelArch::Logreg { vocab: 64, tags: 8 };
        let client = &ds.train[0];
        let keys = vec![client.features_by_frequency()[..4.min(client.feature_counts.len())].to_vec()];
        let mut rng = Rng::new(1, 0);
        let (batch, used) = build_cu_batch(&arch, client, &keys, &mut rng).unwrap();
        assert!(used > 0);
        let cap = arch.cu_batch().capacity();
        let m = keys[0].len();
        let x = batch[0].as_f32().unwrap();
        assert_eq!(x.len(), cap * m);
        // at least one selected word must appear
        assert!(x.iter().any(|&v| v == 1.0));
        // padding rows have zero weight
        let wgt = batch[2].as_f32().unwrap();
        assert_eq!(wgt.iter().filter(|&&w| w > 0.0).count(), used);
    }

    #[test]
    fn transformer_batch_remaps_to_local_ids() {
        use crate::data::text::{generate as gen_text, TextConfig};
        let cfg = TextConfig::new(128, 20).with_clients(2, 0, 0);
        let ds = gen_text(&cfg);
        let arch = ModelArch::transformer();
        let client = &ds.train[0];
        // keys: UNK + top-7 local tokens
        let mut keys0 = vec![0u32];
        for f in client.features_by_frequency() {
            if f != 0 && keys0.len() < 8 {
                keys0.push(f);
            }
        }
        let keys = vec![keys0.clone(), (0..16u32).collect()];
        let mut rng = Rng::new(1, 0);
        let (batch, _) = build_cu_batch(&arch, client, &keys, &mut rng).unwrap();
        let x = batch[0].as_i32().unwrap();
        // every id must be a valid local slice index
        assert!(x.iter().all(|&v| (v as usize) < keys0.len()));
    }

    #[test]
    fn eval_batches_cover_all_examples() {
        let ds = generate(&BowConfig::new(64, 8).with_clients(4, 0, 2));
        let arch = ModelArch::Logreg { vocab: 64, tags: 8 };
        let pool: Vec<&Example> = ds.test.iter().flat_map(|c| c.examples.iter()).collect();
        let batches = build_eval_batches(&arch, &pool).unwrap();
        let total_w: f32 = batches
            .iter()
            .map(|b| b[2].as_f32().unwrap().iter().sum::<f32>())
            .sum();
        assert_eq!(total_w as usize, pool.len());
    }

    #[test]
    fn memory_accounting_scales_with_slice() {
        let b = [Buf::F32(vec![0.0; 100])];
        assert!(client_memory_bytes(1000, &b) > client_memory_bytes(10, &b));
    }
}

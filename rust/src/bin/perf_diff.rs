//! Perf-trajectory gate: diff `fedselect-bench-v1` JSON between a committed
//! baseline and the current bench run.
//!
//! ```text
//! perf_diff <baseline_dir> <current_dir> [--threshold 0.15] [--sim-only] [--report]
//! ```
//!
//! For every `BENCH_*.json` present in *both* directories, every derived
//! metric is compared by name: throughput metrics (`*_per_s`) regress when
//! the current value drops more than `threshold` below the baseline;
//! simulated-time metrics (`sim_round_s`, `sim_total_s`) and stall
//! metrics (`*_stall_ms`, e.g. the executor's merge stall) regress when
//! they *rise* more than `threshold` above it. Counters (`discarded`) are
//! informational. Other wall times are ignored — CI hosts are too noisy;
//! the derived metrics are the trajectory. Note that throughput and stall
//! metrics are still host-speed-dependent: on heterogeneous CI runners
//! pass `--sim-only` to gate only the deterministic simulated-time
//! metrics and report the rest informationally. Exit status 1 on any
//! regression; missing baselines are a note, not a failure (first run
//! seeds them).
//!
//! `--report` additionally prints a per-metric summary table — baseline,
//! current, and signed delta for *every* numeric metric in every bench
//! file, gated or not — even when all gates pass. Use it to eyeball the
//! full trajectory rather than just the pass/fail verdict.
//!
//! Refresh the baseline by copying the current `BENCH_*.json` files into
//! the baseline directory and committing them.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fedselect::metrics::Table;
use fedselect::util::json::Json;
use fedselect::{obs_error, obs_info};

const DEFAULT_THRESHOLD: f64 = 0.15;

/// Metrics where smaller is worse (throughput).
fn higher_is_better(key: &str) -> bool {
    key.ends_with("_per_s")
}

/// Metrics where larger is worse: simulated latency, plus host-side stall
/// metrics (`merge_stall_ms` from the pipelined executor and friends).
fn lower_is_better(key: &str) -> bool {
    key == "sim_round_s" || key == "sim_total_s" || key.ends_with("_stall_ms")
}

/// Metrics whose absolute value depends on host speed; informational
/// under `--sim-only`.
fn host_dependent(key: &str) -> bool {
    key.ends_with("_per_s") || key.ends_with("_stall_ms")
}

/// name -> (metric key -> value), from the "metrics" array.
fn load_metrics(path: &Path) -> Result<Vec<(String, Vec<(String, f64)>)>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some("fedselect-bench-v1") => {}
        other => return Err(format!("{}: unexpected schema {other:?}", path.display())),
    }
    let mut out = Vec::new();
    for entry in doc.get("metrics").and_then(Json::as_arr).unwrap_or(&[]) {
        let Some(name) = entry.get("name").and_then(Json::as_str) else {
            continue;
        };
        let Json::Obj(map) = entry else { continue };
        let mut metrics = Vec::new();
        for (k, v) in map {
            if k == "name" {
                continue;
            }
            if let Some(x) = v.as_f64() {
                metrics.push((k.clone(), x));
            }
        }
        out.push((name.to_string(), metrics));
    }
    Ok(out)
}

fn bench_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .into_iter()
        .flatten()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    files
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut positional = Vec::new();
    let mut threshold = DEFAULT_THRESHOLD;
    let mut sim_only = false;
    let mut report = false;
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        if a == "--sim-only" {
            sim_only = true;
        } else if a == "--report" {
            report = true;
        } else if a == "--threshold" {
            let v = it.next().ok_or("--threshold needs a value")?;
            threshold = v.parse().map_err(|e| format!("bad --threshold {v:?}: {e}"))?;
        } else if let Some(v) = a.strip_prefix("--threshold=") {
            threshold = v.parse().map_err(|e| format!("bad --threshold {v:?}: {e}"))?;
        } else {
            positional.push(a);
        }
    }
    let [baseline_dir, current_dir] = positional.as_slice() else {
        return Err(
            "usage: perf_diff <baseline_dir> <current_dir> [--threshold 0.15] \
             [--sim-only] [--report]"
                .into(),
        );
    };
    let baseline_dir = Path::new(baseline_dir);
    let current_dir = Path::new(current_dir);

    let baselines = bench_files(baseline_dir);
    if baselines.is_empty() {
        obs_info!(
            "perf_diff: no BENCH_*.json baselines in {} — nothing to compare \
             (copy the current run there to seed the trajectory)",
            baseline_dir.display()
        );
        return Ok(false);
    }

    let mut regressed = false;
    let mut compared = 0usize;
    let mut summary = Table::new(
        "Perf summary (baseline -> current)",
        &["bench", "metric", "key", "baseline", "current", "delta", "gate"],
    );
    for base_path in &baselines {
        let file = base_path.file_name().expect("bench file name");
        let cur_path = current_dir.join(file);
        if !cur_path.exists() {
            obs_info!(
                "perf_diff: {} missing from {} — skipped",
                file.to_string_lossy(),
                current_dir.display()
            );
            continue;
        }
        let base = load_metrics(base_path)?;
        let cur = load_metrics(&cur_path)?;
        for (name, metrics) in &base {
            let Some((_, cur_metrics)) = cur.iter().find(|(n, _)| n == name) else {
                obs_info!("perf_diff: {name} absent from current run — skipped");
                continue;
            };
            for (key, base_val) in metrics {
                let Some(cur_val) =
                    cur_metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
                else {
                    continue;
                };
                let gated = (higher_is_better(key) || lower_is_better(key))
                    && *base_val > 0.0
                    && !(sim_only && host_dependent(key));
                let (bad, arrow) = if gated && higher_is_better(key) {
                    (cur_val < base_val * (1.0 - threshold), "dropped")
                } else if gated {
                    (cur_val > base_val * (1.0 + threshold), "rose")
                } else {
                    (false, "")
                };
                compared += 1;
                if bad {
                    regressed = true;
                    obs_info!(
                        "REGRESSION {name} {key}: {arrow} {base_val:.2} -> {cur_val:.2} \
                         (>{:.0}%)",
                        threshold * 100.0
                    );
                } else if higher_is_better(key) || lower_is_better(key) {
                    obs_info!("ok {name} {key}: {base_val:.2} -> {cur_val:.2}");
                }
                if report {
                    let delta = if *base_val != 0.0 {
                        format!("{:+.1}%", (cur_val - base_val) / base_val * 100.0)
                    } else {
                        format!("{:+.2}", cur_val - base_val)
                    };
                    let file_stem = base_path
                        .file_stem()
                        .and_then(|s| s.to_str())
                        .unwrap_or("?")
                        .trim_start_matches("BENCH_")
                        .to_string();
                    summary.push(vec![
                        file_stem,
                        name.clone(),
                        key.clone(),
                        format!("{base_val:.3}"),
                        format!("{cur_val:.3}"),
                        delta,
                        if bad {
                            "FAIL".to_string()
                        } else if gated {
                            "ok".to_string()
                        } else {
                            "-".to_string()
                        },
                    ]);
                }
            }
        }
    }
    if report && !summary.rows.is_empty() {
        obs_info!("{}", summary.to_pretty());
    }
    obs_info!(
        "perf_diff: {compared} metric comparisons, threshold {:.0}%{}",
        threshold * 100.0,
        if regressed { " — REGRESSED" } else { "" }
    );
    Ok(regressed)
}

fn main() -> ExitCode {
    match run() {
        Ok(false) => ExitCode::SUCCESS,
        Ok(true) => ExitCode::from(1),
        Err(e) => {
            obs_error!("perf_diff: {e}");
            ExitCode::from(2)
        }
    }
}

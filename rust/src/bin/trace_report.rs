//! `trace_report` — offline analysis of `fedselect-trace-v1` JSONL traces.
//!
//! ```text
//! trace_report <trace.jsonl>            validate + per-phase/per-tier report
//! trace_report --incidents <trace.jsonl>    health-incident timeline + drill-down
//! trace_report --diff <a.jsonl> <b.jsonl>   compare sim-time content
//! ```
//!
//! Report mode validates every line against the versioned schema and
//! renders the run shape (rounds, namespaces, event counts), the
//! per-phase profile (span counts, host wall time, simulated time), a
//! per-tier rollup of pipelined-executor `task` spans when present
//! (count, total/mean/max host wall, mean completion sim-time), and the
//! per-tier client lifecycle rollup (selected → fetched → computed →
//! merged/dropped/discarded/deferred, with wire bytes and cache hits).
//!
//! Incidents mode lists the health monitor's `incident` lifecycle events
//! (open/update/resolve) as a timeline, then drills each incident down
//! into its covered round window, correlating against the `round_close`
//! ledger (drops, deferrals, mean eligibility, simulated time) so a
//! burning SLO can be read next to what the fleet was doing.
//!
//! Diff mode strips the nondeterministic `wall_ms` fields and `log`
//! events, then compares the remaining (sim-clock) content line by line:
//! two same-seed runs must be byte-identical here, so a non-empty diff
//! means the trajectory diverged. Exit status: 0 clean, 1 divergence or
//! invalid trace, 2 usage/IO error.

use std::collections::BTreeSet;
use std::process::ExitCode;

use fedselect::metrics::{human_bytes, Table};
use fedselect::obs::trace::{diff_traces, validate_trace_line};
use fedselect::util::json::Json;
use fedselect::{obs_error, obs_info};

/// Validate every line of a trace file and return the parsed events
/// (header line excluded).
fn load(path: &str) -> Result<Vec<Json>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        validate_trace_line(line).map_err(|e| format!("{path}:{}: {e}", i + 1))?;
        let ev = Json::parse(line).map_err(|e| format!("{path}:{}: {e}", i + 1))?;
        if ev.get("t").and_then(Json::as_str) != Some("header") {
            events.push(ev);
        }
    }
    Ok(events)
}

fn tag(ev: &Json) -> &str {
    ev.get("t").and_then(Json::as_str).unwrap_or("?")
}

fn f(ev: &Json, key: &str) -> f64 {
    ev.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn u(ev: &Json, key: &str) -> u64 {
    f(ev, key) as u64
}

/// Per-round phase order of the trace schema.
const PHASES: [&str; 5] = ["plan", "fetch", "compute", "close", "eval"];

fn report(path: &str) -> Result<(), String> {
    let events = load(path)?;

    let rounds = events.iter().filter(|e| tag(e) == "round_close").count();
    let namespaces: BTreeSet<u64> = events
        .iter()
        .filter(|e| e.get("ns").is_some())
        .map(|e| u(e, "ns"))
        .collect();
    obs_info!(
        "{path}: {} events | {rounds} round closes | {} namespace(s)",
        events.len(),
        namespaces.len()
    );

    // per-phase profile over the span events
    let mut phases = Table::new(
        "Phase profile",
        &["phase", "spans", "wall_total_ms", "wall_mean_ms", "sim_total_s"],
    );
    for phase in PHASES {
        let spans: Vec<&Json> = events
            .iter()
            .filter(|e| tag(e) == "span" && e.get("phase").and_then(Json::as_str) == Some(phase))
            .collect();
        if spans.is_empty() {
            continue;
        }
        let wall: f64 = spans.iter().map(|e| f(e, "wall_ms")).sum();
        let sim: f64 = spans.iter().map(|e| f(e, "sim_s")).sum();
        phases.push(vec![
            phase.to_string(),
            spans.len().to_string(),
            format!("{wall:.2}"),
            format!("{:.3}", wall / spans.len() as f64),
            format!("{sim:.2}"),
        ]);
    }
    obs_info!("{}", phases.to_pretty());

    // per-tier task rollup over the pipelined-executor `task` spans: one
    // per surviving cohort slot, overlapping in host time, so wall totals
    // here can exceed the round's span-union wall_ms
    let tasks: Vec<&Json> = events.iter().filter(|e| tag(e) == "task").collect();
    if !tasks.is_empty() {
        let task_tiers: BTreeSet<u64> = tasks.iter().map(|e| u(e, "tier")).collect();
        let mut task_table = Table::new(
            "Task spans by tier",
            &["tier", "tasks", "wall_total_ms", "wall_mean_ms", "wall_max_ms", "sim_mean_s"],
        );
        for tier in &task_tiers {
            let of_tier: Vec<&&Json> =
                tasks.iter().filter(|e| u(e, "tier") == *tier).collect();
            let wall: f64 = of_tier.iter().map(|e| f(e, "wall_ms")).sum();
            let max: f64 = of_tier.iter().map(|e| f(e, "wall_ms")).fold(0.0, f64::max);
            let sim: f64 = of_tier.iter().map(|e| f(e, "sim_s")).sum();
            let n = of_tier.len() as f64;
            task_table.push(vec![
                format!("t{tier}"),
                of_tier.len().to_string(),
                format!("{wall:.2}"),
                format!("{:.3}", wall / n),
                format!("{max:.3}"),
                format!("{:.2}", sim / n),
            ]);
        }
        obs_info!("{}", task_table.to_pretty());
    }

    // per-tier client lifecycle rollup ("-" collects events with no tier,
    // e.g. committee reconstruction-path dropouts)
    let clients: Vec<&Json> = events.iter().filter(|e| tag(e) == "client").collect();
    let tiers: BTreeSet<Option<u64>> = clients
        .iter()
        .map(|e| e.get("tier").and_then(Json::as_f64).map(|t| t as u64))
        .collect();
    let mut lifecycle = Table::new(
        "Client lifecycle by tier",
        &[
            "tier", "selected", "fetched", "dropped", "computed", "merged", "discarded",
            "deferred", "committee_keyed", "down", "cache_hit_pieces",
        ],
    );
    for tier in &tiers {
        let of_tier: Vec<&&Json> = clients
            .iter()
            .filter(|e| e.get("tier").and_then(Json::as_f64).map(|t| t as u64) == *tier)
            .collect();
        let count = |stage: &str| -> usize {
            of_tier
                .iter()
                .filter(|e| e.get("stage").and_then(Json::as_str) == Some(stage))
                .count()
        };
        let down: u64 = of_tier.iter().map(|e| u(e, "down_bytes")).sum();
        let hits: u64 = of_tier.iter().map(|e| u(e, "cache_hit_pieces")).sum();
        lifecycle.push(vec![
            tier.map_or("-".to_string(), |t| format!("t{t}")),
            count("selected").to_string(),
            count("fetched").to_string(),
            count("dropped").to_string(),
            count("computed").to_string(),
            count("merged").to_string(),
            count("discarded").to_string(),
            count("deferred").to_string(),
            count("committee_keyed").to_string(),
            human_bytes(down),
            hits.to_string(),
        ]);
    }
    if !lifecycle.rows.is_empty() {
        obs_info!("{}", lifecycle.to_pretty());
    }
    Ok(())
}

fn s<'a>(ev: &'a Json, key: &str) -> &'a str {
    ev.get(key).and_then(Json::as_str).unwrap_or("?")
}

/// Health-incident timeline plus per-incident drill-down into the
/// covered round window of the `round_close` ledger.
fn incidents(path: &str) -> Result<(), String> {
    let events = load(path)?;
    let incs: Vec<&Json> = events.iter().filter(|e| tag(e) == "incident").collect();
    if incs.is_empty() {
        obs_info!("{path}: no incident events (health monitor off, or the fleet stayed healthy)");
        return Ok(());
    }
    let mut timeline = Table::new(
        "Incident timeline",
        &["round", "id", "action", "severity", "rule", "observed", "expected", "sim_s"],
    );
    for ev in &incs {
        timeline.push(vec![
            u(ev, "round").to_string(),
            u(ev, "id").to_string(),
            s(ev, "action").to_string(),
            s(ev, "severity").to_string(),
            s(ev, "rule").to_string(),
            format!("{:.4}", f(ev, "observed")),
            format!("{:.4}", f(ev, "expected")),
            format!("{:.2}", f(ev, "sim_s")),
        ]);
    }
    obs_info!("{}", timeline.to_pretty());

    // drill-down: per incident id, the covered rounds correlated with the
    // round_close ledger — what the fleet was doing while the rule burned
    let ids: BTreeSet<u64> = incs.iter().map(|e| u(e, "id")).collect();
    let mut drill = Table::new(
        "Incident drill-down",
        &[
            "id", "severity", "rule", "window", "rounds", "dropped", "deferred",
            "eligible_mean", "sim_s",
        ],
    );
    for id in &ids {
        let of_id: Vec<&&Json> = incs.iter().filter(|e| u(e, "id") == *id).collect();
        let lo = of_id.iter().map(|e| u(e, "round")).min().unwrap_or(0);
        let hi = of_id.iter().map(|e| u(e, "round")).max().unwrap_or(0);
        let resolved = of_id.iter().any(|e| s(e, "action") == "resolve");
        let closes: Vec<&Json> = events
            .iter()
            .filter(|e| tag(e) == "round_close" && u(e, "round") >= lo && u(e, "round") <= hi)
            .collect();
        let dropped: u64 = closes.iter().map(|e| u(e, "dropped")).sum();
        let deferred: u64 = closes.iter().map(|e| u(e, "deferred")).sum();
        let eligible: u64 = closes.iter().map(|e| u(e, "eligible")).sum();
        let sim: f64 = closes.iter().map(|e| f(e, "sim_round_s")).sum();
        let n = closes.len().max(1) as f64;
        drill.push(vec![
            id.to_string(),
            s(of_id[0], "severity").to_string(),
            s(of_id[0], "rule").to_string(),
            format!("r{lo}..r{hi}{}", if resolved { "" } else { " (open)" }),
            closes.len().to_string(),
            dropped.to_string(),
            deferred.to_string(),
            format!("{:.1}", eligible as f64 / n),
            format!("{sim:.2}"),
        ]);
    }
    obs_info!("{}", drill.to_pretty());
    Ok(())
}

fn diff(a_path: &str, b_path: &str) -> Result<bool, String> {
    let a = std::fs::read_to_string(a_path).map_err(|e| format!("cannot read {a_path}: {e}"))?;
    let b = std::fs::read_to_string(b_path).map_err(|e| format!("cannot read {b_path}: {e}"))?;
    match diff_traces(&a, &b) {
        Some(msg) => {
            obs_info!("trace divergence: {msg}");
            Ok(true)
        }
        None => {
            obs_info!("traces agree on sim-time content ({a_path} vs {b_path})");
            Ok(false)
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let result = match refs.as_slice() {
        ["--diff", a, b] => diff(a, b).map(|diverged| diverged as u8),
        ["--incidents", path] => incidents(path).map(|()| 0),
        [path] if !path.starts_with("--") => report(path).map(|()| 0),
        _ => Err(
            "usage: trace_report <trace.jsonl> | trace_report --incidents <trace.jsonl> | \
             trace_report --diff <a> <b>"
                .to_string(),
        ),
    };
    match result {
        Ok(code) => ExitCode::from(code),
        Err(e) => {
            obs_error!("trace_report: {e}");
            let usage_or_io = e.contains("usage:") || e.contains("cannot read");
            ExitCode::from(if usage_or_io { 2 } else { 1 })
        }
    }
}

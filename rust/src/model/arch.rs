//! The four experiment model families and their FedSelect specifications.
//!
//! Shapes here must match `python/compile/aot.py` exactly — the manifest is
//! cross-checked at runtime, and `rust/tests/pjrt_parity.rs` pins numerics.

use super::{Binding, KeyMap, Keyspace, ParamStore, Segment, SelectSpec};
use crate::tensor::rng::Rng;

/// Static training-batch geometry of a client-update artifact.
#[derive(Clone, Copy, Debug)]
pub struct BatchSpec {
    /// SGD steps per local epoch (scan length).
    pub steps: usize,
    /// Minibatch size per step.
    pub mb: usize,
}

impl BatchSpec {
    pub fn capacity(&self) -> usize {
        self.steps * self.mb
    }
}

/// Transformer shape configuration (mirrors `model.TransformerCfg`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerShape {
    pub vocab: usize,
    pub d: usize,
    pub seq: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
}

/// Model family + full-model hyperparameters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelArch {
    /// Multi-label logistic regression over a `vocab`-word BOW, `tags` labels.
    Logreg { vocab: usize, tags: usize },
    /// 2NN: 784 -> K -> hidden -> classes, hidden-1 neurons keyed (K = 200).
    Mlp {
        neurons: usize,
        hidden: usize,
        classes: usize,
    },
    /// CNN: conv(32) -> conv(`filters`, keyed) -> dense 512 -> classes.
    Cnn { filters: usize, classes: usize },
    /// Next-word-prediction transformer; `prefix` selects the artifact family
    /// ("tf" for the §5.4 grid, "e2e" for the large end-to-end driver).
    Transformer {
        shape: TransformerShape,
        prefix: &'static str,
    },
}

impl ModelArch {
    // -- canonical experiment configurations (match aot.py) ----------------

    pub fn logreg(vocab: usize) -> Self {
        ModelArch::Logreg { vocab, tags: 50 }
    }

    pub fn mlp2nn() -> Self {
        ModelArch::Mlp {
            neurons: 200,
            hidden: 200,
            classes: 62,
        }
    }

    pub fn cnn() -> Self {
        ModelArch::Cnn {
            filters: 64,
            classes: 62,
        }
    }

    pub fn transformer() -> Self {
        ModelArch::Transformer {
            shape: TransformerShape {
                vocab: 2048,
                d: 128,
                seq: 20,
                layers: 2,
                heads: 4,
                ffn: 512,
            },
            prefix: "tf",
        }
    }

    pub fn transformer_e2e() -> Self {
        ModelArch::Transformer {
            shape: TransformerShape {
                vocab: 65536,
                d: 256,
                seq: 32,
                layers: 4,
                heads: 8,
                ffn: 1024,
            },
            prefix: "e2e",
        }
    }

    /// Keyspace count: 1 for row/filter/neuron models, 2 for the transformer
    /// (0 = structured vocab keys, 1 = random FFN keys).
    pub fn num_keyspaces(&self) -> usize {
        match self {
            ModelArch::Transformer { .. } => 2,
            _ => 1,
        }
    }

    /// Client-update batch geometry (matches aot.py).
    pub fn cu_batch(&self) -> BatchSpec {
        match self {
            ModelArch::Logreg { .. } => BatchSpec { steps: 4, mb: 16 },
            ModelArch::Mlp { .. } => BatchSpec { steps: 4, mb: 16 },
            ModelArch::Cnn { .. } => BatchSpec { steps: 2, mb: 10 },
            ModelArch::Transformer { .. } => BatchSpec { steps: 2, mb: 8 },
        }
    }

    /// Eval artifact batch size.
    pub fn eval_batch(&self) -> usize {
        match self {
            ModelArch::Logreg { .. } | ModelArch::Mlp { .. } => 256,
            ModelArch::Cnn { .. } => 64,
            ModelArch::Transformer { prefix, .. } => {
                if *prefix == "e2e" {
                    4
                } else {
                    32
                }
            }
        }
    }

    /// Client-update artifact name for per-keyspace key counts `ms`.
    pub fn cu_name(&self, ms: &[usize]) -> String {
        match self {
            ModelArch::Logreg { .. } => format!("logreg_cu_m{}", ms[0]),
            ModelArch::Mlp { .. } => format!("mlp_cu_m{}", ms[0]),
            ModelArch::Cnn { .. } => format!("cnn_cu_m{}", ms[0]),
            ModelArch::Transformer { prefix, .. } => {
                if *prefix == "e2e" {
                    "e2e_cu".to_string()
                } else {
                    format!("tf_cu_v{}_h{}", ms[0], ms[1])
                }
            }
        }
    }

    /// Eval artifact name.
    pub fn eval_name(&self) -> String {
        match self {
            ModelArch::Logreg { vocab, .. } => format!("logreg_eval_n{vocab}"),
            ModelArch::Mlp { .. } => "mlp_eval".to_string(),
            ModelArch::Cnn { .. } => "cnn_eval".to_string(),
            ModelArch::Transformer { prefix, .. } => format!("{prefix}_eval"),
        }
    }

    /// Initialize the full server model. Distributions mirror the python
    /// inits (exact bit-equality is not required — the server owns init).
    pub fn init_store(&self, rng: &mut Rng) -> ParamStore {
        match *self {
            ModelArch::Logreg { vocab, tags } => {
                let mut w = Segment::zeros("w", &[vocab, tags]);
                for v in &mut w.data {
                    *v = rng.normal() * 0.01;
                }
                let b = Segment::zeros("b", &[tags]);
                ParamStore {
                    segments: vec![w, b],
                }
            }
            ModelArch::Mlp {
                neurons,
                hidden,
                classes,
            } => {
                let mut segs = Vec::new();
                segs.push(glorot(rng, "w1", 784, neurons));
                segs.push(Segment::zeros("b1", &[neurons]));
                segs.push(glorot(rng, "w2", neurons, hidden));
                segs.push(Segment::zeros("b2", &[hidden]));
                segs.push(glorot(rng, "w3", hidden, classes));
                segs.push(Segment::zeros("b3", &[classes]));
                ParamStore { segments: segs }
            }
            ModelArch::Cnn { filters, classes } => {
                let mut segs = Vec::new();
                segs.push(he(rng, "k1", &[5, 5, 1, 32], 25));
                segs.push(Segment::zeros("c1", &[32]));
                segs.push(he(rng, "k2", &[5, 5, 32, filters], 25 * 32));
                segs.push(Segment::zeros("c2", &[filters]));
                segs.push(he(rng, "w1", &[49 * filters, 512], 49 * filters));
                segs.push(Segment::zeros("d1", &[512]));
                segs.push(he(rng, "w2", &[512, classes], 512));
                segs.push(Segment::zeros("d2", &[classes]));
                ParamStore { segments: segs }
            }
            ModelArch::Transformer { shape, .. } => {
                let TransformerShape {
                    vocab,
                    d,
                    seq,
                    layers,
                    ffn,
                    ..
                } = shape;
                let mut segs = Vec::new();
                segs.push(fan_in_normal(rng, "emb", &[vocab, d], vocab));
                segs.push(scaled_normal(rng, "pos", &[seq, d], 0.02));
                for l in 0..layers {
                    segs.push(ones(&format!("l{l}_ln1_s"), &[d]));
                    segs.push(Segment::zeros(&format!("l{l}_ln1_b"), &[d]));
                    for nm in ["wq", "wk", "wv", "wo"] {
                        segs.push(fan_in_normal(rng, &format!("l{l}_{nm}"), &[d, d], d));
                    }
                    segs.push(ones(&format!("l{l}_ln2_s"), &[d]));
                    segs.push(Segment::zeros(&format!("l{l}_ln2_b"), &[d]));
                    segs.push(fan_in_normal(rng, &format!("l{l}_w1"), &[d, ffn], d));
                    segs.push(Segment::zeros(&format!("l{l}_bf1"), &[ffn]));
                    segs.push(fan_in_normal(rng, &format!("l{l}_w2"), &[ffn, d], ffn));
                    segs.push(Segment::zeros(&format!("l{l}_bf2"), &[d]));
                }
                segs.push(ones("lnf_s", &[d]));
                segs.push(Segment::zeros("lnf_b", &[d]));
                segs.push(fan_in_normal(rng, "wout", &[d, vocab], d));
                segs.push(Segment::zeros("bout", &[vocab]));
                ParamStore { segments: segs }
            }
        }
    }

    /// Build the SelectSpec matching the artifact parameter order.
    pub fn select_spec(&self) -> SelectSpec {
        match *self {
            ModelArch::Logreg { vocab, tags } => SelectSpec {
                bindings: vec![
                    Binding::Keyed {
                        seg: 0,
                        keyspace: 0,
                        map: KeyMap::rows(vocab, tags),
                    },
                    Binding::Full { seg: 1 },
                ],
                keyspaces: vec![Keyspace {
                    name: "vocab".into(),
                    size: vocab,
                }],
            },
            ModelArch::Mlp {
                neurons, hidden, ..
            } => SelectSpec {
                bindings: vec![
                    Binding::Keyed {
                        seg: 0,
                        keyspace: 0,
                        map: KeyMap::cols(784, neurons),
                    },
                    Binding::Keyed {
                        seg: 1,
                        keyspace: 0,
                        map: KeyMap::rows(neurons, 1),
                    },
                    Binding::Keyed {
                        seg: 2,
                        keyspace: 0,
                        map: KeyMap::rows(neurons, hidden),
                    },
                    Binding::Full { seg: 3 },
                    Binding::Full { seg: 4 },
                    Binding::Full { seg: 5 },
                ],
                keyspaces: vec![Keyspace {
                    name: "neurons".into(),
                    size: neurons,
                }],
            },
            ModelArch::Cnn { filters, .. } => SelectSpec {
                bindings: vec![
                    Binding::Full { seg: 0 },
                    Binding::Full { seg: 1 },
                    Binding::Keyed {
                        seg: 2,
                        keyspace: 0,
                        map: KeyMap::cols(5 * 5 * 32, filters),
                    },
                    Binding::Keyed {
                        seg: 3,
                        keyspace: 0,
                        map: KeyMap::rows(filters, 1),
                    },
                    Binding::Keyed {
                        seg: 4,
                        keyspace: 0,
                        map: KeyMap::grouped_rows(49, filters, 512),
                    },
                    Binding::Full { seg: 5 },
                    Binding::Full { seg: 6 },
                    Binding::Full { seg: 7 },
                ],
                keyspaces: vec![Keyspace {
                    name: "filters".into(),
                    size: filters,
                }],
            },
            ModelArch::Transformer { shape, .. } => {
                let TransformerShape {
                    vocab,
                    d,
                    layers,
                    ffn,
                    ..
                } = shape;
                let mut bindings = Vec::new();
                // emb [vocab, d]: structured rows
                bindings.push(Binding::Keyed {
                    seg: 0,
                    keyspace: 0,
                    map: KeyMap::rows(vocab, d),
                });
                bindings.push(Binding::Full { seg: 1 }); // pos
                let mut seg = 2;
                for _ in 0..layers {
                    for _ in 0..8 {
                        // ln1_s, ln1_b, wq, wk, wv, wo, ln2_s, ln2_b
                        bindings.push(Binding::Full { seg });
                        seg += 1;
                    }
                    // w1 [d, ffn]: random FFN cols
                    bindings.push(Binding::Keyed {
                        seg,
                        keyspace: 1,
                        map: KeyMap::cols(d, ffn),
                    });
                    seg += 1;
                    // bf1 [ffn]
                    bindings.push(Binding::Keyed {
                        seg,
                        keyspace: 1,
                        map: KeyMap::rows(ffn, 1),
                    });
                    seg += 1;
                    // w2 [ffn, d]: random FFN rows
                    bindings.push(Binding::Keyed {
                        seg,
                        keyspace: 1,
                        map: KeyMap::rows(ffn, d),
                    });
                    seg += 1;
                    // bf2 [d]
                    bindings.push(Binding::Full { seg });
                    seg += 1;
                }
                bindings.push(Binding::Full { seg }); // lnf_s
                bindings.push(Binding::Full { seg: seg + 1 }); // lnf_b
                // wout [d, vocab]: structured cols (tied keyspace with emb)
                bindings.push(Binding::Keyed {
                    seg: seg + 2,
                    keyspace: 0,
                    map: KeyMap::cols(d, vocab),
                });
                bindings.push(Binding::Keyed {
                    seg: seg + 3,
                    keyspace: 0,
                    map: KeyMap::rows(vocab, 1),
                });
                SelectSpec {
                    bindings,
                    keyspaces: vec![
                        Keyspace {
                            name: "vocab".into(),
                            size: vocab,
                        },
                        Keyspace {
                            name: "ffn".into(),
                            size: ffn,
                        },
                    ],
                }
            }
        }
    }
}

fn glorot(rng: &mut Rng, name: &str, fi: usize, fo: usize) -> Segment {
    let mut s = Segment::zeros(name, &[fi, fo]);
    let std = (2.0 / (fi + fo) as f32).sqrt();
    for v in &mut s.data {
        *v = rng.normal() * std;
    }
    s
}

fn he(rng: &mut Rng, name: &str, shape: &[usize], fan_in: usize) -> Segment {
    let mut s = Segment::zeros(name, shape);
    let std = (2.0 / fan_in as f32).sqrt();
    for v in &mut s.data {
        *v = rng.normal() * std;
    }
    s
}

fn fan_in_normal(rng: &mut Rng, name: &str, shape: &[usize], fan_in: usize) -> Segment {
    let mut s = Segment::zeros(name, shape);
    let std = 1.0 / (fan_in as f32).sqrt();
    for v in &mut s.data {
        *v = rng.normal() * std;
    }
    s
}

fn scaled_normal(rng: &mut Rng, name: &str, shape: &[usize], std: f32) -> Segment {
    let mut s = Segment::zeros(name, shape);
    for v in &mut s.data {
        *v = rng.normal() * std;
    }
    s
}

fn ones(name: &str, shape: &[usize]) -> Segment {
    let mut s = Segment::zeros(name, shape);
    s.data.fill(1.0);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_validate_against_inits() {
        let mut rng = Rng::new(1, 0);
        for arch in [
            ModelArch::logreg(512),
            ModelArch::mlp2nn(),
            ModelArch::cnn(),
            ModelArch::transformer(),
        ] {
            let store = arch.init_store(&mut rng);
            let spec = arch.select_spec();
            spec.validate(&store)
                .unwrap_or_else(|e| panic!("{arch:?}: {e}"));
            assert_eq!(
                spec.bindings.len(),
                store.segments.len(),
                "{arch:?} binds every segment"
            );
        }
    }

    #[test]
    fn transformer_param_order_matches_python() {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(0, 0));
        let names: Vec<&str> = store.segments.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names[0], "emb");
        assert_eq!(names[1], "pos");
        assert_eq!(names[2], "l0_ln1_s");
        assert_eq!(names[13], "l0_bf2");
        assert_eq!(names[names.len() - 2], "wout");
        assert_eq!(names[names.len() - 1], "bout");
        assert_eq!(names.len(), 2 + 12 * 2 + 4);
    }

    #[test]
    fn client_floats_shrink_with_m() {
        let arch = ModelArch::logreg(512);
        let store = arch.init_store(&mut Rng::new(0, 0));
        let spec = arch.select_spec();
        let full = spec.client_floats(&store, &[512]);
        let small = spec.client_floats(&store, &[64]);
        assert_eq!(full, store.num_params());
        assert!(small < full / 7);
    }

    #[test]
    fn mlp_slice_shapes_match_artifacts() {
        let arch = ModelArch::mlp2nn();
        let store = arch.init_store(&mut Rng::new(0, 0));
        let spec = arch.select_spec();
        let ms = [50usize];
        assert_eq!(spec.sliced_shape(&store, 0, &ms), vec![784, 50]);
        assert_eq!(spec.sliced_shape(&store, 1, &ms), vec![50]);
        assert_eq!(spec.sliced_shape(&store, 2, &ms), vec![50, 200]);
        assert_eq!(spec.sliced_shape(&store, 3, &ms), vec![200]);
    }

    #[test]
    fn cnn_slice_shapes_match_artifacts() {
        let arch = ModelArch::cnn();
        let store = arch.init_store(&mut Rng::new(0, 0));
        let spec = arch.select_spec();
        let ms = [16usize];
        assert_eq!(spec.sliced_shape(&store, 2, &ms), vec![5, 5, 32, 16]);
        assert_eq!(spec.sliced_shape(&store, 3, &ms), vec![16]);
        assert_eq!(spec.sliced_shape(&store, 4, &ms), vec![49 * 16, 512]);
    }

    #[test]
    fn artifact_names() {
        assert_eq!(ModelArch::logreg(2048).cu_name(&[256]), "logreg_cu_m256");
        assert_eq!(ModelArch::logreg(2048).eval_name(), "logreg_eval_n2048");
        assert_eq!(
            ModelArch::transformer().cu_name(&[512, 128]),
            "tf_cu_v512_h128"
        );
        assert_eq!(ModelArch::transformer_e2e().cu_name(&[1024, 256]), "e2e_cu");
    }
}

//! Server model storage and the FedSelect ψ/φ machinery.
//!
//! [`ParamStore`] holds the full server model as named, flat f32 segments in
//! exactly the layouts the AOT artifacts use. [`SelectSpec`] describes, per
//! artifact parameter, whether it is broadcast in full or keyed by one of the
//! model's keyspaces, and implements
//!
//! * ψ — [`SelectSpec::slice`]: materialize a client's sub-model from its
//!   select keys (paper eq. 4), and
//! * φ — [`SelectSpec::deselect_add`]: scatter a client's update back into
//!   full model space (paper eq. 5), tracking per-coordinate counts.
//!
//! A single [`KeyMap`] shape (`groups × keys_total × row_len`) expresses all
//! of the paper's slicing patterns: weight-matrix rows (logreg, embedding),
//! columns (hidden-neuron inputs, output vocab), conv-filter output channels,
//! and channel-grouped dense rows after a flatten (the CNN's coupled slice).

pub mod arch;

pub use arch::ModelArch;

use crate::error::{Error, Result};

/// One named tensor of the server model, flat row-major f32.
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Segment {
    pub fn zeros(name: &str, shape: &[usize]) -> Self {
        Segment {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The full server model: an ordered list of segments. Order matches the
/// parameter order of the model's AOT artifacts.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub segments: Vec<Segment>,
}

impl ParamStore {
    pub fn num_params(&self) -> usize {
        self.segments.iter().map(|s| s.len()).sum()
    }

    pub fn bytes(&self) -> usize {
        self.num_params() * 4
    }

    pub fn seg(&self, name: &str) -> Result<&Segment> {
        self.segments
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| Error::Shape(format!("no segment named {name}")))
    }

    /// Zero-filled clone with identical structure (update accumulators).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            segments: self
                .segments
                .iter()
                .map(|s| Segment::zeros(&s.name, &s.shape))
                .collect(),
        }
    }
}

/// A keyspace `[K]` clients select from (paper §3): e.g. "vocab" or "ffn".
#[derive(Clone, Debug)]
pub struct Keyspace {
    pub name: String,
    pub size: usize,
}

/// How a key indexes into a segment.
///
/// For key `k`, the selected elements are the `groups` runs
/// `[(g * keys_total + k) * row_len .. +row_len)` for `g in 0..groups`.
/// In a slice of `m` keys, key position `j` lands at the runs
/// `[(g * m + j) * row_len ..)` — i.e. the keyed dimension is compacted from
/// `keys_total` to `m` while every other dimension is preserved.
///
/// * rows of `[K, t]`:                `groups=1, row_len=t`
/// * columns of `[R, K]`:             `groups=R, row_len=1`
/// * last axis of `[d0,..,K]`:        `groups=prod(d0..), row_len=1`
/// * channel-grouped rows `[P*K, t]`: `groups=P, row_len=t`
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeyMap {
    pub groups: usize,
    pub keys_total: usize,
    pub row_len: usize,
}

impl KeyMap {
    pub fn rows(keys_total: usize, row_len: usize) -> Self {
        KeyMap {
            groups: 1,
            keys_total,
            row_len,
        }
    }

    pub fn cols(rows: usize, keys_total: usize) -> Self {
        KeyMap {
            groups: rows,
            keys_total,
            row_len: 1,
        }
    }

    pub fn grouped_rows(groups: usize, keys_total: usize, row_len: usize) -> Self {
        KeyMap {
            groups,
            keys_total,
            row_len,
        }
    }

    /// Elements selected per key.
    pub fn per_key(&self) -> usize {
        self.groups * self.row_len
    }

    /// Total elements of the keyed segment.
    pub fn total(&self) -> usize {
        self.groups * self.keys_total * self.row_len
    }

    /// Length of a slice over `m` keys.
    pub fn sliced_len(&self, m: usize) -> usize {
        self.groups * m * self.row_len
    }
}

/// One artifact parameter: broadcast in full or keyed.
#[derive(Clone, Debug)]
pub enum Binding {
    /// Broadcast as-is; aggregated densely.
    Full { seg: usize },
    /// Sliced by the keys of `keyspace` according to `map`.
    Keyed {
        seg: usize,
        keyspace: usize,
        map: KeyMap,
    },
}

impl Binding {
    pub fn seg(&self) -> usize {
        match self {
            Binding::Full { seg } | Binding::Keyed { seg, .. } => *seg,
        }
    }
}

/// The ψ/φ specification for a model family.
#[derive(Clone, Debug)]
pub struct SelectSpec {
    /// In artifact parameter order.
    pub bindings: Vec<Binding>,
    pub keyspaces: Vec<Keyspace>,
}

impl SelectSpec {
    /// Validate against a store (shapes and keyspace sizes line up).
    pub fn validate(&self, store: &ParamStore) -> Result<()> {
        for b in &self.bindings {
            let seg = store
                .segments
                .get(b.seg())
                .ok_or_else(|| Error::Shape(format!("binding references segment {}", b.seg())))?;
            if let Binding::Keyed { keyspace, map, .. } = b {
                if *keyspace >= self.keyspaces.len() {
                    return Err(Error::Shape(format!("keyspace {keyspace} out of range")));
                }
                if map.keys_total != self.keyspaces[*keyspace].size {
                    return Err(Error::Shape(format!(
                        "segment {}: map keys_total {} != keyspace size {}",
                        seg.name, map.keys_total, self.keyspaces[*keyspace].size
                    )));
                }
                if map.total() != seg.len() {
                    return Err(Error::Shape(format!(
                        "segment {}: map total {} != segment len {}",
                        seg.name,
                        map.total(),
                        seg.len()
                    )));
                }
            }
        }
        Ok(())
    }

    /// ψ: materialize the client sub-model for `keys[ks]` per keyspace `ks`.
    /// Returns one flat buffer per binding, in artifact parameter order.
    pub fn slice(&self, store: &ParamStore, keys: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(self.bindings.len());
        for b in &self.bindings {
            match b {
                Binding::Full { seg } => out.push(store.segments[*seg].data.clone()),
                Binding::Keyed { seg, keyspace, map } => {
                    let src = &store.segments[*seg].data;
                    let ks_keys = keys.get(*keyspace).ok_or_else(|| {
                        Error::Shape(format!("missing keys for keyspace {keyspace}"))
                    })?;
                    out.push(slice_one(src, map, ks_keys));
                }
            }
        }
        Ok(out)
    }

    /// Shape of binding `i`'s slice when keyspace key counts are `ms`.
    pub fn sliced_shape(&self, store: &ParamStore, i: usize, ms: &[usize]) -> Vec<usize> {
        match &self.bindings[i] {
            Binding::Full { seg } => store.segments[*seg].shape.clone(),
            Binding::Keyed { seg, keyspace, map } => {
                let m = ms[*keyspace];
                let shape = &store.segments[*seg].shape;
                // replace the keyed axis: the axis whose size == keys_total
                // and whose trailing product == row_len (and grouped-rows
                // segments replace dim0 = groups*keys_total by groups*m).
                sliced_shape_of(shape, map, m)
            }
        }
    }

    /// φ: scatter-add `updates` (artifact output order == binding order) into
    /// `acc`, incrementing `counts` at every touched coordinate.
    pub fn deselect_add(
        &self,
        acc: &mut ParamStore,
        counts: &mut ParamStore,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        if updates.len() != self.bindings.len() {
            return Err(Error::Shape(format!(
                "expected {} update tensors, got {}",
                self.bindings.len(),
                updates.len()
            )));
        }
        for (b, upd) in self.bindings.iter().zip(updates.iter()) {
            match b {
                Binding::Full { seg } => {
                    let dst = &mut acc.segments[*seg].data;
                    let cnt = &mut counts.segments[*seg].data;
                    if upd.len() != dst.len() {
                        return Err(Error::Shape(format!(
                            "dense update len {} != segment len {}",
                            upd.len(),
                            dst.len()
                        )));
                    }
                    for ((d, c), &u) in dst.iter_mut().zip(cnt.iter_mut()).zip(upd.iter()) {
                        *d += u;
                        *c += 1.0;
                    }
                }
                Binding::Keyed { seg, keyspace, map } => {
                    let ks_keys = &keys[*keyspace];
                    let m = ks_keys.len();
                    if upd.len() != map.sliced_len(m) {
                        return Err(Error::Shape(format!(
                            "keyed update len {} != sliced len {}",
                            upd.len(),
                            map.sliced_len(m)
                        )));
                    }
                    let dst = &mut acc.segments[*seg].data;
                    let cnt = &mut counts.segments[*seg].data;
                    deselect_one(dst, cnt, map, ks_keys, upd);
                }
            }
        }
        Ok(())
    }

    /// Floats a client receives for key counts `ms` (per keyspace) —
    /// the client model size of the paper's "relative model size" metric.
    pub fn client_floats(&self, store: &ParamStore, ms: &[usize]) -> usize {
        self.bindings
            .iter()
            .map(|b| match b {
                Binding::Full { seg } => store.segments[*seg].len(),
                Binding::Keyed { keyspace, map, .. } => map.sliced_len(ms[*keyspace]),
            })
            .sum()
    }

    /// Full server-model float count across bound segments.
    pub fn server_floats(&self, store: &ParamStore) -> usize {
        self.bindings
            .iter()
            .map(|b| store.segments[b.seg()].len())
            .sum()
    }

    /// Per-key slice size (floats) of one keyspace, summed over bindings.
    pub fn per_key_floats(&self, keyspace: usize) -> usize {
        self.bindings
            .iter()
            .map(|b| match b {
                Binding::Keyed {
                    keyspace: ks, map, ..
                } if *ks == keyspace => map.per_key(),
                _ => 0,
            })
            .sum()
    }

    /// Floats broadcast regardless of keys.
    pub fn broadcast_floats(&self, store: &ParamStore) -> usize {
        self.bindings
            .iter()
            .map(|b| match b {
                Binding::Full { seg } => store.segments[*seg].len(),
                _ => 0,
            })
            .sum()
    }
}

fn sliced_shape_of(shape: &[usize], map: &KeyMap, m: usize) -> Vec<usize> {
    // Identify the keyed axis from the KeyMap structure.
    let mut out = shape.to_vec();
    if map.groups == 1 {
        // rows: first axis is keys_total (or the only axis)
        out[0] = m;
        return out;
    }
    // trailing product after some axis == row_len and that axis == keys_total
    let mut trail = 1usize;
    for ax in (0..shape.len()).rev() {
        if trail == map.row_len && shape[ax] == map.keys_total {
            // check leading product == groups
            let lead: usize = shape[..ax].iter().product();
            if lead == map.groups {
                out[ax] = m;
                return out;
            }
        }
        trail *= shape[ax];
    }
    // grouped-rows with fused leading dim (CNN dense1: [P*K, t]):
    if shape[0] == map.groups * map.keys_total {
        out[0] = map.groups * m;
        return out;
    }
    panic!("KeyMap {map:?} does not match shape {shape:?}");
}

fn slice_one(src: &[f32], map: &KeyMap, keys: &[u32]) -> Vec<f32> {
    // Destination offsets (g*m + j)*rl are visited strictly sequentially
    // when iterating (g, j) in order, so build by append — no zero-fill
    // pass over the slice (≈12% of fetch wall time at m=1024, §Perf).
    let m = keys.len();
    let rl = map.row_len;
    let mut out = Vec::with_capacity(map.sliced_len(m));
    for g in 0..map.groups {
        let base = g * map.keys_total;
        for &k in keys {
            let s = (base + k as usize) * rl;
            out.extend_from_slice(&src[s..s + rl]);
        }
    }
    debug_assert_eq!(out.len(), map.sliced_len(m));
    out
}

fn deselect_one(dst: &mut [f32], cnt: &mut [f32], map: &KeyMap, keys: &[u32], upd: &[f32]) {
    let m = keys.len();
    let rl = map.row_len;
    for g in 0..map.groups {
        for (j, &k) in keys.iter().enumerate() {
            let s = (g * m + j) * rl;
            let d = (g * map.keys_total + k as usize) * rl;
            for o in 0..rl {
                dst[d + o] += upd[s + o];
                cnt[d + o] += 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_2seg() -> (ParamStore, SelectSpec) {
        // seg0: [4, 3] keyed rows; seg1: [3] full
        let mut s0 = Segment::zeros("w", &[4, 3]);
        for (i, v) in s0.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let mut s1 = Segment::zeros("b", &[3]);
        for (i, v) in s1.data.iter_mut().enumerate() {
            *v = 100.0 + i as f32;
        }
        let store = ParamStore {
            segments: vec![s0, s1],
        };
        let spec = SelectSpec {
            bindings: vec![
                Binding::Keyed {
                    seg: 0,
                    keyspace: 0,
                    map: KeyMap::rows(4, 3),
                },
                Binding::Full { seg: 1 },
            ],
            keyspaces: vec![Keyspace {
                name: "rows".into(),
                size: 4,
            }],
        };
        spec.validate(&store).unwrap();
        (store, spec)
    }

    #[test]
    fn slice_rows_picks_rows_in_key_order() {
        let (store, spec) = store_2seg();
        let keys = vec![vec![2u32, 0u32]];
        let slices = spec.slice(&store, &keys).unwrap();
        assert_eq!(slices[0], vec![6.0, 7.0, 8.0, 0.0, 1.0, 2.0]);
        assert_eq!(slices[1], vec![100.0, 101.0, 102.0]);
    }

    #[test]
    fn deselect_is_inverse_on_selected_coords() {
        let (store, spec) = store_2seg();
        let keys = vec![vec![2u32, 0u32]];
        let slices = spec.slice(&store, &keys).unwrap();
        let mut acc = store.zeros_like();
        let mut cnt = store.zeros_like();
        spec.deselect_add(&mut acc, &mut cnt, &keys, &slices).unwrap();
        // selected rows recovered, unselected rows zero
        assert_eq!(&acc.segments[0].data[0..3], &store.segments[0].data[0..3]);
        assert_eq!(&acc.segments[0].data[6..9], &store.segments[0].data[6..9]);
        assert_eq!(&acc.segments[0].data[3..6], &[0.0, 0.0, 0.0]);
        assert_eq!(&cnt.segments[0].data[3..6], &[0.0, 0.0, 0.0]);
        assert_eq!(&cnt.segments[0].data[0..3], &[1.0, 1.0, 1.0]);
        // full binding aggregated densely
        assert_eq!(acc.segments[1].data, store.segments[1].data);
    }

    #[test]
    fn duplicate_keys_double_count() {
        let (store, spec) = store_2seg();
        let keys = vec![vec![1u32, 1u32]];
        let slices = spec.slice(&store, &keys).unwrap();
        let mut acc = store.zeros_like();
        let mut cnt = store.zeros_like();
        spec.deselect_add(&mut acc, &mut cnt, &keys, &slices).unwrap();
        assert_eq!(cnt.segments[0].data[3], 2.0);
        assert_eq!(acc.segments[0].data[3], 2.0 * store.segments[0].data[3]);
    }

    #[test]
    fn cols_keymap_slices_columns() {
        // seg [2 rows, 4 cols], select cols {3, 1}
        let mut s = Segment::zeros("w", &[2, 4]);
        for (i, v) in s.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let store = ParamStore { segments: vec![s] };
        let spec = SelectSpec {
            bindings: vec![Binding::Keyed {
                seg: 0,
                keyspace: 0,
                map: KeyMap::cols(2, 4),
            }],
            keyspaces: vec![Keyspace {
                name: "cols".into(),
                size: 4,
            }],
        };
        spec.validate(&store).unwrap();
        let sl = spec.slice(&store, &[vec![3, 1]]).unwrap();
        // [[3,1],[7,5]]
        assert_eq!(sl[0], vec![3.0, 1.0, 7.0, 5.0]);
        assert_eq!(
            spec.sliced_shape(&store, 0, &[2]),
            vec![2, 2]
        );
    }

    #[test]
    fn grouped_rows_keymap_matches_cnn_flatten() {
        // P=2 spatial positions, K=3 channels, row_len=2:
        // segment [P*K, 2] = [6, 2]; key k selects rows {k, K + k}.
        let mut s = Segment::zeros("w", &[6, 2]);
        for (i, v) in s.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let store = ParamStore { segments: vec![s] };
        let map = KeyMap::grouped_rows(2, 3, 2);
        let spec = SelectSpec {
            bindings: vec![Binding::Keyed {
                seg: 0,
                keyspace: 0,
                map,
            }],
            keyspaces: vec![Keyspace {
                name: "ch".into(),
                size: 3,
            }],
        };
        spec.validate(&store).unwrap();
        let sl = spec.slice(&store, &[vec![2]]).unwrap();
        // rows 2 and 5 of [6,2]: [4,5] and [10,11]
        assert_eq!(sl[0], vec![4.0, 5.0, 10.0, 11.0]);
        assert_eq!(spec.sliced_shape(&store, 0, &[1]), vec![2, 2]);
    }

    #[test]
    fn all_keys_identity_recovers_broadcast() {
        let (store, spec) = store_2seg();
        let keys = vec![(0u32..4).collect::<Vec<_>>()];
        let slices = spec.slice(&store, &keys).unwrap();
        assert_eq!(slices[0], store.segments[0].data);
        assert_eq!(
            spec.client_floats(&store, &[4]),
            store.num_params()
        );
    }

    #[test]
    fn validate_rejects_mismatched_map() {
        let (store, mut spec) = store_2seg();
        spec.keyspaces[0].size = 5;
        assert!(spec.validate(&store).is_err());
    }
}

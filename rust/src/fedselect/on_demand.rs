//! Option 2 (paper §3.2/§6): on-demand slice generation.
//!
//! Clients upload their select keys; the server computes ψ per key and ships
//! back exactly the requested slice. A per-round memo cache amortizes
//! repeated keys across clients (the "more complicated distributed caching
//! system" the paper mentions — here a striped, read-mostly map the whole
//! cohort's fetch threads share: lookups take a shard read-lock, which is
//! uncontended once the working set is warm). The server sees every client's
//! keys: the weakest key privacy of the three options.
//!
//! A new session (== a new round) starts with an empty cache: the model
//! changed, so every memoized piece is stale by construction.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use super::piece::{piece_for_key, DeltaPlan, FetchOutcome, SlicePlan};
use super::{CommLedger, RoundComm, RoundSession, SliceService};
use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

/// Striped read-mostly memo map. 16 shards keeps write contention negligible
/// at realistic thread counts while reads stay a single uncontended RwLock
/// read-acquire.
struct PieceCache {
    shards: Vec<RwLock<HashMap<(usize, u32), Arc<Vec<f32>>>>>,
}

impl PieceCache {
    fn new(shards: usize) -> Self {
        PieceCache {
            shards: (0..shards.max(1)).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: (usize, u32)) -> &RwLock<HashMap<(usize, u32), Arc<Vec<f32>>>> {
        let h = (key.1 as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(key.0 as u64);
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    fn get(&self, key: (usize, u32)) -> Option<Arc<Vec<f32>>> {
        self.shard(key).read().expect("piece cache poisoned").get(&key).cloned()
    }

    /// First writer wins; a racing duplicate insert is dropped (both threads
    /// already paid the ψ, which the ledger faithfully records).
    fn insert(&self, key: (usize, u32), val: Arc<Vec<f32>>) {
        self.shard(key)
            .write()
            .expect("piece cache poisoned")
            .entry(key)
            .or_insert(val);
    }
}

pub struct OnDemandService {
    /// Memoize per-key pieces within a round.
    memoize: bool,
}

impl OnDemandService {
    pub fn new(memoize: bool) -> Self {
        OnDemandService { memoize }
    }
}

struct OnDemandSession<'a> {
    store: &'a ParamStore,
    spec: &'a SelectSpec,
    plan: SlicePlan,
    memoize: bool,
    cache: PieceCache,
    ledger: CommLedger,
}

impl SliceService for OnDemandService {
    fn name(&self) -> &'static str {
        "on-demand"
    }

    fn begin_round<'a>(
        &'a mut self,
        store: &'a ParamStore,
        spec: &'a SelectSpec,
    ) -> Result<Box<dyn RoundSession + 'a>> {
        Ok(Box::new(OnDemandSession {
            store,
            spec,
            plan: SlicePlan::new(store, spec),
            memoize: self.memoize,
            cache: PieceCache::new(16),
            ledger: CommLedger::default(),
        }))
    }
}

impl RoundSession for OnDemandSession<'_> {
    fn name(&self) -> &'static str {
        "on-demand"
    }

    fn fetch_delta(&self, keys: &[Vec<u32>], delta: &DeltaPlan) -> Result<FetchOutcome> {
        self.plan.check_keys(keys)?;
        // keys go up: 4 bytes per key. Cache-fresh keys go up too — the
        // server must see the full key+version list to answer "fresh", so
        // revalidation costs the same uplink as serving.
        let total_keys: usize = keys.iter().map(|k| k.len()).sum();
        self.ledger.add_up_key_bytes((total_keys * 4) as u64);

        // resolve this client's pieces: reuse from the shared memo when
        // possible, compute (and publish) otherwise. Exactly one of
        // psi_evals / memo_hits is charged per requested key occurrence
        // (duplicates included), matching the sequential accounting; the
        // cross-round delta plan deliberately does NOT short-circuit this —
        // ψ/memo charges are identical with the client cache on or off.
        let mut local: HashMap<(usize, u32), Arc<Vec<f32>>> =
            HashMap::with_capacity(total_keys);
        for (ks, kk) in keys.iter().enumerate() {
            for &k in kk {
                if self.memoize {
                    // covers duplicates within this fetch too: the first
                    // occurrence published the piece to the shared memo
                    if let Some(piece) = self.cache.get((ks, k)) {
                        self.ledger.add_memo_hits(1);
                        local.insert((ks, k), piece);
                        continue;
                    }
                } else if local.contains_key(&(ks, k)) {
                    // without the memo a duplicate key pays ψ again; charge
                    // it without redoing the copy
                    self.ledger.add_psi_evals(1);
                    self.ledger
                        .add_service_us(1 + self.plan.per_key_floats(ks) as u64 / 256);
                    continue;
                }
                let piece = Arc::new(piece_for_key(self.store, self.spec, ks, k));
                self.ledger.add_psi_evals(1);
                self.ledger.add_service_us(1 + piece.len() as u64 / 256); // ~1GB/s ψ model
                if self.memoize {
                    self.cache.insert((ks, k), piece.clone());
                }
                local.insert((ks, k), piece);
            }
        }

        // downlink: broadcast segments + selected slice bytes, minus what
        // the client's cross-round cache already holds at a fresh version
        let (down, hits, hit_bytes) = self.plan.delta_down_bytes(keys, delta);
        self.ledger.add_down_bytes(down);
        self.ledger.add_client_cache_hits(hits);

        Ok(FetchOutcome {
            bundle: self
                .plan
                .assemble(keys, |ks, k| local[&(ks, k)].as_slice())?,
            down_bytes: down,
            piece_hits: hits,
            hit_bytes,
        })
    }

    fn finish(self: Box<Self>) -> RoundComm {
        self.ledger.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    #[test]
    fn memoization_counts_hits_and_resets_per_round() {
        let arch = ModelArch::mlp2nn();
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![0u32, 5, 9]];
        let mut svc = OnDemandService::new(true);
        let sess = svc.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        sess.fetch(&keys).unwrap();
        let l1 = sess.finish();
        assert_eq!(l1.psi_evals, 3);
        assert_eq!(l1.memo_hits, 3);
        // new round == new session: cache starts empty
        let sess = svc.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        let l2 = sess.finish();
        assert_eq!(l2.psi_evals, 3);
        assert_eq!(l2.memo_hits, 0);
    }

    #[test]
    fn without_memoization_every_fetch_pays() {
        let arch = ModelArch::logreg(16);
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![1u32, 2]];
        let mut svc = OnDemandService::new(false);
        let sess = svc.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        sess.fetch(&keys).unwrap();
        let l = sess.finish();
        assert_eq!(l.psi_evals, 4);
        assert_eq!(l.memo_hits, 0);
    }

    #[test]
    fn duplicate_keys_are_charged_per_occurrence() {
        let arch = ModelArch::logreg(16);
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        let dup = vec![vec![3u32, 3]];

        let mut svc = OnDemandService::new(true);
        let sess = svc.begin_round(&store, &spec).unwrap();
        sess.fetch(&dup).unwrap();
        let l = sess.finish();
        assert_eq!((l.psi_evals, l.memo_hits), (1, 1));

        let mut svc = OnDemandService::new(false);
        let sess = svc.begin_round(&store, &spec).unwrap();
        sess.fetch(&dup).unwrap();
        let l = sess.finish();
        assert_eq!((l.psi_evals, l.memo_hits), (2, 0));
    }

    #[test]
    fn concurrent_fetches_share_the_memo() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(7, 0));
        let spec = arch.select_spec();
        let batch: Vec<Vec<Vec<u32>>> = (0..8).map(|_| vec![vec![1u32, 2, 3, 4]]).collect();
        let mut svc = OnDemandService::new(true);
        let sess = svc.begin_round(&store, &spec).unwrap();
        let out = sess.fetch_batch(&batch, 4).unwrap();
        assert_eq!(out.len(), 8);
        let l = sess.finish();
        // every fetch asked for the same 4 keys: at most one ψ per key per
        // racing thread, and at least the 4 required; the rest were hits
        assert!(l.psi_evals >= 4, "psi {}", l.psi_evals);
        assert_eq!(l.psi_evals + l.memo_hits, 8 * 4);
    }
}

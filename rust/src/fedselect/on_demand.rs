//! Option 2 (paper §3.2/§6): on-demand slice generation.
//!
//! Clients upload their select keys; the server computes ψ per key and ships
//! back exactly the requested slice. A per-round memo cache amortizes
//! repeated keys across clients (the "more complicated distributed caching
//! system" the paper mentions — here a single-node memo whose hit statistics
//! the benches report). The server sees every client's keys: the weakest key
//! privacy of the three options.

use std::collections::HashMap;

use super::piece::{assemble, piece_bytes, piece_for_key};
use super::{RoundComm, SliceService};
use crate::error::Result;
use crate::model::{Binding, ParamStore, SelectSpec};

pub struct OnDemandService {
    /// Memoize per-key pieces within a round (cleared by `begin_round`).
    memoize: bool,
    cache: HashMap<(usize, u32), Vec<f32>>,
    ledger: RoundComm,
}

impl OnDemandService {
    pub fn new(memoize: bool) -> Self {
        OnDemandService {
            memoize,
            cache: HashMap::new(),
            ledger: RoundComm::default(),
        }
    }
}

impl SliceService for OnDemandService {
    fn name(&self) -> &'static str {
        "on-demand"
    }

    fn begin_round(&mut self, _store: &ParamStore, _spec: &SelectSpec) -> Result<()> {
        // The model changed: all cached slices are stale.
        self.cache.clear();
        Ok(())
    }

    fn fetch(
        &mut self,
        store: &ParamStore,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
    ) -> Result<Vec<Vec<f32>>> {
        // keys go up: 4 bytes per key
        let total_keys: usize = keys.iter().map(|k| k.len()).sum();
        self.ledger.up_key_bytes += (total_keys * 4) as u64;

        // compute / reuse per-key pieces
        for (ks, kk) in keys.iter().enumerate() {
            for &k in kk {
                if self.memoize && self.cache.contains_key(&(ks, k)) {
                    self.ledger.cache_hits += 1;
                    continue;
                }
                let piece = piece_for_key(store, spec, ks, k);
                self.ledger.psi_evals += 1;
                self.ledger.service_us += 1 + piece.len() as u64 / 256; // ~1GB/s ψ model
                if self.memoize {
                    self.cache.insert((ks, k), piece);
                } else {
                    // still pay for it below via direct assembly
                    self.cache.insert((ks, k), piece);
                }
            }
        }

        // downlink: broadcast segments + selected slice bytes
        let bcast = spec.broadcast_floats(store) * 4;
        let keyed: u64 = keys
            .iter()
            .enumerate()
            .map(|(ks, kk)| kk.len() as u64 * piece_bytes(spec, ks))
            .sum();
        self.ledger.down_bytes += bcast as u64 + keyed;

        let out = assemble(store, spec, keys, |ks, k| {
            self.cache.get(&(ks, k)).expect("piece computed above")
        });
        if !self.memoize {
            self.cache.clear();
        }
        // sanity: bundle covers every binding
        debug_assert_eq!(out.len(), spec.bindings.len());
        debug_assert!(spec
            .bindings
            .iter()
            .zip(out.iter())
            .all(|(b, o)| match b {
                Binding::Full { seg } => o.len() == store.segments[*seg].len(),
                Binding::Keyed { keyspace, map, .. } =>
                    o.len() == map.sliced_len(keys[*keyspace].len()),
            }));
        Ok(out)
    }

    fn end_round(&mut self) -> RoundComm {
        std::mem::take(&mut self.ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    #[test]
    fn memoization_counts_hits_and_resets_per_round() {
        let arch = ModelArch::mlp2nn();
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![0u32, 5, 9]];
        let mut svc = OnDemandService::new(true);
        svc.begin_round(&store, &spec).unwrap();
        svc.fetch(&store, &spec, &keys).unwrap();
        svc.fetch(&store, &spec, &keys).unwrap();
        let l1 = svc.end_round();
        assert_eq!(l1.psi_evals, 3);
        assert_eq!(l1.cache_hits, 3);
        // new round: cache cleared
        svc.begin_round(&store, &spec).unwrap();
        svc.fetch(&store, &spec, &keys).unwrap();
        let l2 = svc.end_round();
        assert_eq!(l2.psi_evals, 3);
        assert_eq!(l2.cache_hits, 0);
    }

    #[test]
    fn without_memoization_every_fetch_pays() {
        let arch = ModelArch::logreg(16);
        let store = arch.init_store(&mut Rng::new(1, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![1u32, 2]];
        let mut svc = OnDemandService::new(false);
        svc.begin_round(&store, &spec).unwrap();
        svc.fetch(&store, &spec, &keys).unwrap();
        svc.fetch(&store, &spec, &keys).unwrap();
        let l = svc.end_round();
        assert_eq!(l.psi_evals, 4);
        assert_eq!(l.cache_hits, 0);
    }
}

//! The FEDSELECT primitive (paper §3) and its system implementations (§3.2).
//!
//! `FEDSELECT(x@S, {z_n}@C, ψ) = {[ψ(x, z_n,1), …, ψ(x, z_n,m)]}@C`
//!
//! FEDSELECT is defined over a *cohort*: one server state `x` is mapped to
//! per-client slices for all N clients of a round at once. The API mirrors
//! that. A [`SliceService`] is the long-lived implementation choice; calling
//! [`SliceService::begin_round`] snapshots the model into an immutable
//! [`RoundSession`] which any number of threads can slice through
//! concurrently ([`RoundSession::fetch_batch`]); consuming the session with
//! [`RoundSession::finish`] drains the round's [`RoundComm`] ledger.
//!
//! Three implementations, mirroring the paper's §3.2 Options 1–3 — they
//! differ precisely in *where* the cohort-level ψ work happens and in the
//! ledger each session accumulates:
//!
//! | impl | ψ happens | session ledger semantics | key privacy |
//! |---|---|---|---|
//! | [`broadcast::BroadcastService`] | on clients, after a full-model download | `down_bytes` += full model per fetch; no server `psi_evals` | keys never leave device |
//! | [`on_demand::OnDemandService`]  | on the server, per distinct key, at fetch time | `psi_evals` per computed piece, `memo_hits` for memoized ones (shared across the cohort's threads), `up_key_bytes` for uploaded keys | server sees keys |
//! | [`pregen::PregenCdnService`]    | on the server, for *all* K keys, inside `begin_round` | `pregen_slices`/`psi_evals` charged at session start; fetches only count `cdn_queries` and bytes; `service_us` is bounded below by the busiest CDN shard | CDN sees keys (PIR optional) |
//!
//! Two caches appear in the ledger, deliberately split: `memo_hits` are
//! *within-round, server-side* — the on-demand memo amortizing ψ across one
//! cohort — while `client_cache_hits` are *cross-round, device-side* — the
//! [`crate::cache`] subsystem serving unchanged pieces without downlink
//! bytes via [`RoundSession::fetch_delta`]. A delta fetch changes only
//! `down_bytes` and `client_cache_hits`; every other ledger charge
//! (keys up, ψ/memo/CDN work, service time) models revalidation at full
//! cost, so cache-on and cache-off runs agree on every non-downlink field.
//!
//! Every implementation returns byte-identical slices — property-tested both
//! sequentially and across threads — so they are interchangeable behind the
//! trait; they differ only in the communication/computation/privacy ledger
//! they produce.
//!
//! Slices are delivered as [`SliceBundle`]s built from a per-round
//! [`SlicePlan`]: broadcast-in-full segments are cloned **once per round**
//! and shared across the whole cohort via `Arc` (zero per-client copies),
//! keyed rows are copied directly out of the [`ParamStore`] spans the plan
//! resolved up front.

pub mod broadcast;
pub mod keys;
pub mod on_demand;
pub mod piece;
pub mod pregen;

pub use broadcast::BroadcastService;
pub use keys::KeyPolicy;
pub use on_demand::OnDemandService;
pub use piece::{DeltaPlan, FetchOutcome, SliceBundle, SlicePlan, SliceSeg};
pub use pregen::PregenCdnService;

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

/// One client's select keys: `keys[ks]` per keyspace `ks`.
pub type ClientKeys = Vec<Vec<u32>>;

/// Which implementation to instantiate (config-level knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SliceImpl {
    /// Option 1: broadcast everything, clients slice locally.
    Broadcast,
    /// Option 2: clients upload keys, server slices on demand (with a
    /// per-round memo cache shared across fetch threads).
    OnDemand,
    /// Option 3: server pre-generates all K slices to a CDN before the round.
    PregenCdn,
}

impl SliceImpl {
    pub fn build(self) -> Box<dyn SliceService> {
        match self {
            SliceImpl::Broadcast => Box::new(BroadcastService::new()),
            SliceImpl::OnDemand => Box::new(OnDemandService::new(true)),
            SliceImpl::PregenCdn => Box::new(PregenCdnService::new()),
        }
    }
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for SliceImpl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SliceImpl::Broadcast => "broadcast",
            SliceImpl::OnDemand => "on-demand",
            SliceImpl::PregenCdn => "pregen-cdn",
        })
    }
}

impl std::str::FromStr for SliceImpl {
    type Err = String;
    /// Case-insensitive; accepts the canonical `Display` names plus the
    /// historical aliases (`on_demand`, `pregen`, `cdn`).
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "broadcast" => Ok(SliceImpl::Broadcast),
            "on-demand" | "on_demand" | "ondemand" => Ok(SliceImpl::OnDemand),
            "pregen" | "pregen-cdn" | "pregen_cdn" | "cdn" => Ok(SliceImpl::PregenCdn),
            other => Err(format!(
                "unknown slice impl {other:?} (want {}, {} or {})",
                SliceImpl::Broadcast,
                SliceImpl::OnDemand,
                SliceImpl::PregenCdn
            )),
        }
    }
}

/// Per-round communication/computation ledger of a slice service.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundComm {
    /// Bytes sent server->clients (or CDN->clients) this round.
    pub down_bytes: u64,
    /// Bytes of select keys sent clients->server/CDN.
    pub up_key_bytes: u64,
    /// Server-side ψ evaluations (per key).
    pub psi_evals: u64,
    /// ψ evaluations avoided by the on-demand *within-round* memo (Option
    /// 2's server-side cache, reset every session).
    pub memo_hits: u64,
    /// Pieces served from clients' *cross-round* on-device caches
    /// ([`crate::cache`]) instead of the wire — each hit's bytes are
    /// absent from `down_bytes`.
    pub client_cache_hits: u64,
    /// Slices pre-generated before the round (Option 3).
    pub pregen_slices: u64,
    /// CDN queries served.
    pub cdn_queries: u64,
    /// Simulated CDN/network service latency (µs, accounting model).
    pub service_us: u64,
}

impl RoundComm {
    pub fn accumulate(&mut self, other: &RoundComm) {
        self.down_bytes += other.down_bytes;
        self.up_key_bytes += other.up_key_bytes;
        self.psi_evals += other.psi_evals;
        self.memo_hits += other.memo_hits;
        self.client_cache_hits += other.client_cache_hits;
        self.pregen_slices += other.pregen_slices;
        self.cdn_queries += other.cdn_queries;
        self.service_us += other.service_us;
    }
}

/// Interior-mutable [`RoundComm`] accumulator: sessions record through
/// `&self` (relaxed atomics — the counters are independent tallies), so a
/// cohort can be sliced from many threads without locks.
#[derive(Debug, Default)]
pub struct CommLedger {
    down_bytes: AtomicU64,
    up_key_bytes: AtomicU64,
    psi_evals: AtomicU64,
    memo_hits: AtomicU64,
    client_cache_hits: AtomicU64,
    pregen_slices: AtomicU64,
    cdn_queries: AtomicU64,
    service_us: AtomicU64,
}

impl CommLedger {
    pub fn add_down_bytes(&self, n: u64) {
        self.down_bytes.fetch_add(n, Relaxed);
    }
    pub fn add_up_key_bytes(&self, n: u64) {
        self.up_key_bytes.fetch_add(n, Relaxed);
    }
    pub fn add_psi_evals(&self, n: u64) {
        self.psi_evals.fetch_add(n, Relaxed);
    }
    pub fn add_memo_hits(&self, n: u64) {
        self.memo_hits.fetch_add(n, Relaxed);
    }
    pub fn add_client_cache_hits(&self, n: u64) {
        self.client_cache_hits.fetch_add(n, Relaxed);
    }
    pub fn add_pregen_slices(&self, n: u64) {
        self.pregen_slices.fetch_add(n, Relaxed);
    }
    pub fn add_cdn_queries(&self, n: u64) {
        self.cdn_queries.fetch_add(n, Relaxed);
    }
    pub fn add_service_us(&self, n: u64) {
        self.service_us.fetch_add(n, Relaxed);
    }
    /// Raise `service_us` to at least `n` (peak-bound accounting).
    pub fn max_service_us(&self, n: u64) {
        self.service_us.fetch_max(n, Relaxed);
    }

    /// Read the ledger out as a plain [`RoundComm`].
    pub fn snapshot(&self) -> RoundComm {
        RoundComm {
            down_bytes: self.down_bytes.load(Relaxed),
            up_key_bytes: self.up_key_bytes.load(Relaxed),
            psi_evals: self.psi_evals.load(Relaxed),
            memo_hits: self.memo_hits.load(Relaxed),
            client_cache_hits: self.client_cache_hits.load(Relaxed),
            pregen_slices: self.pregen_slices.load(Relaxed),
            cdn_queries: self.cdn_queries.load(Relaxed),
            service_us: self.service_us.load(Relaxed),
        }
    }
}

/// A FEDSELECT implementation: turns one model snapshot into an immutable
/// per-round slicing session.
pub trait SliceService: Send {
    fn name(&self) -> &'static str;

    /// Start a round against the current model. Option 3 pre-generates its
    /// CDN content here. The returned session borrows `store`/`spec` (and
    /// the service) immutably and is `Sync`: the whole cohort can fetch
    /// through it concurrently.
    fn begin_round<'a>(
        &'a mut self,
        store: &'a ParamStore,
        spec: &'a SelectSpec,
    ) -> Result<Box<dyn RoundSession + 'a>>;

    /// Tag the service with a tenancy namespace (job id; 0 = single-tenant).
    /// Only backends holding shared addressable state need it — the CDN
    /// prefixes its piece addresses so N jobs never collide — so the
    /// default is a no-op.
    fn set_namespace(&mut self, _ns: u32) {}
}

/// One round's slicing session. All methods take `&self`; ledgers use
/// interior mutability ([`CommLedger`]) so [`fetch`](Self::fetch) can run
/// from any number of threads.
pub trait RoundSession: Send + Sync {
    fn name(&self) -> &'static str;

    /// Deliver the sub-model for one client (`keys[ks]` per keyspace `ks`),
    /// in artifact parameter order. Equivalent to a delta fetch with
    /// nothing fresh (every piece downloads).
    fn fetch(&self, keys: &[Vec<u32>]) -> Result<SliceBundle> {
        self.fetch_delta(keys, &DeltaPlan::default()).map(|o| o.bundle)
    }

    /// Delta-aware fetch: the same bundle as [`fetch`](Self::fetch), but
    /// pieces listed fresh in `delta` are served from the client's
    /// cross-round on-device cache — ledgered as `client_cache_hits`
    /// instead of `down_bytes`. Every *other* ledger charge (keys up,
    /// ψ/memo/CDN work, service time) is made exactly as in a plain fetch:
    /// revalidation rides the same code path as serving, only the payload
    /// bytes are saved. With an empty `delta` the ledger is byte-identical
    /// to [`fetch`](Self::fetch).
    fn fetch_delta(&self, keys: &[Vec<u32>], delta: &DeltaPlan) -> Result<FetchOutcome>;

    /// Delta-aware [`fetch_batch`](Self::fetch_batch): `deltas` is aligned
    /// with `batch` (one plan per client). Same chunked-threads execution
    /// and ordering guarantees.
    fn fetch_batch_delta(
        &self,
        batch: &[ClientKeys],
        deltas: &[DeltaPlan],
        threads: usize,
    ) -> Result<Vec<FetchOutcome>> {
        if batch.len() != deltas.len() {
            return Err(crate::error::Error::Shape(format!(
                "fetch_batch_delta: {} clients but {} delta plans",
                batch.len(),
                deltas.len()
            )));
        }
        let threads = threads.max(1).min(batch.len().max(1));
        if threads <= 1 {
            return batch
                .iter()
                .zip(deltas.iter())
                .map(|(keys, d)| self.fetch_delta(keys, d))
                .collect();
        }
        let base = batch.len() / threads;
        let extra = batch.len() % threads;
        let mut results: Vec<Result<FetchOutcome>> = Vec::with_capacity(batch.len());
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            let (mut rest, mut drest) = (batch, deltas);
            for i in 0..threads {
                let take = base + usize::from(i < extra);
                let (ch, tail) = rest.split_at(take);
                let (dh, dtail) = drest.split_at(take);
                rest = tail;
                drest = dtail;
                handles.push(s.spawn(move || {
                    ch.iter()
                        .zip(dh.iter())
                        .map(|(keys, d)| self.fetch_delta(keys, d))
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                results.extend(h.join().expect("slice fetch worker panicked"));
            }
        });
        results.into_iter().collect()
    }

    /// Slice a whole cohort, preserving input order. With `threads > 1` the
    /// batch is split into contiguous chunks sliced concurrently via
    /// `std::thread::scope`; output is byte-identical to the sequential
    /// per-client path (property-tested). One threading implementation
    /// exists — this is [`fetch_batch_delta`](Self::fetch_batch_delta) with
    /// empty plans, bundles only.
    fn fetch_batch(&self, batch: &[ClientKeys], threads: usize) -> Result<Vec<SliceBundle>> {
        let empty = vec![DeltaPlan::default(); batch.len()];
        Ok(self
            .fetch_batch_delta(batch, &empty, threads)?
            .into_iter()
            .map(|o| o.bundle)
            .collect())
    }

    /// End the round and drain its ledger.
    fn finish(self: Box<Self>) -> RoundComm;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    /// All three implementations must produce byte-identical slices.
    #[test]
    fn implementations_agree() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(3, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![5u32, 0, 63, 17]];

        let mut results = Vec::new();
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            let slices = session.fetch(&keys).unwrap().to_vecs();
            assert_eq!(slices, spec.slice(&store, &keys).unwrap(), "{imp} vs ψ");
            results.push((imp, slices));
        }
        for w in results.windows(2) {
            assert_eq!(w[0].1, w[1].1, "{} vs {}", w[0].0, w[1].0);
        }
    }

    /// fetch_batch across threads == per-client fetch, in order.
    #[test]
    fn fetch_batch_matches_sequential_fetch() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(5, 0));
        let spec = arch.select_spec();
        let mut rng = Rng::new(9, 1);
        let batch: Vec<ClientKeys> = (0..10)
            .map(|_| {
                vec![rng
                    .sample_without_replacement(64, 8)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()]
            })
            .collect();
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            let seq: Vec<_> = batch.iter().map(|k| session.fetch(k).unwrap().to_vecs()).collect();
            for threads in [1usize, 3, 8] {
                let par: Vec<_> = session
                    .fetch_batch(&batch, threads)
                    .unwrap()
                    .into_iter()
                    .map(|b| b.to_vecs())
                    .collect();
                assert_eq!(seq, par, "{imp} threads={threads}");
            }
        }
    }

    #[test]
    fn ledgers_reflect_design_tradeoffs() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(3, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![5u32, 0, 63, 17]];

        let mut bc = SliceImpl::Broadcast.build();
        let sess = bc.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        let lc_bc = sess.finish();

        let mut od = SliceImpl::OnDemand.build();
        let sess = od.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        sess.fetch(&keys).unwrap();
        let lc_od = sess.finish();

        let mut pg = SliceImpl::PregenCdn.build();
        let sess = pg.begin_round(&store, &spec).unwrap();
        sess.fetch(&keys).unwrap();
        let lc_pg = sess.finish();

        // broadcast: full model down, no keys up, no server psi
        assert_eq!(lc_bc.down_bytes, store.bytes() as u64);
        assert_eq!(lc_bc.up_key_bytes, 0);
        assert_eq!(lc_bc.psi_evals, 0);
        // on-demand: far less down, keys visible, cache hits on 2nd fetch
        assert!(lc_od.down_bytes < lc_bc.down_bytes);
        assert!(lc_od.up_key_bytes > 0);
        assert_eq!(lc_od.psi_evals, 4);
        assert_eq!(lc_od.memo_hits, 4);
        assert_eq!(lc_od.client_cache_hits, 0);
        // pregen: all K slices computed ahead of time
        assert_eq!(lc_pg.pregen_slices, 64);
        assert_eq!(lc_pg.cdn_queries, 4);
        assert!(lc_pg.down_bytes < lc_bc.down_bytes);
    }

    #[test]
    fn delta_fetch_saves_only_downlink_bytes() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(3, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![5u32, 0, 63]];
        for imp in [SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let sess = svc.begin_round(&store, &spec).unwrap();
            let plain = sess.fetch(&keys).unwrap();
            let mut d = DeltaPlan::default();
            d.fresh_keys.insert((0, 5));
            d.fresh_segs.insert(1); // logreg bias segment
            let out = sess.fetch_delta(&keys, &d).unwrap();
            assert_eq!(out.bundle.to_vecs(), plain.to_vecs(), "{imp}: bundle identical");
            assert_eq!(out.piece_hits, 2, "{imp}");
            assert_eq!(out.down_bytes + out.hit_bytes, plain.bytes(), "{imp}");
            let l = sess.finish();
            assert_eq!(l.client_cache_hits, 2, "{imp}");
            // plain fetch charged the full bundle, delta fetch only the
            // stale remainder; everything else was charged both times
            assert_eq!(l.down_bytes, plain.bytes() + out.down_bytes, "{imp}");
            assert_eq!(l.up_key_bytes, 2 * 3 * 4, "{imp}: keys go up both times");
        }
        // Option 1 deltas work at segment granularity
        let mut svc = SliceImpl::Broadcast.build();
        let sess = svc.begin_round(&store, &spec).unwrap();
        let mut d = DeltaPlan::default();
        d.fresh_segs.insert(0);
        d.fresh_segs.insert(1);
        let out = sess.fetch_delta(&keys, &d).unwrap();
        assert_eq!(out.down_bytes, 0, "everything fresh: nothing on the wire");
        assert_eq!(out.hit_bytes, store.bytes() as u64);
        let l = sess.finish();
        assert_eq!(l.down_bytes, 0);
        assert_eq!(l.client_cache_hits, 2);
    }

    #[test]
    fn fetch_batch_delta_matches_per_client_delta_fetches() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(5, 0));
        let spec = arch.select_spec();
        let mut rng = Rng::new(9, 1);
        let batch: Vec<ClientKeys> = (0..9)
            .map(|_| {
                vec![rng
                    .sample_without_replacement(64, 8)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()]
            })
            .collect();
        // every other client has its first two keys "cached"
        let deltas: Vec<DeltaPlan> = batch
            .iter()
            .enumerate()
            .map(|(i, keys)| {
                let mut d = DeltaPlan::default();
                if i % 2 == 0 {
                    d.fresh_keys.insert((0, keys[0][0]));
                    d.fresh_keys.insert((0, keys[0][1]));
                }
                d
            })
            .collect();
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            let session = svc.begin_round(&store, &spec).unwrap();
            let seq: Vec<_> = batch
                .iter()
                .zip(deltas.iter())
                .map(|(k, d)| session.fetch_delta(k, d).unwrap())
                .collect();
            for threads in [1usize, 3, 8] {
                let par = session.fetch_batch_delta(&batch, &deltas, threads).unwrap();
                for (a, b) in seq.iter().zip(par.iter()) {
                    assert_eq!(a.bundle.to_vecs(), b.bundle.to_vecs(), "{imp}");
                    assert_eq!(a.down_bytes, b.down_bytes, "{imp} threads={threads}");
                    assert_eq!(a.piece_hits, b.piece_hits, "{imp}");
                }
            }
            // misaligned plans are an error, not a truncation
            assert!(session.fetch_batch_delta(&batch, &deltas[1..], 2).is_err());
        }
    }

    #[test]
    fn slice_impl_display_round_trips_case_insensitively() {
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let shown = imp.to_string();
            assert_eq!(shown.parse::<SliceImpl>().unwrap(), imp);
            assert_eq!(shown.to_uppercase().parse::<SliceImpl>().unwrap(), imp);
        }
        assert_eq!("Pregen".parse::<SliceImpl>().unwrap(), SliceImpl::PregenCdn);
        let err = "bogus".parse::<SliceImpl>().unwrap_err();
        assert!(err.contains("broadcast") && err.contains("on-demand"), "{err}");
    }
}

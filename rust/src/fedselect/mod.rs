//! The FEDSELECT primitive (paper §3) and its system implementations (§3.2).
//!
//! `FEDSELECT(x@S, {z_n}@C, ψ) = {[ψ(x, z_n,1), …, ψ(x, z_n,m)]}@C`
//!
//! A [`SliceService`] delivers each client its sub-model given its select
//! keys. Three implementations, mirroring the paper's Options 1–3:
//!
//! | impl | communication | server ψ cost | key privacy |
//! |---|---|---|---|
//! | [`broadcast::BroadcastService`] | full model down | none (client-side ψ) | keys never leave device |
//! | [`on_demand::OnDemandService`]  | keys up, slice down | per distinct key (memoized) | server sees keys |
//! | [`pregen::PregenCdnService`]    | keys to CDN, slice down | all K keys before the round | CDN sees keys (PIR optional) |
//!
//! Every implementation returns byte-identical slices (property-tested), so
//! they are interchangeable behind the trait; they differ only in the
//! communication/computation/privacy ledger they produce.

pub mod broadcast;
pub mod keys;
pub mod on_demand;
pub mod piece;
pub mod pregen;

pub use broadcast::BroadcastService;
pub use keys::KeyPolicy;
pub use on_demand::OnDemandService;
pub use pregen::PregenCdnService;

use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

/// Which implementation to instantiate (config-level knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SliceImpl {
    /// Option 1: broadcast everything, clients slice locally.
    Broadcast,
    /// Option 2: clients upload keys, server slices on demand (with a
    /// per-round memo cache).
    OnDemand,
    /// Option 3: server pre-generates all K slices to a CDN before the round.
    PregenCdn,
}

impl SliceImpl {
    pub fn build(self) -> Box<dyn SliceService> {
        match self {
            SliceImpl::Broadcast => Box::new(BroadcastService::new()),
            SliceImpl::OnDemand => Box::new(OnDemandService::new(true)),
            SliceImpl::PregenCdn => Box::new(PregenCdnService::new()),
        }
    }
}

impl std::str::FromStr for SliceImpl {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "broadcast" => Ok(SliceImpl::Broadcast),
            "on-demand" | "on_demand" => Ok(SliceImpl::OnDemand),
            "pregen" | "pregen-cdn" | "cdn" => Ok(SliceImpl::PregenCdn),
            other => Err(format!("unknown slice impl {other:?}")),
        }
    }
}

/// Per-round communication/computation ledger of a slice service.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundComm {
    /// Bytes sent server->clients (or CDN->clients) this round.
    pub down_bytes: u64,
    /// Bytes of select keys sent clients->server/CDN.
    pub up_key_bytes: u64,
    /// Server-side ψ evaluations (per key).
    pub psi_evals: u64,
    /// ψ evaluations avoided by the on-demand memo cache.
    pub cache_hits: u64,
    /// Slices pre-generated before the round (Option 3).
    pub pregen_slices: u64,
    /// CDN queries served.
    pub cdn_queries: u64,
    /// Simulated CDN/network service latency (µs, accounting model).
    pub service_us: u64,
}

impl RoundComm {
    pub fn accumulate(&mut self, other: &RoundComm) {
        self.down_bytes += other.down_bytes;
        self.up_key_bytes += other.up_key_bytes;
        self.psi_evals += other.psi_evals;
        self.cache_hits += other.cache_hits;
        self.pregen_slices += other.pregen_slices;
        self.cdn_queries += other.cdn_queries;
        self.service_us += other.service_us;
    }
}

/// A FEDSELECT implementation: delivers client sub-models for select keys.
pub trait SliceService: Send {
    fn name(&self) -> &'static str;

    /// Called once per round before any client fetches (pre-generation hook).
    fn begin_round(&mut self, store: &ParamStore, spec: &SelectSpec) -> Result<()>;

    /// Deliver the sub-model for one client (`keys[ks]` per keyspace `ks`),
    /// in artifact parameter order.
    fn fetch(
        &mut self,
        store: &ParamStore,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
    ) -> Result<Vec<Vec<f32>>>;

    /// Drain and return this round's ledger.
    fn end_round(&mut self) -> RoundComm;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    /// All three implementations must produce byte-identical slices.
    #[test]
    fn implementations_agree() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(3, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![5u32, 0, 63, 17]];

        let mut results = Vec::new();
        for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
            let mut svc = imp.build();
            svc.begin_round(&store, &spec).unwrap();
            let slices = svc.fetch(&store, &spec, &keys).unwrap();
            results.push((imp, slices));
        }
        for w in results.windows(2) {
            assert_eq!(w[0].1, w[1].1, "{:?} vs {:?}", w[0].0, w[1].0);
        }
    }

    #[test]
    fn ledgers_reflect_design_tradeoffs() {
        let arch = ModelArch::logreg(64);
        let store = arch.init_store(&mut Rng::new(3, 0));
        let spec = arch.select_spec();
        let keys = vec![vec![5u32, 0, 63, 17]];

        let mut bc = SliceImpl::Broadcast.build();
        bc.begin_round(&store, &spec).unwrap();
        bc.fetch(&store, &spec, &keys).unwrap();
        let lc_bc = bc.end_round();

        let mut od = SliceImpl::OnDemand.build();
        od.begin_round(&store, &spec).unwrap();
        od.fetch(&store, &spec, &keys).unwrap();
        od.fetch(&store, &spec, &keys).unwrap();
        let lc_od = od.end_round();

        let mut pg = SliceImpl::PregenCdn.build();
        pg.begin_round(&store, &spec).unwrap();
        pg.fetch(&store, &spec, &keys).unwrap();
        let lc_pg = pg.end_round();

        // broadcast: full model down, no keys up, no server psi
        assert_eq!(lc_bc.down_bytes, store.bytes() as u64);
        assert_eq!(lc_bc.up_key_bytes, 0);
        assert_eq!(lc_bc.psi_evals, 0);
        // on-demand: far less down, keys visible, cache hits on 2nd fetch
        assert!(lc_od.down_bytes < lc_bc.down_bytes);
        assert!(lc_od.up_key_bytes > 0);
        assert_eq!(lc_od.psi_evals, 4);
        assert_eq!(lc_od.cache_hits, 4);
        // pregen: all K slices computed ahead of time
        assert_eq!(lc_pg.pregen_slices, 64);
        assert_eq!(lc_pg.cdn_queries, 4);
        assert!(lc_pg.down_bytes < lc_bc.down_bytes);
    }
}

//! Option 1 (paper §3.2): broadcast the full value, compute ψ on clients.
//!
//! Maximal key privacy (keys never leave the device), no communication
//! savings: every client downloads the entire server model.

use super::{RoundComm, SliceService};
use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

#[derive(Default)]
pub struct BroadcastService {
    ledger: RoundComm,
}

impl BroadcastService {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SliceService for BroadcastService {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn begin_round(&mut self, _store: &ParamStore, _spec: &SelectSpec) -> Result<()> {
        Ok(())
    }

    fn fetch(
        &mut self,
        store: &ParamStore,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
    ) -> Result<Vec<Vec<f32>>> {
        // Full model over the wire; ψ runs client-side (not counted as
        // server psi_evals).
        self.ledger.down_bytes += store.bytes() as u64;
        spec.slice(store, keys)
    }

    fn end_round(&mut self) -> RoundComm {
        std::mem::take(&mut self.ledger)
    }
}

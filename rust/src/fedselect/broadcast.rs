//! Option 1 (paper §3.2): broadcast the full value, compute ψ on clients.
//!
//! Maximal key privacy (keys never leave the device), no communication
//! savings: every client downloads the entire server model. The session is
//! a thin wrapper over the round's [`SlicePlan`] — broadcast segments are
//! `Arc`-shared instead of cloned per client, so the simulator no longer
//! pays a full-model copy per fetch (the wire ledger still charges one).

use super::piece::{SliceBundle, SlicePlan};
use super::{CommLedger, RoundComm, RoundSession, SliceService};
use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

#[derive(Default)]
pub struct BroadcastService;

impl BroadcastService {
    pub fn new() -> Self {
        Self
    }
}

struct BroadcastSession<'a> {
    store: &'a ParamStore,
    plan: SlicePlan,
    full_bytes: u64,
    ledger: CommLedger,
}

impl SliceService for BroadcastService {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn begin_round<'a>(
        &'a mut self,
        store: &'a ParamStore,
        spec: &'a SelectSpec,
    ) -> Result<Box<dyn RoundSession + 'a>> {
        Ok(Box::new(BroadcastSession {
            store,
            plan: SlicePlan::new(store, spec),
            full_bytes: store.bytes() as u64,
            ledger: CommLedger::default(),
        }))
    }
}

impl RoundSession for BroadcastSession<'_> {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn fetch(&self, keys: &[Vec<u32>]) -> Result<SliceBundle> {
        // Full model over the wire; ψ runs client-side (not counted as
        // server psi_evals).
        self.ledger.add_down_bytes(self.full_bytes);
        self.plan.fetch(self.store, keys)
    }

    fn finish(self: Box<Self>) -> RoundComm {
        self.ledger.snapshot()
    }
}

//! Option 1 (paper §3.2): broadcast the full value, compute ψ on clients.
//!
//! Maximal key privacy (keys never leave the device), no communication
//! savings: every client downloads the entire server model. The session is
//! a thin wrapper over the round's [`SlicePlan`] — broadcast segments are
//! `Arc`-shared instead of cloned per client, so the simulator no longer
//! pays a full-model copy per fetch (the wire ledger still charges one).
//!
//! Under a delta fetch the wire unit is the whole *segment* (keys never go
//! up, so the server cannot diff finer): a client re-downloads only the
//! segments written since its last fetch. Keyed segments are written by
//! nearly every round, so Option 1 benefits least from the cross-round
//! cache — which is itself part of the §3.2 trade-off story.

use super::piece::{DeltaPlan, FetchOutcome, SlicePlan};
use super::{CommLedger, RoundComm, RoundSession, SliceService};
use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

#[derive(Default)]
pub struct BroadcastService;

impl BroadcastService {
    pub fn new() -> Self {
        Self
    }
}

struct BroadcastSession<'a> {
    store: &'a ParamStore,
    plan: SlicePlan,
    ledger: CommLedger,
}

impl SliceService for BroadcastService {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn begin_round<'a>(
        &'a mut self,
        store: &'a ParamStore,
        spec: &'a SelectSpec,
    ) -> Result<Box<dyn RoundSession + 'a>> {
        Ok(Box::new(BroadcastSession {
            store,
            plan: SlicePlan::new(store, spec),
            ledger: CommLedger::default(),
        }))
    }
}

impl RoundSession for BroadcastSession<'_> {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn fetch_delta(&self, keys: &[Vec<u32>], delta: &DeltaPlan) -> Result<FetchOutcome> {
        // Full model over the wire, minus cache-fresh segments; ψ runs
        // client-side (not counted as server psi_evals). With an empty
        // delta this charges exactly `store.bytes()` — the legacy ledger.
        let (mut down, mut hits, mut hit_bytes) = (0u64, 0u64, 0u64);
        for (i, seg) in self.store.segments.iter().enumerate() {
            let b = seg.len() as u64 * 4;
            if delta.fresh_segs.contains(&i) {
                hits += 1;
                hit_bytes += b;
            } else {
                down += b;
            }
        }
        self.ledger.add_down_bytes(down);
        self.ledger.add_client_cache_hits(hits);
        Ok(FetchOutcome {
            bundle: self.plan.fetch(self.store, keys)?,
            down_bytes: down,
            piece_hits: hits,
            hit_bytes,
        })
    }

    fn finish(self: Box<Self>) -> RoundComm {
        self.ledger.snapshot()
    }
}

//! Slice plans, bundles, and per-key "pieces".
//!
//! [`SlicePlan`] is the per-round resolution of a [`SelectSpec`] against one
//! model snapshot: every binding is resolved once to either a shared
//! broadcast segment (cloned **once per round** into an `Arc`, then handed
//! to every client for free) or to the `(segment, group, row-range)` spans a
//! key selects. Sessions build one plan in `begin_round` and serve the whole
//! cohort from it — the plan is immutable, so fetches can run concurrently.
//!
//! [`SliceBundle`] is the unit of delivery: one [`SliceSeg`] per binding in
//! artifact parameter order, `Arc`-shared for broadcast segments and owned
//! for keyed slices.
//!
//! A *piece* is the unit of storage for on-demand memoization and CDN
//! pre-generation: for keyspace `ks`, the piece of key `k` is the
//! concatenation, over the keyed bindings of `ks` in binding order, of that
//! key's `groups × row_len` elements (group-major). [`SlicePlan::assemble`]
//! reconstructs a client's bundle from pieces — the exact inverse used by
//! both [`super::on_demand`] and [`super::pregen`], so Options 2 and 3 are
//! byte-identical with Option 1's direct [`SlicePlan::fetch`].

use std::collections::HashSet;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::{Binding, KeyMap, ParamStore, SelectSpec};

/// Which of one client's pieces are *fresh* in its cross-round on-device
/// cache — built by [`crate::cache::FleetCaches::plan_for`] from the
/// client's cache versus the server's
/// [`VersionClock`](crate::cache::VersionClock), and consumed by
/// [`RoundSession::fetch_delta`](super::RoundSession::fetch_delta): fresh
/// pieces are served locally (ledgered as client-cache hits, zero downlink
/// bytes), everything else downloads exactly as a plain fetch would. The
/// default (empty) plan reproduces the cache-off ledger byte for byte.
#[derive(Clone, Debug, Default)]
pub struct DeltaPlan {
    /// Keyed pieces fresh in the client's cache, as `(keyspace, key)`.
    pub fresh_keys: HashSet<(usize, u32)>,
    /// Model segments (by segment index) whose full broadcast copy is
    /// fresh: `Binding::Full` segments under Options 2/3, any segment
    /// under Option 1's whole-model download.
    pub fresh_segs: HashSet<usize>,
}

impl DeltaPlan {
    /// Nothing is fresh: every piece downloads (the cache-off ledger).
    pub fn is_empty(&self) -> bool {
        self.fresh_keys.is_empty() && self.fresh_segs.is_empty()
    }
}

/// One client's delta-aware fetch result: the bundle (byte-identical to a
/// plain [`RoundSession::fetch`](super::RoundSession::fetch)) plus the
/// wire/cache split of its downlink.
#[derive(Clone, Debug)]
pub struct FetchOutcome {
    pub bundle: SliceBundle,
    /// Bytes that actually crossed the wire for this client (post-cache);
    /// equals `bundle`-level downlink when the delta plan is empty.
    pub down_bytes: u64,
    /// Piece/segment lookups served from the client's cache.
    pub piece_hits: u64,
    /// Bytes those hits would have cost on the wire.
    pub hit_bytes: u64,
}

/// One delivered buffer: a broadcast segment shared across the cohort, or a
/// keyed slice owned by this client.
#[derive(Clone, Debug)]
pub enum SliceSeg {
    /// Broadcast-in-full segment, cloned once per round and `Arc`-shared.
    Shared(Arc<Vec<f32>>),
    /// Keyed slice materialized for one client.
    Owned(Vec<f32>),
}

impl SliceSeg {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            SliceSeg::Shared(a) => a,
            SliceSeg::Owned(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// Take the data by value; a shared segment is unwrapped without a copy
    /// when this is the last reference.
    pub fn into_vec(self) -> Vec<f32> {
        match self {
            SliceSeg::Owned(v) => v,
            SliceSeg::Shared(a) => Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()),
        }
    }
}

impl PartialEq for SliceSeg {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// A client's sub-model: one segment per binding, artifact parameter order.
#[derive(Clone, Debug, PartialEq)]
pub struct SliceBundle {
    pub segs: Vec<SliceSeg>,
}

impl SliceBundle {
    pub fn num_segs(&self) -> usize {
        self.segs.len()
    }

    /// Total floats delivered (what the client must hold in memory).
    pub fn total_floats(&self) -> usize {
        self.segs.iter().map(|s| s.len()).sum()
    }

    /// Logical wire size of the bundle.
    pub fn bytes(&self) -> u64 {
        self.total_floats() as u64 * 4
    }

    pub fn as_slices(&self) -> Vec<&[f32]> {
        self.segs.iter().map(|s| s.as_slice()).collect()
    }

    /// Consume into plain vectors (engine input); shared segments are only
    /// copied if still aliased by other clients.
    pub fn into_vecs(self) -> Vec<Vec<f32>> {
        self.segs.into_iter().map(|s| s.into_vec()).collect()
    }

    /// Copy out as plain vectors (test/inspection helper).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        self.segs.iter().map(|s| s.as_slice().to_vec()).collect()
    }
}

/// Compute the piece for (`keyspace`, `key`).
pub fn piece_for_key(store: &ParamStore, spec: &SelectSpec, keyspace: usize, key: u32) -> Vec<f32> {
    let mut out = Vec::with_capacity(spec.per_key_floats(keyspace));
    for b in &spec.bindings {
        if let Binding::Keyed {
            seg,
            keyspace: ks,
            map,
        } = b
        {
            if *ks != keyspace {
                continue;
            }
            let src = &store.segments[*seg].data;
            let rl = map.row_len;
            for g in 0..map.groups {
                let s = (g * map.keys_total + key as usize) * rl;
                out.extend_from_slice(&src[s..s + rl]);
            }
        }
    }
    out
}

/// Bytes of one piece of `keyspace`.
pub fn piece_bytes(spec: &SelectSpec, keyspace: usize) -> u64 {
    (spec.per_key_floats(keyspace) * 4) as u64
}

/// Resolved form of one binding inside a [`SlicePlan`].
enum PlanEntry {
    /// Broadcast segment, cloned once at plan build and shared from then on.
    /// `seg` is the source segment id (delta plans track broadcast
    /// freshness per segment).
    Full { seg: usize, data: Arc<Vec<f32>> },
    /// Keyed binding: source segment + geometry + its offset inside a piece
    /// of its keyspace.
    Keyed {
        seg: usize,
        keyspace: usize,
        map: KeyMap,
        piece_offset: usize,
    },
}

/// Per-round, immutable resolution of a [`SelectSpec`] against one
/// [`ParamStore`] snapshot. Shared by every fetch of a round.
pub struct SlicePlan {
    entries: Vec<PlanEntry>,
    keyspace_sizes: Vec<usize>,
    /// Piece length (floats) per keyspace.
    per_key_floats: Vec<usize>,
    broadcast_floats: usize,
}

impl SlicePlan {
    pub fn new(store: &ParamStore, spec: &SelectSpec) -> SlicePlan {
        let nks = spec.keyspaces.len();
        let mut acc = vec![0usize; nks];
        let mut broadcast_floats = 0usize;
        let mut entries = Vec::with_capacity(spec.bindings.len());
        for b in &spec.bindings {
            match b {
                Binding::Full { seg } => {
                    // the one and only per-round copy of a broadcast segment
                    let data = Arc::new(store.segments[*seg].data.clone());
                    broadcast_floats += data.len();
                    entries.push(PlanEntry::Full { seg: *seg, data });
                }
                Binding::Keyed { seg, keyspace, map } => {
                    entries.push(PlanEntry::Keyed {
                        seg: *seg,
                        keyspace: *keyspace,
                        map: *map,
                        piece_offset: acc[*keyspace],
                    });
                    acc[*keyspace] += map.per_key();
                }
            }
        }
        SlicePlan {
            entries,
            keyspace_sizes: spec.keyspaces.iter().map(|k| k.size).collect(),
            per_key_floats: acc,
            broadcast_floats,
        }
    }

    pub fn num_keyspaces(&self) -> usize {
        self.keyspace_sizes.len()
    }

    /// Piece length (floats) of one key of `keyspace`.
    pub fn per_key_floats(&self, keyspace: usize) -> usize {
        self.per_key_floats[keyspace]
    }

    /// Bytes of one piece of `keyspace`.
    pub fn piece_bytes(&self, keyspace: usize) -> u64 {
        (self.per_key_floats[keyspace] * 4) as u64
    }

    /// Bytes broadcast to every client regardless of keys.
    pub fn broadcast_bytes(&self) -> u64 {
        self.broadcast_floats as u64 * 4
    }

    /// Keyed downlink bytes for one client's key sets.
    pub fn keyed_bytes(&self, keys: &[Vec<u32>]) -> u64 {
        keys.iter()
            .enumerate()
            .map(|(ks, kk)| kk.len() as u64 * self.piece_bytes(ks))
            .sum()
    }

    /// Downlink split of one client's fetch under a [`DeltaPlan`]:
    /// `(wire_bytes, cache_hits, hit_bytes)`. Broadcast segments are fresh
    /// or stale as whole segments; keyed pieces per key occurrence
    /// (duplicates pay or hit per occurrence, matching
    /// [`SlicePlan::keyed_bytes`]). An empty plan yields exactly
    /// `broadcast_bytes() + keyed_bytes(keys)` on the wire.
    pub fn delta_down_bytes(&self, keys: &[Vec<u32>], delta: &DeltaPlan) -> (u64, u64, u64) {
        let (mut down, mut hits, mut hit_bytes) = (0u64, 0u64, 0u64);
        for e in &self.entries {
            if let PlanEntry::Full { seg, data } = e {
                let b = data.len() as u64 * 4;
                if delta.fresh_segs.contains(seg) {
                    hits += 1;
                    hit_bytes += b;
                } else {
                    down += b;
                }
            }
        }
        for (ks, kk) in keys.iter().enumerate() {
            let pb = self.piece_bytes(ks);
            for &k in kk {
                if delta.fresh_keys.contains(&(ks, k)) {
                    hits += 1;
                    hit_bytes += pb;
                } else {
                    down += pb;
                }
            }
        }
        (down, hits, hit_bytes)
    }

    /// Validate key-set arity and ranges up front (so concurrent fetches
    /// fail with an error instead of an out-of-bounds panic).
    pub fn check_keys(&self, keys: &[Vec<u32>]) -> Result<()> {
        if keys.len() != self.keyspace_sizes.len() {
            return Err(Error::Shape(format!(
                "expected keys for {} keyspaces, got {}",
                self.keyspace_sizes.len(),
                keys.len()
            )));
        }
        for (ks, kk) in keys.iter().enumerate() {
            let size = self.keyspace_sizes[ks];
            if let Some(&bad) = kk.iter().find(|&&k| k as usize >= size) {
                return Err(Error::Shape(format!(
                    "key {bad} out of range for keyspace {ks} (size {size})"
                )));
            }
        }
        Ok(())
    }

    /// ψ for one client, straight out of the store: broadcast segments are
    /// `Arc`-shared (no per-client copy), keyed rows are copied directly
    /// from their resolved spans — no intermediate per-key pieces.
    pub fn fetch(&self, store: &ParamStore, keys: &[Vec<u32>]) -> Result<SliceBundle> {
        self.check_keys(keys)?;
        let mut segs = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            match e {
                PlanEntry::Full { data, .. } => segs.push(SliceSeg::Shared(data.clone())),
                PlanEntry::Keyed {
                    seg, keyspace, map, ..
                } => {
                    let src = &store.segments[*seg].data;
                    let kk = &keys[*keyspace];
                    let rl = map.row_len;
                    // destination (g, j) order is strictly sequential: build
                    // by append, no zero-fill pass (§Perf)
                    let mut buf = Vec::with_capacity(map.sliced_len(kk.len()));
                    for g in 0..map.groups {
                        let base = g * map.keys_total;
                        for &k in kk {
                            let s = (base + k as usize) * rl;
                            buf.extend_from_slice(&src[s..s + rl]);
                        }
                    }
                    debug_assert_eq!(buf.len(), map.sliced_len(kk.len()));
                    segs.push(SliceSeg::Owned(buf));
                }
            }
        }
        Ok(SliceBundle { segs })
    }

    /// Assemble one client's bundle from per-key pieces.
    ///
    /// `get_piece(ks, key)` must return the piece produced by
    /// [`piece_for_key`] against the same store/spec this plan was built on.
    pub fn assemble<'p>(
        &self,
        keys: &[Vec<u32>],
        mut get_piece: impl FnMut(usize, u32) -> &'p [f32],
    ) -> Result<SliceBundle> {
        self.check_keys(keys)?;
        let mut segs = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            match e {
                PlanEntry::Full { data, .. } => segs.push(SliceSeg::Shared(data.clone())),
                PlanEntry::Keyed {
                    keyspace,
                    map,
                    piece_offset,
                    ..
                } => {
                    let kk = &keys[*keyspace];
                    let rl = map.row_len;
                    let mut buf = Vec::with_capacity(map.sliced_len(kk.len()));
                    for g in 0..map.groups {
                        let s = piece_offset + g * rl;
                        for &k in kk {
                            let piece = get_piece(*keyspace, k);
                            buf.extend_from_slice(&piece[s..s + rl]);
                        }
                    }
                    debug_assert_eq!(buf.len(), map.sliced_len(kk.len()));
                    segs.push(SliceSeg::Owned(buf));
                }
            }
        }
        Ok(SliceBundle { segs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    fn random_keys(spec: &SelectSpec) -> Vec<Vec<u32>> {
        spec.keyspaces
            .iter()
            .map(|ks| {
                let m = (ks.size / 4).max(1);
                Rng::new(ks.size as u64, 1)
                    .sample_without_replacement(ks.size, m)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn plan_fetch_and_assembly_equal_direct_slice() {
        for arch in [
            ModelArch::logreg(32),
            ModelArch::mlp2nn(),
            ModelArch::cnn(),
            ModelArch::transformer(),
        ] {
            let store = arch.init_store(&mut Rng::new(9, 0));
            let spec = arch.select_spec();
            let keys = random_keys(&spec);
            let plan = SlicePlan::new(&store, &spec);
            let direct = spec.slice(&store, &keys).unwrap();

            // Option 1 path: spans straight out of the store
            let fetched = plan.fetch(&store, &keys).unwrap();
            assert_eq!(fetched.to_vecs(), direct, "{arch:?} fetch");
            assert_eq!(fetched.total_floats() as u64 * 4, fetched.bytes());

            // Options 2/3 path: via precomputed pieces
            let mut pieces = std::collections::HashMap::new();
            for (ks, kk) in keys.iter().enumerate() {
                for &k in kk {
                    pieces.insert((ks, k), piece_for_key(&store, &spec, ks, k));
                }
            }
            let assembled = plan
                .assemble(&keys, |ks, k| pieces.get(&(ks, k)).unwrap().as_slice())
                .unwrap();
            assert_eq!(assembled.to_vecs(), direct, "{arch:?} assemble");
        }
    }

    #[test]
    fn broadcast_segments_are_shared_not_recopied() {
        let arch = ModelArch::logreg(32);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        let keys = vec![vec![1u32, 3]];
        let a = plan.fetch(&store, &keys).unwrap();
        let b = plan.fetch(&store, &keys).unwrap();
        // logreg binding 1 is the Full bias segment
        match (&a.segs[1], &b.segs[1]) {
            (SliceSeg::Shared(x), SliceSeg::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "clients must share one Arc per round")
            }
            other => panic!("expected shared segments, got {other:?}"),
        }
    }

    #[test]
    fn plan_rejects_bad_keys() {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        assert!(plan.fetch(&store, &[vec![255u32]]).is_err());
        assert!(plan.fetch(&store, &[]).is_err());
        assert!(plan
            .assemble(&[vec![0u32], vec![0u32]], |_, _| &[])
            .is_err());
    }

    #[test]
    fn delta_down_bytes_splits_wire_and_cache() {
        let arch = ModelArch::logreg(32);
        let store = arch.init_store(&mut Rng::new(6, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        let keys = vec![vec![1u32, 3, 5]];
        // the empty plan reproduces the plain accounting exactly
        let (down, hits, hb) = plan.delta_down_bytes(&keys, &DeltaPlan::default());
        assert_eq!(down, plan.broadcast_bytes() + plan.keyed_bytes(&keys));
        assert_eq!((hits, hb), (0, 0));
        // fresh key 3 plus the fresh bias segment (logreg segment 1)
        let mut d = DeltaPlan::default();
        d.fresh_keys.insert((0, 3));
        d.fresh_segs.insert(1);
        assert!(!d.is_empty());
        let (down2, hits2, hb2) = plan.delta_down_bytes(&keys, &d);
        assert_eq!(down2 + hb2, down, "wire + cache must cover the bundle");
        assert_eq!(hits2, 2);
        assert_eq!(hb2, plan.piece_bytes(0) + plan.broadcast_bytes());
        assert!(down2 < down);
        // a fresh key the client did not select changes nothing
        let mut irrelevant = DeltaPlan::default();
        irrelevant.fresh_keys.insert((0, 31));
        let (down3, hits3, _) = plan.delta_down_bytes(&keys, &irrelevant);
        assert_eq!((down3, hits3), (down, 0));
    }

    #[test]
    fn ledger_geometry_helpers_match_spec() {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(4, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        assert_eq!(plan.num_keyspaces(), 2);
        for ks in 0..2 {
            assert_eq!(plan.per_key_floats(ks), spec.per_key_floats(ks));
            assert_eq!(plan.piece_bytes(ks), piece_bytes(&spec, ks));
        }
        assert_eq!(
            plan.broadcast_bytes(),
            (spec.broadcast_floats(&store) * 4) as u64
        );
    }
}

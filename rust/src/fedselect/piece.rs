//! Per-key slice "pieces": the unit of storage for on-demand memoization and
//! CDN pre-generation.
//!
//! For keyspace `ks`, the piece of key `k` is the concatenation, over the
//! keyed bindings of `ks` in binding order, of that key's `groups × row_len`
//! elements (group-major). [`assemble`] reconstructs a client's full slice
//! bundle from pieces plus the broadcast segments — the exact inverse used
//! by both [`super::on_demand`] and [`super::pregen`], so the two options
//! are byte-identical with Option 1.

use crate::model::{Binding, ParamStore, SelectSpec};

/// Compute the piece for (`keyspace`, `key`).
pub fn piece_for_key(store: &ParamStore, spec: &SelectSpec, keyspace: usize, key: u32) -> Vec<f32> {
    let mut out = Vec::with_capacity(spec.per_key_floats(keyspace));
    for b in &spec.bindings {
        if let Binding::Keyed {
            seg,
            keyspace: ks,
            map,
        } = b
        {
            if *ks != keyspace {
                continue;
            }
            let src = &store.segments[*seg].data;
            let rl = map.row_len;
            for g in 0..map.groups {
                let s = (g * map.keys_total + key as usize) * rl;
                out.extend_from_slice(&src[s..s + rl]);
            }
        }
    }
    out
}

/// Bytes of one piece of `keyspace`.
pub fn piece_bytes(spec: &SelectSpec, keyspace: usize) -> u64 {
    (spec.per_key_floats(keyspace) * 4) as u64
}

/// Assemble the client slice bundle (artifact parameter order) from pieces.
///
/// `get_piece(ks, key)` must return the piece produced by [`piece_for_key`].
pub fn assemble<'a>(
    store: &ParamStore,
    spec: &SelectSpec,
    keys: &[Vec<u32>],
    mut get_piece: impl FnMut(usize, u32) -> &'a [f32],
) -> Vec<Vec<f32>> {
    // Per-keyspace offset of each keyed binding within a piece.
    let nks = spec.keyspaces.len();
    let mut offsets = vec![0usize; spec.bindings.len()];
    let mut acc = vec![0usize; nks];
    for (i, b) in spec.bindings.iter().enumerate() {
        if let Binding::Keyed { keyspace, map, .. } = b {
            offsets[i] = acc[*keyspace];
            acc[*keyspace] += map.per_key();
        }
    }
    let mut out = Vec::with_capacity(spec.bindings.len());
    for (i, b) in spec.bindings.iter().enumerate() {
        match b {
            Binding::Full { seg } => out.push(store.segments[*seg].data.clone()),
            Binding::Keyed { keyspace, map, .. } => {
                let ks_keys = &keys[*keyspace];
                let m = ks_keys.len();
                let rl = map.row_len;
                // append in (g, j) order: destination is strictly sequential
                let mut buf = Vec::with_capacity(map.sliced_len(m));
                for g in 0..map.groups {
                    let s = offsets[i] + g * rl;
                    for &k in ks_keys {
                        let piece = get_piece(*keyspace, k);
                        buf.extend_from_slice(&piece[s..s + rl]);
                    }
                }
                debug_assert_eq!(buf.len(), map.sliced_len(m));
                out.push(buf);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    #[test]
    fn assemble_from_pieces_equals_direct_slice() {
        for arch in [
            ModelArch::logreg(32),
            ModelArch::mlp2nn(),
            ModelArch::cnn(),
            ModelArch::transformer(),
        ] {
            let store = arch.init_store(&mut Rng::new(9, 0));
            let spec = arch.select_spec();
            let keys: Vec<Vec<u32>> = spec
                .keyspaces
                .iter()
                .map(|ks| {
                    let m = (ks.size / 4).max(1);
                    Rng::new(ks.size as u64, 1)
                        .sample_without_replacement(ks.size, m)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect()
                })
                .collect();
            // precompute all needed pieces
            let mut pieces = std::collections::HashMap::new();
            for (ks, kk) in keys.iter().enumerate() {
                for &k in kk {
                    pieces.insert((ks, k), piece_for_key(&store, &spec, ks, k));
                }
            }
            let assembled = assemble(&store, &spec, &keys, |ks, k| {
                pieces.get(&(ks, k)).unwrap().as_slice()
            });
            let direct = spec.slice(&store, &keys).unwrap();
            assert_eq!(assembled, direct, "{arch:?}");
        }
    }
}

//! Slice plans, bundles, and per-key "pieces".
//!
//! [`SlicePlan`] is the per-round resolution of a [`SelectSpec`] against one
//! model snapshot: every binding is resolved once to either a shared
//! broadcast segment (cloned **once per round** into an `Arc`, then handed
//! to every client for free) or to the `(segment, group, row-range)` spans a
//! key selects. Sessions build one plan in `begin_round` and serve the whole
//! cohort from it — the plan is immutable, so fetches can run concurrently.
//!
//! [`SliceBundle`] is the unit of delivery: one [`SliceSeg`] per binding in
//! artifact parameter order, `Arc`-shared for broadcast segments and owned
//! for keyed slices.
//!
//! A *piece* is the unit of storage for on-demand memoization and CDN
//! pre-generation: for keyspace `ks`, the piece of key `k` is the
//! concatenation, over the keyed bindings of `ks` in binding order, of that
//! key's `groups × row_len` elements (group-major). [`SlicePlan::assemble`]
//! reconstructs a client's bundle from pieces — the exact inverse used by
//! both [`super::on_demand`] and [`super::pregen`], so Options 2 and 3 are
//! byte-identical with Option 1's direct [`SlicePlan::fetch`].

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::{Binding, KeyMap, ParamStore, SelectSpec};

/// One delivered buffer: a broadcast segment shared across the cohort, or a
/// keyed slice owned by this client.
#[derive(Clone, Debug)]
pub enum SliceSeg {
    /// Broadcast-in-full segment, cloned once per round and `Arc`-shared.
    Shared(Arc<Vec<f32>>),
    /// Keyed slice materialized for one client.
    Owned(Vec<f32>),
}

impl SliceSeg {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            SliceSeg::Shared(a) => a,
            SliceSeg::Owned(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// Take the data by value; a shared segment is unwrapped without a copy
    /// when this is the last reference.
    pub fn into_vec(self) -> Vec<f32> {
        match self {
            SliceSeg::Owned(v) => v,
            SliceSeg::Shared(a) => Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()),
        }
    }
}

impl PartialEq for SliceSeg {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// A client's sub-model: one segment per binding, artifact parameter order.
#[derive(Clone, Debug, PartialEq)]
pub struct SliceBundle {
    pub segs: Vec<SliceSeg>,
}

impl SliceBundle {
    pub fn num_segs(&self) -> usize {
        self.segs.len()
    }

    /// Total floats delivered (what the client must hold in memory).
    pub fn total_floats(&self) -> usize {
        self.segs.iter().map(|s| s.len()).sum()
    }

    /// Logical wire size of the bundle.
    pub fn bytes(&self) -> u64 {
        self.total_floats() as u64 * 4
    }

    pub fn as_slices(&self) -> Vec<&[f32]> {
        self.segs.iter().map(|s| s.as_slice()).collect()
    }

    /// Consume into plain vectors (engine input); shared segments are only
    /// copied if still aliased by other clients.
    pub fn into_vecs(self) -> Vec<Vec<f32>> {
        self.segs.into_iter().map(|s| s.into_vec()).collect()
    }

    /// Copy out as plain vectors (test/inspection helper).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        self.segs.iter().map(|s| s.as_slice().to_vec()).collect()
    }
}

/// Compute the piece for (`keyspace`, `key`).
pub fn piece_for_key(store: &ParamStore, spec: &SelectSpec, keyspace: usize, key: u32) -> Vec<f32> {
    let mut out = Vec::with_capacity(spec.per_key_floats(keyspace));
    for b in &spec.bindings {
        if let Binding::Keyed {
            seg,
            keyspace: ks,
            map,
        } = b
        {
            if *ks != keyspace {
                continue;
            }
            let src = &store.segments[*seg].data;
            let rl = map.row_len;
            for g in 0..map.groups {
                let s = (g * map.keys_total + key as usize) * rl;
                out.extend_from_slice(&src[s..s + rl]);
            }
        }
    }
    out
}

/// Bytes of one piece of `keyspace`.
pub fn piece_bytes(spec: &SelectSpec, keyspace: usize) -> u64 {
    (spec.per_key_floats(keyspace) * 4) as u64
}

/// Resolved form of one binding inside a [`SlicePlan`].
enum PlanEntry {
    /// Broadcast segment, cloned once at plan build and shared from then on.
    Full { data: Arc<Vec<f32>> },
    /// Keyed binding: source segment + geometry + its offset inside a piece
    /// of its keyspace.
    Keyed {
        seg: usize,
        keyspace: usize,
        map: KeyMap,
        piece_offset: usize,
    },
}

/// Per-round, immutable resolution of a [`SelectSpec`] against one
/// [`ParamStore`] snapshot. Shared by every fetch of a round.
pub struct SlicePlan {
    entries: Vec<PlanEntry>,
    keyspace_sizes: Vec<usize>,
    /// Piece length (floats) per keyspace.
    per_key_floats: Vec<usize>,
    broadcast_floats: usize,
}

impl SlicePlan {
    pub fn new(store: &ParamStore, spec: &SelectSpec) -> SlicePlan {
        let nks = spec.keyspaces.len();
        let mut acc = vec![0usize; nks];
        let mut broadcast_floats = 0usize;
        let mut entries = Vec::with_capacity(spec.bindings.len());
        for b in &spec.bindings {
            match b {
                Binding::Full { seg } => {
                    // the one and only per-round copy of a broadcast segment
                    let data = Arc::new(store.segments[*seg].data.clone());
                    broadcast_floats += data.len();
                    entries.push(PlanEntry::Full { data });
                }
                Binding::Keyed { seg, keyspace, map } => {
                    entries.push(PlanEntry::Keyed {
                        seg: *seg,
                        keyspace: *keyspace,
                        map: *map,
                        piece_offset: acc[*keyspace],
                    });
                    acc[*keyspace] += map.per_key();
                }
            }
        }
        SlicePlan {
            entries,
            keyspace_sizes: spec.keyspaces.iter().map(|k| k.size).collect(),
            per_key_floats: acc,
            broadcast_floats,
        }
    }

    pub fn num_keyspaces(&self) -> usize {
        self.keyspace_sizes.len()
    }

    /// Piece length (floats) of one key of `keyspace`.
    pub fn per_key_floats(&self, keyspace: usize) -> usize {
        self.per_key_floats[keyspace]
    }

    /// Bytes of one piece of `keyspace`.
    pub fn piece_bytes(&self, keyspace: usize) -> u64 {
        (self.per_key_floats[keyspace] * 4) as u64
    }

    /// Bytes broadcast to every client regardless of keys.
    pub fn broadcast_bytes(&self) -> u64 {
        self.broadcast_floats as u64 * 4
    }

    /// Keyed downlink bytes for one client's key sets.
    pub fn keyed_bytes(&self, keys: &[Vec<u32>]) -> u64 {
        keys.iter()
            .enumerate()
            .map(|(ks, kk)| kk.len() as u64 * self.piece_bytes(ks))
            .sum()
    }

    /// Validate key-set arity and ranges up front (so concurrent fetches
    /// fail with an error instead of an out-of-bounds panic).
    pub fn check_keys(&self, keys: &[Vec<u32>]) -> Result<()> {
        if keys.len() != self.keyspace_sizes.len() {
            return Err(Error::Shape(format!(
                "expected keys for {} keyspaces, got {}",
                self.keyspace_sizes.len(),
                keys.len()
            )));
        }
        for (ks, kk) in keys.iter().enumerate() {
            let size = self.keyspace_sizes[ks];
            if let Some(&bad) = kk.iter().find(|&&k| k as usize >= size) {
                return Err(Error::Shape(format!(
                    "key {bad} out of range for keyspace {ks} (size {size})"
                )));
            }
        }
        Ok(())
    }

    /// ψ for one client, straight out of the store: broadcast segments are
    /// `Arc`-shared (no per-client copy), keyed rows are copied directly
    /// from their resolved spans — no intermediate per-key pieces.
    pub fn fetch(&self, store: &ParamStore, keys: &[Vec<u32>]) -> Result<SliceBundle> {
        self.check_keys(keys)?;
        let mut segs = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            match e {
                PlanEntry::Full { data } => segs.push(SliceSeg::Shared(data.clone())),
                PlanEntry::Keyed {
                    seg, keyspace, map, ..
                } => {
                    let src = &store.segments[*seg].data;
                    let kk = &keys[*keyspace];
                    let rl = map.row_len;
                    // destination (g, j) order is strictly sequential: build
                    // by append, no zero-fill pass (§Perf)
                    let mut buf = Vec::with_capacity(map.sliced_len(kk.len()));
                    for g in 0..map.groups {
                        let base = g * map.keys_total;
                        for &k in kk {
                            let s = (base + k as usize) * rl;
                            buf.extend_from_slice(&src[s..s + rl]);
                        }
                    }
                    debug_assert_eq!(buf.len(), map.sliced_len(kk.len()));
                    segs.push(SliceSeg::Owned(buf));
                }
            }
        }
        Ok(SliceBundle { segs })
    }

    /// Assemble one client's bundle from per-key pieces.
    ///
    /// `get_piece(ks, key)` must return the piece produced by
    /// [`piece_for_key`] against the same store/spec this plan was built on.
    pub fn assemble<'p>(
        &self,
        keys: &[Vec<u32>],
        mut get_piece: impl FnMut(usize, u32) -> &'p [f32],
    ) -> Result<SliceBundle> {
        self.check_keys(keys)?;
        let mut segs = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            match e {
                PlanEntry::Full { data } => segs.push(SliceSeg::Shared(data.clone())),
                PlanEntry::Keyed {
                    keyspace,
                    map,
                    piece_offset,
                    ..
                } => {
                    let kk = &keys[*keyspace];
                    let rl = map.row_len;
                    let mut buf = Vec::with_capacity(map.sliced_len(kk.len()));
                    for g in 0..map.groups {
                        let s = piece_offset + g * rl;
                        for &k in kk {
                            let piece = get_piece(*keyspace, k);
                            buf.extend_from_slice(&piece[s..s + rl]);
                        }
                    }
                    debug_assert_eq!(buf.len(), map.sliced_len(kk.len()));
                    segs.push(SliceSeg::Owned(buf));
                }
            }
        }
        Ok(SliceBundle { segs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    fn random_keys(spec: &SelectSpec) -> Vec<Vec<u32>> {
        spec.keyspaces
            .iter()
            .map(|ks| {
                let m = (ks.size / 4).max(1);
                Rng::new(ks.size as u64, 1)
                    .sample_without_replacement(ks.size, m)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn plan_fetch_and_assembly_equal_direct_slice() {
        for arch in [
            ModelArch::logreg(32),
            ModelArch::mlp2nn(),
            ModelArch::cnn(),
            ModelArch::transformer(),
        ] {
            let store = arch.init_store(&mut Rng::new(9, 0));
            let spec = arch.select_spec();
            let keys = random_keys(&spec);
            let plan = SlicePlan::new(&store, &spec);
            let direct = spec.slice(&store, &keys).unwrap();

            // Option 1 path: spans straight out of the store
            let fetched = plan.fetch(&store, &keys).unwrap();
            assert_eq!(fetched.to_vecs(), direct, "{arch:?} fetch");
            assert_eq!(fetched.total_floats() as u64 * 4, fetched.bytes());

            // Options 2/3 path: via precomputed pieces
            let mut pieces = std::collections::HashMap::new();
            for (ks, kk) in keys.iter().enumerate() {
                for &k in kk {
                    pieces.insert((ks, k), piece_for_key(&store, &spec, ks, k));
                }
            }
            let assembled = plan
                .assemble(&keys, |ks, k| pieces.get(&(ks, k)).unwrap().as_slice())
                .unwrap();
            assert_eq!(assembled.to_vecs(), direct, "{arch:?} assemble");
        }
    }

    #[test]
    fn broadcast_segments_are_shared_not_recopied() {
        let arch = ModelArch::logreg(32);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        let keys = vec![vec![1u32, 3]];
        let a = plan.fetch(&store, &keys).unwrap();
        let b = plan.fetch(&store, &keys).unwrap();
        // logreg binding 1 is the Full bias segment
        match (&a.segs[1], &b.segs[1]) {
            (SliceSeg::Shared(x), SliceSeg::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "clients must share one Arc per round")
            }
            other => panic!("expected shared segments, got {other:?}"),
        }
    }

    #[test]
    fn plan_rejects_bad_keys() {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        assert!(plan.fetch(&store, &[vec![255u32]]).is_err());
        assert!(plan.fetch(&store, &[]).is_err());
        assert!(plan
            .assemble(&[vec![0u32], vec![0u32]], |_, _| &[])
            .is_err());
    }

    #[test]
    fn ledger_geometry_helpers_match_spec() {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(4, 0));
        let spec = arch.select_spec();
        let plan = SlicePlan::new(&store, &spec);
        assert_eq!(plan.num_keyspaces(), 2);
        for ks in 0..2 {
            assert_eq!(plan.per_key_floats(ks), spec.per_key_floats(ks));
            assert_eq!(plan.piece_bytes(ks), piece_bytes(&spec, ks));
        }
        assert_eq!(
            plan.broadcast_bytes(),
            (spec.broadcast_floats(&store) * 4) as u64
        );
    }
}

//! Option 3 (paper §3.2/§6): pre-generation of slices to a CDN.
//!
//! Before each round the server evaluates ψ for *every* key in every
//! keyspace and publishes the pieces to the [`crate::cdn::CdnStore`];
//! clients then query the CDN directly. Amortizes ψ across overlapping
//! client key sets, moves serving off the training server, and enables the
//! data-minimization barrier / PIR discussion of §6 — at the cost of
//! computing slices nobody may download when K is large.

use std::collections::HashMap;

use super::piece::{assemble, piece_bytes, piece_for_key};
use super::{RoundComm, SliceService};
use crate::cdn::CdnStore;
use crate::error::{Error, Result};
use crate::model::{ParamStore, SelectSpec};

pub struct PregenCdnService {
    cdn: CdnStore,
    ledger: RoundComm,
}

impl PregenCdnService {
    pub fn new() -> Self {
        PregenCdnService {
            cdn: CdnStore::new(8),
            ledger: RoundComm::default(),
        }
    }

    pub fn with_cdn(cdn: CdnStore) -> Self {
        PregenCdnService {
            cdn,
            ledger: RoundComm::default(),
        }
    }

    pub fn cdn(&self) -> &CdnStore {
        &self.cdn
    }
}

impl Default for PregenCdnService {
    fn default() -> Self {
        Self::new()
    }
}

impl SliceService for PregenCdnService {
    fn name(&self) -> &'static str {
        "pregen-cdn"
    }

    fn begin_round(&mut self, store: &ParamStore, spec: &SelectSpec) -> Result<()> {
        // ψ(x, k) for all k in all keyspaces, published as one version.
        let mut pieces = HashMap::new();
        for (ks, keyspace) in spec.keyspaces.iter().enumerate() {
            for k in 0..keyspace.size as u32 {
                let piece = piece_for_key(store, spec, ks, k);
                self.ledger.psi_evals += 1;
                self.ledger.service_us += 1 + piece.len() as u64 / 256;
                pieces.insert((ks, k), piece);
            }
        }
        self.ledger.pregen_slices += pieces.len() as u64;
        self.cdn.publish(pieces);
        Ok(())
    }

    fn fetch(
        &mut self,
        store: &ParamStore,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
    ) -> Result<Vec<Vec<f32>>> {
        // keys go up to the CDN (not the training server)
        let total_keys: usize = keys.iter().map(|k| k.len()).sum();
        self.ledger.up_key_bytes += (total_keys * 4) as u64;
        self.ledger.cdn_queries += total_keys as u64;

        let bcast = spec.broadcast_floats(store) * 4;
        let keyed: u64 = keys
            .iter()
            .enumerate()
            .map(|(ks, kk)| kk.len() as u64 * piece_bytes(spec, ks))
            .sum();
        self.ledger.down_bytes += bcast as u64 + keyed;

        // pull pieces through the CDN (records shard load / latency)
        let mut fetched: HashMap<(usize, u32), Vec<f32>> = HashMap::new();
        for (ks, kk) in keys.iter().enumerate() {
            for &k in kk {
                if fetched.contains_key(&(ks, k)) {
                    continue;
                }
                let piece = self
                    .cdn
                    .query(ks, k)
                    .ok_or_else(|| Error::Shape(format!("CDN missing piece ({ks}, {k})")))?
                    .to_vec();
                fetched.insert((ks, k), piece);
            }
        }
        self.ledger.service_us = self.ledger.service_us.max(self.cdn.makespan_us());
        Ok(assemble(store, spec, keys, |ks, k| {
            fetched.get(&(ks, k)).expect("fetched above").as_slice()
        }))
    }

    fn end_round(&mut self) -> RoundComm {
        self.cdn.reset_stats();
        std::mem::take(&mut self.ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    #[test]
    fn pregen_publishes_every_key_once() {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let mut svc = PregenCdnService::new();
        svc.begin_round(&store, &spec).unwrap();
        // vocab (2048) + ffn (512) pieces
        assert_eq!(svc.cdn().num_pieces(), 2048 + 512);
        let keys = vec![vec![0u32, 7, 2047], vec![3u32, 500]];
        let got = svc.fetch(&store, &spec, &keys).unwrap();
        let want = spec.slice(&store, &keys).unwrap();
        assert_eq!(got, want);
        let ledger = svc.end_round();
        assert_eq!(ledger.pregen_slices, 2560);
        assert_eq!(ledger.cdn_queries, 5);
    }

    #[test]
    fn missing_key_is_an_error() {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let mut svc = PregenCdnService::new();
        svc.begin_round(&store, &spec).unwrap();
        let bad = vec![vec![255u32]];
        assert!(svc.fetch(&store, &spec, &bad).is_err());
    }
}

//! Option 3 (paper §3.2/§6): pre-generation of slices to a CDN.
//!
//! `begin_round` evaluates ψ for *every* key in every keyspace and publishes
//! the pieces to the [`crate::cdn::CdnStore`] as one version; the session
//! then serves the whole cohort straight off the CDN (queries are `&self`
//! and `Arc`-shared, so fetch threads contend on nothing but atomic
//! counters). Amortizes ψ across overlapping client key sets, moves serving
//! off the training server, and enables the data-minimization barrier / PIR
//! discussion of §6 — at the cost of computing slices nobody may download
//! when K is large.

use std::collections::HashMap;
use std::sync::Arc;

use super::piece::{piece_for_key, DeltaPlan, FetchOutcome, SlicePlan};
use super::{CommLedger, RoundComm, RoundSession, SliceService};
use crate::cdn::CdnStore;
use crate::error::{Error, Result};
use crate::model::{ParamStore, SelectSpec};

pub struct PregenCdnService {
    cdn: CdnStore,
}

impl PregenCdnService {
    pub fn new() -> Self {
        PregenCdnService {
            cdn: CdnStore::new(8),
        }
    }

    pub fn with_cdn(cdn: CdnStore) -> Self {
        PregenCdnService { cdn }
    }

    pub fn cdn(&self) -> &CdnStore {
        &self.cdn
    }
}

impl Default for PregenCdnService {
    fn default() -> Self {
        Self::new()
    }
}

struct PregenSession<'a> {
    plan: SlicePlan,
    cdn: &'a CdnStore,
    ledger: CommLedger,
}

impl SliceService for PregenCdnService {
    fn name(&self) -> &'static str {
        "pregen-cdn"
    }

    fn begin_round<'a>(
        &'a mut self,
        store: &'a ParamStore,
        spec: &'a SelectSpec,
    ) -> Result<Box<dyn RoundSession + 'a>> {
        // ψ(x, k) for all k in all keyspaces, published as one version.
        let mut pieces = HashMap::new();
        let mut psi = 0u64;
        let mut us = 0u64;
        for (ks, keyspace) in spec.keyspaces.iter().enumerate() {
            for k in 0..keyspace.size as u32 {
                let piece = piece_for_key(store, spec, ks, k);
                psi += 1;
                us += 1 + piece.len() as u64 / 256;
                pieces.insert((ks, k), piece);
            }
        }
        let pregen = pieces.len() as u64;
        self.cdn.publish(pieces);

        let ledger = CommLedger::default();
        ledger.add_psi_evals(psi);
        ledger.add_service_us(us);
        ledger.add_pregen_slices(pregen);
        Ok(Box::new(PregenSession {
            plan: SlicePlan::new(store, spec),
            cdn: &self.cdn,
            ledger,
        }))
    }

    /// Namespace the CDN piece addresses by job id (multi-tenant runs
    /// sharing one CDN publish into disjoint address prefixes).
    fn set_namespace(&mut self, ns: u32) {
        self.cdn.set_ns(ns);
    }
}

impl RoundSession for PregenSession<'_> {
    fn name(&self) -> &'static str {
        "pregen-cdn"
    }

    fn fetch_delta(&self, keys: &[Vec<u32>], delta: &DeltaPlan) -> Result<FetchOutcome> {
        self.plan.check_keys(keys)?;
        // keys go up to the CDN (not the training server); cache-fresh keys
        // included — the CDN answers "fresh" from the same query path, so
        // revalidation is charged exactly like serving (shard load and
        // latency too), only the payload bytes differ.
        let total_keys: usize = keys.iter().map(|k| k.len()).sum();
        self.ledger.add_up_key_bytes((total_keys * 4) as u64);
        self.ledger.add_cdn_queries(total_keys as u64);

        let (down, hits, hit_bytes) = self.plan.delta_down_bytes(keys, delta);
        self.ledger.add_down_bytes(down);
        self.ledger.add_client_cache_hits(hits);

        // pull pieces through the CDN (records shard load / latency)
        let mut fetched: HashMap<(usize, u32), Arc<Vec<f32>>> =
            HashMap::with_capacity(total_keys);
        for (ks, kk) in keys.iter().enumerate() {
            for &k in kk {
                if fetched.contains_key(&(ks, k)) {
                    continue;
                }
                let piece = self
                    .cdn
                    .query(ks, k)
                    .ok_or_else(|| Error::Shape(format!("CDN missing piece ({ks}, {k})")))?;
                fetched.insert((ks, k), piece);
            }
        }
        Ok(FetchOutcome {
            bundle: self
                .plan
                .assemble(keys, |ks, k| fetched[&(ks, k)].as_slice())?,
            down_bytes: down,
            piece_hits: hits,
            hit_bytes,
        })
    }

    fn finish(self: Box<Self>) -> RoundComm {
        // the busiest shard bounds round completion (peak-demand accounting)
        self.ledger.max_service_us(self.cdn.makespan_us());
        let comm = self.ledger.snapshot();
        self.cdn.reset_stats();
        comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    #[test]
    fn pregen_publishes_every_key_once() {
        let arch = ModelArch::transformer();
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let mut svc = PregenCdnService::new();
        let sess = svc.begin_round(&store, &spec).unwrap();
        let keys = vec![vec![0u32, 7, 2047], vec![3u32, 500]];
        let got = sess.fetch(&keys).unwrap().to_vecs();
        let want = spec.slice(&store, &keys).unwrap();
        assert_eq!(got, want);
        let ledger = sess.finish();
        // vocab (2048) + ffn (512) pieces
        assert_eq!(svc.cdn().num_pieces(), 2048 + 512);
        assert_eq!(ledger.pregen_slices, 2560);
        assert_eq!(ledger.cdn_queries, 5);
    }

    #[test]
    fn missing_key_is_an_error() {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(2, 0));
        let spec = arch.select_spec();
        let mut svc = PregenCdnService::new();
        let sess = svc.begin_round(&store, &spec).unwrap();
        let bad = vec![vec![255u32]];
        assert!(sess.fetch(&bad).is_err());
    }
}

//! Client select-key policies (paper §4.1 and the §5 ablations).
//!
//! Structured policies derive keys from the client's local feature
//! frequencies (§4.1.1); random policies sample the keyspace (§4.1.2);
//! `FixedPerRound` reproduces the Fig. 6 ablation where all clients in a
//! round share one random key set (which a server could serve with plain
//! BROADCAST). `AllKeys` (m = K) recovers training without FedSelect.

use crate::data::ClientData;
use crate::tensor::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyPolicy {
    /// "Top": the client's m most frequent local features (§5.2).
    TopFreq { m: usize },
    /// "Random": m uniform draws from the client's local feature set.
    RandomLocal { m: usize },
    /// "Random Top": m uniform draws from the client's top-2m features.
    RandomTopLocal { m: usize },
    /// m uniform draws from the whole keyspace [K] (no local structure, §5.3).
    RandomGlobal { m: usize },
    /// One random key set per round, shared by every client (Fig. 6 "True").
    FixedPerRound { m: usize },
    /// All K keys in order — recovers BROADCAST (§3.3).
    AllKeys,
}

impl KeyPolicy {
    /// Number of keys this policy yields for a keyspace of size `k`.
    pub fn m(&self, k: usize) -> usize {
        match *self {
            KeyPolicy::TopFreq { m }
            | KeyPolicy::RandomLocal { m }
            | KeyPolicy::RandomTopLocal { m }
            | KeyPolicy::RandomGlobal { m }
            | KeyPolicy::FixedPerRound { m } => m.min(k),
            KeyPolicy::AllKeys => k,
        }
    }

    /// The same policy with its key budget replaced by `m` — how the
    /// scheduler's per-client budgets (e.g. `MemoryCapped`) are applied.
    /// `AllKeys` and `FixedPerRound` are budget-less (the former is the
    /// BROADCAST identity, the latter serves one shared cohort-wide slice)
    /// and are returned unchanged.
    pub fn with_m(self, m: usize) -> KeyPolicy {
        match self {
            KeyPolicy::TopFreq { .. } => KeyPolicy::TopFreq { m },
            KeyPolicy::RandomLocal { .. } => KeyPolicy::RandomLocal { m },
            KeyPolicy::RandomTopLocal { .. } => KeyPolicy::RandomTopLocal { m },
            KeyPolicy::RandomGlobal { .. } => KeyPolicy::RandomGlobal { m },
            KeyPolicy::FixedPerRound { m: orig } => KeyPolicy::FixedPerRound { m: orig },
            KeyPolicy::AllKeys => KeyPolicy::AllKeys,
        }
    }

    /// Whether the coordinator must draw one shared key set per round.
    pub fn needs_round_keys(&self) -> bool {
        matches!(self, KeyPolicy::FixedPerRound { .. })
    }

    /// Draw the shared per-round key set (for [`KeyPolicy::FixedPerRound`]).
    pub fn round_keys(&self, k: usize, rng: &mut Rng) -> Option<Vec<u32>> {
        match *self {
            KeyPolicy::FixedPerRound { m } => Some(
                rng.sample_without_replacement(k, m.min(k))
                    .into_iter()
                    .map(|x| x as u32)
                    .collect(),
            ),
            _ => None,
        }
    }

    /// Select this client's keys.
    ///
    /// * `k` — keyspace size.
    /// * `round_shared` — the per-round key set when `FixedPerRound`.
    /// * `force_key_zero` — guarantee key 0 is *included* (the transformer's
    ///   UNK token embedding; see `data::text`). Position is irrelevant —
    ///   the batch builder looks the UNK slot up by key value.
    ///
    /// Always returns exactly `self.m(k)` *distinct* keys (structured
    /// policies pad with globally-frequent indices when the client's local
    /// feature set is too small — global rank order == index order in the
    /// synthetic corpora).
    pub fn keys_for(
        &self,
        client: &ClientData,
        k: usize,
        rng: &mut Rng,
        round_shared: Option<&[u32]>,
        force_key_zero: bool,
    ) -> Vec<u32> {
        let m = self.m(k);
        let mut keys: Vec<u32> = match *self {
            KeyPolicy::TopFreq { .. } => {
                let mut f = client.features_by_frequency();
                f.retain(|&w| (w as usize) < k);
                f.truncate(m);
                f
            }
            KeyPolicy::RandomLocal { .. } => {
                let mut f = client.features_by_frequency();
                f.retain(|&w| (w as usize) < k);
                rng.shuffle(&mut f);
                f.truncate(m);
                f
            }
            KeyPolicy::RandomTopLocal { .. } => {
                let mut f = client.features_by_frequency();
                f.retain(|&w| (w as usize) < k);
                f.truncate(2 * m);
                rng.shuffle(&mut f);
                f.truncate(m);
                f
            }
            KeyPolicy::RandomGlobal { .. } => rng
                .sample_without_replacement(k, m)
                .into_iter()
                .map(|x| x as u32)
                .collect(),
            KeyPolicy::FixedPerRound { .. } => round_shared
                .expect("FixedPerRound requires round_keys()")
                .to_vec(),
            KeyPolicy::AllKeys => (0..k as u32).collect(),
        };
        // pad with globally-frequent (low-index) keys not already present
        if keys.len() < m {
            let present: std::collections::HashSet<u32> = keys.iter().copied().collect();
            for cand in 0..k as u32 {
                if keys.len() >= m {
                    break;
                }
                if !present.contains(&cand) {
                    keys.push(cand);
                }
            }
        }
        if force_key_zero && !keys.contains(&0) {
            let last = keys.len() - 1;
            keys[last] = 0;
            keys.swap(0, last);
        }
        debug_assert_eq!(keys.len(), m);
        keys
    }
}

/// Canonical CLI spelling (`kind:m`, or `all`); round-trips with `FromStr`.
impl std::fmt::Display for KeyPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            KeyPolicy::TopFreq { m } => write!(f, "top:{m}"),
            KeyPolicy::RandomLocal { m } => write!(f, "random-local:{m}"),
            KeyPolicy::RandomTopLocal { m } => write!(f, "random-top:{m}"),
            KeyPolicy::RandomGlobal { m } => write!(f, "random-global:{m}"),
            KeyPolicy::FixedPerRound { m } => write!(f, "fixed-round:{m}"),
            KeyPolicy::AllKeys => f.write_str("all"),
        }
    }
}

impl std::str::FromStr for KeyPolicy {
    type Err = String;

    /// e.g. "top:1000", "random-local:1000", "random-global:32",
    /// "fixed-round:32", "all". Kinds are case-insensitive.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        if lower == "all" {
            return Ok(KeyPolicy::AllKeys);
        }
        let (kind, m) = lower
            .split_once(':')
            .ok_or_else(|| format!("bad key policy {s:?} (want kind:m, or \"all\")"))?;
        let m: usize = m.parse().map_err(|e| format!("bad m in {s:?}: {e}"))?;
        match kind {
            "top" => Ok(KeyPolicy::TopFreq { m }),
            "random-local" => Ok(KeyPolicy::RandomLocal { m }),
            "random-top" => Ok(KeyPolicy::RandomTopLocal { m }),
            "random-global" => Ok(KeyPolicy::RandomGlobal { m }),
            "fixed-round" => Ok(KeyPolicy::FixedPerRound { m }),
            other => Err(format!(
                "unknown key policy kind {other:?} (want top, random-local, \
                 random-top, random-global, fixed-round, or all)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;

    fn client() -> ClientData {
        let examples = vec![
            Example::Bow {
                words: vec![7, 3, 9],
                tags: vec![0],
            },
            Example::Bow {
                words: vec![3, 9],
                tags: vec![0],
            },
            Example::Bow {
                words: vec![3],
                tags: vec![0],
            },
        ];
        let feature_counts = ClientData::compute_feature_counts(&examples);
        ClientData {
            id: 1,
            examples,
            feature_counts,
        }
    }

    #[test]
    fn top_freq_orders_by_local_frequency() {
        let c = client();
        let mut rng = Rng::new(0, 0);
        let keys = KeyPolicy::TopFreq { m: 2 }.keys_for(&c, 16, &mut rng, None, false);
        assert_eq!(keys, vec![3, 9]); // 3 appears 3x, 9 2x, 7 1x
    }

    #[test]
    fn policies_always_return_exactly_m_distinct_keys() {
        let c = client();
        let mut rng = Rng::new(1, 0);
        for pol in [
            KeyPolicy::TopFreq { m: 8 },
            KeyPolicy::RandomLocal { m: 8 },
            KeyPolicy::RandomTopLocal { m: 8 },
            KeyPolicy::RandomGlobal { m: 8 },
        ] {
            let keys = pol.keys_for(&c, 16, &mut rng, None, false);
            assert_eq!(keys.len(), 8, "{pol:?}");
            let set: std::collections::HashSet<_> = keys.iter().collect();
            assert_eq!(set.len(), 8, "{pol:?} duplicated keys");
            assert!(keys.iter().all(|&k| k < 16));
        }
    }

    #[test]
    fn all_keys_is_identity() {
        let c = client();
        let mut rng = Rng::new(1, 0);
        let keys = KeyPolicy::AllKeys.keys_for(&c, 5, &mut rng, None, false);
        assert_eq!(keys, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fixed_per_round_uses_shared_keys() {
        let c = client();
        let mut rng = Rng::new(1, 0);
        let pol = KeyPolicy::FixedPerRound { m: 3 };
        let shared = pol.round_keys(16, &mut rng).unwrap();
        let k1 = pol.keys_for(&c, 16, &mut rng, Some(&shared), false);
        let k2 = pol.keys_for(&c, 16, &mut rng, Some(&shared), false);
        assert_eq!(k1, shared);
        assert_eq!(k1, k2);
    }

    #[test]
    fn force_key_zero_puts_unk_first() {
        let c = client();
        let mut rng = Rng::new(1, 0);
        let keys = KeyPolicy::TopFreq { m: 2 }.keys_for(&c, 16, &mut rng, None, true);
        assert_eq!(keys[0], 0);
        assert_eq!(keys.len(), 2);
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(
            "top:100".parse::<KeyPolicy>().unwrap(),
            KeyPolicy::TopFreq { m: 100 }
        );
        assert_eq!("all".parse::<KeyPolicy>().unwrap(), KeyPolicy::AllKeys);
        assert!("bogus:1".parse::<KeyPolicy>().is_err());
    }

    #[test]
    fn display_round_trips_every_policy() {
        for pol in [
            KeyPolicy::TopFreq { m: 7 },
            KeyPolicy::RandomLocal { m: 9 },
            KeyPolicy::RandomTopLocal { m: 11 },
            KeyPolicy::RandomGlobal { m: 13 },
            KeyPolicy::FixedPerRound { m: 15 },
            KeyPolicy::AllKeys,
        ] {
            let shown = pol.to_string();
            assert_eq!(shown.parse::<KeyPolicy>().unwrap(), pol, "{shown}");
            // parsing is case-insensitive
            assert_eq!(shown.to_uppercase().parse::<KeyPolicy>().unwrap(), pol);
        }
    }

    #[test]
    fn clamps_m_to_keyspace() {
        assert_eq!(KeyPolicy::RandomGlobal { m: 100 }.m(16), 16);
    }

    #[test]
    fn with_m_rebudgets_only_budgeted_policies() {
        assert_eq!(
            KeyPolicy::TopFreq { m: 64 }.with_m(8),
            KeyPolicy::TopFreq { m: 8 }
        );
        assert_eq!(
            KeyPolicy::RandomGlobal { m: 64 }.with_m(8),
            KeyPolicy::RandomGlobal { m: 8 }
        );
        assert_eq!(KeyPolicy::AllKeys.with_m(8), KeyPolicy::AllKeys);
        assert_eq!(
            KeyPolicy::FixedPerRound { m: 64 }.with_m(8),
            KeyPolicy::FixedPerRound { m: 64 }
        );
    }
}

//! Server optimizers (Reddi et al. 2021; paper §2.2/§5.1).
//!
//! The aggregated client delta `u` is treated as a pseudo-gradient of the
//! server model: `x ← ServerUpdate(x, u)`. SGD at the server with η = 1
//! recovers FedAvg; Adagrad/Adam give FedAdagrad/FedAdam (the optimizers
//! §5.2/§5.4 use). Yogi is included as the paper-adjacent extension from the
//! same work.

use crate::model::ParamStore;

/// Server optimizer configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServerOpt {
    Sgd { lr: f32, momentum: f32 },
    Adagrad { lr: f32, eps: f32 },
    Adam { lr: f32, b1: f32, b2: f32, eps: f32 },
    Yogi { lr: f32, b1: f32, b2: f32, eps: f32 },
}

impl ServerOpt {
    pub fn fedavg(lr: f32) -> Self {
        ServerOpt::Sgd { lr, momentum: 0.0 }
    }

    pub fn fedadagrad(lr: f32) -> Self {
        ServerOpt::Adagrad { lr, eps: 1e-3 }
    }

    pub fn fedadam(lr: f32) -> Self {
        ServerOpt::Adam {
            lr,
            b1: 0.9,
            b2: 0.99,
            eps: 1e-3,
        }
    }

    pub fn fedyogi(lr: f32) -> Self {
        ServerOpt::Yogi {
            lr,
            b1: 0.9,
            b2: 0.99,
            eps: 1e-3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServerOpt::Sgd { .. } => "fedavg",
            ServerOpt::Adagrad { .. } => "fedadagrad",
            ServerOpt::Adam { .. } => "fedadam",
            ServerOpt::Yogi { .. } => "fedyogi",
        }
    }
}

impl std::str::FromStr for ServerOpt {
    type Err = String;

    /// "fedavg:1.0" / "fedadagrad:0.1" / "fedadam:0.01" / "fedyogi:0.01"
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (kind, lr) = s.split_once(':').unwrap_or((s, "1.0"));
        let lr: f32 = lr.parse().map_err(|e| format!("bad lr in {s:?}: {e}"))?;
        match kind {
            "fedavg" | "sgd" => Ok(ServerOpt::fedavg(lr)),
            "fedadagrad" | "adagrad" => Ok(ServerOpt::fedadagrad(lr)),
            "fedadam" | "adam" => Ok(ServerOpt::fedadam(lr)),
            "fedyogi" | "yogi" => Ok(ServerOpt::fedyogi(lr)),
            other => Err(format!("unknown server optimizer {other:?}")),
        }
    }
}

/// Stateful optimizer instance bound to one model.
pub struct Optimizer {
    pub opt: ServerOpt,
    m: Option<ParamStore>,
    v: Option<ParamStore>,
    t: u64,
}

impl Optimizer {
    pub fn new(opt: ServerOpt, store: &ParamStore) -> Self {
        let needs_m = matches!(opt, ServerOpt::Adam { .. } | ServerOpt::Yogi { .. })
            || matches!(opt, ServerOpt::Sgd { momentum, .. } if momentum != 0.0);
        let needs_v = matches!(
            opt,
            ServerOpt::Adagrad { .. } | ServerOpt::Adam { .. } | ServerOpt::Yogi { .. }
        );
        Optimizer {
            opt,
            m: needs_m.then(|| store.zeros_like()),
            v: needs_v.then(|| store.zeros_like()),
            t: 0,
        }
    }

    /// Optimizer state memory in bytes (server memory accounting).
    pub fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |s| s.bytes()) + self.v.as_ref().map_or(0, |s| s.bytes())
    }

    /// Apply one server update: `x ← x - step(u)`.
    pub fn step(&mut self, store: &mut ParamStore, update: &ParamStore) {
        self.t += 1;
        match self.opt {
            ServerOpt::Sgd { lr, momentum } => {
                if momentum != 0.0 {
                    let mstore = self.m.as_mut().expect("momentum state");
                    for ((xs, us), ms) in store
                        .segments
                        .iter_mut()
                        .zip(update.segments.iter())
                        .zip(mstore.segments.iter_mut())
                    {
                        for ((x, &u), mm) in
                            xs.data.iter_mut().zip(us.data.iter()).zip(ms.data.iter_mut())
                        {
                            *mm = momentum * *mm + u;
                            *x -= lr * *mm;
                        }
                    }
                } else {
                    for (xs, us) in store.segments.iter_mut().zip(update.segments.iter()) {
                        for (x, &u) in xs.data.iter_mut().zip(us.data.iter()) {
                            *x -= lr * u;
                        }
                    }
                }
            }
            ServerOpt::Adagrad { lr, eps } => {
                let vstore = self.v.as_mut().expect("adagrad state");
                for ((xs, us), vs) in store
                    .segments
                    .iter_mut()
                    .zip(update.segments.iter())
                    .zip(vstore.segments.iter_mut())
                {
                    for ((x, &u), vv) in
                        xs.data.iter_mut().zip(us.data.iter()).zip(vs.data.iter_mut())
                    {
                        *vv += u * u;
                        *x -= lr * u / (vv.sqrt() + eps);
                    }
                }
            }
            ServerOpt::Adam { lr, b1, b2, eps } => {
                let t = self.t as i32;
                let bc1 = 1.0 - b1.powi(t);
                let bc2 = 1.0 - b2.powi(t);
                let mstore = self.m.as_mut().expect("adam m");
                let vstore = self.v.as_mut().expect("adam v");
                for (((xs, us), ms), vs) in store
                    .segments
                    .iter_mut()
                    .zip(update.segments.iter())
                    .zip(mstore.segments.iter_mut())
                    .zip(vstore.segments.iter_mut())
                {
                    for (((x, &u), mm), vv) in xs
                        .data
                        .iter_mut()
                        .zip(us.data.iter())
                        .zip(ms.data.iter_mut())
                        .zip(vs.data.iter_mut())
                    {
                        *mm = b1 * *mm + (1.0 - b1) * u;
                        *vv = b2 * *vv + (1.0 - b2) * u * u;
                        let mhat = *mm / bc1;
                        let vhat = *vv / bc2;
                        *x -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
            ServerOpt::Yogi { lr, b1, b2, eps } => {
                let t = self.t as i32;
                let bc1 = 1.0 - b1.powi(t);
                let bc2 = 1.0 - b2.powi(t);
                let mstore = self.m.as_mut().expect("yogi m");
                let vstore = self.v.as_mut().expect("yogi v");
                for (((xs, us), ms), vs) in store
                    .segments
                    .iter_mut()
                    .zip(update.segments.iter())
                    .zip(mstore.segments.iter_mut())
                    .zip(vstore.segments.iter_mut())
                {
                    for (((x, &u), mm), vv) in xs
                        .data
                        .iter_mut()
                        .zip(us.data.iter())
                        .zip(ms.data.iter_mut())
                        .zip(vs.data.iter_mut())
                    {
                        *mm = b1 * *mm + (1.0 - b1) * u;
                        let u2 = u * u;
                        *vv -= (1.0 - b2) * u2 * (*vv - u2).signum();
                        let mhat = *mm / bc1;
                        let vhat = *vv / bc2;
                        *x -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ParamStore, Segment};

    fn store(vals: &[f32]) -> ParamStore {
        ParamStore {
            segments: vec![Segment {
                name: "w".into(),
                shape: vec![vals.len()],
                data: vals.to_vec(),
            }],
        }
    }

    #[test]
    fn sgd_step_is_x_minus_lr_u() {
        let mut x = store(&[1.0, 2.0]);
        let u = store(&[0.5, -0.5]);
        let mut opt = Optimizer::new(ServerOpt::fedavg(1.0), &x);
        opt.step(&mut x, &u);
        assert_eq!(x.segments[0].data, vec![0.5, 2.5]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut x = store(&[0.0]);
        let u = store(&[1.0]);
        let mut opt = Optimizer::new(
            ServerOpt::Sgd {
                lr: 1.0,
                momentum: 0.5,
            },
            &x,
        );
        opt.step(&mut x, &u); // m=1, x=-1
        opt.step(&mut x, &u); // m=1.5, x=-2.5
        assert!((x.segments[0].data[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn adagrad_shrinks_effective_lr() {
        let mut x = store(&[0.0]);
        let u = store(&[1.0]);
        let mut opt = Optimizer::new(ServerOpt::fedadagrad(1.0), &x);
        opt.step(&mut x, &u);
        let d1 = -x.segments[0].data[0];
        let before = x.segments[0].data[0];
        opt.step(&mut x, &u);
        let d2 = before - x.segments[0].data[0];
        assert!(d2 < d1, "second step {d2} should be smaller than first {d1}");
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        let mut x = store(&[0.0]);
        let u = store(&[0.3]);
        let mut opt = Optimizer::new(ServerOpt::fedadam(0.1), &x);
        opt.step(&mut x, &u);
        // bias-corrected first step ≈ lr * sign(u)
        let step = -x.segments[0].data[0];
        assert!((step - 0.1).abs() < 0.04, "step {step}");
    }

    #[test]
    fn yogi_moves_toward_gradient() {
        let mut x = store(&[1.0]);
        let u = store(&[1.0]);
        let mut opt = Optimizer::new(ServerOpt::fedyogi(0.1), &x);
        for _ in 0..5 {
            opt.step(&mut x, &u);
        }
        assert!(x.segments[0].data[0] < 1.0);
    }

    #[test]
    fn zero_update_is_a_fixed_point_for_sgd_and_adagrad() {
        for opt_cfg in [ServerOpt::fedavg(1.0), ServerOpt::fedadagrad(0.1)] {
            let mut x = store(&[3.0, -4.0]);
            let u = store(&[0.0, 0.0]);
            let mut opt = Optimizer::new(opt_cfg, &x);
            opt.step(&mut x, &u);
            assert_eq!(x.segments[0].data, vec![3.0, -4.0]);
        }
    }

    #[test]
    fn parse() {
        assert_eq!(
            "fedadam:0.01".parse::<ServerOpt>().unwrap().name(),
            "fedadam"
        );
        assert!("nope:1".parse::<ServerOpt>().is_err());
    }
}

//! `fedselect` — CLI entrypoint for the Federated Select coordinator.
//!
//! ```text
//! fedselect train       [--model logreg|mlp|cnn|transformer] [--vocab N]
//!                       [--key-policy top:M] [--policy2 random-global:D]
//!                       [--fleet uniform|tiered-3|diurnal|flaky-edge|trace:PATH]
//!                       [--fleet-size N]
//!                       [--sched-policy uniform|availability-aware|
//!                                       memory-capped|staleness-fair|
//!                                       loss-weighted]
//!                       [--mem-cap-frac F]
//!                       [--churn RATE[:WIDTH]] [--outage START:DUR:FRAC]
//!                       [--wave DUTY] [--horizon HOURS]
//!                       [--agg-mode sync|over-select|buffered]
//!                       [--over-select-frac F] [--goal-count N]
//!                       [--max-staleness S]
//!                       [--rounds R] [--cohort C] [--slice-impl pregen]
//!                       [--fetch-threads N]
//!                       [--exec strict|fast] [--exec-workers N]
//!                       [--agg-shards N]
//!                       [--server-opt fedadagrad:0.1] [--client-lr LR]
//!                       [--agg cohort|per-coord] [--secure-agg]
//!                       [--secure-committee] [--min-committee N]
//!                       [--committee-defer]
//!                       [--cache] [--cache-budget-frac F]
//!                       [--cache-evict lru|lfu|version-distance]
//!                       [--max-stale-rounds S]
//!                       [--engine native|pjrt]
//!                       [--artifacts-dir DIR] [--seed S] [--eval-every K]
//!                       [--trace-out PATH] [--trace-format jsonl|chrome]
//!                       [--slo RULE[,RULE..]] [--detect] [--detect-warmup N]
//! fedselect experiment  --id table1|fig2..fig7|table2|table3|sched|async|
//!                            secagg|cache|multitenant|scale|health|all|list
//!                       [--quick] [--engine native|pjrt] [--trials T]
//!                       [--out-dir results] [--artifacts-dir DIR]
//! fedselect artifacts   [--dir artifacts]
//! fedselect info
//! ```
//!
//! Global flags (any subcommand): `--log-level error|warn|info|debug`
//! (default `info`) and `--quiet` (shorthand for `--log-level error`).
//! Leveled output goes through the [`fedselect::obs`] logger; at the
//! default level stdout is byte-identical to the historical `println!`
//! output.
//!
//! `--policy` accepts either namespace — a key policy (`top:256`) or a
//! scheduler policy (`memory-capped`); the spellings are disjoint. A bare
//! `fedselect --fleet tiered-3 --policy memory-capped` (no subcommand)
//! trains. `--dropout` / `--dropout-rate` are deprecated but accepted: the
//! scalar is mapped onto a fleet-wide failure hazard. Giving
//! `--over-select-frac` (or `--goal-count` / `--max-staleness`) without
//! `--agg-mode` implies the matching mode. `--secure-committee` implies
//! `--secure-agg` and re-keys the pairwise masks per close group, which is
//! what lets secure aggregation run under `over-select` / `buffered`
//! closes (whole-cohort masks still require `--agg-mode sync`).
//!
//! `--exec-workers N` (N > 1) runs each cohort slot's fetch→train task on
//! a bounded worker pool (native engine only; conflicts with
//! `--fetch-threads`). `--exec strict` (default) replays merges in
//! deterministic cohort order — byte-identical to the sequential
//! coordinator; `--exec fast` merges in completion order over a sharded
//! accumulator (`--agg-shards`, 0 = match worker count). Giving
//! `--exec-workers` or `--agg-shards` alone keeps `--exec strict`.

use fedselect::aggregation::AggMode;
use fedselect::cache::EvictPolicy;
use fedselect::config::{EngineKind, TrainConfig};
use fedselect::coordinator::{AggregationMode, Trainer};
use fedselect::error::{Error, Result};
use fedselect::exec::ExecMode;
use fedselect::experiments::{self, ExpOptions};
use fedselect::fedselect::{KeyPolicy, SliceImpl};
use fedselect::fleet::{ChurnSpec, OutageSpec, WaveSpec};
use fedselect::metrics::{fleet_summary_from, human_bytes, latency_summary_from};
use fedselect::obs::{self, LogLevel, SloRule, TraceFormat};
use fedselect::optim::ServerOpt;
use fedselect::runtime::PjrtRuntime;
use fedselect::scheduler::{FleetKind, SchedPolicy};
use fedselect::util::cli::Args;
use fedselect::{obs_error, obs_info, obs_warn};

fn parse_engine(engine: &str, dir: &str) -> Result<EngineKind> {
    match engine {
        "native" => Ok(EngineKind::Native),
        "pjrt" => Ok(EngineKind::Pjrt {
            artifacts_dir: dir.to_string(),
        }),
        other => Err(Error::Config(format!(
            "unknown engine {other:?} (native | pjrt)"
        ))),
    }
}

/// Compose the round engine's aggregation mode from `--agg-mode` plus the
/// per-mode knob flags. Knob flags with a mismatched mode are an error
/// (including an *explicit* `--agg-mode sync`); when `--agg-mode` is absent
/// they *imply* the matching mode, so `--over-select-frac 0.5` alone runs
/// over-selection.
fn parse_agg_mode(a: &Args) -> Result<AggregationMode> {
    let explicit = a.get("agg-mode").map(str::to_string);
    let mut mode: AggregationMode = explicit
        .as_deref()
        .unwrap_or("sync")
        .parse()
        .map_err(Error::Config)?;
    let osf: Option<f64> = match a.get("over-select-frac") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| Error::Config(format!("bad --over-select-frac: {e}")))?,
        ),
    };
    let goal: Option<usize> = match a.get("goal-count") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| Error::Config(format!("bad --goal-count: {e}")))?,
        ),
    };
    let stale: Option<usize> = match a.get("max-staleness") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| Error::Config(format!("bad --max-staleness: {e}")))?,
        ),
    };
    if osf.is_some() && (goal.is_some() || stale.is_some()) {
        return Err(Error::Config(
            "--over-select-frac conflicts with --goal-count/--max-staleness \
             (pick one aggregation mode)"
                .into(),
        ));
    }
    if mode == AggregationMode::Synchronous {
        if explicit.is_some() {
            // the user pinned the barrier; don't let a leftover knob flag
            // silently switch modes under them
            if osf.is_some() || goal.is_some() || stale.is_some() {
                return Err(Error::Config(
                    "--agg-mode sync conflicts with \
                     --over-select-frac/--goal-count/--max-staleness"
                        .into(),
                ));
            }
        } else if let Some(f) = osf {
            mode = AggregationMode::OverSelect { extra_frac: f };
        } else if goal.is_some() || stale.is_some() {
            mode = AggregationMode::Buffered {
                goal_count: goal.unwrap_or(0),
                max_staleness: stale.unwrap_or(AggregationMode::DEFAULT_MAX_STALENESS),
            };
        }
        return Ok(mode);
    }
    match &mut mode {
        AggregationMode::OverSelect { extra_frac } => {
            if goal.is_some() || stale.is_some() {
                return Err(Error::Config(
                    "--goal-count/--max-staleness apply to --agg-mode buffered".into(),
                ));
            }
            if let Some(f) = osf {
                *extra_frac = f;
            }
        }
        AggregationMode::Buffered {
            goal_count,
            max_staleness,
        } => {
            if osf.is_some() {
                return Err(Error::Config(
                    "--over-select-frac applies to --agg-mode over-select".into(),
                ));
            }
            if let Some(g) = goal {
                *goal_count = g;
            }
            if let Some(s) = stale {
                *max_staleness = s;
            }
        }
        AggregationMode::Synchronous => unreachable!("handled above"),
    }
    Ok(mode)
}

fn cmd_train(a: &Args) -> Result<()> {
    let model = a.str_or("model", "logreg");
    let vocab = a.parse_or("vocab", 2048usize).map_err(Error::Config)?;

    // --policy historically named the key policy; it now also accepts a
    // scheduler policy (the namespaces are disjoint). --key-policy and
    // --sched-policy are the unambiguous spellings.
    let mut sched_policy: Option<SchedPolicy> = None;
    let mut key_policy_src: Option<String> = a.get("key-policy").map(str::to_string);
    if let Some(v) = a.get("policy") {
        if let Ok(sp) = v.parse::<SchedPolicy>() {
            sched_policy = Some(sp);
        } else if key_policy_src.is_none() {
            key_policy_src = Some(v.to_string());
        } else {
            return Err(Error::Config(format!(
                "--policy {v:?} is not a scheduler policy, and --key-policy is already given"
            )));
        }
    }
    if let Some(v) = a.get("sched-policy") {
        sched_policy = Some(v.parse::<SchedPolicy>().map_err(Error::Config)?);
    }
    let p0: KeyPolicy = key_policy_src
        .as_deref()
        .unwrap_or("top:256")
        .parse()
        .map_err(Error::Config)?;
    let mut cfg = match model.as_str() {
        "logreg" => {
            let mut c = TrainConfig::logreg_default(vocab, p0.m(vocab));
            c.policies = vec![p0];
            c
        }
        "mlp" => {
            let mut c = TrainConfig::mlp_default(p0.m(200));
            c.policies = vec![p0];
            c
        }
        "cnn" => {
            let mut c = TrainConfig::cnn_default(p0.m(64));
            c.policies = vec![p0];
            c
        }
        "transformer" => {
            let p1: KeyPolicy = a
                .str_or("policy2", "random-global:128")
                .parse()
                .map_err(Error::Config)?;
            let mut c = TrainConfig::transformer_default(p0.m(2048), p1.m(512));
            c.policies = vec![p0, p1];
            c
        }
        other => return Err(Error::Config(format!("unknown model {other:?}"))),
    };
    if model != "transformer" {
        let _ = a.get("policy2");
    }
    cfg.rounds = a.parse_or("rounds", 20usize).map_err(Error::Config)?;
    cfg.cohort = a.parse_or("cohort", 50usize).map_err(Error::Config)?;
    cfg.slice_impl = a
        .str_or("slice-impl", "pregen")
        .parse::<SliceImpl>()
        .map_err(Error::Config)?;
    cfg.fetch_threads = a.parse_or("fetch-threads", 1usize).map_err(Error::Config)?;
    // pipelined round executor: --exec picks the merge-order contract,
    // --exec-workers sizes the task pool, --agg-shards stripes the fast
    // accumulator (0 = match the worker count)
    cfg.exec = a
        .str_or("exec", "strict")
        .parse::<ExecMode>()
        .map_err(Error::Config)?;
    cfg.exec_workers = a.parse_or("exec-workers", 1usize).map_err(Error::Config)?;
    cfg.agg_shards = a.parse_or("agg-shards", 0usize).map_err(Error::Config)?;
    cfg.server_opt = a
        .str_or("server-opt", "fedadagrad:0.1")
        .parse::<ServerOpt>()
        .map_err(Error::Config)?;
    cfg.client_lr = a.parse_or("client-lr", 0.5f32).map_err(Error::Config)?;
    cfg.agg = a
        .str_or("agg", "cohort")
        .parse::<AggMode>()
        .map_err(Error::Config)?;
    cfg.agg_mode = parse_agg_mode(a)?;
    cfg.secure_committee = a.flag("secure-committee");
    // the committee flag names the protocol variant, so it implies the
    // protocol itself
    cfg.secure_agg = a.flag("secure-agg") || cfg.secure_committee;
    cfg.min_committee = a.parse_or("min-committee", 0usize).map_err(Error::Config)?;
    cfg.committee_defer = a.flag("committee-defer");
    // cross-round slice cache: any cache knob implies --cache (matching the
    // agg-mode knob convention)
    let budget_frac = a.get("cache-budget-frac").map(str::to_string);
    let evict = a.get("cache-evict").map(str::to_string);
    let max_stale = a.get("max-stale-rounds").map(str::to_string);
    cfg.cache = a.flag("cache")
        || budget_frac.is_some()
        || evict.is_some()
        || max_stale.is_some();
    if let Some(v) = budget_frac {
        cfg.cache_budget_frac = v
            .parse()
            .map_err(|e| Error::Config(format!("bad --cache-budget-frac: {e}")))?;
    }
    if let Some(v) = evict {
        cfg.cache_evict = v.parse::<EvictPolicy>().map_err(Error::Config)?;
    }
    if let Some(v) = max_stale {
        cfg.max_stale_rounds = v
            .parse()
            .map_err(|e| Error::Config(format!("bad --max-stale-rounds: {e}")))?;
    }
    cfg.fleet = a
        .str_or("fleet", "uniform")
        .parse::<FleetKind>()
        .map_err(Error::Config)?;
    // --fleet-size 0 (default) keeps the legacy dataset-sized fleet;
    // profiles are lazy, so a 10M-client fleet costs nothing until touched
    cfg.fleet_size = a.parse_or("fleet-size", 0usize).map_err(Error::Config)?;
    if let Some(sp) = sched_policy {
        cfg.sched_policy = sp;
    }
    cfg.mem_cap_frac = a.parse_or("mem-cap-frac", 0.25f64).map_err(Error::Config)?;
    // scale scenarios: churn / regional outage / diurnal wave shape
    // per-round eligibility on the simulated clock; --horizon bounds the
    // run by sim time instead of round count
    if let Some(v) = a.get("churn") {
        cfg.scenario.churn = Some(ChurnSpec::parse(v)?);
    }
    if let Some(v) = a.get("outage") {
        cfg.scenario.outage = Some(OutageSpec::parse(v)?);
    }
    if let Some(v) = a.get("wave") {
        cfg.scenario.wave = Some(WaveSpec::parse(v)?);
    }
    cfg.scenario.horizon_h = a.parse_or("horizon", 0.0f64).map_err(Error::Config)?;
    // deprecated scalar dropout: accepted under both historical spellings,
    // mapped onto a fleet-wide failure hazard (flaky-edge style)
    let dropout = a.parse_or("dropout", 0.0f32).map_err(Error::Config)?;
    let dropout = a.parse_or("dropout-rate", dropout).map_err(Error::Config)?;
    if dropout > 0.0 {
        obs_warn!(
            "warning: --dropout/--dropout-rate is deprecated; the scalar is applied \
             as a per-client failure hazard floor — prefer --fleet flaky-edge"
        );
    }
    cfg.dropout_rate = dropout;
    let dir = a.str_or("artifacts-dir", "artifacts");
    cfg.engine = parse_engine(&a.str_or("engine", "native"), &dir)?;
    cfg.seed = a.parse_or("seed", 7u64).map_err(Error::Config)?;
    cfg.eval.every = a.parse_or("eval-every", 10usize).map_err(Error::Config)?;
    // structured trace sink (observability): --trace-out enables it, the
    // format defaults to line-delimited JSON (`fedselect-trace-v1`)
    cfg.obs.trace_out = a.get("trace-out").map(str::to_string);
    cfg.obs.trace_format = a
        .str_or("trace-format", "jsonl")
        .parse::<TraceFormat>()
        .map_err(Error::Config)?;
    // fleet health monitor: declarative SLO rules (comma-separated
    // KEY:OP:VALUE[:FOR_ROUNDS]) and/or statistical anomaly detectors.
    // Off by default — the round loop then carries no monitoring code.
    if let Some(rules) = a.get("slo") {
        cfg.obs.health.slos = SloRule::parse_list(rules)?;
    }
    cfg.obs.health.detectors = a.flag("detect") || a.get("detect-warmup").is_some();
    cfg.obs.health.warmup = a
        .parse_or("detect-warmup", cfg.obs.health.warmup)
        .map_err(Error::Config)?;
    a.reject_unknown().map_err(Error::Config)?;

    let mut tr = Trainer::new(cfg)?;
    // mirror leveled CLI lines into the trace (`log` events) when tracing
    if tr.recorder().enabled() {
        obs::log::set_sink(Some(tr.recorder().clone()));
    }
    obs_info!(
        "server model: {} params ({}), client slice ratio {:.4}",
        tr.store().num_params(),
        human_bytes(tr.store().bytes() as u64),
        tr.rel_model_size()
    );
    let report = tr.run()?;
    for e in &report.evals {
        obs_info!(
            "round {:>4}: loss {:.4}  metric {:.4}",
            e.round, e.loss, e.metric
        );
    }
    if let Some(last) = report.rounds.last() {
        obs_info!(
            "per-round comm (last): down {} | up {} | psi {} | memo hits {} | cdn q {}",
            human_bytes(last.comm.down_bytes),
            human_bytes(last.up_bytes),
            last.comm.psi_evals,
            last.comm.memo_hits,
            last.comm.cdn_queries
        );
        if tr.cfg.cache {
            let hits: u64 = report.rounds.iter().map(|r| r.comm.client_cache_hits).sum();
            let lookups: u64 = report
                .rounds
                .iter()
                .flat_map(|r| r.tier_cache_lookups.iter())
                .sum();
            let evictions: u64 = report.rounds.iter().map(|r| r.cache_evictions).sum();
            let stale: u64 = report.rounds.iter().map(|r| r.cache_stale_refreshes).sum();
            obs_info!(
                "slice cache: {hits}/{lookups} hits ({:.1}%) | evictions {evictions} | \
                 stale refreshes {stale}",
                if lookups > 0 {
                    100.0 * hits as f64 / lookups as f64
                } else {
                    0.0
                }
            );
        }
        let fleet = tr.scheduler().fleet();
        let tiers: Vec<String> = last
            .tier_completed
            .iter()
            .enumerate()
            .map(|(t, &c)| format!("{}={}c/{}d", fleet.tier_name(t), c, last.tier_dropped[t]))
            .collect();
        obs_info!(
            "sim (last round): {:.2}s | total {:.1}s | per-tier completed/dropped: {}",
            last.sim_round_s,
            report.total_sim_s,
            tiers.join(" ")
        );
        // fleet-scale ledger: only printed when a scale knob is on, so
        // legacy invocations keep their historical stdout bytes
        if tr.cfg.fleet_size > 0 || tr.cfg.scenario.shapes_eligibility() {
            obs_info!(
                "fleet scale (last round): eligible {} | arrivals {} | departures {} | \
                 outage-excluded {} | touched {} | resident {}",
                last.eligible,
                last.arrivals,
                last.departures,
                last.outage_excluded,
                last.clients_touched,
                human_bytes(last.resident_bytes)
            );
        }
        if last.mode != AggregationMode::Synchronous {
            obs_info!(
                "agg mode {} (last round): merged {} | discarded {} | mean staleness {:.2} \
                 | in flight {}",
                last.mode,
                last.completed,
                last.discarded_clients,
                last.mean_staleness,
                tr.round_engine().in_flight()
            );
        }
        if last.committees > 0 {
            obs_info!(
                "secure committees (last round): {} keyed | mean size {:.1} | min size {}",
                last.committees, last.mean_committee_size, last.min_committee_size
            );
        }
    }
    if tr.scheduler().fleet().num_tiers() > 1 {
        obs_info!(
            "{}",
            // rendered from the trainer's live metrics registry — same
            // bytes as the ledger-walking fleet_summary over report.rounds
            fleet_summary_from(tr.scheduler().fleet(), tr.metrics()).to_pretty()
        );
    }
    // health monitor output only when the monitor is on, so legacy
    // invocations keep their historical stdout bytes
    if tr.cfg.obs.health.is_active() {
        if let Some(t) = latency_summary_from(tr.metrics()) {
            obs_info!("{}", t.to_pretty());
        }
        obs_info!("{}", report.health.summary());
    }
    obs_info!("{}", report.summary());
    Ok(())
}

fn cmd_experiment(a: &Args) -> Result<()> {
    let id = a
        .get("id")
        .ok_or_else(|| Error::Config("--id required (or --id list)".into()))?
        .to_string();
    if id == "list" {
        for i in experiments::ALL_IDS {
            obs_info!("{i}");
        }
        return Ok(());
    }
    let dir = a.str_or("artifacts-dir", "artifacts");
    let mut opts = ExpOptions::new(a.flag("quick"), parse_engine(&a.str_or("engine", "native"), &dir)?);
    opts.out_dir = a.str_or("out-dir", "results");
    if let Some(t) = a.get("trials") {
        opts.trials = t
            .parse()
            .map_err(|e| Error::Config(format!("bad --trials: {e}")))?;
    }
    a.reject_unknown().map_err(Error::Config)?;
    let ids: Vec<String> = if id == "all" {
        experiments::ALL_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![id]
    };
    for id in ids {
        obs_info!("=== experiment {id} ===");
        match experiments::run(&id, &opts) {
            Ok(tables) => {
                for t in tables {
                    obs_info!("{}", t.to_pretty());
                }
            }
            Err(e) => obs_error!("[{id}] failed: {e}"),
        }
    }
    Ok(())
}

fn cmd_artifacts(a: &Args) -> Result<()> {
    let dir = a.str_or("dir", "artifacts");
    a.reject_unknown().map_err(Error::Config)?;
    let rt = PjrtRuntime::load(&dir)?;
    obs_info!("{} artifacts in {dir}:", rt.manifest().len());
    for name in rt.manifest().names() {
        let art = rt.artifact(name)?;
        let in_elems: usize = art
            .inputs
            .iter()
            .map(|i| i.shape.iter().product::<usize>().max(1))
            .sum();
        obs_info!(
            "  {name:<24} {:<14} {:>2} inputs ({} floats) -> {} outputs",
            art.kind,
            art.inputs.len(),
            in_elems,
            art.outputs.len()
        );
    }
    Ok(())
}

fn real_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(Error::Config)?;
    // global log level, any subcommand: --quiet is shorthand for
    // --log-level error; an explicit --log-level always wins
    let mut level = if args.flag("quiet") {
        LogLevel::Error
    } else {
        LogLevel::Info
    };
    if let Some(v) = args.get("log-level") {
        level = v.parse::<LogLevel>().map_err(Error::Config)?;
    }
    obs::set_level(level);
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("artifacts") => cmd_artifacts(&args),
        // a bare flags-only invocation (e.g. `fedselect --fleet tiered-3
        // --policy memory-capped`) trains; a truly bare one prints info
        None if args.has_flags() => cmd_train(&args),
        Some("info") | None => {
            obs_info!(
                "fedselect {} — Federated Select reproduction",
                env!("CARGO_PKG_VERSION")
            );
            obs_info!("three-layer stack: rust coordinator -> XLA/PJRT -> pallas kernels");
            obs_info!("subcommands: train, experiment, artifacts, info");
            obs_info!("experiments: {}", experiments::ALL_IDS.join(", "));
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand {other:?} (train | experiment | artifacts | info)"
        ))),
    }
}

fn main() {
    if let Err(e) = real_main() {
        obs_error!("error: {e}");
        std::process::exit(1);
    }
}

//! CDN substrate for Option 3 (paper §3.2/§6): a sharded, read-only slice
//! store that clients query by key, decoupled from the training server.
//!
//! The simulator models what the paper's trade-off discussion depends on:
//! per-shard query/byte accounting (peak-demand behaviour), a publish step
//! with its own cost (the pre-generation the server must finish before the
//! round), a simple latency model, and optional PIR cost accounting
//! ([`pir`]) for private queries.

pub mod pir;

use std::collections::HashMap;

/// Latency/bandwidth accounting model (all simulated, not wall-clock).
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    /// Fixed per-query overhead (µs).
    pub per_query_us: u64,
    /// Serving bandwidth per shard (bytes/µs ≈ MB/ms).
    pub bytes_per_us: u64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            per_query_us: 200,
            bytes_per_us: 100, // ~100 MB/s per shard
        }
    }
}

/// Per-shard counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    pub queries: u64,
    pub bytes: u64,
    pub busy_us: u64,
}

/// A versioned, sharded content-delivery store of per-key slice pieces.
pub struct CdnStore {
    shards: usize,
    latency: LatencyModel,
    /// (keyspace, key) -> piece, for the current published version.
    pieces: HashMap<(usize, u32), Vec<f32>>,
    version: u64,
    stats: Vec<ShardStats>,
    publish_bytes: u64,
}

impl CdnStore {
    pub fn new(shards: usize) -> Self {
        CdnStore {
            shards: shards.max(1),
            latency: LatencyModel::default(),
            pieces: HashMap::new(),
            version: 0,
            stats: vec![ShardStats::default(); shards.max(1)],
            publish_bytes: 0,
        }
    }

    pub fn with_latency(mut self, latency: LatencyModel) -> Self {
        self.latency = latency;
        self
    }

    fn shard_of(&self, keyspace: usize, key: u32) -> usize {
        let h = (key as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(keyspace as u64);
        (h % self.shards as u64) as usize
    }

    /// Publish a new model version's slices (replaces the previous version).
    pub fn publish(&mut self, pieces: HashMap<(usize, u32), Vec<f32>>) -> u64 {
        self.publish_bytes += pieces.values().map(|p| p.len() as u64 * 4).sum::<u64>();
        self.pieces = pieces;
        self.version += 1;
        self.version
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn num_pieces(&self) -> usize {
        self.pieces.len()
    }

    /// Serve one key query; returns the piece and records shard load.
    pub fn query(&mut self, keyspace: usize, key: u32) -> Option<&[f32]> {
        let shard = self.shard_of(keyspace, key);
        let piece = self.pieces.get(&(keyspace, key))?;
        let bytes = piece.len() as u64 * 4;
        let st = &mut self.stats[shard];
        st.queries += 1;
        st.bytes += bytes;
        st.busy_us += self.latency.per_query_us + bytes / self.latency.bytes_per_us.max(1);
        Some(piece.as_slice())
    }

    pub fn shard_stats(&self) -> &[ShardStats] {
        &self.stats
    }

    pub fn total_queries(&self) -> u64 {
        self.stats.iter().map(|s| s.queries).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes).sum()
    }

    /// Simulated makespan of the round: the busiest shard bounds service
    /// completion (the peak-demand bottleneck §6 worries about).
    pub fn makespan_us(&self) -> u64 {
        self.stats.iter().map(|s| s.busy_us).max().unwrap_or(0)
    }

    pub fn publish_bytes(&self) -> u64 {
        self.publish_bytes
    }

    pub fn reset_stats(&mut self) {
        self.stats = vec![ShardStats::default(); self.shards];
        self.publish_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(n: usize) -> CdnStore {
        let mut cdn = CdnStore::new(4);
        let mut pieces = HashMap::new();
        for k in 0..n as u32 {
            pieces.insert((0usize, k), vec![k as f32; 8]);
        }
        cdn.publish(pieces);
        cdn
    }

    #[test]
    fn publish_and_query_roundtrip() {
        let mut cdn = store_with(10);
        assert_eq!(cdn.version(), 1);
        assert_eq!(cdn.num_pieces(), 10);
        let p = cdn.query(0, 3).unwrap();
        assert_eq!(p, &[3.0; 8]);
        assert!(cdn.query(0, 99).is_none());
        assert_eq!(cdn.total_queries(), 1);
        assert_eq!(cdn.total_bytes(), 32);
    }

    #[test]
    fn republish_replaces_version() {
        let mut cdn = store_with(4);
        let mut pieces = HashMap::new();
        pieces.insert((0usize, 0u32), vec![7.0; 8]);
        cdn.publish(pieces);
        assert_eq!(cdn.version(), 2);
        assert_eq!(cdn.num_pieces(), 1);
        assert_eq!(cdn.query(0, 0).unwrap()[0], 7.0);
        assert!(cdn.query(0, 3).is_none());
    }

    #[test]
    fn load_spreads_across_shards() {
        let mut cdn = store_with(256);
        for k in 0..256u32 {
            cdn.query(0, k);
        }
        let loaded = cdn.shard_stats().iter().filter(|s| s.queries > 0).count();
        assert!(loaded >= 3, "only {loaded} shards loaded");
        assert!(cdn.makespan_us() > 0);
        assert!(cdn.makespan_us() < cdn.shard_stats().iter().map(|s| s.busy_us).sum::<u64>());
    }
}

//! CDN substrate for Option 3 (paper §3.2/§6): a sharded, read-only slice
//! store that clients query by key, decoupled from the training server.
//!
//! The simulator models what the paper's trade-off discussion depends on:
//! per-shard query/byte accounting (peak-demand behaviour), a publish step
//! with its own cost (the pre-generation the server must finish before the
//! round), a simple latency model, and optional PIR cost accounting
//! ([`pir`]) for private queries.
//!
//! Serving is read-only by construction: [`CdnStore::query`] takes `&self`
//! (pieces are immutable between publishes, shard counters are relaxed
//! atomics), so a whole cohort's fetch threads can hit the CDN concurrently.
//! Only [`CdnStore::publish`] — the between-rounds version bump — needs
//! `&mut self`.

pub mod pir;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

/// Latency/bandwidth accounting model (all simulated, not wall-clock).
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    /// Fixed per-query overhead (µs).
    pub per_query_us: u64,
    /// Serving bandwidth per shard (bytes/µs ≈ MB/ms).
    pub bytes_per_us: u64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            per_query_us: 200,
            bytes_per_us: 100, // ~100 MB/s per shard
        }
    }
}

/// Per-shard counters (point-in-time snapshot).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    pub queries: u64,
    pub bytes: u64,
    pub busy_us: u64,
}

/// Live per-shard counters: relaxed atomics so queries record through
/// `&self` from any thread.
#[derive(Debug, Default)]
struct ShardLoad {
    queries: AtomicU64,
    bytes: AtomicU64,
    busy_us: AtomicU64,
}

impl ShardLoad {
    fn snapshot(&self) -> ShardStats {
        ShardStats {
            queries: self.queries.load(Relaxed),
            bytes: self.bytes.load(Relaxed),
            busy_us: self.busy_us.load(Relaxed),
        }
    }
}

/// A versioned, sharded content-delivery store of per-key slice pieces.
///
/// Publishing is *versioned per piece*: a publish compares each incoming
/// piece against the copy already serving and re-ships only the changed
/// ones — unchanged pieces keep their `Arc` (no copy) and their piece
/// version, and `publish_bytes` counts only bytes that actually travel
/// server→CDN. This is the server-side half of the cross-round delta
/// story ([`crate::cache`]): a round that never touches a row republishes
/// nothing for it.
pub struct CdnStore {
    shards: usize,
    latency: LatencyModel,
    /// Tenancy namespace (job id; 0 = single-tenant) prefixed onto every
    /// piece address, so N jobs sharing one CDN never collide at the same
    /// `(keyspace, key)`.
    ns: u32,
    /// (ns, keyspace, key) -> piece, for the current published version.
    /// `Arc`-wrapped so queries hand out references without copying.
    pieces: HashMap<(u32, usize, u32), Arc<Vec<f32>>>,
    /// (ns, keyspace, key) -> publish ordinal at which the piece's
    /// *content* last changed.
    piece_versions: HashMap<(u32, usize, u32), u64>,
    version: u64,
    stats: Vec<ShardLoad>,
    publish_bytes: AtomicU64,
}

impl CdnStore {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        CdnStore {
            shards,
            latency: LatencyModel::default(),
            ns: 0,
            pieces: HashMap::new(),
            piece_versions: HashMap::new(),
            version: 0,
            stats: (0..shards).map(|_| ShardLoad::default()).collect(),
            publish_bytes: AtomicU64::new(0),
        }
    }

    pub fn with_latency(mut self, latency: LatencyModel) -> Self {
        self.latency = latency;
        self
    }

    /// Set the namespace future publishes and queries address. Publishing
    /// replaces only the *current namespace's* piece set, so one CDN can
    /// serve N jobs' slices side by side.
    pub fn set_ns(&mut self, ns: u32) {
        self.ns = ns;
    }

    pub fn ns(&self) -> u32 {
        self.ns
    }

    fn shard_of(&self, ns: u32, keyspace: usize, key: u32) -> usize {
        // ns folds in multiplicatively so ns 0 (single-tenant) hashes
        // exactly as before the tenancy prefix existed
        let h = (key as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(keyspace as u64)
            .wrapping_add((ns as u64).wrapping_mul(0x517C_C1B7_2722_0A95));
        (h % self.shards as u64) as usize
    }

    /// Publish a new model version's slices (replaces the previous piece
    /// *set* of the current namespace; keys absent from `pieces` are
    /// dropped, other namespaces' pieces are untouched). Content-versioned:
    /// pieces byte-identical to the serving copy are retained (shared
    /// `Arc`, piece version unchanged) and cost no publish bytes — only
    /// changed pieces ship and bump their piece version to the new publish
    /// ordinal.
    pub fn publish(&mut self, pieces: HashMap<(usize, u32), Vec<f32>>) -> u64 {
        self.version += 1;
        let ns = self.ns;
        let mut changed_bytes = 0u64;
        let mut next: HashMap<(u32, usize, u32), Arc<Vec<f32>>> =
            HashMap::with_capacity(pieces.len());
        for ((ks, key), v) in pieces {
            let k = (ns, ks, key);
            match self.pieces.get(&k) {
                Some(old) if **old == v => {
                    next.insert(k, old.clone());
                }
                _ => {
                    changed_bytes += v.len() as u64 * 4;
                    self.piece_versions.insert(k, self.version);
                    next.insert(k, Arc::new(v));
                }
            }
        }
        self.pieces.retain(|k, _| k.0 != ns);
        self.pieces.extend(next);
        let pieces_ref = &self.pieces;
        self.piece_versions
            .retain(|k, _| k.0 != ns || pieces_ref.contains_key(k));
        self.publish_bytes.fetch_add(changed_bytes, Relaxed);
        self.version
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Publish ordinal at which this piece's content last changed (None if
    /// the piece is not currently published).
    ///
    /// Deliberately a *different clock* from the trainer's
    /// [`VersionClock`](crate::cache::VersionClock): client freshness
    /// decisions use the aggregator's write clock (which the trainer owns),
    /// while the CDN — which cannot see the aggregator — derives its
    /// versions from content alone. This accessor exists for publish-delta
    /// observability (benches/diagnostics), not for the delta-fetch
    /// protocol.
    pub fn piece_version(&self, keyspace: usize, key: u32) -> Option<u64> {
        self.piece_versions.get(&(self.ns, keyspace, key)).copied()
    }

    /// Published pieces in the current namespace.
    pub fn num_pieces(&self) -> usize {
        self.pieces.keys().filter(|k| k.0 == self.ns).count()
    }

    /// Serve one key query in the current namespace; returns the piece
    /// (zero-copy, `Arc`-shared) and records shard load. Safe to call from
    /// many threads at once.
    pub fn query(&self, keyspace: usize, key: u32) -> Option<Arc<Vec<f32>>> {
        let shard = self.shard_of(self.ns, keyspace, key);
        let piece = self.pieces.get(&(self.ns, keyspace, key))?;
        let bytes = piece.len() as u64 * 4;
        let st = &self.stats[shard];
        st.queries.fetch_add(1, Relaxed);
        st.bytes.fetch_add(bytes, Relaxed);
        st.busy_us.fetch_add(
            self.latency.per_query_us + bytes / self.latency.bytes_per_us.max(1),
            Relaxed,
        );
        Some(piece.clone())
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.stats.iter().map(|s| s.snapshot()).collect()
    }

    pub fn total_queries(&self) -> u64 {
        self.stats.iter().map(|s| s.queries.load(Relaxed)).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes.load(Relaxed)).sum()
    }

    /// Simulated makespan of the round: the busiest shard bounds service
    /// completion (the peak-demand bottleneck §6 worries about).
    pub fn makespan_us(&self) -> u64 {
        self.stats
            .iter()
            .map(|s| s.busy_us.load(Relaxed))
            .max()
            .unwrap_or(0)
    }

    pub fn publish_bytes(&self) -> u64 {
        self.publish_bytes.load(Relaxed)
    }

    /// Clear counters between rounds. `&self`: counters are atomic, and the
    /// per-round session only holds a shared borrow of the store.
    pub fn reset_stats(&self) {
        for s in &self.stats {
            s.queries.store(0, Relaxed);
            s.bytes.store(0, Relaxed);
            s.busy_us.store(0, Relaxed);
        }
        self.publish_bytes.store(0, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(n: usize) -> CdnStore {
        let mut cdn = CdnStore::new(4);
        let mut pieces = HashMap::new();
        for k in 0..n as u32 {
            pieces.insert((0usize, k), vec![k as f32; 8]);
        }
        cdn.publish(pieces);
        cdn
    }

    #[test]
    fn publish_and_query_roundtrip() {
        let cdn = store_with(10);
        assert_eq!(cdn.version(), 1);
        assert_eq!(cdn.num_pieces(), 10);
        let p = cdn.query(0, 3).unwrap();
        assert_eq!(*p, vec![3.0; 8]);
        assert!(cdn.query(0, 99).is_none());
        assert_eq!(cdn.total_queries(), 1);
        assert_eq!(cdn.total_bytes(), 32);
    }

    #[test]
    fn republish_replaces_version() {
        let mut cdn = store_with(4);
        let mut pieces = HashMap::new();
        pieces.insert((0usize, 0u32), vec![7.0; 8]);
        cdn.publish(pieces);
        assert_eq!(cdn.version(), 2);
        assert_eq!(cdn.num_pieces(), 1);
        assert_eq!(cdn.query(0, 0).unwrap()[0], 7.0);
        assert!(cdn.query(0, 3).is_none());
    }

    #[test]
    fn republishing_unchanged_pieces_ships_no_bytes() {
        let mut cdn = CdnStore::new(4);
        let make = |a: f32| {
            let mut p = HashMap::new();
            p.insert((0usize, 0u32), vec![a; 8]);
            p.insert((0usize, 1u32), vec![1.0; 8]);
            p
        };
        cdn.publish(make(5.0));
        assert_eq!(cdn.publish_bytes(), 2 * 32);
        assert_eq!(cdn.piece_version(0, 0), Some(1));
        // second publish: piece 0 changes, piece 1 is byte-identical
        cdn.publish(make(6.0));
        assert_eq!(cdn.version(), 2);
        assert_eq!(cdn.publish_bytes(), 2 * 32 + 32, "only the changed piece ships");
        assert_eq!(cdn.piece_version(0, 0), Some(2));
        assert_eq!(
            cdn.piece_version(0, 1),
            Some(1),
            "unchanged piece keeps its content version"
        );
        assert_eq!(cdn.query(0, 0).unwrap()[0], 6.0);
        // dropping a piece from the published set removes its version too
        let mut only = HashMap::new();
        only.insert((0usize, 0u32), vec![6.0f32; 8]);
        cdn.publish(only);
        assert_eq!(cdn.piece_version(0, 1), None);
        assert_eq!(cdn.piece_version(0, 0), Some(2), "still byte-identical");
    }

    #[test]
    fn namespaces_isolate_piece_sets_within_one_store() {
        let mut cdn = CdnStore::new(4);
        let piece = |a: f32| {
            let mut p = HashMap::new();
            p.insert((0usize, 0u32), vec![a; 8]);
            p
        };
        cdn.publish(piece(1.0)); // ns 0
        cdn.set_ns(7);
        cdn.publish(piece(2.0)); // ns 7, same (keyspace, key)
        assert_eq!(cdn.query(0, 0).unwrap()[0], 2.0, "ns 7 sees its own piece");
        assert_eq!(cdn.num_pieces(), 1);
        cdn.set_ns(0);
        assert_eq!(cdn.query(0, 0).unwrap()[0], 1.0, "ns 0 piece survives ns 7 publish");
        // republishing an empty set in ns 0 drops only ns 0's pieces
        cdn.publish(HashMap::new());
        assert!(cdn.query(0, 0).is_none());
        assert_eq!(cdn.piece_version(0, 0), None);
        cdn.set_ns(7);
        assert_eq!(cdn.query(0, 0).unwrap()[0], 2.0);
        assert_eq!(cdn.piece_version(0, 0), Some(2));
    }

    #[test]
    fn load_spreads_across_shards() {
        let cdn = store_with(256);
        for k in 0..256u32 {
            cdn.query(0, k);
        }
        let loaded = cdn.shard_stats().iter().filter(|s| s.queries > 0).count();
        assert!(loaded >= 3, "only {loaded} shards loaded");
        assert!(cdn.makespan_us() > 0);
        assert!(
            cdn.makespan_us() < cdn.shard_stats().iter().map(|s| s.busy_us).sum::<u64>()
        );
    }

    #[test]
    fn reset_clears_counters_through_shared_ref() {
        let cdn = store_with(16);
        for k in 0..16u32 {
            cdn.query(0, k);
        }
        assert!(cdn.total_queries() > 0);
        cdn.reset_stats();
        assert_eq!(cdn.total_queries(), 0);
        assert_eq!(cdn.makespan_us(), 0);
        assert_eq!(cdn.publish_bytes(), 0);
    }
}

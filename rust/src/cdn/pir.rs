//! Private-information-retrieval cost model (paper §6).
//!
//! The paper notes PIR can hide *which* slices a client fetches from the
//! CDN, at a communication overhead it leaves unquantified ("we leave a
//! formal evaluation of the trade-off ... to future work"). This module
//! quantifies that trade-off with standard cost models so
//! `bench_slice_service` can chart fedselect-savings vs PIR-overhead.
//!
//! Models:
//! * [`PirScheme::Trivial`] — download the whole database (information-
//!   theoretic, single server): per-query down = K · piece_bytes.
//! * [`PirScheme::SqrtComm`] — classic single-server computational PIR with
//!   O(√(K·B)) communication per query (e.g. Kushilevitz-Ostrovsky shaped).
//! * [`PirScheme::LogComm`] — modern lattice-based schemes with
//!   polylogarithmic communication and a fixed ciphertext floor.

/// Cost model selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PirScheme {
    Trivial,
    SqrtComm,
    LogComm,
}

/// Per-query PIR communication estimate for a database of `k` records of
/// `record_bytes` each. Returns (up_bytes, down_bytes).
pub fn query_cost(scheme: PirScheme, k: usize, record_bytes: usize) -> (u64, u64) {
    let db = (k as u64) * record_bytes as u64;
    match scheme {
        PirScheme::Trivial => (8, db),
        PirScheme::SqrtComm => {
            let c = (db as f64).sqrt().ceil() as u64;
            // query vector up, one "row" down; both ~sqrt(db)
            (c.max(64), c.max(record_bytes as u64))
        }
        PirScheme::LogComm => {
            // ~2KB ciphertext floor, log2(K) ciphertexts up, response is a
            // small constant factor over the record.
            let ct = 2048u64;
            let logk = (k.max(2) as f64).log2().ceil() as u64;
            (ct * logk, (record_bytes as u64 * 4).max(ct))
        }
    }
}

/// Total per-client download with PIR for `m` key queries.
pub fn client_down_bytes(scheme: PirScheme, m: usize, k: usize, record_bytes: usize) -> u64 {
    (0..m).map(|_| query_cost(scheme, k, record_bytes).1).sum()
}

/// Break-even analysis: FedSelect+PIR beats plain broadcast when
/// `m * pir_down(K, B) < full_model_bytes`. Returns true if private
/// selection still saves download bytes.
pub fn pir_still_saves(
    scheme: PirScheme,
    m: usize,
    k: usize,
    record_bytes: usize,
    full_model_bytes: u64,
) -> bool {
    client_down_bytes(scheme, m, k, record_bytes) < full_model_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_pir_downloads_database() {
        let (_, down) = query_cost(PirScheme::Trivial, 1000, 400);
        assert_eq!(down, 400_000);
    }

    #[test]
    fn sqrt_pir_is_sublinear() {
        let (_, down) = query_cost(PirScheme::SqrtComm, 10_000, 400);
        assert!(down < 10_000 * 400 / 10);
        assert!(down >= 400);
    }

    #[test]
    fn log_pir_has_ciphertext_floor() {
        let (up, down) = query_cost(PirScheme::LogComm, 1 << 16, 4);
        assert!(up >= 2048 * 16);
        assert!(down >= 2048);
    }

    #[test]
    fn breakeven_matches_intuition() {
        // Large model, few keys: log-PIR still saves.
        let full = 1_000_000_000u64;
        assert!(pir_still_saves(PirScheme::LogComm, 100, 1 << 20, 512, full));
        // Trivial PIR never saves (m >= 1 downloads the whole DB).
        assert!(!pir_still_saves(
            PirScheme::Trivial,
            1,
            1 << 20,
            512,
            (1u64 << 20) * 512
        ));
    }
}

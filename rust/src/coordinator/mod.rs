//! The round driver: federated model training with FedSelect (Algorithm 2).
//!
//! Each round runs in four phases:
//! 0. **Plan** — [`Scheduler::plan_round`] chooses the cohort from the
//!    device fleet via the configured selection policy (over-selection
//!    inflates the requested size via [`RoundEngine::planned_cohort`];
//!    buffered mode excludes clients whose update is still in flight —
//!    FedBuff's per-client concurrency cap), with per-slot failure hazards
//!    and (optionally) per-client select-key budgets; the `uniform` fleet +
//!    `uniform` policy path with an empty exclusion set is byte-identical
//!    to the pre-scheduler inline sampling (§5.1: uniform without
//!    replacement);
//! 1. **Keys** — fork each client's RNG and draw its select keys via its
//!    [`KeyPolicy`] (re-budgeted per client when the plan says so), in
//!    cohort order (phases 0–1 are the only consumers of the round RNG);
//! 2.–3. **Tasks** — `begin_round` on the slice service (Option 3
//!    pre-generates here) yields one immutable session, and every cohort
//!    slot then flows as *one task* (slice/delta fetch → hazard coin →
//!    `ClientUpdate`, one local epoch of SGD) through the pipelined
//!    executor ([`crate::exec`]). With `--exec-workers N > 1` a bounded
//!    worker pool drives [`RoundSession::fetch_delta`] per task and trains
//!    through the pure native engine; at the default `N = 1` the session is
//!    batch-fetched across `fetch_threads` and tasks run inline (the
//!    legacy wall-clock shape, and the only shape the exclusive PJRT
//!    engine supports). Either way task outputs are staged slot-indexed
//!    and every side effect — ledger sums, client trace events, RNG
//!    consumption, cache commits ([`crate::cache`]: version-fresh pieces
//!    served locally, version clock bumped after each close for exactly
//!    the rows the aggregator wrote) — is replayed in cohort order, so the
//!    trajectory is byte-identical at any worker count. The executor hands
//!    the engine per-slot [`TaskCompletion`]s (the scheduler's simulated
//!    [`crate::scheduler::CompletionEvent`] paired with the slot's work) in
//!    host pool-drain order; [`RoundEngine::close_from_tasks`] re-sorts
//!    them onto the simulated timeline and decides — per its
//!    [`AggregationMode`] — which updates `AGGREGATE*` merges now (and at
//!    what staleness weight), which stay in flight, and when the round
//!    *closes*; then `ServerUpdate` applies the server optimizer to the
//!    pseudo-gradient and [`Scheduler::complete_round_at`] lands the close
//!    point as simulated round wall-time plus per-tier completion counts.
//!    `--exec strict` (default) merges in cohort order — byte-identical to
//!    the legacy round; `--exec fast` merges in simulated completion order
//!    over the key-striped [`ShardedAccumulator`] (deterministic
//!    run-to-run, float-add order differs from strict).
//!
//! Under `AggregationMode::Synchronous` (the default) the engine reproduces
//! the pre-engine barrier loop byte for byte — proven against a legacy-loop
//! replica in `tests/round_engine.rs`. `over-select` and `buffered` trade
//! bit-compatibility for straggler immunity; see [`engine`].
//!
//! Failure injection: a client drops *after* fetching its slice (download
//! wasted, no contribution) with its profile's hazard — the paper's §6
//! dropout pattern, per-device. The deprecated scalar `dropout_rate` floors
//! every hazard, reproducing the old behavior exactly on the uniform fleet.

pub mod engine;

pub use engine::{
    AggregationMode, CommitteeSpec, MergeItem, RoundEngine, RoundOutcome, SlotWork,
    TaskCompletion,
};

use std::sync::Arc;
use std::time::Instant;

use crate::aggregation::{
    finalize_mean, Aggregator, SecAggCommittee, SecureAggSim, ShardedAccumulator,
    SparseAccumulator, TouchedKeys,
};
use crate::cache::{CacheGeometry, CommitStats, FleetCaches, VersionClock};
use crate::clients::{build_cu_batch, build_eval_batches, client_memory_bytes, Engine};
use crate::config::{DatasetConfig, EngineKind, TrainConfig};
use crate::data::{bow, images, text, ClientData, Example, FederatedDataset};
use crate::error::{Error, Result};
use crate::exec::{self, ExecMode};
use crate::fedselect::{
    ClientKeys, DeltaPlan, FetchOutcome, RoundComm, RoundSession, SliceImpl, SliceService,
};
use crate::metrics::{human_bytes, keys, record_round};
use crate::model::{Binding, ModelArch, ParamStore, SelectSpec};
use crate::native::{self, Buf};
use crate::obs::{
    self, ClientStage, HealthMonitor, HealthReport, IncidentAction, MetricsRegistry, Phase,
    Recorder, Severity, TraceEvent,
};
use crate::optim::Optimizer;
use crate::runtime::PjrtRuntime;
use crate::scheduler::{ClientRoundStats, CompletionEvent, Scheduler, SliceGeometry};
use crate::tensor::rng::Rng;

/// Per-round ledger.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Updates merged into the server model this round (under buffered
    /// aggregation this may include updates launched in earlier rounds).
    pub completed: usize,
    pub dropped: usize,
    /// Aggregation mode the engine ran this round under.
    pub mode: AggregationMode,
    /// Computed updates whose bytes were spent but never merged:
    /// over-selected stragglers, or buffered updates past `max_staleness`.
    pub discarded_clients: usize,
    /// Mean rounds-of-staleness over the merged updates (0 outside
    /// buffered mode).
    pub mean_staleness: f64,
    /// Secure-aggregation committees keyed at this round's close (0 unless
    /// the run uses `--secure-agg --secure-committee`).
    pub committees: usize,
    /// Mean keyed committee size — submitters plus reconstruction-path
    /// dropouts (0 when no committee was keyed).
    pub mean_committee_size: f64,
    /// Smallest *submitter count* over this round's keyed committees (0
    /// when none was keyed) — the anonymity set of the most exposed
    /// committee sum; reconstruction-path dropouts are excluded because
    /// they contribute nothing to it. The `--min-committee` floor coalesces
    /// staleness classes to keep this above water.
    pub min_committee_size: usize,
    pub comm: RoundComm,
    /// Client->server upload bytes (updates + keys, or masked vectors).
    pub up_bytes: u64,
    /// Max client memory this round (bytes).
    pub max_client_mem: usize,
    /// Host wall time of the round, plan start → close end (the *union* of
    /// the recorder's `plan`/`fetch`/`compute`/`close` span extents — once
    /// fetch and compute overlap under the pipelined executor the spans
    /// sum to more than the round actually took, so `wall_ms ≤
    /// sum-of-spans` always). Evaluation is ledgered separately as
    /// [`EvalRecord::eval_ms`].
    pub wall_ms: f64,
    /// Host wall time the round spent serialized in the merge: the
    /// aggregation substrate's add loop plus finalize. Wall-clock metric
    /// like `wall_ms` — excluded from byte-identity comparisons.
    pub merge_stall_ms: f64,
    /// Executor pool utilization of the task phase in [0, 1]
    /// ([`crate::exec::ExecStats::utilization`]; 1.0 for inline execution).
    /// Wall-clock metric — excluded from byte-identity comparisons.
    pub exec_util: f64,
    /// Simulated round duration on the device fleet: close point (straggler
    /// under `sync`, goal-count completion otherwise) plus server overhead.
    pub sim_round_s: f64,
    /// Merged updates per fleet tier.
    pub tier_completed: Vec<usize>,
    /// Post-fetch dropouts per fleet tier.
    pub tier_dropped: Vec<usize>,
    /// Discarded updates per fleet tier (over-selected stragglers, buffered
    /// staleness-bound discards).
    pub tier_discarded: Vec<usize>,
    /// Download bytes per fleet tier (wasted downloads of dropouts and
    /// discarded stragglers included). With `--cache` these are post-cache
    /// wire bytes, matching `comm.down_bytes`.
    pub tier_down_bytes: Vec<u64>,
    /// Client-cache piece hits per fleet tier (all zero without `--cache`).
    pub tier_cache_hits: Vec<u64>,
    /// Client-cache piece lookups (hits + misses) per fleet tier.
    pub tier_cache_lookups: Vec<u64>,
    /// Cache entries evicted this round across the cohort (byte budgets).
    pub cache_evictions: u64,
    /// Version-fresh pieces refetched only because they aged past
    /// `--max-stale-rounds`.
    pub cache_stale_refreshes: u64,
    /// Landed updates pushed back into the in-flight pool by
    /// `--committee-defer` because their staleness class was below the
    /// `--min-committee` floor (0 unless the defer variant is on).
    pub deferrals: usize,
    /// Clients eligible for selection this round: the fleet minus scenario
    /// ineligibility (churn/outage/wave) minus the in-flight exclusion set.
    pub eligible: usize,
    /// Clients that churned into the population since the last plan (0
    /// without `--churn`).
    pub arrivals: usize,
    /// Clients that churned out of the population since the last plan.
    pub departures: usize,
    /// Clients an active regional outage excluded this round (0 without
    /// `--outage`).
    pub outage_excluded: usize,
    /// Clients with resident scheduler state after this round (ever
    /// selected) — the fleet-sparsity gauge.
    pub clients_touched: usize,
    /// Approximate resident bytes of all per-client fleet state
    /// (touched-state entries + materialized caches + trace rows).
    pub resident_bytes: u64,
}

/// Periodic evaluation snapshot.
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub loss: f64,
    /// recall@5 (logreg) or accuracy (MLP/CNN/transformer).
    pub metric: f64,
    pub examples: usize,
    /// Host wall time of this evaluation (kept out of
    /// [`RoundRecord::wall_ms`], which covers plan→close only).
    pub eval_ms: f64,
}

/// Full run report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
    pub final_eval: EvalRecord,
    /// client sub-model floats / server selectable+broadcast floats
    pub rel_model_size: f64,
    pub server_params: usize,
    pub total_down_bytes: u64,
    pub total_up_bytes: u64,
    /// Simulated training time on the device fleet, seconds.
    pub total_sim_s: f64,
    /// Computed-but-never-merged updates across the run: over-selected
    /// stragglers, staleness-bound discards, plus any buffered updates
    /// still in flight when training ended.
    pub total_discarded: usize,
    /// The health monitor's incident ledger (empty/default when no SLOs
    /// or detectors were configured — the monitor is then fully off).
    pub health: HealthReport,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        format!(
            "final metric {:.4} | loss {:.4} | rel size {:.3} | down {} | up {} | sim {:.1}s",
            self.final_eval.metric,
            self.final_eval.loss,
            self.rel_model_size,
            human_bytes(self.total_down_bytes),
            human_bytes(self.total_up_bytes),
            self.total_sim_s,
        )
    }
}

/// What one round cost on the shared device fleet — the slice of
/// [`Trainer::run_round_with`] the multi-tenant [`crate::tenancy`]
/// coordinator prices its fleet clock with. All times are round-relative
/// seconds on the simulated timeline.
#[derive(Clone, Debug, Default)]
pub struct RoundTick {
    /// Fleet client indices this round selected (dropouts included — their
    /// download happened).
    pub cohort: Vec<usize>,
    /// The round's close point (straggler under sync, goal-count landing
    /// otherwise), before server overhead.
    pub close_s: f64,
    /// Per completion event: `(fleet client index, completion time)` — the
    /// device was busy from round start until then.
    pub busy: Vec<(usize, f64)>,
}

/// Bucket bounds (simulated seconds) of the per-tier fetch-latency
/// histograms the trainer's live [`MetricsRegistry`] observes.
pub const FETCH_LATENCY_BOUNDS: [f64; 8] = [0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0, 120.0];

/// Histogram of merged-update staleness (rounds), observed per merge item.
pub const STALENESS_HIST: &str = "staleness_rounds";

/// Bucket bounds (rounds) of [`STALENESS_HIST`].
pub const STALENESS_BOUNDS: [f64; 6] = [0.0, 1.0, 2.0, 4.0, 8.0, 16.0];

/// Registry name of the per-tier fetch-latency histogram.
pub fn fetch_latency_key(tier: usize) -> String {
    format!("fetch_latency_s.t{tier}")
}

/// Federated trainer (Algorithm 2).
pub struct Trainer {
    pub cfg: TrainConfig,
    arch: ModelArch,
    store: ParamStore,
    spec: SelectSpec,
    dataset: FederatedDataset,
    service: Box<dyn SliceService>,
    engine: Engine,
    optimizer: Optimizer,
    scheduler: Scheduler,
    round_engine: RoundEngine,
    geom: SliceGeometry,
    /// Server-side piece version clock (`--cache` only): bumped at every
    /// close for exactly the rows the aggregator wrote.
    versions: Option<VersionClock>,
    /// Cache-entry geometry (piece/segment byte sizes per the slice impl).
    cache_geom: Option<CacheGeometry>,
    rng: Rng,
    round: usize,
    /// Telemetry sink ([`crate::obs`]); the default [`obs::NullRecorder`]
    /// reports `enabled() == false`, so instrumented paths skip event
    /// construction entirely.
    recorder: Arc<dyn Recorder>,
    /// Live metrics registry: per-round ledgers folded by
    /// [`record_round`] plus fetch-latency/staleness histograms.
    metrics: MetricsRegistry,
    /// Pre-registered per-tier fetch-latency histogram keys (steady-state
    /// observations never allocate).
    fetch_hist_keys: Vec<String>,
    /// Tenancy namespace tag stamped on every trace event (0 =
    /// single-tenant).
    ns: u32,
    /// Health monitor ([`crate::obs::health`]): `None` unless SLO rules
    /// or anomaly detectors are configured, so the default round loop
    /// carries no monitoring code at all.
    health: Option<HealthMonitor>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let dataset = build_dataset(&cfg.dataset);
        Self::build(cfg, dataset)
    }

    /// Construct with an externally built dataset (reused across a sweep).
    pub fn with_dataset(cfg: TrainConfig, dataset: FederatedDataset) -> Result<Self> {
        cfg.validate()?;
        Self::build(cfg, dataset)
    }

    fn build(cfg: TrainConfig, dataset: FederatedDataset) -> Result<Self> {
        if dataset.train.is_empty() {
            return Err(Error::Data("dataset has no training clients".into()));
        }
        let arch = cfg.arch.clone();
        let mut rng = Rng::new(cfg.seed, 100);
        let store = arch.init_store(&mut rng);
        let spec = arch.select_spec();
        spec.validate(&store)?;
        let service = cfg.slice_impl.build();
        let engine = match &cfg.engine {
            EngineKind::Native => Engine::Native,
            EngineKind::Pjrt { artifacts_dir } => {
                Engine::Pjrt(Box::new(PjrtRuntime::load(artifacts_dir)?))
            }
        };
        let optimizer = Optimizer::new(cfg.server_opt, &store);
        let geom = SliceGeometry {
            base_ms: spec
                .keyspaces
                .iter()
                .zip(cfg.policies.iter())
                .map(|(ks, p)| p.m(ks.size))
                .collect(),
            per_key_floats: (0..spec.keyspaces.len())
                .map(|ks| spec.per_key_floats(ks))
                .collect(),
            broadcast_floats: spec.broadcast_floats(&store),
            server_floats: spec.server_floats(&store),
        };
        let mut scheduler = Scheduler::new(&cfg, dataset.train.len())?;
        let round_engine = RoundEngine::new(cfg.agg_mode)
            .with_min_committee(cfg.min_committee)
            .with_defer(cfg.committee_defer);
        // --cache: version clock + cache geometry + one budgeted cache per
        // train client (budget = device memory cap × cache_budget_frac)
        let (versions, cache_geom) = if cfg.cache {
            let sizes: Vec<usize> = spec.keyspaces.iter().map(|k| k.size).collect();
            let broadcast_impl = cfg.slice_impl == SliceImpl::Broadcast;
            let cached_segs: Vec<usize> = if broadcast_impl {
                (0..store.segments.len()).collect()
            } else {
                spec.bindings
                    .iter()
                    .filter_map(|b| match b {
                        Binding::Full { seg } => Some(*seg),
                        Binding::Keyed { .. } => None,
                    })
                    .collect()
            };
            let cgeom = CacheGeometry {
                // the canonical wire piece size — the same helper the slice
                // ledger charges with, so geometry and ledger cannot drift
                piece_bytes: (0..sizes.len())
                    .map(|ks| crate::fedselect::piece::piece_bytes(&spec, ks))
                    .collect(),
                seg_bytes: store.segments.iter().map(|s| s.len() as u64 * 4).collect(),
                cached_segs,
                keyed: !broadcast_impl,
            };
            // budgets are derived lazily per client (device memory cap ×
            // cache_budget_frac) — no O(fleet) budget table
            scheduler.install_caches(FleetCaches::derived(
                cfg.cache_evict,
                cfg.max_stale_rounds,
                store.bytes(),
                cfg.cache_budget_frac,
            ));
            (
                Some(VersionClock::new(&sizes, store.segments.len())),
                Some(cgeom),
            )
        } else {
            (None, None)
        };
        let recorder = obs::build_recorder(&cfg.obs)?;
        let mut metrics = MetricsRegistry::new();
        let fetch_hist_keys: Vec<String> = (0..scheduler.fleet().num_tiers())
            .map(fetch_latency_key)
            .collect();
        for key in &fetch_hist_keys {
            metrics.register_hist(key, &FETCH_LATENCY_BOUNDS);
        }
        metrics.register_hist(STALENESS_HIST, &STALENESS_BOUNDS);
        let health = HealthMonitor::new(&cfg.obs.health, scheduler.fleet().len(), cfg.cohort);
        Ok(Trainer {
            cfg,
            arch,
            store,
            spec,
            dataset,
            service,
            engine,
            optimizer,
            scheduler,
            round_engine,
            geom,
            versions,
            cache_geom,
            rng,
            round: 0,
            recorder,
            metrics,
            fetch_hist_keys,
            ns: 0,
            health,
        })
    }

    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// The cohort scheduler (fleet, policy, simulated clock).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Mutable scheduler access — the multi-tenant coordinator's contended
    /// cache share swaps one pooled [`FleetCaches`] in and out around each
    /// job's round via [`Scheduler::take_caches`] / `install_caches`.
    pub fn scheduler_mut(&mut self) -> &mut Scheduler {
        &mut self.scheduler
    }

    /// Tag this trainer with a tenancy namespace (job id; 0 = the
    /// single-tenant default). Prefixes the version clock — so client-cache
    /// entries committed under one job can never validate against another
    /// job's pieces — and the slice service's shared addressable state (the
    /// CDN piece addresses). Namespace 0 is byte-identical to an untagged
    /// trainer.
    pub fn set_namespace(&mut self, ns: u32) {
        if let Some(v) = self.versions.take() {
            self.versions = Some(v.with_ns(ns));
        }
        self.service.set_namespace(ns);
        self.ns = ns;
    }

    /// The telemetry sink this trainer reports to.
    pub fn recorder(&self) -> &Arc<dyn Recorder> {
        &self.recorder
    }

    /// Replace the telemetry sink — the multi-tenant coordinator points
    /// every job's trainer at one shared recorder.
    pub fn set_recorder(&mut self, recorder: Arc<dyn Recorder>) {
        self.recorder = recorder;
    }

    /// The live metrics registry (counters/gauges folded per round by
    /// [`record_round`], plus fetch-latency and staleness histograms).
    /// `metrics::fleet_summary_from` renders the fleet table from it
    /// without re-walking the round records.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn dataset(&self) -> &FederatedDataset {
        &self.dataset
    }

    /// Per-keyspace key counts of the configured policies.
    pub fn key_counts(&self) -> Vec<usize> {
        self.spec
            .keyspaces
            .iter()
            .zip(self.cfg.policies.iter())
            .map(|(ks, p)| p.m(ks.size))
            .collect()
    }

    /// Client/server relative model size (the paper's Fig. 3 x-axis).
    pub fn rel_model_size(&self) -> f64 {
        let ms = self.key_counts();
        self.spec.client_floats(&self.store, &ms) as f64
            / self.spec.server_floats(&self.store) as f64
    }

    /// The round engine (aggregation mode, in-flight update pool).
    pub fn round_engine(&self) -> &RoundEngine {
        &self.round_engine
    }

    /// The server-side piece version clock (`Some` only under `--cache`).
    pub fn versions(&self) -> Option<&VersionClock> {
        self.versions.as_ref()
    }

    /// Run one round of Algorithm 2.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        self.run_round_with(&[]).map(|(rec, _)| rec)
    }

    /// Run one round, additionally excluding `extra_exclude` (fleet client
    /// indices) from cohort selection — the multi-tenant arbiter's
    /// `priority` / `drr` policies pass the clients earlier jobs already
    /// claimed this tick. With an empty slice the exclusion set reduces to
    /// the engine's own in-flight list, making this exactly
    /// [`Self::run_round`] — the identity a single-job coordinator's
    /// byte-compatibility rests on. Also returns the [`RoundTick`] the
    /// coordinator prices its shared-fleet clock with.
    pub fn run_round_with(
        &mut self,
        extra_exclude: &[usize],
    ) -> Result<(RoundRecord, RoundTick)> {
        let obs_on = self.recorder.enabled();
        if obs_on && self.round == 0 {
            self.recorder.record(&TraceEvent::RunStart {
                ns: self.ns,
                seed: self.cfg.seed,
                rounds: self.cfg.rounds,
                cohort: self.cfg.cohort,
                mode: self.round_engine.mode().to_string(),
            });
        }
        self.round += 1;
        let sim_start_s = self.scheduler.sim_total_s();
        if obs_on {
            self.recorder.record(&TraceEvent::RoundStart {
                ns: self.ns,
                round: self.round,
                sim_start_s,
            });
        }
        let t_plan = Instant::now();
        let mut round_rng = self.rng.fork(self.round as u64);

        // Phase 0 — plan: the scheduler picks the cohort from the fleet
        // (over-selection asks for extra clients; buffered mode excludes
        // clients with an update still in flight — FedBuff caps per-client
        // concurrency at one). Under the uniform policy with an empty
        // exclusion set this is the identical sample_without_replacement
        // draw the pre-scheduler coordinator made, so trajectories are
        // byte-identical at the same seed.
        let want = self.round_engine.planned_cohort(self.cfg.cohort);
        let mut in_flight = self.round_engine.in_flight_clients();
        if !extra_exclude.is_empty() {
            in_flight.extend_from_slice(extra_exclude);
            in_flight.sort_unstable();
            in_flight.dedup();
        }
        let plan = self
            .scheduler
            .plan_round(self.round, want, &self.geom, &mut round_rng, &in_flight);
        let cohort = &plan.cohort;
        let slot_tiers: Vec<usize> = cohort
            .iter()
            .map(|&ci| self.scheduler.fleet().profile(ci).tier)
            .collect();
        let ntiers = self.scheduler.fleet().num_tiers();
        if obs_on {
            for (slot, &ci) in cohort.iter().enumerate() {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client: ci,
                    tier: Some(slot_tiers[slot]),
                    stage: ClientStage::Selected,
                });
            }
        }

        // shared per-round key sets (Fig. 6 "fixed" ablation)
        let shared: Vec<Option<Vec<u32>>> = self
            .cfg
            .policies
            .iter()
            .zip(self.spec.keyspaces.iter())
            .map(|(p, ks)| p.round_keys(ks.size, &mut round_rng))
            .collect();

        let force_unk = matches!(self.arch, ModelArch::Transformer { .. });

        // Phase 1 — keys: fork each client's RNG and draw its select keys
        // (re-budgeted per client when the plan carries key budgets), in
        // cohort order (phases 0-1 are the only consumers of round_rng).
        // An oversized fleet (`--fleet-size` > dataset clients) maps fleet
        // ids onto dataset clients modulo n_train and keys the client RNG
        // by the fleet id, so two fleet clients sharing data still draw
        // independent keys/batches; at the legacy size both reduce to the
        // pre-fleet behavior bit for bit.
        let n_train = self.dataset.train.len();
        let oversized = self.scheduler.fleet().len() > n_train;
        let mut client_keys: Vec<ClientKeys> = Vec::with_capacity(cohort.len());
        let mut client_rngs: Vec<Rng> = Vec::with_capacity(cohort.len());
        for (slot, &ci) in cohort.iter().enumerate() {
            let client = &self.dataset.train[ci % n_train];
            let fork_salt = if oversized {
                ci as u64 ^ 0xC11E47
            } else {
                client.id ^ 0xC11E47
            };
            let mut crng = round_rng.fork(fork_salt);
            let keys: ClientKeys = self
                .cfg
                .policies
                .iter()
                .enumerate()
                .map(|(ksi, p)| {
                    let p = match &plan.key_budgets {
                        Some(budgets) => p.with_m(budgets[slot][ksi]),
                        None => *p,
                    };
                    p.keys_for(
                        client,
                        self.spec.keyspaces[ksi].size,
                        &mut crng,
                        shared[ksi].as_deref(),
                        force_unk && ksi == 0,
                    )
                })
                .collect();
            client_keys.push(keys);
            client_rngs.push(crng);
        }
        let plan_ms = t_plan.elapsed().as_secs_f64() * 1e3;
        let t_task = Instant::now();

        // Phases 2+3a — tasks: one immutable session for the round, then
        // every cohort slot flows as one task (slice/delta fetch → hazard
        // coin → ClientUpdate) through the pipelined executor. With --cache
        // each client first gets a DeltaPlan from its on-device cache
        // (fresh pieces serve locally, no wire bytes); without, the same
        // path runs with empty plans — so per-client down_bytes is always
        // the *session's* wire charge (full model under Option 1, bundle
        // bytes otherwise) and the SimClock agrees with the comm ledger
        // whether the cache is on or off. Task outputs are staged
        // slot-indexed and all side effects are replayed in cohort order
        // below, so the trajectory is byte-identical at any worker count.
        let session = self.service.begin_round(&self.store, &self.spec)?;
        let deltas: Vec<DeltaPlan> = match (self.scheduler.caches(), self.versions.as_ref()) {
            (Some(caches), Some(versions)) => {
                let cgeom = self.cache_geom.as_ref().expect("cache geometry");
                cohort
                    .iter()
                    .zip(client_keys.iter())
                    .map(|(&ci, keys)| {
                        caches.plan_for(ci, self.round as u64, keys, cgeom, versions)
                    })
                    .collect()
            }
            _ => vec![DeltaPlan::default(); cohort.len()],
        };
        // everything a task body touches, hoisted out of `self` so the
        // closures borrow disjoint fields (the exclusive engine mutably,
        // everything else shared)
        let arch = &self.arch;
        let train = &self.dataset.train;
        let hazards: &[f32] = &plan.hazards;
        let cohort_ids: &[usize] = cohort;
        let lr = self.cfg.client_lr;
        // §4.2 upload pricing is model-global, so it is a per-round
        // constant: committee SecAgg ships masked update + masked counts as
        // u64 group elements (16 bytes per coordinate), legacy dense SecAgg
        // ships one full-model float vector; None = plain per-client bytes
        let secure_up: Option<u64> = if self.cfg.secure_agg {
            Some(if self.cfg.secure_committee {
                self.store.num_params() as u64 * 16
            } else {
                self.store.bytes() as u64
            })
        } else {
            None
        };
        let (task_results, exec_stats, fetch_ms, compute_ms) = if self.cfg.exec_workers > 1 {
            // pooled path: per-task fetch_delta through the shared session,
            // training through the pure native engine (validated Native-only)
            let session_ref: &dyn RoundSession = session.as_ref();
            let inputs: Vec<((ClientKeys, Rng), DeltaPlan)> = client_keys
                .into_iter()
                .zip(client_rngs)
                .zip(deltas)
                .collect();
            let (outs, stats) = exec::run_tasks(
                self.cfg.exec_workers,
                inputs,
                |slot, ((keys, mut crng), delta)| -> Result<TaskOut> {
                    let fetched = session_ref.fetch_delta(&keys, &delta)?;
                    let fetch_end_ms = t_task.elapsed().as_secs_f64() * 1e3;
                    run_client_task(
                        arch,
                        &train[cohort_ids[slot] % n_train],
                        hazards[slot],
                        secure_up,
                        fetched,
                        keys,
                        &mut crng,
                        fetch_end_ms,
                        |ms, slices, batch| native::client_update(arch, ms, &slices, batch, lr),
                    )
                },
            );
            // span extents under overlap, every offset measured from
            // t_task: fetch runs until the last task's slice landed,
            // compute from the first slice to the end of the drain — so
            // fetch+compute covers the whole task phase (session setup
            // included, since the first fetch end sits after it) and
            // exceeds it by exactly max−min fetch end, which is what
            // wall_ms ≤ sum-of-spans pins down
            let drain_end_ms = t_task.elapsed().as_secs_f64() * 1e3;
            let ends = || outs.iter().filter_map(|o| o.as_ref().ok()).map(|o| o.fetch_end_ms);
            let fetch_span_ms = ends().fold(0.0, f64::max);
            let first_end = ends().fold(f64::INFINITY, f64::min);
            let compute_span_ms = if first_end.is_finite() {
                (drain_end_ms - first_end).max(0.0)
            } else {
                0.0
            };
            (outs, stats, fetch_span_ms, compute_span_ms)
        } else {
            // inline path (legacy wall-clock shape; required for the
            // exclusive PJRT engine): batch-fetch the cohort across
            // fetch_threads, then drain the same per-slot task bodies on
            // the caller thread
            let outcomes =
                session.fetch_batch_delta(&client_keys, &deltas, self.cfg.fetch_threads)?;
            let fetch_ms = t_task.elapsed().as_secs_f64() * 1e3;
            let t_compute = Instant::now();
            let engine = &mut self.engine;
            let inputs: Vec<((ClientKeys, Rng), FetchOutcome)> = client_keys
                .into_iter()
                .zip(client_rngs)
                .zip(outcomes)
                .collect();
            let (outs, stats) = exec::run_tasks_seq(
                inputs,
                |slot, ((keys, mut crng), fetched)| -> Result<TaskOut> {
                    run_client_task(
                        arch,
                        &train[cohort_ids[slot] % n_train],
                        hazards[slot],
                        secure_up,
                        fetched,
                        keys,
                        &mut crng,
                        fetch_ms,
                        |ms, slices, batch| engine.client_update(arch, ms, slices, batch, lr),
                    )
                },
            );
            let compute_ms = t_compute.elapsed().as_secs_f64() * 1e3;
            (outs, stats, fetch_ms, compute_ms)
        };
        // the close span opens here so the four phase spans *tile* the
        // round: everything after the task phase — session teardown, cache
        // commits, the cohort-order replay, engine close, merge — is close
        // time. That tiling is what makes `wall_ms ≤ sum-of-spans` hold
        // (pinned by a test) once fetch and compute overlap.
        let t_close = Instant::now();
        let comm = session.finish();
        // unwrap task errors in slot order (first failing slot wins, so the
        // surfaced error is deterministic at any worker count)
        let outs: Vec<TaskOut> = task_results.into_iter().collect::<Result<_>>()?;

        // Cache bookkeeping (replayed in cohort order, like every other
        // task side effect): commit every cohort member's round against its
        // cache (the download happened even if the client drops later),
        // before this round's version bumps. Hits/lookups are
        // tier-attributed for the per-tier hit-rate column. Each slot's
        // keys ride back in its TaskOut — dropped slots still committed.
        let mut tier_cache_hits = vec![0u64; ntiers];
        let mut tier_cache_lookups = vec![0u64; ntiers];
        let mut cache_stats = CommitStats::default();
        if let Some(versions) = self.versions.as_ref() {
            let cgeom = self.cache_geom.as_ref().expect("cache geometry");
            // materialize each cohort member's cache first (derived budgets
            // resolve from the device profile) — caches exist only for
            // clients that ever reached a commit
            for &ci in cohort.iter() {
                self.scheduler.ensure_cache(ci);
            }
            let caches = self.scheduler.caches_mut().expect("caches installed");
            for (slot, &ci) in cohort.iter().enumerate() {
                let st = caches.commit(ci, self.round as u64, &outs[slot].keys, cgeom, versions);
                tier_cache_hits[slot_tiers[slot]] += st.hits;
                tier_cache_lookups[slot_tiers[slot]] += st.lookups;
                cache_stats.accumulate(&st);
            }
            // the session and the caches classified independently from the
            // same immutable state: they must agree
            debug_assert_eq!(
                cache_stats.hits,
                outs.iter().map(|o| o.piece_hits).sum::<u64>(),
                "session ledger and cache commit disagree on hits"
            );
        }

        // Phase 3a — replay: fold every slot's staged TaskOut into the
        // ledgers, trace stream, and engine work vector in cohort-index
        // order, so the observable side-effect sequence is identical to the
        // sequential round at any worker count.
        let mut dropped = 0usize;
        let mut up_bytes_plain = 0u64;
        let mut up_bytes_secure = 0u64;
        let mut max_mem = 0usize;
        let mut stats: Vec<ClientRoundStats> = Vec::with_capacity(cohort.len());
        let mut work: Vec<Option<SlotWork>> = Vec::with_capacity(cohort.len());
        for (i, out) in outs.into_iter().enumerate() {
            if obs_on {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client: cohort[i],
                    tier: Some(slot_tiers[i]),
                    stage: ClientStage::Fetched {
                        down_bytes: out.down_bytes,
                        cache_hit_pieces: out.piece_hits,
                    },
                });
            }
            if out.dropped {
                dropped += 1;
                if obs_on {
                    self.recorder.record(&TraceEvent::Client {
                        ns: self.ns,
                        round: self.round,
                        client: cohort[i],
                        tier: Some(slot_tiers[i]),
                        stage: ClientStage::Dropped,
                    });
                }
                stats.push(ClientRoundStats {
                    down_bytes: out.down_bytes,
                    dropped: true,
                    ..ClientRoundStats::default()
                });
                work.push(None);
                continue;
            }
            max_mem = max_mem.max(out.mem);
            up_bytes_plain += out.plain_up;
            up_bytes_secure += out.up_bytes;
            stats.push(ClientRoundStats {
                down_bytes: out.down_bytes,
                up_bytes: out.up_bytes,
                compute_units: out.compute_units,
                update_norm: out.update_norm,
                dropped: false,
            });
            if obs_on {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client: cohort[i],
                    tier: Some(slot_tiers[i]),
                    stage: ClientStage::Computed {
                        up_bytes: out.up_bytes,
                    },
                });
            }
            work.push(Some(SlotWork {
                client: cohort[i],
                tier: slot_tiers[i],
                keys: out.keys,
                deltas: out.deltas.expect("computed slot carries deltas"),
            }));
        }

        // Phase 3b — close: the scheduler prices each slot's completion on
        // the simulated timeline; the engine consumes the executor's
        // per-slot task completions — handed over in host pool-drain order
        // — re-sorts them onto the simulated clock, and decides which
        // updates merge (strict sync: all, in slot order; fast sync:
        // completion order; over-select: the first `cohort`; buffered: the
        // goal count, carried in-flight updates included) and when the
        // round closes.
        let events = self.scheduler.events(&plan, &stats);
        let mut event_by_slot: Vec<Option<CompletionEvent>> = vec![None; cohort.len()];
        for e in &events {
            event_by_slot[e.slot] = Some(*e);
        }
        if obs_on {
            // per-task spans (slot order): host wall time of the slot's
            // fetch→train task body against its simulated completion point
            for (slot, ev) in event_by_slot.iter().enumerate() {
                if let Some(e) = ev {
                    self.recorder.record(&TraceEvent::Task {
                        ns: self.ns,
                        round: self.round,
                        client: e.client,
                        tier: e.tier,
                        wall_ms: exec_stats.task_wall_ms[slot],
                        sim_s: e.at_s,
                    });
                }
            }
        }
        let round_start_s = self.scheduler.sim_total_s();
        let completions: Vec<TaskCompletion> = exec_stats
            .completion_order
            .iter()
            .filter_map(|&slot| {
                let w = work[slot].take()?;
                Some(TaskCompletion {
                    event: event_by_slot[slot].expect("live slot has a completion event"),
                    work: w,
                })
            })
            .collect();
        let outcome = self.round_engine.close_from_tasks(
            self.round,
            self.cfg.cohort,
            cohort.len(),
            round_start_s,
            completions,
            self.cfg.exec,
        );

        // live registry: per-tier fetch-latency and merged-staleness
        // histograms (deterministic sim quantities — always on, the
        // registry never feeds back into the trajectory)
        for e in &events {
            self.metrics
                .observe(&self.fetch_hist_keys[e.tier], e.timing.download_s);
        }
        for item in &outcome.merged {
            self.metrics.observe(STALENESS_HIST, item.staleness as f64);
        }
        if obs_on {
            for item in &outcome.merged {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client: item.client,
                    tier: Some(item.tier),
                    stage: ClientStage::Merged {
                        staleness: item.staleness,
                        weight: item.weight,
                    },
                });
            }
            for (i, &client) in outcome.discarded_ids.iter().enumerate() {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client,
                    tier: outcome.discarded_tiers.get(i).copied(),
                    stage: ClientStage::Discarded,
                });
            }
            for &(client, tier) in &outcome.deferred_ids {
                self.recorder.record(&TraceEvent::Client {
                    ns: self.ns,
                    round: self.round,
                    client,
                    tier: Some(tier),
                    stage: ClientStage::Deferred,
                });
            }
            // committee membership is only meaningful when the committee
            // SecAgg substrate actually keys masks from it
            if self.cfg.secure_agg && self.cfg.secure_committee {
                for (ci, com) in outcome.committees.iter().enumerate() {
                    for &mi in &com.submitters {
                        let item = &outcome.merged[mi];
                        self.recorder.record(&TraceEvent::Client {
                            ns: self.ns,
                            round: self.round,
                            client: item.client,
                            tier: Some(item.tier),
                            stage: ClientStage::CommitteeKeyed {
                                committee: ci,
                                submitter: true,
                            },
                        });
                    }
                    for &d in &com.dropped {
                        self.recorder.record(&TraceEvent::Client {
                            ns: self.ns,
                            round: self.round,
                            client: d as usize,
                            tier: None,
                            stage: ClientStage::CommitteeKeyed {
                                committee: ci,
                                submitter: false,
                            },
                        });
                    }
                }
            }
        }

        // Phase 3c — aggregate and step the server optimizer on the
        // pseudo-gradient. Three substrates:
        //  * plain: the engine's merge list through the sparse accumulator
        //    (weight 1.0 routes through the exact unweighted float path);
        //  * secure, whole-cohort (legacy, sync-only): one float-mask
        //    SecureAggSim over the round cohort;
        //  * secure committees: one fixed-point SecAggCommittee per close
        //    group/staleness class — members mask against committee peers
        //    only, keyed-but-silent members (over-select stragglers,
        //    staleness discards) take the per-committee mask-reconstruction
        //    path, and each committee's staleness weight is applied to its
        //    *unmasked sum* (the equal-scale mask algebra is preserved).
        let completed = outcome.merged.len();
        let mut committees_keyed = 0usize;
        let mut committee_members = 0usize;
        let mut min_committee_size = usize::MAX;
        // each substrate yields the finalized server update (None when
        // nothing merged) and reports the merged updates' touched keys —
        // the version clock's candidate rows ride the aggregator instead of
        // being re-unioned trainer-side; the optimizer step is shared below
        let mut touched = TouchedKeys::new(self.spec.keyspaces.len());
        let t_merge = Instant::now();
        let update: Option<ParamStore> = if self.cfg.secure_agg && self.cfg.secure_committee {
            // committee id = run seed ⊕ close ordinal, spread over the
            // staleness classes of one close. The close ordinal is the
            // varying term — it must NOT be XORed against anything that
            // already contains the round number (that would cancel and
            // reuse mask material across closes).
            let run_seed = self.cfg.seed ^ 0x5EC_C0117EE;
            let mut acc = self.store.zeros_like();
            let mut counts = self.store.zeros_like();
            for com in &outcome.committees {
                let seed = (run_seed ^ com.close_ordinal)
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(com.staleness as u64);
                let members: Vec<u64> = com
                    .submitters
                    .iter()
                    .map(|&i| outcome.merged[i].client as u64)
                    .chain(com.dropped.iter().copied())
                    .collect();
                let mut sec = SecAggCommittee::new(&self.store, members, seed);
                for &i in &com.submitters {
                    let item = &outcome.merged[i];
                    sec.submit(item.client as u64, &self.spec, &item.keys, &item.deltas)?;
                }
                for &d in &com.dropped {
                    sec.mark_dropped(d);
                }
                let (csum, ccnt) = sec.unmask_sum();
                touched.merge(sec.touched());
                for (a, s) in acc.segments.iter_mut().zip(csum.segments.iter()) {
                    for (x, &v) in a.data.iter_mut().zip(s.data.iter()) {
                        *x += com.weight * v;
                    }
                }
                // selection counts land unweighted, matching the ledger
                // semantics of Aggregator::add_client_weighted
                for (a, s) in counts.segments.iter_mut().zip(ccnt.segments.iter()) {
                    for (x, &v) in a.data.iter_mut().zip(s.data.iter()) {
                        *x += v;
                    }
                }
                committees_keyed += 1;
                committee_members += com.size();
                // the anonymity set of a committee's unmasked sum is its
                // *submitters* — reconstruction-path dropouts contribute
                // nothing — so the floor metric counts only those
                min_committee_size = min_committee_size.min(com.submitters.len());
            }
            (completed > 0).then(|| finalize_mean(acc, &counts, completed, self.cfg.agg))
        } else if self.cfg.secure_agg {
            // whole-cohort float masks (sync-only, validated): every cohort
            // member was keyed at selection, so members that dropped
            // post-fetch never submit and their orphan masks must be
            // reconstructed — otherwise full-scale Gaussian residue lands
            // in the server update.
            let ids: Vec<u64> = cohort.iter().map(|&c| c as u64).collect();
            let mut sec =
                SecureAggSim::new(&self.store, ids.clone(), self.cfg.seed ^ self.round as u64);
            for item in &outcome.merged {
                // sync mode: every merge weight is exactly 1.0
                sec.submit(item.client as u64, &self.spec, &item.keys, &item.deltas)?;
            }
            let submitted: std::collections::HashSet<u64> =
                outcome.merged.iter().map(|m| m.client as u64).collect();
            for &id in &ids {
                if !submitted.contains(&id) {
                    sec.mark_dropped(id);
                }
            }
            touched.merge(sec.touched());
            (completed > 0).then(|| {
                let (acc, secure_counts) = sec.unmask_sum();
                finalize_mean(acc, &secure_counts, completed, self.cfg.agg)
            })
        } else {
            // plain path: strict keeps the sequential sparse accumulator
            // (byte-identity anchor); fast stripes the adds over the
            // key-sharded accumulator (bit-exact per coordinate at any
            // shard count — stripes partition coordinates — but paired
            // with completion-order merging above). agg_shards = 0 derives
            // the shard count from the worker pool.
            let mut agg: Box<dyn Aggregator> = if self.cfg.exec == ExecMode::Fast {
                let shards = if self.cfg.agg_shards == 0 {
                    self.cfg.exec_workers
                } else {
                    self.cfg.agg_shards
                };
                Box::new(ShardedAccumulator::new(&self.store, shards))
            } else {
                Box::new(SparseAccumulator::new(&self.store))
            };
            for item in &outcome.merged {
                agg.add_client_weighted(&self.spec, &item.keys, &item.deltas, item.weight)?;
            }
            if completed > 0 {
                let (update, agg_touched) = agg.finalize(self.cfg.agg);
                touched = agg_touched;
                Some(update)
            } else {
                None
            }
        };
        let merge_stall_ms = t_merge.elapsed().as_secs_f64() * 1e3;
        if let Some(update) = &update {
            self.optimizer.step(&mut self.store, update);
        }

        // --cache: bump the version clock for exactly the rows this close
        // wrote. Candidate rows are the aggregator-reported touched set —
        // the union of the merged updates' keys, identical across all three
        // aggregation substrates; of those, only rows with a nonzero
        // finalized aggregate actually changed the store (zero update =
        // fixed point for the cache-validated server optimizers), so
        // zero-aggregate rows — padded select keys nobody's data exercises,
        // cancelling contributions — keep their version and every cached
        // copy of them stays valid. An empty close bumps nothing.
        if let (Some(versions), Some(update)) = (self.versions.as_mut(), update.as_ref()) {
            versions.bump_written(self.round as u64, &touched, update, &self.spec);
        }

        // bytes uploaded *this round* by every computed client — like the
        // plain path, discarded stragglers' (masked) uploads stay on the
        // ledger; carried in-flight merges were charged at launch
        let up_bytes = if self.cfg.secure_agg {
            up_bytes_secure
        } else {
            up_bytes_plain
        };

        // Phase 3d — land the close point on the simulated clock and tally
        // tiers (merged updates by their own tier; drops/downloads over the
        // whole cohort).
        let merged_tiers: Vec<usize> = outcome.merged.iter().map(|m| m.tier).collect();
        let sim = self.scheduler.complete_round_at(
            &plan,
            &stats,
            &events,
            outcome.close_s,
            &merged_tiers,
        );

        let mut tier_discarded = vec![0usize; self.scheduler.fleet().num_tiers()];
        for &t in &outcome.discarded_tiers {
            tier_discarded[t] += 1;
        }
        let close_ms = t_close.elapsed().as_secs_f64() * 1e3;
        // span *union*: plan start → now. Under the pooled executor fetch
        // and compute overlap, so this is ≤ the sum of the four phase spans
        // (by exactly last-minus-first fetch end) — pinned by a test.
        let wall_ms = t_plan.elapsed().as_secs_f64() * 1e3;

        let tick = RoundTick {
            cohort: plan.cohort.clone(),
            close_s: outcome.close_s,
            busy: events.iter().map(|e| (e.client, e.at_s)).collect(),
        };
        let rec = RoundRecord {
            round: self.round,
            completed,
            dropped,
            mode: self.round_engine.mode(),
            discarded_clients: outcome.discarded_tiers.len(),
            mean_staleness: outcome.mean_staleness,
            committees: committees_keyed,
            mean_committee_size: if committees_keyed > 0 {
                committee_members as f64 / committees_keyed as f64
            } else {
                0.0
            },
            min_committee_size: if committees_keyed > 0 {
                min_committee_size
            } else {
                0
            },
            comm,
            up_bytes,
            max_client_mem: max_mem,
            // plan→close only; eval wall time lands on EvalRecord::eval_ms
            wall_ms,
            merge_stall_ms,
            exec_util: exec_stats.utilization(),
            sim_round_s: sim.sim_round_s,
            tier_completed: sim.tier_completed,
            tier_dropped: sim.tier_dropped,
            tier_discarded,
            tier_down_bytes: sim.tier_down_bytes,
            tier_cache_hits,
            tier_cache_lookups,
            cache_evictions: cache_stats.evictions,
            cache_stale_refreshes: cache_stats.stale_refreshes,
            deferrals: outcome.deferred,
            eligible: plan.eligible,
            arrivals: plan.arrivals,
            departures: plan.departures,
            outage_excluded: plan.outage_excluded,
            clients_touched: self.scheduler.clients_touched(),
            resident_bytes: self.scheduler.resident_state_bytes(),
        };
        record_round(&mut self.metrics, &rec);
        // Health monitor: observes the finished record, never steers it.
        // All sampled series are sim-clock quantities, so the resulting
        // incident stream is byte-identical across same-seed runs.
        let health_events = match self.health.as_mut() {
            Some(mon) => {
                let evs = mon.observe_round(&rec);
                let mut violating = 0u64;
                for ev in &evs {
                    match ev.action {
                        IncidentAction::Open => {
                            self.metrics.counter_add(keys::HEALTH_INCIDENTS, 1);
                            if ev.severity == Severity::Critical {
                                self.metrics.counter_add(keys::HEALTH_CRITICAL, 1);
                            }
                            violating += 1;
                        }
                        IncidentAction::Update => violating += 1,
                        IncidentAction::Resolve => {
                            self.metrics.counter_add(keys::HEALTH_RESOLVED, 1)
                        }
                    }
                }
                if violating > 0 {
                    self.metrics.counter_add(keys::HEALTH_VIOLATION_ROUNDS, violating);
                }
                self.metrics
                    .gauge_set(keys::HEALTH_OPEN, mon.open_incidents() as f64);
                evs
            }
            None => Vec::new(),
        };
        if obs_on {
            // per-phase sim spans: fetch/compute take the slowest client's
            // leg (phases overlap per client on the simulated timeline, so
            // these are envelopes), close is the engine's close point
            let sim_fetch_s = events
                .iter()
                .map(|e| e.timing.download_s)
                .fold(0.0, f64::max);
            let sim_compute_s = events
                .iter()
                .map(|e| e.timing.compute_s)
                .fold(0.0, f64::max);
            for (phase, wall_ms, sim_s) in [
                (Phase::Plan, plan_ms, 0.0),
                (Phase::Fetch, fetch_ms, sim_fetch_s),
                (Phase::Compute, compute_ms, sim_compute_s),
                (Phase::Close, close_ms, outcome.close_s),
            ] {
                self.recorder.record(&TraceEvent::Span {
                    ns: self.ns,
                    round: self.round,
                    phase,
                    wall_ms,
                    sim_s,
                });
            }
            self.recorder.record(&TraceEvent::RoundClose {
                ns: self.ns,
                round: self.round,
                completed,
                dropped,
                discarded: outcome.discarded_tiers.len(),
                deferred: outcome.deferred,
                committees: committees_keyed,
                close_s: outcome.close_s,
                sim_round_s: rec.sim_round_s,
                sim_total_s: self.scheduler.sim_total_s(),
                down_bytes: rec.comm.down_bytes,
                up_bytes,
                eligible: rec.eligible,
                arrivals: rec.arrivals,
                departures: rec.departures,
                outage_excluded: rec.outage_excluded,
                clients_touched: rec.clients_touched,
                resident_bytes: rec.resident_bytes,
            });
            let sim_total_s = self.scheduler.sim_total_s();
            for ev in &health_events {
                self.recorder.record(&TraceEvent::Incident {
                    ns: self.ns,
                    round: ev.round,
                    id: ev.id,
                    action: ev.action,
                    severity: ev.severity,
                    rule: ev.rule.clone(),
                    series: ev.series.name().to_string(),
                    observed: ev.observed,
                    expected: ev.expected,
                    sim_s: sim_total_s,
                });
            }
        }
        Ok((rec, tick))
    }

    /// Evaluate the full server model on held-out clients.
    pub fn evaluate(&mut self) -> Result<EvalRecord> {
        let t_eval = Instant::now();
        let split = if self.cfg.eval.use_val && !self.dataset.val.is_empty() {
            &self.dataset.val
        } else if !self.dataset.test.is_empty() {
            &self.dataset.test
        } else {
            &self.dataset.train
        };
        let mut pool: Vec<&Example> = split.iter().flat_map(|c| c.examples.iter()).collect();
        pool.truncate(self.cfg.eval.max_examples);
        if pool.is_empty() {
            return Err(Error::Data("no eval examples".into()));
        }
        let batches = build_eval_batches(&self.arch, &pool)?;
        let (mut loss, mut metric, mut wsum) = (0.0f64, 0.0f64, 0.0f64);
        for b in &batches {
            let (l, m, w) = self.engine.eval(&self.arch, &self.store, b)?;
            loss += l;
            metric += m;
            wsum += w;
        }
        let w = wsum.max(1.0);
        let rec = EvalRecord {
            round: self.round,
            loss: loss / w,
            metric: metric / w,
            examples: wsum as usize,
            eval_ms: t_eval.elapsed().as_secs_f64() * 1e3,
        };
        if self.recorder.enabled() {
            self.recorder.record(&TraceEvent::Span {
                ns: self.ns,
                round: rec.round,
                phase: Phase::Eval,
                wall_ms: rec.eval_ms,
                sim_s: 0.0,
            });
            self.recorder.record(&TraceEvent::Eval {
                ns: self.ns,
                round: rec.round,
                loss: rec.loss,
                metric: rec.metric,
                examples: rec.examples,
                wall_ms: rec.eval_ms,
            });
        }
        Ok(rec)
    }

    /// Whether [`Self::run`] evaluates after 0-based round `r` (the final
    /// round's eval is always taken separately). Exposed so the multi-tenant
    /// coordinator reproduces the run-loop cadence per job exactly.
    pub fn should_eval(&self, r: usize) -> bool {
        let every = self.cfg.eval.every;
        every > 0 && (r + 1) % every == 0 && r + 1 < self.cfg.rounds
    }

    /// Take the final evaluation and assemble the [`TrainReport`] — the tail
    /// of [`Self::run`], shared with the multi-tenant coordinator so a
    /// single-job coordinator report is byte-identical to a trainer report.
    pub fn finish_report(
        &mut self,
        rounds: Vec<RoundRecord>,
        mut evals: Vec<EvalRecord>,
    ) -> Result<TrainReport> {
        let final_eval = self.evaluate()?;
        evals.push(final_eval);
        let report = TrainReport {
            rel_model_size: self.rel_model_size(),
            server_params: self.store.num_params(),
            total_down_bytes: rounds.iter().map(|r| r.comm.down_bytes).sum(),
            total_up_bytes: rounds.iter().map(|r| r.up_bytes).sum(),
            total_sim_s: rounds.iter().map(|r| r.sim_round_s).sum(),
            // updates still in flight when training ends will never merge —
            // they are part of the computed-but-wasted ledger too
            total_discarded: rounds.iter().map(|r| r.discarded_clients).sum::<usize>()
                + self.round_engine.in_flight(),
            rounds,
            evals,
            final_eval,
            health: self
                .health
                .as_mut()
                .map(|m| m.finish())
                .unwrap_or_default(),
        };
        if self.recorder.enabled() {
            self.recorder.record(&TraceEvent::RunEnd {
                ns: self.ns,
                rounds: report.rounds.len(),
                sim_total_s: report.total_sim_s,
            });
        }
        self.recorder.flush();
        Ok(report)
    }

    /// Run the configured number of rounds with periodic evaluation. With
    /// `--horizon H` the run additionally stops once the simulated clock
    /// passes `H` hours (whichever bound lands first).
    pub fn run(&mut self) -> Result<TrainReport> {
        let horizon_s = self.cfg.scenario.horizon_h * 3600.0;
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut evals = Vec::new();
        for r in 0..self.cfg.rounds {
            if horizon_s > 0.0 && self.scheduler.sim_total_s() >= horizon_s {
                break;
            }
            let rec = self.run_round()?;
            rounds.push(rec);
            if self.should_eval(r) {
                evals.push(self.evaluate()?);
            }
        }
        self.finish_report(rounds, evals)
    }
}

/// Everything one cohort slot's task stages for the cohort-order replay:
/// ledger arithmetic done off-thread, side effects deferred. Keys ride back
/// in full (dropped slots still commit their cache round), deltas only for
/// computed slots.
struct TaskOut {
    /// The session's per-client wire charge (post-cache): what the SimClock
    /// moves over the client's downlink — full model under Option 1, bundle
    /// bytes under Options 2/3.
    down_bytes: u64,
    /// Piece/segment lookups served from the client's cache.
    piece_hits: u64,
    keys: ClientKeys,
    /// Post-fetch dropout (the profile hazard fired).
    dropped: bool,
    /// Per-binding sliced model deltas (`None` iff dropped).
    deltas: Option<Vec<Vec<f32>>>,
    /// Upload bytes charged to the client (secure-agg pricing applied).
    up_bytes: u64,
    /// Plain upload bytes (update + keys), always tracked so the ledger
    /// can report either pricing.
    plain_up: u64,
    /// Slice-floats × local examples (the SimClock compute model).
    compute_units: f64,
    /// ℓ2 norm of the client's update (0 for dropped).
    update_norm: f32,
    /// Peak client memory (slice + batch working set), bytes.
    mem: usize,
    /// Host ms offset (from task-phase start) at which this slot's slice
    /// was fully fetched — the fetch/compute span extents derive from it.
    fetch_end_ms: f64,
}

/// One cohort slot's post-fetch task body: hazard coin → local batch →
/// one local epoch → ledger arithmetic. Shared verbatim between the inline
/// and pooled executor paths so they cannot drift; `update` is the engine
/// call (exclusive [`Engine::client_update`] inline, pure
/// [`native::client_update`] in the pool). Consumes `crng` in the exact
/// legacy order: hazard coin first, then the batch shuffle.
#[allow(clippy::too_many_arguments)]
fn run_client_task<F>(
    arch: &ModelArch,
    client: &ClientData,
    hazard: f32,
    secure_up: Option<u64>,
    fetched: FetchOutcome,
    keys: ClientKeys,
    crng: &mut Rng,
    fetch_end_ms: f64,
    update: F,
) -> Result<TaskOut>
where
    F: FnOnce(&[usize], Vec<Vec<f32>>, &[Buf]) -> Result<Vec<Vec<f32>>>,
{
    let down_bytes = fetched.down_bytes;
    let piece_hits = fetched.piece_hits;
    let bundle = fetched.bundle;
    let slice_floats = bundle.total_floats();
    // failure injection: drop after download, with the profile's hazard
    // (the coin is only flipped when the hazard is nonzero, matching the
    // legacy `dropout_rate > 0` gate bit for bit)
    if hazard > 0.0 && crng.f32() < hazard {
        return Ok(TaskOut {
            down_bytes,
            piece_hits,
            keys,
            dropped: true,
            deltas: None,
            up_bytes: 0,
            plain_up: 0,
            compute_units: 0.0,
            update_norm: 0.0,
            mem: 0,
            fetch_end_ms,
        });
    }
    let (batch, _used) = build_cu_batch(arch, client, &keys, crng)?;
    let mem = client_memory_bytes(slice_floats, &batch);
    let ms: Vec<usize> = keys.iter().map(|k| k.len()).collect();
    let deltas = update(&ms, bundle.into_vecs(), &batch)?;
    let plain_up = deltas.iter().map(|d| d.len() as u64 * 4).sum::<u64>()
        + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
    let up_bytes = secure_up.unwrap_or(plain_up);
    let update_norm = deltas
        .iter()
        .flat_map(|d| d.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt() as f32;
    Ok(TaskOut {
        down_bytes,
        piece_hits,
        keys,
        dropped: false,
        deltas: Some(deltas),
        up_bytes,
        plain_up,
        compute_units: slice_floats as f64 * client.num_examples() as f64,
        update_norm,
        mem,
        fetch_end_ms,
    })
}

/// Materialize the configured dataset.
pub fn build_dataset(cfg: &DatasetConfig) -> FederatedDataset {
    match cfg {
        DatasetConfig::Bow(c) => bow::generate(c),
        DatasetConfig::Image(c) => images::generate(c),
        DatasetConfig::Text(c) => text::generate(c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::bow::BowConfig;

    fn tiny_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::logreg_default(128, 32);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(24, 4, 8));
        cfg.rounds = 4;
        cfg.cohort = 6;
        cfg.eval.every = 0;
        cfg.eval.max_examples = 256;
        cfg
    }

    #[test]
    fn trainer_runs_and_improves() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        let before = t.evaluate().unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.rounds.len(), 4);
        assert!(report.final_eval.loss.is_finite());
        assert!(
            report.final_eval.loss < before.loss,
            "loss {} !< {}",
            report.final_eval.loss,
            before.loss
        );
        assert!(report.rel_model_size < 0.5);
        assert!(report.total_down_bytes > 0);
        assert!(report.total_up_bytes > 0);
    }

    #[test]
    fn dropout_reduces_completions() {
        let mut cfg = tiny_cfg();
        cfg.dropout_rate = 0.9;
        let mut t = Trainer::new(cfg).unwrap();
        let rec = t.run_round().unwrap();
        assert!(rec.dropped > 0);
        assert_eq!(rec.completed + rec.dropped, 6);
    }

    #[test]
    fn secure_agg_matches_plain_training() {
        // same seed, same clients: masked aggregation must yield (nearly)
        // the same model trajectory as plain aggregation
        let mut cfg_a = tiny_cfg();
        cfg_a.rounds = 2;
        let mut cfg_b = cfg_a.clone();
        cfg_b.secure_agg = true;
        let ra = Trainer::new(cfg_a).unwrap().run().unwrap();
        let rb = Trainer::new(cfg_b).unwrap().run().unwrap();
        assert!(
            (ra.final_eval.loss - rb.final_eval.loss).abs() < 0.05 * ra.final_eval.loss.abs(),
            "plain {} vs secure {}",
            ra.final_eval.loss,
            rb.final_eval.loss
        );
        // secure agg uploads full-model-sized vectors
        assert!(rb.total_up_bytes > ra.total_up_bytes);
    }

    #[test]
    fn secure_agg_reconstructs_postfetch_dropout_masks() {
        // a cohort member that drops after seed agreement never submits, so
        // its pairwise masks must be reconstructed — without that the server
        // update carries full-scale Gaussian residue and training diverges
        // from the plain trajectory instead of tracking it to mask rounding
        let mut cfg_a = tiny_cfg();
        cfg_a.rounds = 3;
        cfg_a.dropout_rate = 0.4;
        let mut cfg_b = cfg_a.clone();
        cfg_b.secure_agg = true;
        let ra = Trainer::new(cfg_a).unwrap().run().unwrap();
        let rb = Trainer::new(cfg_b).unwrap().run().unwrap();
        assert!(
            ra.rounds.iter().map(|r| r.dropped).sum::<usize>() > 0,
            "dropout never fired"
        );
        // same seed => same cohorts, same dropout coins, same merge set
        assert!(
            (ra.final_eval.loss - rb.final_eval.loss).abs()
                < 0.05 * ra.final_eval.loss.abs(),
            "plain {} vs secure-with-dropout {}",
            ra.final_eval.loss,
            rb.final_eval.loss
        );
    }

    #[test]
    fn fetch_threads_do_not_change_the_trajectory() {
        // byte-identical training at any thread count, for every impl
        for imp in [
            crate::fedselect::SliceImpl::Broadcast,
            crate::fedselect::SliceImpl::OnDemand,
            crate::fedselect::SliceImpl::PregenCdn,
        ] {
            let mut cfg = tiny_cfg();
            cfg.rounds = 2;
            cfg.slice_impl = imp;
            let serial = Trainer::new(cfg.clone()).unwrap().run().unwrap();
            cfg.fetch_threads = 4;
            let parallel = Trainer::new(cfg).unwrap().run().unwrap();
            assert_eq!(
                serial.final_eval.loss.to_bits(),
                parallel.final_eval.loss.to_bits(),
                "{imp}"
            );
            assert_eq!(serial.total_down_bytes, parallel.total_down_bytes, "{imp}");
            assert_eq!(serial.total_up_bytes, parallel.total_up_bytes, "{imp}");
        }
    }

    #[test]
    fn tiered_fleet_memory_capped_reports_per_tier_completions() {
        use crate::scheduler::{FleetKind, SchedPolicy};
        let mut cfg = tiny_cfg();
        cfg.fleet = FleetKind::Tiered3;
        cfg.sched_policy = SchedPolicy::MemoryCapped;
        cfg.mem_cap_frac = 0.2;
        let mut t = Trainer::new(cfg).unwrap();
        let report = t.run().unwrap();
        for rec in &report.rounds {
            assert_eq!(rec.tier_completed.len(), 3);
            assert_eq!(
                rec.tier_completed.iter().sum::<usize>(),
                rec.completed,
                "per-tier completions must partition the cohort"
            );
            assert_eq!(rec.tier_dropped.iter().sum::<usize>(), rec.dropped);
            assert!(rec.sim_round_s > 0.0);
        }
        assert!(report.total_sim_s > 0.0);
        assert!(report.final_eval.loss.is_finite());
    }

    #[test]
    fn memory_capped_budgets_shrink_low_tier_downloads() {
        use crate::scheduler::{FleetKind, SchedPolicy};
        let mut base = tiny_cfg();
        base.fleet = FleetKind::Tiered3;
        base.rounds = 2;
        let mut capped = base.clone();
        capped.sched_policy = SchedPolicy::MemoryCapped;
        capped.mem_cap_frac = 0.1;
        let ru = Trainer::new(base).unwrap().run().unwrap();
        let rc = Trainer::new(capped).unwrap().run().unwrap();
        // same cohorts (MemoryCapped samples like Uniform), smaller slices
        assert!(
            rc.total_down_bytes < ru.total_down_bytes,
            "capped {} !< uniform {}",
            rc.total_down_bytes,
            ru.total_down_bytes
        );
    }

    #[test]
    fn cache_saves_down_bytes_at_an_identical_trajectory() {
        use crate::data::bow::BowConfig;
        use crate::scheduler::{FleetKind, SchedPolicy};
        // reuse by construction: TopFreq keys are deterministic per client,
        // staleness-fair selection cycles every client back within 4
        // rounds, a 512 vocab keeps cohorts from writing the whole
        // keyspace, and a high dropout rate leaves many fetched-but-never-
        // merged key sets whose rows stay version-fresh
        let mut base = TrainConfig::logreg_default(512, 64);
        base.dataset = DatasetConfig::Bow(BowConfig::new(512, 50).with_clients(24, 4, 8));
        base.rounds = 8;
        base.cohort = 6;
        base.eval.every = 0;
        base.eval.max_examples = 256;
        base.fleet = FleetKind::Tiered3;
        base.sched_policy = SchedPolicy::StalenessFair;
        base.dropout_rate = 0.4;
        let mut cached = base.clone();
        cached.cache = true;
        let off = Trainer::new(base).unwrap().run().unwrap();
        let on = Trainer::new(cached).unwrap().run().unwrap();
        // byte-identical trajectory: fresh cache entries are exact copies
        assert_eq!(off.final_eval.loss.to_bits(), on.final_eval.loss.to_bits());
        assert_eq!(off.total_up_bytes, on.total_up_bytes);
        // strictly fewer wire bytes, hits on the ledger
        assert!(
            on.total_down_bytes < off.total_down_bytes,
            "cache-on {} !< cache-off {}",
            on.total_down_bytes,
            off.total_down_bytes
        );
        assert!(on.rounds.iter().map(|r| r.comm.client_cache_hits).sum::<u64>() > 0);
        assert_eq!(
            off.rounds.iter().map(|r| r.comm.client_cache_hits).sum::<u64>(),
            0
        );
        for (a, b) in off.rounds.iter().zip(on.rounds.iter()) {
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.comm.psi_evals, b.comm.psi_evals);
            assert_eq!(a.comm.cdn_queries, b.comm.cdn_queries);
            assert_eq!(a.comm.up_key_bytes, b.comm.up_key_bytes);
            assert!(b.comm.down_bytes <= a.comm.down_bytes);
            // the wire ledger and the tier ledger agree post-cache
            assert_eq!(b.tier_down_bytes.iter().sum::<u64>(), b.comm.down_bytes);
            // fewer wire bytes can only shorten the simulated round
            assert!(b.sim_round_s <= a.sim_round_s + 1e-9);
        }
    }

    #[test]
    fn all_keys_recovers_fedavg_sizes() {
        let mut cfg = tiny_cfg();
        cfg.policies = vec![crate::fedselect::KeyPolicy::AllKeys];
        let t = Trainer::new(cfg).unwrap();
        assert!((t.rel_model_size() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn over_select_inflates_the_cohort_and_ledgers_discards() {
        use crate::scheduler::FleetKind;
        let mut cfg = tiny_cfg();
        cfg.fleet = FleetKind::Tiered3;
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.5 };
        let mut t = Trainer::new(cfg).unwrap();
        let rec = t.run_round().unwrap();
        // 6 requested + ceil(6*0.5) = 9 selected
        assert_eq!(rec.completed + rec.dropped + rec.discarded_clients, 9);
        assert!(rec.completed <= 6, "closes at the original goal count");
        assert_eq!(rec.mode.name(), "over-select");
        // every selected client's download is on the ledger — including the
        // discarded stragglers' (the slice session charged each fetch)
        assert_eq!(rec.tier_down_bytes.iter().sum::<u64>(), rec.comm.down_bytes);
        assert_eq!(
            rec.tier_completed.iter().sum::<usize>(),
            rec.completed,
            "tier completions count merges only"
        );
        assert_eq!(
            rec.tier_discarded.iter().sum::<usize>(),
            rec.discarded_clients,
            "discards are tier-attributed"
        );
    }

    #[test]
    fn buffered_mode_cuts_simulated_time_and_reports_staleness() {
        use crate::scheduler::FleetKind;
        let mut base = tiny_cfg();
        base.fleet = FleetKind::Tiered3;
        base.rounds = 4;
        let mut buf = base.clone();
        buf.agg_mode = AggregationMode::Buffered {
            goal_count: 4,
            max_staleness: 3,
        };
        let sync = Trainer::new(base).unwrap().run().unwrap();
        let buffered = Trainer::new(buf).unwrap().run().unwrap();
        // closing at the 4th landing beats waiting for the straggler of a
        // 6-cohort on every round (cohorts diverge after round 1: buffered
        // mode excludes in-flight clients from re-selection)
        assert!(
            buffered.total_sim_s < sync.total_sim_s,
            "buffered {} !< sync {}",
            buffered.total_sim_s,
            sync.total_sim_s
        );
        assert!(buffered.final_eval.loss.is_finite());
        // stragglers carried into later rounds show up as staleness
        assert!(
            buffered.rounds.iter().skip(1).any(|r| r.mean_staleness > 0.0),
            "no staleness ever recorded"
        );
        for r in &buffered.rounds {
            assert!(r.completed <= 4, "round merges are capped at the goal");
        }
    }

    #[test]
    fn buffered_runs_are_deterministic() {
        use crate::scheduler::FleetKind;
        let mut cfg = tiny_cfg();
        cfg.fleet = FleetKind::FlakyEdge;
        cfg.rounds = 3;
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 2,
        };
        let a = Trainer::new(cfg.clone()).unwrap().run().unwrap();
        let b = Trainer::new(cfg).unwrap().run().unwrap();
        assert_eq!(a.final_eval.loss.to_bits(), b.final_eval.loss.to_bits());
        assert_eq!(a.total_sim_s.to_bits(), b.total_sim_s.to_bits());
        assert_eq!(a.total_discarded, b.total_discarded);
    }
}

//! The round driver: federated model training with FedSelect (Algorithm 2).
//!
//! Each round runs in four phases:
//! 0. **Plan** — [`Scheduler::plan_round`] chooses the cohort from the
//!    device fleet via the configured selection policy, with per-slot
//!    failure hazards and (optionally) per-client select-key budgets; the
//!    `uniform` fleet + `uniform` policy path is byte-identical to the
//!    pre-scheduler inline sampling (§5.1: uniform without replacement);
//! 1. **Keys** — fork each client's RNG and draw its select keys via its
//!    [`KeyPolicy`] (re-budgeted per client when the plan says so), in
//!    cohort order (phases 0–1 are the only consumers of the round RNG);
//! 2. **Slice** — `begin_round` on the slice service (Option 3
//!    pre-generates here) yields one immutable session, and the whole
//!    cohort is sliced through [`RoundSession::fetch_batch`] across
//!    `fetch_threads` workers;
//! 3. **Update** — each surviving client runs `ClientUpdate` (one local
//!    epoch of SGD through the engine) and `AGGREGATE*` scatters its delta
//!    into full model space (plain or secure-masked); updates are applied
//!    sequentially in cohort-index order so the trajectory is byte-identical
//!    at any `fetch_threads`; then `ServerUpdate` applies the server
//!    optimizer to the pseudo-gradient, and
//!    [`Scheduler::complete_round`] converts the per-client byte ledgers
//!    into simulated round wall-time and per-tier completion counts.
//!
//! Failure injection: a client drops *after* fetching its slice (download
//! wasted, no contribution) with its profile's hazard — the paper's §6
//! dropout pattern, per-device. The deprecated scalar `dropout_rate` floors
//! every hazard, reproducing the old behavior exactly on the uniform fleet.

use std::time::Instant;

use crate::aggregation::{Aggregator, SecureAggSim, SparseAccumulator};
use crate::clients::{build_cu_batch, build_eval_batches, client_memory_bytes, Engine};
use crate::config::{DatasetConfig, EngineKind, TrainConfig};
use crate::data::{bow, images, text, Example, FederatedDataset};
use crate::error::{Error, Result};
use crate::fedselect::{ClientKeys, RoundComm, RoundSession, SliceService};
use crate::metrics::human_bytes;
use crate::model::{ModelArch, ParamStore, SelectSpec};
use crate::optim::Optimizer;
use crate::runtime::PjrtRuntime;
use crate::scheduler::{ClientRoundStats, Scheduler, SliceGeometry};
use crate::tensor::rng::Rng;

/// Per-round ledger.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub completed: usize,
    pub dropped: usize,
    pub comm: RoundComm,
    /// Client->server upload bytes (updates + keys, or masked vectors).
    pub up_bytes: u64,
    /// Max client memory this round (bytes).
    pub max_client_mem: usize,
    pub wall_ms: f64,
    /// Simulated round duration on the device fleet (straggler-bound).
    pub sim_round_s: f64,
    /// Completing clients per fleet tier.
    pub tier_completed: Vec<usize>,
    /// Post-fetch dropouts per fleet tier.
    pub tier_dropped: Vec<usize>,
    /// Download bytes per fleet tier (wasted downloads of dropouts included).
    pub tier_down_bytes: Vec<u64>,
}

/// Periodic evaluation snapshot.
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub loss: f64,
    /// recall@5 (logreg) or accuracy (MLP/CNN/transformer).
    pub metric: f64,
    pub examples: usize,
}

/// Full run report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
    pub final_eval: EvalRecord,
    /// client sub-model floats / server selectable+broadcast floats
    pub rel_model_size: f64,
    pub server_params: usize,
    pub total_down_bytes: u64,
    pub total_up_bytes: u64,
    /// Simulated training time on the device fleet, seconds.
    pub total_sim_s: f64,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        format!(
            "final metric {:.4} | loss {:.4} | rel size {:.3} | down {} | up {} | sim {:.1}s",
            self.final_eval.metric,
            self.final_eval.loss,
            self.rel_model_size,
            human_bytes(self.total_down_bytes),
            human_bytes(self.total_up_bytes),
            self.total_sim_s,
        )
    }
}

/// Federated trainer (Algorithm 2).
pub struct Trainer {
    pub cfg: TrainConfig,
    arch: ModelArch,
    store: ParamStore,
    spec: SelectSpec,
    dataset: FederatedDataset,
    service: Box<dyn SliceService>,
    engine: Engine,
    optimizer: Optimizer,
    scheduler: Scheduler,
    geom: SliceGeometry,
    rng: Rng,
    round: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let dataset = build_dataset(&cfg.dataset);
        Self::build(cfg, dataset)
    }

    /// Construct with an externally built dataset (reused across a sweep).
    pub fn with_dataset(cfg: TrainConfig, dataset: FederatedDataset) -> Result<Self> {
        cfg.validate()?;
        Self::build(cfg, dataset)
    }

    fn build(cfg: TrainConfig, dataset: FederatedDataset) -> Result<Self> {
        if dataset.train.is_empty() {
            return Err(Error::Data("dataset has no training clients".into()));
        }
        let arch = cfg.arch.clone();
        let mut rng = Rng::new(cfg.seed, 100);
        let store = arch.init_store(&mut rng);
        let spec = arch.select_spec();
        spec.validate(&store)?;
        let service = cfg.slice_impl.build();
        let engine = match &cfg.engine {
            EngineKind::Native => Engine::Native,
            EngineKind::Pjrt { artifacts_dir } => {
                Engine::Pjrt(Box::new(PjrtRuntime::load(artifacts_dir)?))
            }
        };
        let optimizer = Optimizer::new(cfg.server_opt, &store);
        let geom = SliceGeometry {
            base_ms: spec
                .keyspaces
                .iter()
                .zip(cfg.policies.iter())
                .map(|(ks, p)| p.m(ks.size))
                .collect(),
            per_key_floats: (0..spec.keyspaces.len())
                .map(|ks| spec.per_key_floats(ks))
                .collect(),
            broadcast_floats: spec.broadcast_floats(&store),
            server_floats: spec.server_floats(&store),
        };
        let scheduler = Scheduler::new(&cfg, dataset.train.len());
        Ok(Trainer {
            cfg,
            arch,
            store,
            spec,
            dataset,
            service,
            engine,
            optimizer,
            scheduler,
            geom,
            rng,
            round: 0,
        })
    }

    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// The cohort scheduler (fleet, policy, simulated clock).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    pub fn dataset(&self) -> &FederatedDataset {
        &self.dataset
    }

    /// Per-keyspace key counts of the configured policies.
    pub fn key_counts(&self) -> Vec<usize> {
        self.spec
            .keyspaces
            .iter()
            .zip(self.cfg.policies.iter())
            .map(|(ks, p)| p.m(ks.size))
            .collect()
    }

    /// Client/server relative model size (the paper's Fig. 3 x-axis).
    pub fn rel_model_size(&self) -> f64 {
        let ms = self.key_counts();
        self.spec.client_floats(&self.store, &ms) as f64
            / self.spec.server_floats(&self.store) as f64
    }

    /// Run one round of Algorithm 2.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let t0 = Instant::now();
        self.round += 1;
        let mut round_rng = self.rng.fork(self.round as u64);

        // Phase 0 — plan: the scheduler picks the cohort from the fleet.
        // Under the uniform policy this is the identical
        // sample_without_replacement draw the pre-scheduler coordinator
        // made, so trajectories are byte-identical at the same seed.
        let plan =
            self.scheduler
                .plan_round(self.round, self.cfg.cohort, &self.geom, &mut round_rng);
        let cohort = &plan.cohort;

        // shared per-round key sets (Fig. 6 "fixed" ablation)
        let shared: Vec<Option<Vec<u32>>> = self
            .cfg
            .policies
            .iter()
            .zip(self.spec.keyspaces.iter())
            .map(|(p, ks)| p.round_keys(ks.size, &mut round_rng))
            .collect();

        let force_unk = matches!(self.arch, ModelArch::Transformer { .. });

        // Phase 1 — keys: fork each client's RNG and draw its select keys
        // (re-budgeted per client when the plan carries key budgets), in
        // cohort order (phases 0-1 are the only consumers of round_rng).
        let mut client_keys: Vec<ClientKeys> = Vec::with_capacity(cohort.len());
        let mut client_rngs: Vec<Rng> = Vec::with_capacity(cohort.len());
        for (slot, &ci) in cohort.iter().enumerate() {
            let client = &self.dataset.train[ci];
            let mut crng = round_rng.fork(client.id ^ 0xC11E47);
            let keys: ClientKeys = self
                .cfg
                .policies
                .iter()
                .enumerate()
                .map(|(ksi, p)| {
                    let p = match &plan.key_budgets {
                        Some(budgets) => p.with_m(budgets[slot][ksi]),
                        None => *p,
                    };
                    p.keys_for(
                        client,
                        self.spec.keyspaces[ksi].size,
                        &mut crng,
                        shared[ksi].as_deref(),
                        force_unk && ksi == 0,
                    )
                })
                .collect();
            client_keys.push(keys);
            client_rngs.push(crng);
        }

        // Phase 2 — slice: one immutable session for the round, the whole
        // cohort fetched through it in parallel. Bundle order == cohort
        // order, so downstream aggregation is deterministic.
        let (bundles, comm) = {
            let session = self.service.begin_round(&self.store, &self.spec)?;
            let bundles = session.fetch_batch(&client_keys, self.cfg.fetch_threads)?;
            (bundles, session.finish())
        };

        // Phase 3 — update: client updates + aggregation, sequential in
        // cohort-index order (byte-identical at any fetch_threads).
        let mut agg: Box<dyn Aggregator> = if self.cfg.secure_agg {
            let ids: Vec<u64> = cohort.iter().map(|&c| c as u64).collect();
            Box::new(SecureAggSim::new(&self.store, ids, self.cfg.seed ^ self.round as u64))
        } else {
            Box::new(SparseAccumulator::new(&self.store))
        };

        let mut dropped = 0usize;
        let mut completed = 0usize;
        let mut up_bytes_plain = 0u64;
        let mut max_mem = 0usize;
        let mut stats: Vec<ClientRoundStats> = Vec::with_capacity(cohort.len());
        for (i, bundle) in bundles.into_iter().enumerate() {
            let client = &self.dataset.train[cohort[i]];
            let crng = &mut client_rngs[i];
            let keys = &client_keys[i];
            let down_bytes = bundle.bytes();
            let slice_floats = bundle.total_floats();

            // failure injection: drop after download, with the profile's
            // hazard (the coin is only flipped when the hazard is nonzero,
            // matching the legacy `dropout_rate > 0` gate bit for bit)
            if plan.hazards[i] > 0.0 && crng.f32() < plan.hazards[i] {
                dropped += 1;
                stats.push(ClientRoundStats {
                    down_bytes,
                    dropped: true,
                    ..ClientRoundStats::default()
                });
                continue;
            }

            let (batch, _used) = build_cu_batch(&self.arch, client, keys, crng)?;
            max_mem = max_mem.max(client_memory_bytes(slice_floats, &batch));
            let ms: Vec<usize> = keys.iter().map(|k| k.len()).collect();
            let deltas = self.engine.client_update(
                &self.arch,
                &ms,
                bundle.into_vecs(),
                &batch,
                self.cfg.client_lr,
            )?;
            let plain_up = deltas.iter().map(|d| d.len() as u64 * 4).sum::<u64>()
                + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
            let client_up = if self.cfg.secure_agg {
                // §4.2: client-side φ + dense secure agg uploads a
                // full-model-sized masked vector.
                self.store.bytes() as u64
            } else {
                plain_up
            };
            up_bytes_plain += plain_up;
            agg.add_client(&self.spec, keys, &deltas)?;
            completed += 1;
            stats.push(ClientRoundStats {
                down_bytes,
                up_bytes: client_up,
                compute_units: slice_floats as f64 * client.num_examples() as f64,
                dropped: false,
            });
        }

        let up_bytes = if self.cfg.secure_agg {
            completed as u64 * self.store.bytes() as u64
        } else {
            up_bytes_plain
        };

        if completed > 0 {
            let update = agg.finalize(self.cfg.agg);
            self.optimizer.step(&mut self.store, &update);
        }

        let sim = self.scheduler.complete_round(&plan, &stats);

        Ok(RoundRecord {
            round: self.round,
            completed,
            dropped,
            comm,
            up_bytes,
            max_client_mem: max_mem,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            sim_round_s: sim.sim_round_s,
            tier_completed: sim.tier_completed,
            tier_dropped: sim.tier_dropped,
            tier_down_bytes: sim.tier_down_bytes,
        })
    }

    /// Evaluate the full server model on held-out clients.
    pub fn evaluate(&mut self) -> Result<EvalRecord> {
        let split = if self.cfg.eval.use_val && !self.dataset.val.is_empty() {
            &self.dataset.val
        } else if !self.dataset.test.is_empty() {
            &self.dataset.test
        } else {
            &self.dataset.train
        };
        let mut pool: Vec<&Example> = split.iter().flat_map(|c| c.examples.iter()).collect();
        pool.truncate(self.cfg.eval.max_examples);
        if pool.is_empty() {
            return Err(Error::Data("no eval examples".into()));
        }
        let batches = build_eval_batches(&self.arch, &pool)?;
        let (mut loss, mut metric, mut wsum) = (0.0f64, 0.0f64, 0.0f64);
        for b in &batches {
            let (l, m, w) = self.engine.eval(&self.arch, &self.store, b)?;
            loss += l;
            metric += m;
            wsum += w;
        }
        let w = wsum.max(1.0);
        Ok(EvalRecord {
            round: self.round,
            loss: loss / w,
            metric: metric / w,
            examples: wsum as usize,
        })
    }

    /// Run the configured number of rounds with periodic evaluation.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut evals = Vec::new();
        for r in 0..self.cfg.rounds {
            let rec = self.run_round()?;
            rounds.push(rec);
            let every = self.cfg.eval.every;
            if every > 0 && (r + 1) % every == 0 && r + 1 < self.cfg.rounds {
                evals.push(self.evaluate()?);
            }
        }
        let final_eval = self.evaluate()?;
        evals.push(final_eval);
        Ok(TrainReport {
            rel_model_size: self.rel_model_size(),
            server_params: self.store.num_params(),
            total_down_bytes: rounds.iter().map(|r| r.comm.down_bytes).sum(),
            total_up_bytes: rounds.iter().map(|r| r.up_bytes).sum(),
            total_sim_s: rounds.iter().map(|r| r.sim_round_s).sum(),
            rounds,
            evals,
            final_eval,
        })
    }
}

/// Materialize the configured dataset.
pub fn build_dataset(cfg: &DatasetConfig) -> FederatedDataset {
    match cfg {
        DatasetConfig::Bow(c) => bow::generate(c),
        DatasetConfig::Image(c) => images::generate(c),
        DatasetConfig::Text(c) => text::generate(c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::bow::BowConfig;

    fn tiny_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::logreg_default(128, 32);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(128, 50).with_clients(24, 4, 8));
        cfg.rounds = 4;
        cfg.cohort = 6;
        cfg.eval.every = 0;
        cfg.eval.max_examples = 256;
        cfg
    }

    #[test]
    fn trainer_runs_and_improves() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        let before = t.evaluate().unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.rounds.len(), 4);
        assert!(report.final_eval.loss.is_finite());
        assert!(
            report.final_eval.loss < before.loss,
            "loss {} !< {}",
            report.final_eval.loss,
            before.loss
        );
        assert!(report.rel_model_size < 0.5);
        assert!(report.total_down_bytes > 0);
        assert!(report.total_up_bytes > 0);
    }

    #[test]
    fn dropout_reduces_completions() {
        let mut cfg = tiny_cfg();
        cfg.dropout_rate = 0.9;
        let mut t = Trainer::new(cfg).unwrap();
        let rec = t.run_round().unwrap();
        assert!(rec.dropped > 0);
        assert_eq!(rec.completed + rec.dropped, 6);
    }

    #[test]
    fn secure_agg_matches_plain_training() {
        // same seed, same clients: masked aggregation must yield (nearly)
        // the same model trajectory as plain aggregation
        let mut cfg_a = tiny_cfg();
        cfg_a.rounds = 2;
        let mut cfg_b = cfg_a.clone();
        cfg_b.secure_agg = true;
        let ra = Trainer::new(cfg_a).unwrap().run().unwrap();
        let rb = Trainer::new(cfg_b).unwrap().run().unwrap();
        assert!(
            (ra.final_eval.loss - rb.final_eval.loss).abs() < 0.05 * ra.final_eval.loss.abs(),
            "plain {} vs secure {}",
            ra.final_eval.loss,
            rb.final_eval.loss
        );
        // secure agg uploads full-model-sized vectors
        assert!(rb.total_up_bytes > ra.total_up_bytes);
    }

    #[test]
    fn fetch_threads_do_not_change_the_trajectory() {
        // byte-identical training at any thread count, for every impl
        for imp in [
            crate::fedselect::SliceImpl::Broadcast,
            crate::fedselect::SliceImpl::OnDemand,
            crate::fedselect::SliceImpl::PregenCdn,
        ] {
            let mut cfg = tiny_cfg();
            cfg.rounds = 2;
            cfg.slice_impl = imp;
            let serial = Trainer::new(cfg.clone()).unwrap().run().unwrap();
            cfg.fetch_threads = 4;
            let parallel = Trainer::new(cfg).unwrap().run().unwrap();
            assert_eq!(
                serial.final_eval.loss.to_bits(),
                parallel.final_eval.loss.to_bits(),
                "{imp}"
            );
            assert_eq!(serial.total_down_bytes, parallel.total_down_bytes, "{imp}");
            assert_eq!(serial.total_up_bytes, parallel.total_up_bytes, "{imp}");
        }
    }

    #[test]
    fn tiered_fleet_memory_capped_reports_per_tier_completions() {
        use crate::scheduler::{FleetKind, SchedPolicy};
        let mut cfg = tiny_cfg();
        cfg.fleet = FleetKind::Tiered3;
        cfg.sched_policy = SchedPolicy::MemoryCapped;
        cfg.mem_cap_frac = 0.2;
        let mut t = Trainer::new(cfg).unwrap();
        let report = t.run().unwrap();
        for rec in &report.rounds {
            assert_eq!(rec.tier_completed.len(), 3);
            assert_eq!(
                rec.tier_completed.iter().sum::<usize>(),
                rec.completed,
                "per-tier completions must partition the cohort"
            );
            assert_eq!(rec.tier_dropped.iter().sum::<usize>(), rec.dropped);
            assert!(rec.sim_round_s > 0.0);
        }
        assert!(report.total_sim_s > 0.0);
        assert!(report.final_eval.loss.is_finite());
    }

    #[test]
    fn memory_capped_budgets_shrink_low_tier_downloads() {
        use crate::scheduler::{FleetKind, SchedPolicy};
        let mut base = tiny_cfg();
        base.fleet = FleetKind::Tiered3;
        base.rounds = 2;
        let mut capped = base.clone();
        capped.sched_policy = SchedPolicy::MemoryCapped;
        capped.mem_cap_frac = 0.1;
        let ru = Trainer::new(base).unwrap().run().unwrap();
        let rc = Trainer::new(capped).unwrap().run().unwrap();
        // same cohorts (MemoryCapped samples like Uniform), smaller slices
        assert!(
            rc.total_down_bytes < ru.total_down_bytes,
            "capped {} !< uniform {}",
            rc.total_down_bytes,
            ru.total_down_bytes
        );
    }

    #[test]
    fn all_keys_recovers_fedavg_sizes() {
        let mut cfg = tiny_cfg();
        cfg.policies = vec![crate::fedselect::KeyPolicy::AllKeys];
        let t = Trainer::new(cfg).unwrap();
        assert!((t.rel_model_size() - 1.0).abs() < 1e-9);
    }
}

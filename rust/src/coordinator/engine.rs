//! Event-driven round completion: the [`RoundEngine`] and its pluggable
//! [`AggregationMode`]s.
//!
//! The paper's Algorithm 2 closes every round at a synchronous barrier: the
//! server waits for the *slowest* surviving client, so the straggler-bound
//! `SimClock` of the cohort scheduler can only ever report worst-case round
//! times. FEDSELECT makes partial and asynchronous aggregation cheap —
//! only the keys a client actually trained need to land — and the
//! client-selection literature (Fu et al. 2022, Németh et al. 2022) names
//! the two standard systems answers to stragglers. Both are modes here:
//!
//! | `--agg-mode` | selects | round closes at | discounts |
//! |---|---|---|---|
//! | `sync` | the cohort | the straggler (barrier) — **byte-identical** to the pre-engine coordinator | — |
//! | `over-select:F` | `cohort·(1+F)` clients | the `cohort`-th completion; later reporters' updates are **discarded but their bytes stay on the ledger** | — |
//! | `buffered:G:S` | the cohort | the `G`-th landed update (carried in-flight updates included) | stale updates merge at weight `1/√(1+staleness)`; staleness > `S` discards |
//!
//! The engine consumes the scheduler's per-client
//! [`CompletionEvent`]s *in completion order* and decides which updates
//! merge now, which stay in flight (buffered mode trains clients against
//! the `SlicePlan` of their launch round — exactly FedBuff's stale-update
//! model, since each delta was computed against the launch-round store),
//! and which are discarded. The trainer then applies the merge list through
//! [`crate::aggregation::Aggregator::add_client_weighted`] and feeds the
//! engine's close point to [`crate::scheduler::Scheduler::complete_round_at`],
//! so simulated round seconds reflect the goal-count close rather than the
//! barrier.
//!
//! Determinism: everything is a pure function of the round RNG and the
//! simulated timeline (ties broken by launch round, then client index), so
//! buffered merge order is reproducible bit-for-bit at a fixed seed —
//! property-tested in `tests/round_engine.rs`.

use crate::exec::ExecMode;
use crate::fedselect::ClientKeys;
use crate::scheduler::CompletionEvent;

/// When a round's aggregation closes, and with what update-weighting
/// (config-level knob; CLI `--agg-mode`, `--over-select-frac`,
/// `--goal-count`, `--max-staleness`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregationMode {
    /// Wait for every surviving client — the paper's barrier, byte-identical
    /// to the pre-engine coordinator at the same seed.
    Synchronous,
    /// Sample `ceil(cohort * extra_frac)` extra clients and close the round
    /// at the original cohort count of completions; stragglers beyond the
    /// goal are discarded (bytes stay on the ledger).
    OverSelect { extra_frac: f64 },
    /// FedBuff-style buffered asynchrony: updates land in completion order,
    /// the round closes once `goal_count` of them have landed (0 = half the
    /// cohort, rounded up), unlanded updates stay in flight into later
    /// rounds at weight `1/sqrt(1+staleness)`, and updates older than
    /// `max_staleness` rounds are discarded.
    Buffered {
        goal_count: usize,
        max_staleness: usize,
    },
}

impl AggregationMode {
    pub const DEFAULT_OVER_SELECT_FRAC: f64 = 0.25;
    pub const DEFAULT_MAX_STALENESS: usize = 4;

    /// Mode family name (table rows, ledger records).
    pub fn name(&self) -> &'static str {
        match self {
            AggregationMode::Synchronous => "sync",
            AggregationMode::OverSelect { .. } => "over-select",
            AggregationMode::Buffered { .. } => "buffered",
        }
    }

    /// The merge weight of an update `staleness` rounds old (FedBuff's
    /// `1/sqrt(1+staleness)`); exactly 1.0 at staleness 0 so fresh updates
    /// take the unweighted aggregation path.
    pub fn staleness_weight(staleness: usize) -> f32 {
        if staleness == 0 {
            1.0
        } else {
            1.0 / (1.0 + staleness as f32).sqrt()
        }
    }
}

/// Canonical CLI spellings; `Display` round-trips with `FromStr`.
impl std::fmt::Display for AggregationMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AggregationMode::Synchronous => f.write_str("sync"),
            AggregationMode::OverSelect { extra_frac } => write!(f, "over-select:{extra_frac}"),
            AggregationMode::Buffered {
                goal_count,
                max_staleness,
            } => write!(f, "buffered:{goal_count}:{max_staleness}"),
        }
    }
}

impl std::str::FromStr for AggregationMode {
    type Err = String;
    /// Case-insensitive. `sync` | `over-select[:FRAC]` |
    /// `buffered[:GOAL[:MAX_STALENESS]]`; omitted knobs take the defaults
    /// (`FRAC` 0.25, `GOAL` 0 = half the cohort, `MAX_STALENESS` 4).
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        let (head, rest) = match lower.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (lower.as_str(), None),
        };
        match head {
            "sync" | "synchronous" | "barrier" => match rest {
                None => Ok(AggregationMode::Synchronous),
                Some(r) => Err(format!("sync takes no parameter, got {r:?}")),
            },
            "over-select" | "over_select" | "overselect" => {
                let extra_frac = match rest {
                    None => Self::DEFAULT_OVER_SELECT_FRAC,
                    Some(r) => r
                        .parse::<f64>()
                        .map_err(|e| format!("bad over-select fraction {r:?}: {e}"))?,
                };
                Ok(AggregationMode::OverSelect { extra_frac })
            }
            "buffered" | "fedbuff" | "async" => {
                let (goal_count, max_staleness) = match rest {
                    None => (0, Self::DEFAULT_MAX_STALENESS),
                    Some(r) => match r.split_once(':') {
                        None => (
                            r.parse::<usize>()
                                .map_err(|e| format!("bad goal count {r:?}: {e}"))?,
                            Self::DEFAULT_MAX_STALENESS,
                        ),
                        Some((g, st)) => (
                            g.parse::<usize>()
                                .map_err(|e| format!("bad goal count {g:?}: {e}"))?,
                            st.parse::<usize>()
                                .map_err(|e| format!("bad max staleness {st:?}: {e}"))?,
                        ),
                    },
                };
                Ok(AggregationMode::Buffered {
                    goal_count,
                    max_staleness,
                })
            }
            other => Err(format!(
                "unknown aggregation mode {other:?} (want sync, over-select[:frac] or \
                 buffered[:goal[:max_staleness]])"
            )),
        }
    }
}

/// One cohort slot's computed contribution, handed to the engine by the
/// trainer after the client-update phase (`None` slots dropped post-fetch).
#[derive(Clone, Debug)]
pub struct SlotWork {
    /// Train-client index.
    pub client: usize,
    /// Fleet tier of the client's device.
    pub tier: usize,
    pub keys: ClientKeys,
    /// Per-binding sliced model deltas, in binding order.
    pub deltas: Vec<Vec<f32>>,
}

/// One cohort slot as it leaves the pipelined executor: the scheduler's
/// completion event for the slot paired with its computed work. The task
/// pool hands these over in whatever order workers drained them; the engine
/// re-establishes the canonical simulated order in
/// [`RoundEngine::close_from_tasks`].
#[derive(Clone, Debug)]
pub struct TaskCompletion {
    /// Simulated completion of the slot (same content the scheduler's
    /// `events()` would have produced for it).
    pub event: CompletionEvent,
    /// The slot's computed contribution.
    pub work: SlotWork,
}

/// One update the engine decided to merge this round, in merge order.
#[derive(Clone, Debug)]
pub struct MergeItem {
    pub client: usize,
    pub tier: usize,
    /// Rounds since the update's slice plan was cut (0 = this round).
    pub staleness: usize,
    /// `AggregationMode::staleness_weight(staleness)`.
    pub weight: f32,
    pub keys: ClientKeys,
    pub deltas: Vec<Vec<f32>>,
}

/// One close group's secure-aggregation committee: the members the server
/// re-keys against each other when a close fires. Formed for every close
/// (cheap index bookkeeping); only consumed when the run enables
/// `--secure-agg --secure-committee`, where the trainer instantiates one
/// [`crate::aggregation::SecAggCommittee`] per spec.
///
/// Membership = the merged updates of one staleness class at this close,
/// plus the same class's keyed-but-never-submitting members (over-select
/// stragglers past the close, buffered updates past the staleness bound) —
/// those trigger the per-committee mask-reconstruction path. A committee is
/// one staleness class by construction, so its staleness weight applies to
/// the *committee sum* server-side and the equal-scale mask algebra is
/// preserved. In-flight members that stay viable are not keyed here; they
/// are carried into the committee of the close where they eventually merge.
#[derive(Clone, Debug)]
pub struct CommitteeSpec {
    /// Close ordinal (the 1-based round whose close formed this committee);
    /// the trainer keys masks from `run_seed ^ close_ordinal` (the per-run
    /// seed — a per-round seed already contains the round number and would
    /// cancel the ordinal, reusing mask material across closes).
    pub close_ordinal: u64,
    /// Rounds-of-staleness class shared by every member.
    pub staleness: usize,
    /// `AggregationMode::staleness_weight(staleness)` — applied by the
    /// server to the unmasked committee sum.
    pub weight: f32,
    /// Indices into [`RoundOutcome::merged`] that submit to this committee.
    pub submitters: Vec<usize>,
    /// Train-client ids keyed into the committee that never submit; their
    /// orphan masks are reconstructed per committee.
    pub dropped: Vec<u64>,
}

impl CommitteeSpec {
    /// Keyed members: submitters plus reconstruction-path dropouts.
    pub fn size(&self) -> usize {
        self.submitters.len() + self.dropped.len()
    }
}

/// What the engine decided for one round.
#[derive(Debug, Default)]
pub struct RoundOutcome {
    /// Updates to aggregate, in merge order (synchronous: cohort order;
    /// over-select/buffered: completion order).
    pub merged: Vec<MergeItem>,
    /// When the server closed the round, relative to round start (seconds on
    /// the simulated clock; the fixed overhead is added by the scheduler).
    pub close_s: f64,
    /// Fleet tier of each computed update whose bytes were spent but which
    /// will never merge: over-selected stragglers, or buffered updates past
    /// `max_staleness` (one entry per discarded update).
    pub discarded_tiers: Vec<usize>,
    /// Train-client id of each discarded update, index-aligned with
    /// `discarded_tiers` (telemetry: per-client lifecycle events).
    pub discarded_ids: Vec<usize>,
    /// `(client, tier)` of each landed update held back by the
    /// merge-deferral committee floor this close (telemetry; the count is
    /// `deferred`).
    pub deferred_ids: Vec<(usize, usize)>,
    /// Mean staleness over `merged` (0 outside buffered mode).
    pub mean_staleness: f64,
    /// Updates still in flight after this round (buffered mode only).
    pub in_flight: usize,
    /// Landed updates held back by the merge-deferral committee floor
    /// (`--committee-defer`): their staleness class was below
    /// `min_committee` submitters, so they returned to the in-flight pool
    /// to merge at a later close with more classmates.
    pub deferred: usize,
    /// Secure-aggregation committees of this close, one per staleness
    /// class, in ascending staleness order; every `merged` index appears in
    /// exactly one committee.
    pub committees: Vec<CommitteeSpec>,
}

/// A buffered-mode update that has been computed but has not landed yet.
#[derive(Clone, Debug)]
struct InFlight {
    client: usize,
    tier: usize,
    keys: ClientKeys,
    deltas: Vec<Vec<f32>>,
    launch_round: usize,
    /// Absolute simulated time at which the update lands at the server.
    done_abs_s: f64,
}

/// Event-driven round completion. Owns the aggregation mode and, in
/// buffered mode, the cross-round in-flight update pool.
pub struct RoundEngine {
    mode: AggregationMode,
    /// Committee size floor (`--min-committee`; 0 = off): buffered closes
    /// whose staleness-class committees would fall below it are coalesced —
    /// or, under [`Self::with_defer`], deferred.
    min_committee: usize,
    /// `--committee-defer`: instead of coalescing a below-floor staleness
    /// class into a neighbor (server-side weight splitting), hold its landed
    /// updates in flight until enough same-class members land — bounded by
    /// `max_staleness`, past which they merge (or age out) regardless.
    defer: bool,
    in_flight: Vec<InFlight>,
}

impl RoundEngine {
    pub fn new(mode: AggregationMode) -> Self {
        RoundEngine {
            mode,
            min_committee: 0,
            defer: false,
            in_flight: Vec::new(),
        }
    }

    /// Set the committee size floor (see [`Self::new`]); 0 disables it.
    pub fn with_min_committee(mut self, floor: usize) -> Self {
        self.min_committee = floor;
        self
    }

    /// Enable merge-deferral for below-floor committees (see the `defer`
    /// field); only meaningful with a floor > 1 in buffered mode.
    pub fn with_defer(mut self, defer: bool) -> Self {
        self.defer = defer;
        self
    }

    pub fn mode(&self) -> AggregationMode {
        self.mode
    }

    /// Updates currently in flight (buffered mode; 0 otherwise).
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Train-client indices with an update currently in flight, sorted and
    /// deduplicated — the planner's exclusion set (FedBuff caps per-client
    /// concurrency at one: a client is never re-selected while one of its
    /// updates is still in flight).
    pub fn in_flight_clients(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.in_flight.iter().map(|f| f.client).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// How many clients to select this round for a configured cohort size
    /// of `base`: over-selection inflates by `ceil(base * extra_frac)`
    /// (at least one extra), the other modes select exactly `base`.
    pub fn planned_cohort(&self, base: usize) -> usize {
        match self.mode {
            AggregationMode::OverSelect { extra_frac } => {
                base + (((base as f64) * extra_frac).ceil() as usize).max(1)
            }
            _ => base,
        }
    }

    /// The buffered goal for a configured cohort size (0 = half the cohort,
    /// rounded up; synchronous/over-select close by their own rules).
    pub fn effective_goal(&self, base: usize) -> usize {
        match self.mode {
            AggregationMode::Buffered { goal_count, .. } => {
                if goal_count == 0 {
                    base.div_ceil(2).max(1)
                } else {
                    goal_count
                }
            }
            _ => base,
        }
    }

    /// Coalesce staleness-class committees below the size floor (the
    /// ROADMAP "committee size floors" item: a single-member committee
    /// hides nothing). The floor is measured over **submitters only**:
    /// reconstruction-path dropouts contribute nothing to the unmasked sum,
    /// so they do not enlarge the anonymity set — a committee with one
    /// submitter and one dropped member still exposes a single client's
    /// update in the clear. A below-floor committee is merged with its next
    /// (staler) neighbor — or its previous one when it is last — until
    /// every committee meets the floor or only one remains. The coalesced
    /// committee spans staleness classes, so a single per-class weight no
    /// longer exists: the server applies the *submitter-count-weighted
    /// mean* of the member classes' weights to the whole unmasked committee
    /// sum (server-side weight splitting — an approximation the floor
    /// trades for hiding, documented in the README). Its staleness label is
    /// the youngest member class's, which keeps labels unique within a
    /// close (mask seeds mix the label, so uniqueness matters). A close
    /// whose *only* committee is below the floor is left as-is — there is
    /// nothing to coalesce with — and surfaces through
    /// `RoundRecord::min_committee_size`.
    fn apply_committee_floor(
        mut specs: Vec<CommitteeSpec>,
        floor: usize,
    ) -> Vec<CommitteeSpec> {
        if floor <= 1 {
            return specs;
        }
        while specs.len() > 1 {
            let Some(i) = specs.iter().position(|c| c.submitters.len() < floor) else {
                break;
            };
            let j = if i + 1 < specs.len() { i + 1 } else { i - 1 };
            let (lo, hi) = (i.min(j), i.max(j));
            let b = specs.remove(hi);
            let a = specs.remove(lo);
            let (na, nb) = (a.submitters.len() as f32, b.submitters.len() as f32);
            let weight = if na + nb > 0.0 {
                (na * a.weight + nb * b.weight) / (na + nb)
            } else {
                a.weight
            };
            let mut submitters = a.submitters;
            submitters.extend(b.submitters);
            submitters.sort_unstable();
            let mut dropped = a.dropped;
            dropped.extend(b.dropped);
            specs.insert(
                lo,
                CommitteeSpec {
                    close_ordinal: a.close_ordinal,
                    staleness: a.staleness.min(b.staleness),
                    weight,
                    submitters,
                    dropped,
                },
            );
        }
        specs
    }

    /// One staleness-0 committee over `n_merged` submitters plus `dropped`
    /// keyed-but-silent members; empty when nobody merges (a close that
    /// merges nothing keys nothing).
    fn fresh_committee(round: usize, n_merged: usize, dropped: Vec<u64>) -> Vec<CommitteeSpec> {
        if n_merged == 0 {
            return Vec::new();
        }
        vec![CommitteeSpec {
            close_ordinal: round as u64,
            staleness: 0,
            weight: 1.0,
            submitters: (0..n_merged).collect(),
            dropped,
        }]
    }

    /// Decide the round: which updates merge (and at what weight), when the
    /// round closes, and what is discarded. `events` are this round's
    /// completion events in completion order; `work` is indexed by cohort
    /// slot (`None` = dropped post-fetch); `round_start_s` is the simulated
    /// clock before this round. Pure in its inputs plus the engine's
    /// in-flight pool, so trajectories are deterministic at a fixed seed.
    pub fn close_round(
        &mut self,
        round: usize,
        base_cohort: usize,
        round_start_s: f64,
        events: &[CompletionEvent],
        mut work: Vec<Option<SlotWork>>,
    ) -> RoundOutcome {
        match self.mode {
            AggregationMode::Synchronous => {
                // barrier: close at the straggler, merge every survivor in
                // cohort-slot order — the legacy loop, byte for byte
                let close_s = events.last().map_or(0.0, |e| e.at_s);
                let merged: Vec<MergeItem> = work
                    .into_iter()
                    .flatten()
                    .map(|w| MergeItem {
                        client: w.client,
                        tier: w.tier,
                        staleness: 0,
                        weight: 1.0,
                        keys: w.keys,
                        deltas: w.deltas,
                    })
                    .collect();
                // one committee: the whole merge set (post-fetch dropouts
                // dropped before the close, so they were never keyed)
                let committees = Self::fresh_committee(round, merged.len(), Vec::new());
                RoundOutcome {
                    merged,
                    close_s,
                    committees,
                    ..RoundOutcome::default()
                }
            }
            AggregationMode::OverSelect { .. } => {
                // close at the goal-count-th completion; later reporters'
                // updates are discarded (their bytes were already spent and
                // stay on the round ledgers)
                let goal = base_cohort.min(events.len());
                let close_s = if goal == 0 { 0.0 } else { events[goal - 1].at_s };
                let merged: Vec<MergeItem> = events[..goal]
                    .iter()
                    .map(|e| {
                        let w = work[e.slot].take().expect("completion event for live slot");
                        MergeItem {
                            client: w.client,
                            tier: w.tier,
                            staleness: 0,
                            weight: 1.0,
                            keys: w.keys,
                            deltas: w.deltas,
                        }
                    })
                    .collect();
                // every survivor was racing the close, so every survivor was
                // keyed into the committee; the tail never submits and takes
                // the per-committee mask-reconstruction path
                let committees = Self::fresh_committee(
                    round,
                    merged.len(),
                    events[goal..].iter().map(|e| e.client as u64).collect(),
                );
                RoundOutcome {
                    merged,
                    close_s,
                    discarded_tiers: events[goal..].iter().map(|e| e.tier).collect(),
                    discarded_ids: events[goal..].iter().map(|e| e.client).collect(),
                    committees,
                    ..RoundOutcome::default()
                }
            }
            AggregationMode::Buffered { max_staleness, .. } => {
                // launch this round's survivors into the in-flight pool with
                // absolute landing times
                for e in events {
                    let w = work[e.slot].take().expect("completion event for live slot");
                    self.in_flight.push(InFlight {
                        client: w.client,
                        tier: w.tier,
                        keys: w.keys,
                        deltas: w.deltas,
                        launch_round: round,
                        done_abs_s: round_start_s + e.at_s,
                    });
                }
                // land updates in completion order until the goal count;
                // carried updates that finished between rounds land at once
                self.in_flight.sort_by(|a, b| {
                    a.done_abs_s
                        .partial_cmp(&b.done_abs_s)
                        .expect("landing times are finite")
                        .then(a.launch_round.cmp(&b.launch_round))
                        .then(a.client.cmp(&b.client))
                });
                let goal = self.effective_goal(base_cohort).min(self.in_flight.len());
                let mut landed: Vec<InFlight> = self.in_flight.drain(..goal).collect();
                // the close fires at the goal-th landing even when deferral
                // then holds some classes back: the server observed that
                // landing before deciding what to merge
                let mut close_abs = round_start_s;
                for inf in &landed {
                    close_abs = close_abs.max(inf.done_abs_s);
                }
                // merge-deferral floor: a staleness class with fewer than
                // `min_committee` landed submitters returns to the pool
                // (original launch round and landing time intact) to wait
                // for classmates — unless it is already at the staleness
                // bound, where waiting once more would age it out, so it
                // merges below the floor and surfaces via
                // `min_committee_size`
                let mut deferred = 0usize;
                let mut deferred_ids: Vec<(usize, usize)> = Vec::new();
                if self.defer && self.min_committee > 1 {
                    let mut class_counts: std::collections::BTreeMap<usize, usize> =
                        std::collections::BTreeMap::new();
                    for inf in &landed {
                        *class_counts.entry(round - inf.launch_round).or_insert(0) += 1;
                    }
                    let (keep, hold): (Vec<InFlight>, Vec<InFlight>) =
                        landed.into_iter().partition(|inf| {
                            let st = round - inf.launch_round;
                            class_counts[&st] >= self.min_committee || st >= max_staleness
                        });
                    deferred = hold.len();
                    deferred_ids = hold.iter().map(|inf| (inf.client, inf.tier)).collect();
                    self.in_flight.extend(hold);
                    landed = keep;
                }
                let mut stale_sum = 0usize;
                let merged: Vec<MergeItem> = landed
                    .into_iter()
                    .map(|inf| {
                        let staleness = round - inf.launch_round;
                        stale_sum += staleness;
                        MergeItem {
                            client: inf.client,
                            tier: inf.tier,
                            staleness,
                            weight: AggregationMode::staleness_weight(staleness),
                            keys: inf.keys,
                            deltas: inf.deltas,
                        }
                    })
                    .collect();
                // age out anything that would exceed the staleness bound by
                // the time it could next land
                let mut discarded_tiers = Vec::new();
                let mut discarded_ids = Vec::new();
                let mut discarded_members: Vec<(usize, u64)> = Vec::new(); // (staleness, client)
                self.in_flight.retain(|inf| {
                    if round - inf.launch_round < max_staleness {
                        true
                    } else {
                        discarded_tiers.push(inf.tier);
                        discarded_ids.push(inf.client);
                        discarded_members.push((round - inf.launch_round, inf.client as u64));
                        false
                    }
                });
                let mean_staleness = if merged.is_empty() {
                    0.0
                } else {
                    stale_sum as f64 / merged.len() as f64
                };
                // committees: one per staleness class among the merged
                // updates; same-class age-outs are keyed in as dropouts so
                // their masks are reconstructed per committee (a class with
                // no merging member was never keyed at this close)
                let mut classes: std::collections::BTreeMap<usize, CommitteeSpec> =
                    std::collections::BTreeMap::new();
                for (i, item) in merged.iter().enumerate() {
                    classes
                        .entry(item.staleness)
                        .or_insert_with(|| CommitteeSpec {
                            close_ordinal: round as u64,
                            staleness: item.staleness,
                            weight: AggregationMode::staleness_weight(item.staleness),
                            submitters: Vec::new(),
                            dropped: Vec::new(),
                        })
                        .submitters
                        .push(i);
                }
                for (staleness, client) in discarded_members {
                    if let Some(c) = classes.get_mut(&staleness) {
                        c.dropped.push(client);
                    }
                }
                // defer mode already enforced the floor by holding classes
                // back, so the remaining below-floor committees are the
                // at-bound ones that may not wait — coalescing them would
                // reintroduce the weight splitting deferral exists to avoid
                let committees = if self.defer {
                    classes.into_values().collect()
                } else {
                    Self::apply_committee_floor(
                        classes.into_values().collect(),
                        self.min_committee,
                    )
                };
                RoundOutcome {
                    merged,
                    close_s: (close_abs - round_start_s).max(0.0),
                    discarded_tiers,
                    discarded_ids,
                    deferred_ids,
                    mean_staleness,
                    in_flight: self.in_flight.len(),
                    deferred,
                    committees,
                }
            }
        }
    }

    /// Close a round from the pipelined executor's per-slot
    /// [`TaskCompletion`]s instead of a pre-computed event vector.
    ///
    /// Completions arrive in whatever order the worker pool drained them;
    /// this method first re-establishes the canonical *simulated* completion
    /// order — ascending `at_s`, slot index as the tie-break, exactly the
    /// sort `Scheduler::events` applies — so the outcome is a pure function
    /// of the simulated timeline and byte-identical to the phase-sequential
    /// path at any worker count. `cohort_slots` is the planned slot count
    /// (completions cover only non-dropped slots).
    ///
    /// `order` is the merge-order contract: under [`ExecMode::Strict`] the
    /// outcome is exactly [`Self::close_round`]'s (synchronous mode merges
    /// in cohort-slot order). Under [`ExecMode::Fast`] a synchronous-mode
    /// merge list is reordered into simulated completion order — the order
    /// updates actually land at the server — which changes float-add order
    /// but no set membership, weight, or ledger content. Over-select and
    /// buffered modes already merge in completion order, so `order` is a
    /// no-op there.
    pub fn close_from_tasks(
        &mut self,
        round: usize,
        base_cohort: usize,
        cohort_slots: usize,
        round_start_s: f64,
        mut completions: Vec<TaskCompletion>,
        order: ExecMode,
    ) -> RoundOutcome {
        completions.sort_by(|a, b| {
            a.event
                .at_s
                .partial_cmp(&b.event.at_s)
                .expect("client timings are finite")
                .then(a.event.slot.cmp(&b.event.slot))
        });
        let events: Vec<CompletionEvent> = completions.iter().map(|c| c.event).collect();
        let mut work: Vec<Option<SlotWork>> = (0..cohort_slots).map(|_| None).collect();
        for c in completions {
            work[c.event.slot] = Some(c.work);
        }
        let reorder = order == ExecMode::Fast && self.mode == AggregationMode::Synchronous;
        let mut out = self.close_round(round, base_cohort, round_start_s, &events, work);
        if reorder {
            // completion rank by client id: a synchronous cohort is sampled
            // without replacement, so clients are unique within the round.
            // The single staleness-0 committee indexes the full merge set
            // (0..n), so permuting `merged` keeps its submitters valid.
            let rank: std::collections::BTreeMap<usize, usize> = events
                .iter()
                .enumerate()
                .map(|(i, e)| (e.client, i))
                .collect();
            out.merged
                .sort_by_key(|m| rank.get(&m.client).copied().unwrap_or(usize::MAX));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::ClientTiming;

    fn event(slot: usize, client: usize, tier: usize, at_s: f64) -> CompletionEvent {
        CompletionEvent {
            slot,
            client,
            tier,
            at_s,
            timing: ClientTiming {
                download_s: at_s,
                compute_s: 0.0,
                upload_s: 0.0,
            },
        }
    }

    fn slot_work(client: usize, tier: usize) -> SlotWork {
        SlotWork {
            client,
            tier,
            keys: vec![vec![client as u32]],
            deltas: vec![vec![client as f32; 4]],
        }
    }

    #[test]
    fn display_from_str_round_trips_case_insensitively() {
        for mode in [
            AggregationMode::Synchronous,
            AggregationMode::OverSelect { extra_frac: 0.25 },
            AggregationMode::OverSelect { extra_frac: 0.5 },
            AggregationMode::Buffered {
                goal_count: 0,
                max_staleness: 4,
            },
            AggregationMode::Buffered {
                goal_count: 12,
                max_staleness: 2,
            },
        ] {
            let shown = mode.to_string();
            assert_eq!(shown.parse::<AggregationMode>().unwrap(), mode, "{shown}");
            assert_eq!(
                shown.to_uppercase().parse::<AggregationMode>().unwrap(),
                mode,
                "{shown}"
            );
        }
        assert_eq!(
            "over-select".parse::<AggregationMode>().unwrap(),
            AggregationMode::OverSelect {
                extra_frac: AggregationMode::DEFAULT_OVER_SELECT_FRAC
            }
        );
        assert_eq!(
            "fedbuff".parse::<AggregationMode>().unwrap(),
            AggregationMode::Buffered {
                goal_count: 0,
                max_staleness: AggregationMode::DEFAULT_MAX_STALENESS
            }
        );
        assert_eq!(
            "buffered:8".parse::<AggregationMode>().unwrap(),
            AggregationMode::Buffered {
                goal_count: 8,
                max_staleness: AggregationMode::DEFAULT_MAX_STALENESS
            }
        );
        assert!("sync:0.5".parse::<AggregationMode>().is_err());
        assert!("over-select:x".parse::<AggregationMode>().is_err());
        assert!("bogus".parse::<AggregationMode>().is_err());
    }

    #[test]
    fn planned_cohort_and_goal_math() {
        let sync = RoundEngine::new(AggregationMode::Synchronous);
        assert_eq!(sync.planned_cohort(10), 10);
        assert_eq!(sync.effective_goal(10), 10);
        let over = RoundEngine::new(AggregationMode::OverSelect { extra_frac: 0.3 });
        assert_eq!(over.planned_cohort(10), 13);
        assert_eq!(over.planned_cohort(1), 2); // at least one extra
        let auto = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 4,
        });
        assert_eq!(auto.planned_cohort(10), 10);
        assert_eq!(auto.effective_goal(10), 5);
        assert_eq!(auto.effective_goal(9), 5);
        let fixed = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 7,
            max_staleness: 4,
        });
        assert_eq!(fixed.effective_goal(10), 7);
    }

    #[test]
    fn staleness_weight_is_one_when_fresh_and_decays() {
        assert_eq!(AggregationMode::staleness_weight(0).to_bits(), 1.0f32.to_bits());
        let w1 = AggregationMode::staleness_weight(1);
        let w3 = AggregationMode::staleness_weight(3);
        assert!((w1 - 1.0 / 2f32.sqrt()).abs() < 1e-6);
        assert!(w3 < w1 && w1 < 1.0);
    }

    #[test]
    fn synchronous_merges_every_survivor_in_slot_order_at_the_straggler() {
        let mut eng = RoundEngine::new(AggregationMode::Synchronous);
        let work = vec![Some(slot_work(10, 0)), None, Some(slot_work(12, 1))];
        let events = vec![event(2, 12, 1, 0.5), event(0, 10, 0, 3.0)];
        let out = eng.close_round(1, 3, 0.0, &events, work);
        assert_eq!(out.close_s, 3.0);
        assert!(out.discarded_tiers.is_empty());
        let order: Vec<usize> = out.merged.iter().map(|m| m.client).collect();
        assert_eq!(order, vec![10, 12], "slot order, not completion order");
        assert!(out.merged.iter().all(|m| m.weight == 1.0 && m.staleness == 0));
        assert_eq!(out.committees.len(), 1, "one whole-merge-set committee");
        assert_eq!(out.committees[0].submitters, vec![0, 1]);
        assert!(out.committees[0].dropped.is_empty());
    }

    #[test]
    fn close_from_tasks_reorders_events_and_honours_exec_mode() {
        // completions handed over in arbitrary pool-drain order
        let completions = || {
            vec![
                TaskCompletion {
                    event: event(2, 12, 1, 0.5),
                    work: slot_work(12, 1),
                },
                TaskCompletion {
                    event: event(0, 10, 0, 3.0),
                    work: slot_work(10, 0),
                },
                TaskCompletion {
                    event: event(1, 11, 0, 1.5),
                    work: slot_work(11, 0),
                },
            ]
        };
        // strict + synchronous == close_round byte-for-byte: slot order
        let mut eng = RoundEngine::new(AggregationMode::Synchronous);
        let out = eng.close_from_tasks(1, 3, 3, 0.0, completions(), ExecMode::Strict);
        assert_eq!(out.close_s, 3.0, "closes at the straggler");
        let order: Vec<usize> = out.merged.iter().map(|m| m.client).collect();
        assert_eq!(order, vec![10, 11, 12], "strict merges in cohort-slot order");
        // fast + synchronous: same set, simulated completion order
        let mut eng = RoundEngine::new(AggregationMode::Synchronous);
        let out = eng.close_from_tasks(1, 3, 3, 0.0, completions(), ExecMode::Fast);
        assert_eq!(out.close_s, 3.0, "close point is mode-independent");
        let order: Vec<usize> = out.merged.iter().map(|m| m.client).collect();
        assert_eq!(order, vec![12, 11, 10], "fast merges in completion order");
        assert_eq!(out.committees.len(), 1);
        assert_eq!(out.committees[0].submitters, vec![0, 1, 2]);
        // over-select already merges in completion order; exec mode is a
        // no-op and the tail discard logic sees the sorted events
        for mode in [ExecMode::Strict, ExecMode::Fast] {
            let mut eng = RoundEngine::new(AggregationMode::OverSelect { extra_frac: 0.5 });
            let out = eng.close_from_tasks(1, 2, 3, 0.0, completions(), mode);
            let order: Vec<usize> = out.merged.iter().map(|m| m.client).collect();
            assert_eq!(order, vec![12, 11], "{mode}");
            assert_eq!(out.discarded_ids, vec![10], "{mode}");
        }
    }

    #[test]
    fn over_select_closes_at_the_goal_and_discards_the_tail() {
        let mut eng = RoundEngine::new(AggregationMode::OverSelect { extra_frac: 0.5 });
        assert_eq!(eng.planned_cohort(2), 3);
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
        ];
        let events = vec![event(2, 12, 1, 0.5), event(0, 10, 0, 1.0), event(1, 11, 0, 9.0)];
        let out = eng.close_round(1, 2, 0.0, &events, work);
        assert_eq!(out.close_s, 1.0, "closes at the 2nd completion");
        let order: Vec<usize> = out.merged.iter().map(|m| m.client).collect();
        assert_eq!(order, vec![12, 10], "completion order");
        assert_eq!(out.discarded_tiers, vec![0], "the straggler's update is discarded");
    }

    #[test]
    fn buffered_carries_updates_across_rounds_with_staleness() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 2,
            max_staleness: 1,
        });
        // round 1: three survivors, goal 2 — slowest (client 12) stays in flight
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
        ];
        let events = vec![event(0, 10, 0, 1.0), event(1, 11, 0, 2.0), event(2, 12, 1, 8.0)];
        let out1 = eng.close_round(1, 3, 0.0, &events, work);
        assert_eq!(out1.merged.len(), 2);
        assert_eq!(out1.close_s, 2.0);
        assert_eq!(out1.in_flight, 1);
        assert!(out1.discarded_tiers.is_empty());
        assert_eq!(out1.mean_staleness, 0.0);
        // round 2 starts at sim t=3.0: the carried update (lands at t=8.0)
        // races this round's fresh ones and merges first at staleness 1
        let work2 = vec![Some(slot_work(20, 0)), Some(slot_work(21, 0))];
        let events2 = vec![event(0, 20, 0, 9.0), event(1, 21, 0, 12.0)];
        let out2 = eng.close_round(2, 2, 3.0, &events2, work2);
        let merged: Vec<(usize, usize)> =
            out2.merged.iter().map(|m| (m.client, m.staleness)).collect();
        assert_eq!(merged, vec![(12, 1), (20, 0)]);
        assert!((out2.merged[0].weight - 1.0 / 2f32.sqrt()).abs() < 1e-6);
        // close = the 2nd landing: client 20 at absolute 3.0 + 9.0
        assert_eq!(out2.close_s, 9.0);
        assert!((out2.mean_staleness - 0.5).abs() < 1e-12);
        // client 21 (launched round 2) is still fresh enough to carry on
        assert_eq!(out2.in_flight, 1);
        assert!(out2.discarded_tiers.is_empty());
        // round 3: nothing new; the carried update (staleness 1) lands alone
        let out3 = eng.close_round(3, 2, 13.0, &[], vec![]);
        assert_eq!(out3.merged.len(), 1);
        assert_eq!(out3.merged[0].client, 21);
        assert_eq!(out3.merged[0].staleness, 1);
        // it landed at absolute 3.0 + 12.0 = 15.0, i.e. 2.0 into round 3
        assert!((out3.close_s - 2.0).abs() < 1e-12);
        assert_eq!(out3.in_flight, 0);
    }

    #[test]
    fn buffered_discards_past_the_staleness_bound() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 1,
            max_staleness: 0,
        });
        let work = vec![Some(slot_work(10, 0)), Some(slot_work(11, 0))];
        let events = vec![event(0, 10, 0, 1.0), event(1, 11, 0, 5.0)];
        let out = eng.close_round(1, 2, 0.0, &events, work);
        assert_eq!(out.merged.len(), 1);
        // max_staleness 0: the unlanded update may not carry a single round
        assert_eq!(out.discarded_tiers, vec![0]);
        assert_eq!(out.in_flight, 0);
    }

    #[test]
    fn committees_partition_the_merge_set_by_staleness_class() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 3,
            max_staleness: 1,
        });
        // round 1: four survivors, goal 3 — client 13 stays in flight
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
            Some(slot_work(13, 1)),
        ];
        let events = vec![
            event(0, 10, 0, 1.0),
            event(1, 11, 0, 2.0),
            event(2, 12, 1, 3.0),
            event(3, 13, 1, 9.0),
        ];
        let out1 = eng.close_round(1, 4, 0.0, &events, work);
        assert_eq!(out1.committees.len(), 1, "all fresh: one class");
        assert_eq!(out1.committees[0].staleness, 0);
        assert_eq!(out1.committees[0].weight, 1.0);
        assert_eq!(out1.committees[0].submitters, vec![0, 1, 2]);
        assert!(out1.committees[0].dropped.is_empty());
        // round 2: two fresh survivors + the carried update (staleness 1);
        // goal 3 merges all — two staleness classes, two committees
        let work2 = vec![Some(slot_work(20, 0)), Some(slot_work(21, 0))];
        let events2 = vec![event(0, 20, 0, 1.0), event(1, 21, 0, 2.0)];
        let out2 = eng.close_round(2, 3, 10.0, &events2, work2);
        assert_eq!(out2.merged.len(), 3);
        assert_eq!(out2.committees.len(), 2);
        let mut covered: Vec<usize> = out2
            .committees
            .iter()
            .flat_map(|c| c.submitters.iter().copied())
            .collect();
        covered.sort_unstable();
        assert_eq!(covered, vec![0, 1, 2], "committees partition the merge set");
        for c in &out2.committees {
            assert_eq!(c.close_ordinal, 2);
            for &i in &c.submitters {
                assert_eq!(out2.merged[i].staleness, c.staleness, "class purity");
                assert_eq!(out2.merged[i].weight, c.weight, "weight == class weight");
            }
        }
    }

    #[test]
    fn over_select_committee_keys_the_discarded_tail_as_dropouts() {
        let mut eng = RoundEngine::new(AggregationMode::OverSelect { extra_frac: 0.5 });
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
        ];
        let events = vec![event(2, 12, 1, 0.5), event(0, 10, 0, 1.0), event(1, 11, 0, 9.0)];
        let out = eng.close_round(1, 2, 0.0, &events, work);
        assert_eq!(out.committees.len(), 1);
        let c = &out.committees[0];
        assert_eq!(c.submitters, vec![0, 1]);
        assert_eq!(c.dropped, vec![11], "the straggler is keyed but silent");
        assert_eq!(c.size(), 3);
        assert_eq!(c.staleness, 0);
    }

    #[test]
    fn buffered_age_outs_join_their_class_committee_as_dropouts() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 1,
            max_staleness: 1,
        });
        // round 1: client 10 merges, 11 and 12 stay in flight
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 0)),
        ];
        let events = vec![event(0, 10, 0, 1.0), event(1, 11, 0, 8.0), event(2, 12, 0, 9.0)];
        eng.close_round(1, 3, 0.0, &events, work);
        // round 2: carried client 11 merges at staleness 1; client 12 (also
        // staleness 1) ages out at max_staleness 1 — same class, keyed in as
        // a dropout of the staleness-1 committee
        let out2 = eng.close_round(2, 1, 20.0, &[], vec![]);
        assert_eq!(out2.merged.len(), 1);
        assert_eq!(out2.merged[0].client, 11);
        assert_eq!(out2.discarded_tiers.len(), 1);
        assert_eq!(out2.committees.len(), 1);
        assert_eq!(out2.committees[0].staleness, 1);
        assert_eq!(out2.committees[0].submitters, vec![0]);
        assert_eq!(out2.committees[0].dropped, vec![12]);
        assert_eq!(eng.in_flight_clients(), Vec::<usize>::new());
    }

    #[test]
    fn committee_floor_coalesces_small_classes_with_weight_splitting() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 3,
            max_staleness: 2,
        })
        .with_min_committee(2);
        // round 1: four survivors, goal 3 — client 13 carries into round 2
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
            Some(slot_work(13, 1)),
        ];
        let events = vec![
            event(0, 10, 0, 1.0),
            event(1, 11, 0, 2.0),
            event(2, 12, 1, 3.0),
            event(3, 13, 1, 9.0),
        ];
        eng.close_round(1, 4, 0.0, &events, work);
        // round 2: two fresh survivors + the lone carried update — the
        // staleness-1 class would be a single-member committee, below the
        // floor of 2, so it coalesces with the fresh class
        let work2 = vec![Some(slot_work(20, 0)), Some(slot_work(21, 0))];
        let events2 = vec![event(0, 20, 0, 1.0), event(1, 21, 0, 2.0)];
        let out2 = eng.close_round(2, 3, 10.0, &events2, work2);
        assert_eq!(out2.merged.len(), 3);
        assert_eq!(out2.committees.len(), 1, "classes coalesced under the floor");
        let c = &out2.committees[0];
        assert_eq!(c.size(), 3);
        assert_eq!(c.submitters, vec![0, 1, 2]);
        assert_eq!(c.staleness, 0, "youngest member class labels the committee");
        // blended weight: (1 submitter @ w(1) + 2 @ 1.0) / 3
        let expect = (AggregationMode::staleness_weight(1) + 2.0) / 3.0;
        assert!((c.weight - expect).abs() < 1e-6, "{} vs {expect}", c.weight);
        // per-item merge weights are untouched — only the committee blends
        assert!(out2.merged.iter().any(|m| m.staleness == 1 && m.weight < 1.0));
    }

    #[test]
    fn committee_floor_counts_submitters_not_reconstruction_dropouts() {
        // a committee with 1 submitter + 1 keyed-but-dropped member exposes
        // that submitter's update in the clear — the dropout's masks are
        // reconstructed and add nothing to the sum, so it must NOT satisfy
        // the floor
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 1,
            max_staleness: 1,
        })
        .with_min_committee(2);
        // round 1, goal 1: only client 10 merges; 11 (abs 8.0) and the very
        // slow 12 (abs 25.0) stay in flight
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 0)),
        ];
        let events = vec![event(0, 10, 0, 1.0), event(1, 11, 0, 8.0), event(2, 12, 0, 25.0)];
        eng.close_round(1, 3, 0.0, &events, work);
        // round 2 (start 20.0), goal 3: carried 11 (staleness 1) merges
        // with fresh 20/21 (abs 21/22); carried 12 (abs 25.0) is past the
        // goal and ages out as a staleness-1 dropout of 11's class
        eng.mode = AggregationMode::Buffered {
            goal_count: 3,
            max_staleness: 1,
        };
        let work2 = vec![Some(slot_work(20, 0)), Some(slot_work(21, 0))];
        let events2 = vec![event(0, 20, 0, 1.0), event(1, 21, 0, 2.0)];
        let out2 = eng.close_round(2, 3, 20.0, &events2, work2);
        assert_eq!(out2.merged.len(), 3);
        assert_eq!(out2.discarded_tiers.len(), 1, "client 12 ages out");
        // the staleness-1 class has 1 submitter + 1 dropped: size() == 2
        // would have passed the floor; submitters == 1 must not
        assert_eq!(
            out2.committees.len(),
            1,
            "1-submitter class must coalesce despite its dropped member"
        );
        let c = &out2.committees[0];
        assert_eq!(c.submitters, vec![0, 1, 2]);
        assert_eq!(c.dropped, vec![12], "the dropout rides along for reconstruction");
    }

    #[test]
    fn defer_holds_a_below_floor_class_until_classmates_or_the_bound() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 3,
            max_staleness: 2,
        })
        .with_min_committee(2)
        .with_defer(true);
        // round 1: four survivors, goal 3 — client 13 carries into round 2
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
            Some(slot_work(13, 1)),
        ];
        let events = vec![
            event(0, 10, 0, 1.0),
            event(1, 11, 0, 2.0),
            event(2, 12, 1, 3.0),
            event(3, 13, 1, 9.0),
        ];
        let out1 = eng.close_round(1, 4, 0.0, &events, work);
        assert_eq!(out1.deferred, 0, "a full fresh class never defers");
        // round 2: carried 13 (staleness 1) lands first but is the only
        // member of its class — deferred, not merged and not coalesced
        let work2 = vec![Some(slot_work(20, 0)), Some(slot_work(21, 0))];
        let events2 = vec![event(0, 20, 0, 1.0), event(1, 21, 0, 2.0)];
        let out2 = eng.close_round(2, 3, 10.0, &events2, work2);
        let merged: Vec<usize> = out2.merged.iter().map(|m| m.client).collect();
        assert_eq!(merged, vec![20, 21], "the lone stale update is held back");
        assert_eq!(out2.deferred, 1);
        assert_eq!(out2.in_flight, 1, "deferred update returns to the pool");
        assert!(out2.discarded_tiers.is_empty());
        assert_eq!(out2.mean_staleness, 0.0, "only fresh updates merged");
        // the close still fired at the goal-th landing (21 at abs 12.0)
        assert!((out2.close_s - 2.0).abs() < 1e-12);
        assert_eq!(out2.committees.len(), 1);
        assert_eq!(out2.committees[0].staleness, 0);
        assert_eq!(out2.committees[0].submitters, vec![0, 1]);
        // round 3: client 13 is now AT the staleness bound — waiting once
        // more would age it out, so it merges below the floor and surfaces
        // through the lone small committee
        let out3 = eng.close_round(3, 3, 20.0, &[], vec![]);
        assert_eq!(out3.merged.len(), 1);
        assert_eq!(out3.merged[0].client, 13);
        assert_eq!(out3.merged[0].staleness, 2);
        assert_eq!(out3.deferred, 0);
        assert_eq!(out3.in_flight, 0);
        assert_eq!(out3.committees.len(), 1);
        assert_eq!(out3.committees[0].submitters.len(), 1, "at-bound class merges small");
    }

    #[test]
    fn defer_merges_a_class_once_it_reaches_the_floor() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 2,
            max_staleness: 3,
        })
        .with_min_committee(2)
        .with_defer(true);
        // round 1: three survivors, goal 2 — client 12 stays in flight
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(11, 0)),
            Some(slot_work(12, 1)),
        ];
        let events = vec![event(0, 10, 0, 1.0), event(1, 11, 0, 2.0), event(2, 12, 1, 8.0)];
        eng.close_round(1, 3, 0.0, &events, work);
        // round 2: one fresh survivor; goal 2 drains carried 12 (staleness
        // 1) + fresh 20 — BOTH classes are single-member and below the
        // floor; 12 defers, and so does the fresh 20
        let work2 = vec![Some(slot_work(20, 0))];
        let events2 = vec![event(0, 20, 0, 1.0)];
        let out2 = eng.close_round(2, 2, 10.0, &events2, work2);
        assert!(out2.merged.is_empty());
        assert_eq!(out2.deferred, 2);
        assert_eq!(out2.in_flight, 2);
        assert!(out2.committees.is_empty(), "nothing merged, nothing keyed");
        // round 3: one more fresh survivor; the drained pool is 12
        // (staleness 2, still alone — defers again) and 20+30? No: goal 2
        // drains the two earliest landings, 12 (abs 8.0) and 20 (abs 11.0).
        // 20 is now staleness 1, same class as nobody — but 12 is staleness
        // 2, also alone: both defer again.
        let work3 = vec![Some(slot_work(30, 0))];
        let events3 = vec![event(0, 30, 0, 1.0)];
        let out3 = eng.close_round(3, 2, 20.0, &events3, work3);
        assert!(out3.merged.is_empty());
        assert_eq!(out3.deferred, 2);
        assert_eq!(out3.in_flight, 3);
        // round 4: goal 2 drains 12 (staleness 3 == bound: merges) and 20
        // (staleness 2, alone: defers)... but 30 (abs 21.0) lands third and
        // stays pooled. 12 merges below floor at the bound.
        let out4 = eng.close_round(4, 2, 30.0, &[], vec![]);
        assert_eq!(out4.merged.len(), 1);
        assert_eq!(out4.merged[0].client, 12);
        assert_eq!(out4.merged[0].staleness, 3);
        assert_eq!(out4.deferred, 1);
        assert_eq!(out4.in_flight, 2);
    }

    #[test]
    fn committee_floor_leaves_an_unmergeable_lone_committee() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 1,
            max_staleness: 4,
        })
        .with_min_committee(3);
        let work = vec![Some(slot_work(10, 0))];
        let events = vec![event(0, 10, 0, 1.0)];
        let out = eng.close_round(1, 1, 0.0, &events, work);
        assert_eq!(out.committees.len(), 1);
        assert_eq!(out.committees[0].size(), 1, "nothing to coalesce with");
        // floor 0/1 are no-ops by definition
        let eng0 = RoundEngine::new(AggregationMode::Synchronous).with_min_committee(1);
        assert_eq!(eng0.min_committee, 1);
    }

    #[test]
    fn in_flight_clients_tracks_the_buffered_pool() {
        let mut eng = RoundEngine::new(AggregationMode::Buffered {
            goal_count: 1,
            max_staleness: 4,
        });
        assert!(eng.in_flight_clients().is_empty());
        let work = vec![
            Some(slot_work(10, 0)),
            Some(slot_work(12, 0)),
            Some(slot_work(11, 0)),
        ];
        let events = vec![event(0, 10, 0, 1.0), event(1, 12, 0, 8.0), event(2, 11, 0, 9.0)];
        eng.close_round(1, 3, 0.0, &events, work);
        assert_eq!(eng.in_flight_clients(), vec![11, 12], "sorted");
        let sync = RoundEngine::new(AggregationMode::Synchronous);
        assert!(sync.in_flight_clients().is_empty());
    }

    #[test]
    fn empty_rounds_close_immediately() {
        for mode in [
            AggregationMode::Synchronous,
            AggregationMode::OverSelect { extra_frac: 0.5 },
            AggregationMode::Buffered {
                goal_count: 0,
                max_staleness: 4,
            },
        ] {
            let mut eng = RoundEngine::new(mode);
            let out = eng.close_round(1, 4, 0.0, &[], vec![None, None, None, None]);
            assert!(out.merged.is_empty(), "{mode}");
            assert_eq!(out.close_s, 0.0, "{mode}");
            assert!(out.committees.is_empty(), "{mode}: nothing merged, nothing keyed");
        }
    }
}

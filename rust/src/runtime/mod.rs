//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! The interchange contract with `python/compile/aot.py`:
//!
//! * artifacts are HLO **text** (xla_extension 0.5.1 rejects jax≥0.5
//!   serialized protos with 64-bit instruction ids; the text parser
//!   reassigns ids — see /opt/xla-example/README.md),
//! * `manifest.json` pins argument order, shapes and dtypes per artifact,
//! * outputs are a tuple (lowered with `return_tuple=True`).
//!
//! Executables are compiled lazily on first use and cached for the life of
//! the process — one compiled executable per model variant.
//!
//! The real runtime needs the external `xla` bindings crate and is gated
//! behind the `pjrt` cargo feature. Without it (the default, dependency-free
//! build) a stub with the same API returns [`Error::Artifact`] from `load`,
//! so everything that can run artifact-free (native engine, all logreg/MLP
//! experiments, every test that skips on missing artifacts) still works.

pub mod manifest;

pub use manifest::{Artifact, IoSpec, Manifest};

use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::native::Buf;

/// A PJRT CPU runtime bound to an artifacts directory.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions served, per artifact (perf accounting)
    exec_counts: HashMap<String, u64>,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.manifest.get(name)
    }

    /// Compile (or fetch from cache) the named artifact.
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let art = self.manifest.get(name)?;
        let path = self.dir.join(&art.path);
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with inputs given as raw buffers in manifest order.
    /// Returns output buffers in manifest output order.
    pub fn execute(&mut self, name: &str, inputs: &[Buf]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let art = self.manifest.get(name)?.clone();
        if inputs.len() != art.inputs.len() {
            return Err(Error::Shape(format!(
                "{name}: expected {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(art.inputs.iter()) {
            literals.push(to_literal(buf, spec)?);
        }
        let exe = self.cache.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        if parts.len() != art.outputs.len() {
            return Err(Error::Shape(format!(
                "{name}: expected {} outputs, got {}",
                art.outputs.len(),
                parts.len()
            )));
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let mut vecs = Vec::with_capacity(parts.len());
        for (p, spec) in parts.into_iter().zip(art.outputs.iter()) {
            let n: usize = spec.shape.iter().product::<usize>().max(1);
            let v = if p.element_count() == 1 && spec.shape.is_empty() {
                vec![p.get_first_element::<f32>()?]
            } else {
                p.to_vec::<f32>()?
            };
            if v.len() != n {
                return Err(Error::Shape(format!(
                    "{name} output {}: got {} elements, want {n}",
                    spec.name,
                    v.len()
                )));
            }
            vecs.push(v);
        }
        Ok(vecs)
    }

    /// Executions served per artifact so far.
    pub fn exec_counts(&self) -> &HashMap<String, u64> {
        &self.exec_counts
    }

    /// Number of compiled executables currently cached.
    pub fn compiled(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(feature = "pjrt")]
fn to_literal(buf: &Buf, spec: &IoSpec) -> Result<xla::Literal> {
    let n: usize = spec.shape.iter().product::<usize>().max(1);
    if buf.len() != n {
        return Err(Error::Shape(format!(
            "input {}: got {} elements, want {n} (shape {:?})",
            spec.name,
            buf.len(),
            spec.shape
        )));
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match (buf, spec.dtype.as_str()) {
        (Buf::F32(v), "f32") => {
            if spec.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v).reshape(&dims)?
            }
        }
        (Buf::I32(v), "i32") => {
            if spec.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v).reshape(&dims)?
            }
        }
        (b, dt) => {
            return Err(Error::Shape(format!(
                "input {}: buffer kind {:?} does not match manifest dtype {dt}",
                spec.name,
                match b {
                    Buf::F32(_) => "f32",
                    Buf::I32(_) => "i32",
                }
            )))
        }
    };
    Ok(lit)
}

/// Stub runtime for the dependency-free default build (no `pjrt` feature):
/// same API surface, but `load` always fails with an explanation, so any
/// `EngineKind::Pjrt` configuration errors out at `Trainer::new` instead of
/// at link time.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    manifest: Manifest,
    exec_counts: HashMap<String, u64>,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Artifact(format!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (artifacts dir {:?}); rebuild with `--features pjrt` and the \
             `xla` bindings crate, or use `--engine native`",
            dir.as_ref()
        )))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.manifest.get(name)
    }

    pub fn execute(&mut self, name: &str, _inputs: &[Buf]) -> Result<Vec<Vec<f32>>> {
        Err(Error::Artifact(format!(
            "cannot execute {name:?}: built without the `pjrt` feature"
        )))
    }

    /// Executions served per artifact so far.
    pub fn exec_counts(&self) -> &HashMap<String, u64> {
        &self.exec_counts
    }

    /// Number of compiled executables currently cached.
    pub fn compiled(&self) -> usize {
        0
    }
}

//! `artifacts/manifest.json` schema — the cross-language shape contract
//! written by `python/compile/aot.py`. Parsed with the crate's own JSON
//! parser (offline build; see Cargo.toml).

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: String,
    pub model: String,
    pub kind: String,
    pub meta: Json,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub hlo_sha256: String,
}

impl Artifact {
    /// Fetch an integer meta field (e.g. "m", "t", "vocab").
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }
}

/// Parsed manifest with name lookup.
#[derive(Debug)]
pub struct Manifest {
    pub version: u32,
    by_name: HashMap<String, Artifact>,
}

fn io_spec(j: &Json, what: &str) -> Result<IoSpec> {
    let err = |m: &str| Error::Artifact(format!("manifest {what}: {m}"));
    Ok(IoSpec {
        name: j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err("missing name"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| err("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| err("bad shape dim")))
            .collect::<Result<Vec<_>>>()?,
        dtype: j
            .get("dtype")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err("missing dtype"))?
            .to_string(),
    })
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {path:?}: {e}. Run `make artifacts` first."
            ))
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(Error::Json)?;
        let version = j
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Artifact("manifest missing version".into()))?
            as u32;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "unsupported manifest version {version}"
            )));
        }
        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?;
        let mut by_name = HashMap::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Artifact("artifact missing name".into()))?
                .to_string();
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::Artifact(format!("artifact {name}: missing {k}")))
            };
            let ios = |k: &str| -> Result<Vec<IoSpec>> {
                a.get(k)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| Error::Artifact(format!("artifact {name}: missing {k}")))?
                    .iter()
                    .map(|x| io_spec(x, &name))
                    .collect()
            };
            let art = Artifact {
                path: get_str("path")?,
                model: get_str("model")?,
                kind: get_str("kind")?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
                inputs: ios("inputs")?,
                outputs: ios("outputs")?,
                hlo_sha256: get_str("hlo_sha256").unwrap_or_default(),
                name: name.clone(),
            };
            for io in art.inputs.iter().chain(art.outputs.iter()) {
                if io.dtype != "f32" && io.dtype != "i32" {
                    return Err(Error::Artifact(format!(
                        "artifact {name}: unsupported dtype {}",
                        io.dtype
                    )));
                }
            }
            by_name.insert(name, art);
        }
        Ok(Manifest { version, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.by_name.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "artifact {name:?} not in manifest ({} available); \
                 re-run `make artifacts`",
                self.by_name.len()
            ))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "logreg_cu_m64", "path": "logreg_cu_m64.hlo.txt",
         "model": "logreg", "kind": "client_update",
         "meta": {"m": 64, "t": 50},
         "inputs": [{"name": "w", "shape": [64, 50], "dtype": "f32"},
                    {"name": "lr", "shape": [], "dtype": "f32"}],
         "outputs": [{"name": "dw", "shape": [64, 50], "dtype": "f32"}],
         "hlo_sha256": "abc"}
      ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("logreg_cu_m64").unwrap();
        assert_eq!(a.inputs[0].shape, vec![64, 50]);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.meta_usize("m"), Some(64));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn bad_version_rejected() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn bad_dtype_rejected() {
        let bad = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}

//! Sampling primitives for planet-scale cohorts.
//!
//! At seed fleet sizes the selection policies keep their legacy O(fleet)
//! scans — bit-for-bit identical to the pre-lazy scheduler (test-enforced
//! in `tests/scheduler_determinism.rs`). Past
//! [`SPARSE_SCAN_THRESHOLD`] clients they switch to the stratified
//! samplers in this module, which cost O(cohort + touched), not O(fleet):
//!
//! - [`rejection_sample`]: draw distinct ids uniformly from the accepted
//!   subset of `[0, n)` without enumerating it. With the eligible
//!   fraction `f`, a k-cohort costs ~`k/f` O(1) predicate probes — the
//!   scenario layer keeps `f` macroscopic (an outage or a wave blacks
//!   out a bounded fraction), so `plan_round` at 10M clients stays in
//!   the milliseconds.
//! - [`TwoStratumSampler`]: the hierarchical draw behind the
//!   loss-weighted policy at scale. The population is partitioned into
//!   the *touched* stratum (clients with observed signals — a compact
//!   sorted list) and the *untouched* stratum (everyone else, weighted
//!   by the mean positive signal as a prior, exactly the dense policy's
//!   semantics). Each pick first chooses a stratum by total weight, then
//!   resolves within it — O(touched) per pick instead of O(fleet).
//!
//! Both paths consume the round RNG differently from the dense scans, so
//! sparse cohorts are *deterministic* (same seed ⇒ same cohort,
//! test-enforced) but not byte-identical to the dense ones. The
//! threshold pins every seed-size config to the dense path, which is
//! what the byte-identity suite locks.

use std::collections::HashSet;

use crate::tensor::rng::Rng;

/// Fleet sizes at or below this use the legacy dense O(fleet) policy
/// scans; larger fleets use the sparse samplers. 64Ki is far above every
/// seed config (tens of clients) and far below the 1M–10M fleets the
/// sparse path exists for.
pub const SPARSE_SCAN_THRESHOLD: usize = 65_536;

/// How many draw attempts a rejection sampler spends before giving up on
/// filling the remaining slots (pathologically thin eligible sets; the
/// cohort comes back short but deterministic).
fn attempt_budget(k: usize) -> usize {
    64 * k + 1024
}

/// Draw up to `k` *distinct* ids uniformly from `{ci in [0, n) :
/// accept(ci)}` by bounded rejection, in draw order. Never scans `[0,
/// n)`; expected cost `k / eligible_fraction` probes. Returns fewer than
/// `k` ids only when the attempt budget runs dry (near-empty eligible
/// sets).
pub fn rejection_sample(
    rng: &mut Rng,
    n: usize,
    k: usize,
    mut accept: impl FnMut(usize) -> bool,
) -> Vec<usize> {
    let mut picked: Vec<usize> = Vec::with_capacity(k.min(1024));
    let mut seen: HashSet<usize> = HashSet::with_capacity(k.min(1024) * 2);
    if n == 0 || k == 0 {
        return picked;
    }
    let mut attempts = attempt_budget(k);
    while picked.len() < k && attempts > 0 {
        attempts -= 1;
        let ci = rng.below(n as u64) as usize;
        if seen.contains(&ci) || !accept(ci) {
            continue;
        }
        seen.insert(ci);
        picked.push(ci);
    }
    picked
}

/// Hierarchical two-stratum weighted sampler (without replacement).
///
/// The *touched* stratum is a compact `(id, weight)` list in ascending id
/// order; the *untouched* stratum is the rest of `[0, n)` at a uniform
/// `prior` weight, resolved lazily by rejection so it is never
/// enumerated. Matches the dense loss-weighted semantics: observed
/// positive signals weigh clients directly, everyone unobserved gets the
/// mean positive signal as an exploration prior.
pub struct TwoStratumSampler {
    /// `(client id, weight)`, ascending id, weights > 0.
    touched: Vec<(usize, f64)>,
    touched_total: f64,
    /// Per-client prior weight of the untouched stratum.
    prior: f64,
    /// Clients in the untouched stratum still undrawn (approximate
    /// bookkeeping: rejection handles collisions exactly, the count only
    /// steers stratum choice).
    untouched_left: usize,
    n: usize,
}

impl TwoStratumSampler {
    /// `touched` must be ascending in id with strictly positive weights;
    /// `untouched_count` is the size of the complement stratum.
    pub fn new(touched: Vec<(usize, f64)>, untouched_count: usize, prior: f64, n: usize) -> Self {
        debug_assert!(touched.windows(2).all(|w| w[0].0 < w[1].0));
        let touched_total = touched.iter().map(|&(_, w)| w).sum();
        TwoStratumSampler {
            touched,
            touched_total,
            prior: prior.max(0.0),
            untouched_left: untouched_count,
            n,
        }
    }

    /// Draw one id, or `None` when both strata are exhausted (or every
    /// candidate is rejected by `accept` within the attempt budget).
    /// Consumes one `f32` for the stratum-and-position draw plus rejection
    /// draws inside the untouched stratum.
    pub fn draw(&mut self, rng: &mut Rng, mut accept: impl FnMut(usize) -> bool) -> Option<usize> {
        loop {
            let untouched_total = self.untouched_left as f64 * self.prior;
            let total = self.touched_total + untouched_total;
            if total <= 0.0 {
                return None;
            }
            let u = rng.f32() as f64 * total;
            if u < self.touched_total {
                // walk the compact stratum: ids ascend, so the pick is
                // deterministic for a given u
                let mut acc = 0.0;
                let mut hit = self.touched.len() - 1;
                for (i, &(_, w)) in self.touched.iter().enumerate() {
                    acc += w;
                    if u < acc {
                        hit = i;
                        break;
                    }
                }
                let (ci, w) = self.touched[hit];
                self.touched_total -= w;
                self.touched.remove(hit);
                if accept(ci) {
                    return Some(ci);
                }
                // rejected by the caller (excluded/ineligible): weight is
                // already retired, try again
                continue;
            }
            // untouched stratum: uniform over ids not in the touched list,
            // resolved by rejection against the compact list
            let mut attempts = attempt_budget(1);
            while attempts > 0 {
                attempts -= 1;
                let ci = rng.below(self.n as u64) as usize;
                if self.touched.binary_search_by_key(&ci, |&(id, _)| id).is_ok() {
                    continue;
                }
                if accept(ci) {
                    self.untouched_left = self.untouched_left.saturating_sub(1);
                    return Some(ci);
                }
            }
            // budget dry: retire the stratum so the loop can fall back to
            // the touched stratum (or terminate)
            self.untouched_left = 0;
            if self.touched_total <= 0.0 {
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejection_sample_returns_distinct_accepted_ids() {
        let mut rng = Rng::new(7, 1);
        let picks = rejection_sample(&mut rng, 1_000_000, 100, |ci| ci % 3 == 0);
        assert_eq!(picks.len(), 100);
        let set: HashSet<usize> = picks.iter().copied().collect();
        assert_eq!(set.len(), 100, "distinct");
        assert!(picks.iter().all(|&ci| ci % 3 == 0 && ci < 1_000_000));
        // deterministic in the seed
        let mut rng2 = Rng::new(7, 1);
        assert_eq!(
            picks,
            rejection_sample(&mut rng2, 1_000_000, 100, |ci| ci % 3 == 0)
        );
    }

    #[test]
    fn rejection_sample_comes_back_short_on_thin_sets_not_hung() {
        let mut rng = Rng::new(7, 1);
        // only 2 eligible ids in a million: must terminate, possibly short
        let picks = rejection_sample(&mut rng, 1_000_000, 10, |ci| ci < 2);
        assert!(picks.len() <= 2);
        let mut rng = Rng::new(7, 1);
        assert!(rejection_sample(&mut rng, 1_000_000, 10, |_| false).is_empty());
    }

    #[test]
    fn two_stratum_sampler_draws_without_replacement() {
        let touched = vec![(10usize, 5.0), (20, 1.0), (30, 4.0)];
        let mut s = TwoStratumSampler::new(touched, 0, 0.0, 100);
        let mut rng = Rng::new(3, 9);
        let mut got = Vec::new();
        while let Some(ci) = s.draw(&mut rng, |_| true) {
            got.push(ci);
        }
        got.sort_unstable();
        assert_eq!(got, vec![10, 20, 30], "exhausts the touched stratum once");
    }

    #[test]
    fn untouched_stratum_resolves_by_rejection() {
        // heavy prior, no touched weight: picks come from the complement
        let touched = vec![(0usize, 0.0001)];
        let mut s = TwoStratumSampler::new(touched, 999, 10.0, 1000);
        let mut rng = Rng::new(11, 2);
        for _ in 0..50 {
            let ci = s.draw(&mut rng, |_| true).unwrap();
            assert!(ci < 1000);
        }
    }

    #[test]
    fn sampler_is_deterministic_in_the_seed() {
        let run = || {
            let touched = vec![(5usize, 2.0), (50, 8.0), (500, 1.0)];
            let mut s = TwoStratumSampler::new(touched, 100_000 - 3, 3.6667, 100_000);
            let mut rng = Rng::new(42, 7);
            (0..20)
                .map(|_| s.draw(&mut rng, |ci| ci % 7 != 0).unwrap())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}

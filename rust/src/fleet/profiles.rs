//! Lazy, deterministic device-population models ("fleets").
//!
//! The paper evaluates FedSelect under uniform sampling and a scalar
//! post-fetch dropout rate (§6); real cross-device populations are
//! heterogeneous in bandwidth, memory, availability, and reliability — the
//! axes client-selection work (arXiv 2211.01549, 2210.04607) schedules on.
//! A [`Fleet`] assigns every client a [`DeviceProfile`]; since PR 8 the
//! profile is **not stored**: it is recomputed on demand as a pure function
//! of `(run seed, client id, fleet kind)`, so a 10M-client fleet costs zero
//! resident bytes until a client is touched. Trace fleets keep only the
//! compact loaded row table (cycling and offset staggering moved into the
//! lookup). Two calls of [`Fleet::profile`] for the same client always
//! return bit-identical profiles, and [`Fleet::materialize`] — the eager
//! shim used by tests and small-fleet tooling — is definitionally
//! `(0..len).map(profile)`.
//!
//! Built-in fleets:
//!
//! | kind | tiers | what it stresses |
//! |---|---|---|
//! | `uniform`    | all            | none — reproduces the pre-scheduler coordinator |
//! | `tiered-3`   | low/mid/high   | bandwidth + memory spread (MemoryCapped budgets) |
//! | `diurnal`    | day/night      | availability windows (AvailabilityAware) |
//! | `flaky-edge` | core/edge      | high per-round failure hazard on the edge |
//! | `trace:PATH` | lo/mid/hi (bandwidth terciles) | real measurements: one profile per line |
//!
//! `trace:PATH` loads a device trace file (see [`Fleet::from_trace`]): one
//! profile per non-comment line, `down_bps up_bps flops mem_frac avail
//! hazard`, cycled to cover the client population. A 32-profile example
//! ships at `examples/fleet_trace_32.txt`.

use crate::error::{Error, Result};
use crate::tensor::rng::Rng;

/// Stream id for the fleet-generation RNG: profiles are drawn from the run
/// seed on a dedicated stream so generation never perturbs the training
/// trajectory.
const FLEET_STREAM: u64 = 0xF1EE7;

/// One client's simulated device: bandwidth, compute, memory, an
/// availability window, and a per-round failure hazard.
///
/// `Copy`: lazy fleets return profiles by value from [`Fleet::profile`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Index into the fleet's tier-name table.
    pub tier: usize,
    /// Downlink bandwidth, bytes/s.
    pub down_bps: f64,
    /// Uplink bandwidth, bytes/s.
    pub up_bps: f64,
    /// Client-update throughput, in slice-float·example units per second
    /// (the [`crate::scheduler::SimClock`] compute model).
    pub flops: f64,
    /// Fraction of the full server model this device can hold in memory;
    /// `MemoryCapped` clamps the client's select budget to it.
    pub mem_frac: f64,
    /// Availability window phase offset, in rounds.
    pub avail_offset: u32,
    /// Availability window period in rounds; 0 = always available.
    pub avail_period: u32,
    /// Fraction of the period the device is online.
    pub avail_duty: f64,
    /// Probability the client fails *after* fetching its slice (the paper's
    /// §6 dropout pattern, now per-device).
    pub hazard: f32,
}

impl DeviceProfile {
    /// Whether this device is online in `round` (diurnal trace).
    pub fn available(&self, round: usize) -> bool {
        if self.avail_period == 0 {
            return true;
        }
        let pos = (round as u32 + self.avail_offset) % self.avail_period;
        (pos as f64) < self.avail_duty * self.avail_period as f64
    }

    /// Memory cap in bytes given the full server model size.
    pub fn mem_bytes(&self, server_bytes: usize) -> usize {
        (self.mem_frac * server_bytes as f64) as usize
    }
}

/// Which fleet to generate (config-level knob).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetKind {
    /// Homogeneous, always-on, failure-free devices.
    Uniform,
    /// Low-end / mid / high-end split (50/30/20).
    Tiered3,
    /// Day-shift / night-shift availability windows.
    Diurnal,
    /// A reliable core plus a large flaky edge.
    FlakyEdge,
    /// Profiles loaded from a trace file (one device per line, cycled to
    /// cover the population). See [`Fleet::from_trace`].
    Trace(String),
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for FleetKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetKind::Uniform => f.write_str("uniform"),
            FleetKind::Tiered3 => f.write_str("tiered-3"),
            FleetKind::Diurnal => f.write_str("diurnal"),
            FleetKind::FlakyEdge => f.write_str("flaky-edge"),
            FleetKind::Trace(path) => write!(f, "trace:{path}"),
        }
    }
}

impl std::str::FromStr for FleetKind {
    type Err = String;
    /// Case-insensitive; accepts the canonical `Display` names plus
    /// underscore/short aliases, and `trace:PATH` (the path keeps its case).
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        if let Some(prefix) = s.get(..6) {
            if prefix.eq_ignore_ascii_case("trace:") {
                let path = &s[6..];
                if path.is_empty() {
                    return Err("trace fleet needs a path: trace:PATH".to_string());
                }
                return Ok(FleetKind::Trace(path.to_string()));
            }
        }
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Ok(FleetKind::Uniform),
            "tiered-3" | "tiered_3" | "tiered3" | "tiered" => Ok(FleetKind::Tiered3),
            "diurnal" => Ok(FleetKind::Diurnal),
            "flaky-edge" | "flaky_edge" | "flaky" => Ok(FleetKind::FlakyEdge),
            other => Err(format!(
                "unknown fleet {other:?} (want {}, {}, {}, {} or trace:PATH)",
                FleetKind::Uniform,
                FleetKind::Tiered3,
                FleetKind::Diurnal,
                FleetKind::FlakyEdge
            )),
        }
    }
}

/// Where the per-client profiles come from.
///
/// Synthetic kinds carry no per-client data at all — the profile is a pure
/// function of `(seed, client id)`. Trace fleets keep the loaded rows (a
/// few dozen devices, not the population) and cycle them at lookup time.
#[derive(Clone, Debug)]
enum FleetStorage {
    Synthetic,
    Trace { rows: Vec<DeviceProfile> },
}

/// A device population: a lazy profile generator plus tier names for
/// reporting. Resident size is O(trace rows), not O(clients).
#[derive(Clone, Debug)]
pub struct Fleet {
    pub kind: FleetKind,
    seed: u64,
    mem_cap_frac: f64,
    len: usize,
    /// Applied on top of every generated hazard (the deprecated
    /// `--dropout-rate` floor); replaces the old in-place profile mutation.
    hazard_floor: f32,
    storage: FleetStorage,
    tier_names: Vec<&'static str>,
}

impl Fleet {
    /// Build a fleet of `n_clients`, deterministic in `seed`. Profiles are
    /// generated lazily by [`Fleet::profile`]; nothing per-client is
    /// allocated here. `mem_cap_frac` sets the lowest tier's memory cap as
    /// a fraction of the full server model (tiers above scale up from it).
    /// Only the `Trace` kind can fail (unreadable or malformed trace file).
    pub fn generate(
        kind: FleetKind,
        n_clients: usize,
        seed: u64,
        mem_cap_frac: f64,
    ) -> Result<Fleet> {
        if let FleetKind::Trace(path) = &kind {
            let fleet = Fleet::from_trace(path, n_clients)?;
            return Ok(fleet);
        }
        let tier_names: Vec<&'static str> = match &kind {
            FleetKind::Uniform => vec!["all"],
            FleetKind::Tiered3 => vec!["low-end", "mid", "high-end"],
            FleetKind::Diurnal => vec!["day", "night"],
            FleetKind::FlakyEdge => vec!["core", "edge"],
            FleetKind::Trace(_) => unreachable!("trace fleets load above"),
        };
        Ok(Fleet {
            kind,
            seed,
            mem_cap_frac,
            len: n_clients,
            hazard_floor: 0.0,
            storage: FleetStorage::Synthetic,
            tier_names,
        })
    }

    /// Load a fleet from a device trace: one profile per non-empty,
    /// non-`#`-comment line, six whitespace- or comma-separated columns —
    /// `down_bps up_bps flops mem_frac avail hazard`. `avail` is a duty
    /// cycle in (0, 1]: 1 means always online, anything lower puts the
    /// device on a 24-round window (offset staggered by client index).
    /// Rows are cycled at lookup time when the population outnumbers the
    /// trace, so one trace serves any fleet size without materializing it.
    /// Tiers are inferred from downlink bandwidth terciles over the trace
    /// rows (`trace-lo` / `trace-mid` / `trace-hi`): when only two terciles
    /// are populated the remaining bands are *relabeled*
    /// `trace-lo`/`trace-hi` by relative order (whichever terciles they
    /// were), and a flat trace reports one `trace` tier — so per-tier
    /// reporting works on real measurements.
    pub fn from_trace(path: &str, n_clients: usize) -> Result<Fleet> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read fleet trace {path:?}: {e}")))?;
        let mut rows: Vec<DeviceProfile> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line
                .split(|c: char| c.is_whitespace() || c == ',')
                .filter(|t| !t.is_empty())
                .collect();
            if cols.len() != 6 {
                return Err(Error::Config(format!(
                    "{path}:{}: expected 6 columns (down_bps up_bps flops mem_frac avail \
                     hazard), got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let num = |i: usize, name: &str| -> Result<f64> {
                cols[i].parse::<f64>().map_err(|e| {
                    Error::Config(format!("{path}:{}: bad {name} {:?}: {e}", lineno + 1, cols[i]))
                })
            };
            let (down, up, flops) = (num(0, "down_bps")?, num(1, "up_bps")?, num(2, "flops")?);
            let mem = num(3, "mem_frac")?;
            let avail = num(4, "avail")?;
            let hazard = num(5, "hazard")? as f32;
            if down <= 0.0 || up <= 0.0 || flops <= 0.0 {
                return Err(Error::Config(format!(
                    "{path}:{}: bandwidth/compute must be positive",
                    lineno + 1
                )));
            }
            if !(0.0..=1.0).contains(&mem) || mem == 0.0 || !(0.0..=1.0).contains(&avail)
                || avail == 0.0 || !(0.0..1.0).contains(&(hazard as f64))
            {
                return Err(Error::Config(format!(
                    "{path}:{}: mem_frac/avail must be in (0,1], hazard in [0,1)",
                    lineno + 1
                )));
            }
            rows.push(DeviceProfile {
                tier: 0,
                down_bps: down,
                up_bps: up,
                flops,
                mem_frac: mem,
                avail_offset: 0,
                avail_period: if avail < 1.0 { 24 } else { 0 },
                avail_duty: avail,
                hazard,
            });
        }
        if rows.is_empty() {
            return Err(Error::Config(format!(
                "fleet trace {path:?} has no profile lines"
            )));
        }
        // Infer tiers from downlink-bandwidth terciles over the trace rows
        // (collapsing empty terciles), so `fleet_summary` and the per-tier
        // ledgers stay informative on real measurements instead of lumping
        // every device into one "trace" tier. A flat trace keeps one tier.
        let mut bw: Vec<f64> = rows.iter().map(|p| p.down_bps).collect();
        bw.sort_by(|a, b| a.partial_cmp(b).expect("bandwidths are finite"));
        let n = bw.len();
        // tercile upper bounds by exact integer math: the first ceil(n/3)
        // sorted rows fall at or below q1, the first ceil(2n/3) at or below
        // q2 (float division here would make the boundary depend on
        // rounding direction for multiples of 3)
        let (q1, q2) = (bw[n.div_ceil(3) - 1], bw[(2 * n).div_ceil(3) - 1]);
        let raw_tier = |d: f64| {
            if d <= q1 {
                0usize
            } else if d <= q2 {
                1
            } else {
                2
            }
        };
        let mut present = [false; 3];
        for p in &rows {
            present[raw_tier(p.down_bps)] = true;
        }
        let n_present = present.iter().filter(|&&b| b).count();
        let tier_names: Vec<&'static str> = match n_present {
            1 => vec!["trace"],
            2 => vec!["trace-lo", "trace-hi"],
            _ => vec!["trace-lo", "trace-mid", "trace-hi"],
        };
        let mut dense = [0usize; 3];
        let mut next = 0usize;
        for t in 0..3 {
            if present[t] {
                dense[t] = next;
                next += 1;
            }
        }
        for p in &mut rows {
            p.tier = if n_present == 1 {
                0
            } else {
                dense[raw_tier(p.down_bps)]
            };
        }
        Ok(Fleet {
            kind: FleetKind::Trace(path.to_string()),
            seed: 0,
            mem_cap_frac: 1.0,
            len: n_clients,
            hazard_floor: 0.0,
            storage: FleetStorage::Trace { rows },
            tier_names,
        })
    }

    /// The per-client generator RNG. Each client gets its own independent
    /// stream keyed by `(seed, client id)` — a lookup never consumes state
    /// another lookup depends on, so profiles can be generated in any
    /// order (or in parallel) and still match bit-for-bit.
    fn client_rng(&self, ci: usize) -> Rng {
        Rng::new(
            self.seed
                .wrapping_add((ci as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            FLEET_STREAM ^ ci as u64,
        )
    }

    /// The profile of client `ci` — a pure function of the fleet and the
    /// id, recomputed on every call (no cache, no resident state). O(1).
    pub fn profile(&self, ci: usize) -> DeviceProfile {
        debug_assert!(ci < self.len, "client {ci} out of fleet range {}", self.len);
        let mut p = match &self.storage {
            FleetStorage::Trace { rows } => {
                let mut p = rows[ci % rows.len()];
                if p.avail_period > 0 {
                    p.avail_offset = (ci % p.avail_period as usize) as u32;
                }
                p
            }
            FleetStorage::Synthetic => {
                let f = self.mem_cap_frac.clamp(0.01, 1.0);
                match &self.kind {
                    FleetKind::Uniform => DeviceProfile {
                        tier: 0,
                        down_bps: 20e6,
                        up_bps: 5e6,
                        flops: 5e9,
                        mem_frac: 1.0,
                        avail_offset: 0,
                        avail_period: 0,
                        avail_duty: 1.0,
                        hazard: 0.0,
                    },
                    FleetKind::Tiered3 => {
                        // (down, up, flops, mem_frac, hazard) per tier
                        let tiers = [
                            (2e6, 0.5e6, 5e8, f, 0.05f32),
                            (8e6, 2e6, 2e9, (2.0 * f).min(1.0), 0.02),
                            (25e6, 10e6, 1e10, 1.0, 0.01),
                        ];
                        let mut rng = self.client_rng(ci);
                        let t = rng.categorical(&[5.0, 3.0, 2.0]);
                        let (down, up, flops, mem, hz) = tiers[t];
                        let jitter = rng.lognormal(0.0, 0.25) as f64;
                        DeviceProfile {
                            tier: t,
                            down_bps: down * jitter,
                            up_bps: up * jitter,
                            flops,
                            mem_frac: mem,
                            avail_offset: 0,
                            avail_period: 0,
                            avail_duty: 1.0,
                            hazard: hz,
                        }
                    }
                    FleetKind::Diurnal => {
                        // identical mid-range hardware, opposite 24-round windows
                        let mut rng = self.client_rng(ci);
                        let t = usize::from(rng.f32() < 0.5);
                        let jitter = rng.lognormal(0.0, 0.25) as f64;
                        DeviceProfile {
                            tier: t,
                            down_bps: 10e6 * jitter,
                            up_bps: 2.5e6 * jitter,
                            flops: 2e9,
                            mem_frac: 1.0,
                            avail_offset: if t == 0 { 0 } else { 12 },
                            avail_period: 24,
                            avail_duty: 0.5,
                            hazard: 0.02,
                        }
                    }
                    FleetKind::FlakyEdge => {
                        let mut rng = self.client_rng(ci);
                        let core = rng.f32() < 0.25;
                        let jitter = rng.lognormal(0.0, 0.25) as f64;
                        if core {
                            DeviceProfile {
                                tier: 0,
                                down_bps: 25e6 * jitter,
                                up_bps: 10e6 * jitter,
                                flops: 1e10,
                                mem_frac: 1.0,
                                avail_offset: 0,
                                avail_period: 0,
                                avail_duty: 1.0,
                                hazard: 0.01,
                            }
                        } else {
                            DeviceProfile {
                                tier: 1,
                                down_bps: 3e6 * jitter,
                                up_bps: 0.75e6 * jitter,
                                flops: 1e9,
                                mem_frac: (2.0 * f).min(1.0),
                                avail_offset: 0,
                                avail_period: 0,
                                avail_duty: 1.0,
                                hazard: 0.25,
                            }
                        }
                    }
                    FleetKind::Trace(_) => unreachable!("trace storage handled above"),
                }
            }
        };
        p.hazard = p.hazard.max(self.hazard_floor);
        p
    }

    /// Floor every profile's hazard at `rate` (the deprecated
    /// `--dropout-rate` mapping). Applied at lookup time — nothing is
    /// materialized.
    pub fn set_hazard_floor(&mut self, rate: f32) {
        self.hazard_floor = self.hazard_floor.max(rate);
    }

    /// Stream every profile in client-id order. O(1) memory; O(len) work.
    /// Summaries and tier tallies use this instead of a resident table.
    pub fn iter_profiles(&self) -> impl Iterator<Item = DeviceProfile> + '_ {
        (0..self.len).map(move |ci| self.profile(ci))
    }

    /// Eager shim: the full profile table, `(0..len).map(profile)`. For
    /// tests and small-fleet tooling only — allocates O(len).
    pub fn materialize(&self) -> Vec<DeviceProfile> {
        self.iter_profiles().collect()
    }

    /// Bytes of per-client state this fleet keeps resident: the trace row
    /// table for trace fleets, zero for synthetic kinds.
    pub fn resident_bytes(&self) -> u64 {
        match &self.storage {
            FleetStorage::Synthetic => 0,
            FleetStorage::Trace { rows } => {
                (rows.len() * std::mem::size_of::<DeviceProfile>()) as u64
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn num_tiers(&self) -> usize {
        self.tier_names.len()
    }

    pub fn tier_name(&self, tier: usize) -> &'static str {
        self.tier_names.get(tier).copied().unwrap_or("?")
    }

    /// Clients per tier. Streams the generator — O(len) work, O(1) memory.
    pub fn tier_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_tiers()];
        for p in self.iter_profiles() {
            sizes[p.tier] += 1;
        }
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_in_the_seed() {
        for kind in [
            FleetKind::Uniform,
            FleetKind::Tiered3,
            FleetKind::Diurnal,
            FleetKind::FlakyEdge,
        ] {
            let a = Fleet::generate(kind.clone(), 64, 42, 0.25).unwrap();
            let b = Fleet::generate(kind.clone(), 64, 42, 0.25).unwrap();
            assert_eq!(a.len(), 64);
            for (x, y) in a.iter_profiles().zip(b.iter_profiles()) {
                assert_eq!(x.tier, y.tier, "{kind}");
                assert_eq!(x.down_bps.to_bits(), y.down_bps.to_bits(), "{kind}");
                assert_eq!(x.hazard.to_bits(), y.hazard.to_bits(), "{kind}");
            }
            let c = Fleet::generate(kind.clone(), 64, 43, 0.25).unwrap();
            if kind != FleetKind::Uniform {
                let same = a
                    .iter_profiles()
                    .zip(c.iter_profiles())
                    .filter(|(x, y)| x.down_bps == y.down_bps)
                    .count();
                assert!(same < 64, "{kind}: different seeds must differ");
            }
        }
    }

    #[test]
    fn profiles_are_a_pure_function_of_the_client_id() {
        // the lazy profile contract: repeated lookups are bit-identical,
        // lookup order is irrelevant, and materialize() is the same table
        for kind in [
            FleetKind::Uniform,
            FleetKind::Tiered3,
            FleetKind::Diurnal,
            FleetKind::FlakyEdge,
        ] {
            let fl = Fleet::generate(kind.clone(), 128, 42, 0.25).unwrap();
            let eager = fl.materialize();
            assert_eq!(eager.len(), 128);
            // reverse order, repeated lookups: still the same bits
            for ci in (0..128).rev() {
                let p = fl.profile(ci);
                let q = fl.profile(ci);
                assert_eq!(p.down_bps.to_bits(), q.down_bps.to_bits(), "{kind}/{ci}");
                assert_eq!(eager[ci].down_bps.to_bits(), p.down_bps.to_bits());
                assert_eq!(eager[ci].up_bps.to_bits(), p.up_bps.to_bits());
                assert_eq!(eager[ci].tier, p.tier);
                assert_eq!(eager[ci].hazard.to_bits(), p.hazard.to_bits());
                assert_eq!(eager[ci].avail_offset, p.avail_offset);
            }
            // synthetic fleets keep nothing resident per client
            assert_eq!(fl.resident_bytes(), 0, "{kind}");
        }
    }

    #[test]
    fn huge_fleets_cost_no_resident_memory() {
        // 10M clients: construction is O(1), lookups work anywhere in range
        let fl = Fleet::generate(FleetKind::Tiered3, 10_000_000, 42, 0.25).unwrap();
        assert_eq!(fl.len(), 10_000_000);
        assert_eq!(fl.resident_bytes(), 0);
        let p = fl.profile(9_999_999);
        assert!(p.tier < 3 && p.down_bps > 0.0);
        // determinism holds at the far end of the id space too
        assert_eq!(
            fl.profile(9_999_999).down_bps.to_bits(),
            p.down_bps.to_bits()
        );
    }

    #[test]
    fn hazard_floor_applies_at_lookup_time() {
        let mut fl = Fleet::generate(FleetKind::Uniform, 8, 7, 0.25).unwrap();
        assert_eq!(fl.profile(3).hazard, 0.0);
        fl.set_hazard_floor(0.4);
        assert_eq!(fl.profile(3).hazard, 0.4);
        // floors never lower an existing hazard
        fl.set_hazard_floor(0.1);
        assert_eq!(fl.profile(3).hazard, 0.4);
    }

    #[test]
    fn uniform_fleet_is_unconstrained() {
        let fl = Fleet::generate(FleetKind::Uniform, 10, 7, 0.25).unwrap();
        assert_eq!(fl.num_tiers(), 1);
        for p in fl.iter_profiles() {
            assert_eq!(p.hazard, 0.0);
            assert_eq!(p.mem_frac, 1.0);
            assert!(p.available(0) && p.available(1000));
        }
    }

    #[test]
    fn tiered_fleet_covers_all_tiers_and_respects_mem_cap() {
        let fl = Fleet::generate(FleetKind::Tiered3, 200, 7, 0.25).unwrap();
        let sizes = fl.tier_sizes();
        assert_eq!(sizes.len(), 3);
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
        // proportions roughly 50/30/20
        assert!(sizes[0] > sizes[2], "{sizes:?}");
        for p in fl.iter_profiles() {
            match p.tier {
                0 => assert!((p.mem_frac - 0.25).abs() < 1e-12),
                1 => assert!((p.mem_frac - 0.5).abs() < 1e-12),
                _ => assert!((p.mem_frac - 1.0).abs() < 1e-12),
            }
        }
    }

    #[test]
    fn diurnal_windows_alternate() {
        let fl = Fleet::generate(FleetKind::Diurnal, 50, 9, 0.25).unwrap();
        let day = fl.iter_profiles().find(|p| p.tier == 0).unwrap();
        let night = fl.iter_profiles().find(|p| p.tier == 1).unwrap();
        assert!(day.available(0) && !night.available(0));
        assert!(!day.available(12) && night.available(12));
        // complementary over a full period
        for r in 0..24 {
            assert_ne!(day.available(r), night.available(r), "round {r}");
        }
    }

    #[test]
    fn flaky_edge_has_a_hazardous_majority() {
        let fl = Fleet::generate(FleetKind::FlakyEdge, 200, 11, 0.25).unwrap();
        let sizes = fl.tier_sizes();
        assert!(sizes[1] > sizes[0], "edge must outnumber core: {sizes:?}");
        assert!(fl.iter_profiles().any(|p| p.hazard >= 0.2));
    }

    #[test]
    fn fleet_kind_display_round_trips_case_insensitively() {
        for kind in [
            FleetKind::Uniform,
            FleetKind::Tiered3,
            FleetKind::Diurnal,
            FleetKind::FlakyEdge,
        ] {
            let shown = kind.to_string();
            assert_eq!(shown.parse::<FleetKind>().unwrap(), kind);
            assert_eq!(shown.to_uppercase().parse::<FleetKind>().unwrap(), kind);
        }
        assert_eq!("tiered3".parse::<FleetKind>().unwrap(), FleetKind::Tiered3);
        assert!("bogus".parse::<FleetKind>().is_err());
        // trace paths round-trip with their case intact
        let kind = "trace:Examples/My_Trace.txt".parse::<FleetKind>().unwrap();
        assert_eq!(kind, FleetKind::Trace("Examples/My_Trace.txt".into()));
        assert_eq!(kind.to_string(), "trace:Examples/My_Trace.txt");
        assert_eq!(kind.to_string().parse::<FleetKind>().unwrap(), kind);
        assert!("trace:".parse::<FleetKind>().is_err());
    }

    #[test]
    fn trace_fleet_loads_cycles_and_staggers() {
        // the checked-in 32-profile example trace (cwd = the package root)
        let path = "../examples/fleet_trace_32.txt";
        let fl = Fleet::from_trace(path, 50).unwrap();
        assert_eq!(fl.len(), 50);
        // profiles cycle: client 32 repeats line 1's device
        assert_eq!(
            fl.profile(0).down_bps.to_bits(),
            fl.profile(32).down_bps.to_bits()
        );
        assert_eq!(fl.profile(0).tier, fl.profile(32).tier);
        assert!(fl.iter_profiles().any(|p| p.hazard >= 0.2), "edge hazards");
        assert!(fl.iter_profiles().any(|p| p.avail_period == 24));
        assert!(fl.iter_profiles().any(|p| p.avail_period == 0));
        // resident state is the row table, not the population
        assert_eq!(
            fl.resident_bytes(),
            (32 * std::mem::size_of::<DeviceProfile>()) as u64
        );
        // generate() routes trace kinds through the loader
        let via_generate =
            Fleet::generate(FleetKind::Trace(path.to_string()), 50, 7, 0.25).unwrap();
        for (a, b) in fl.iter_profiles().zip(via_generate.iter_profiles()) {
            assert_eq!(a.down_bps.to_bits(), b.down_bps.to_bits());
            assert_eq!(a.tier, b.tier);
        }
    }

    #[test]
    fn trace_fleet_infers_bandwidth_tercile_tiers() {
        let path = "../examples/fleet_trace_32.txt";
        let fl = Fleet::from_trace(path, 64).unwrap();
        assert_eq!(fl.num_tiers(), 3, "the example trace spans 1.2–30 MB/s");
        assert_eq!(fl.tier_name(0), "trace-lo");
        assert_eq!(fl.tier_name(1), "trace-mid");
        assert_eq!(fl.tier_name(2), "trace-hi");
        let sizes = fl.tier_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "every tercile populated: {sizes:?}");
        // tiers are ordered by bandwidth: every lo device is slower than
        // every hi device, and the per-tier means are strictly increasing
        let mean = |t: usize| {
            let ps: Vec<_> = fl.iter_profiles().filter(|p| p.tier == t).collect();
            ps.iter().map(|p| p.down_bps).sum::<f64>() / ps.len() as f64
        };
        assert!(mean(0) < mean(1) && mean(1) < mean(2));
        let max_lo = fl
            .iter_profiles()
            .filter(|p| p.tier == 0)
            .map(|p| p.down_bps)
            .fold(0.0f64, f64::max);
        let min_hi = fl
            .iter_profiles()
            .filter(|p| p.tier == 2)
            .map(|p| p.down_bps)
            .fold(f64::INFINITY, f64::min);
        assert!(max_lo < min_hi, "{max_lo} !< {min_hi}");
    }

    #[test]
    fn flat_and_two_level_traces_collapse_tiers() {
        let dir = std::env::temp_dir();
        // a flat trace (identical bandwidth) stays one "trace" tier
        let flat = dir.join("fedselect_trace_flat.txt");
        std::fs::write(&flat, "1e6 1e5 1e9 0.5 1.0 0.0\n".repeat(5)).unwrap();
        let fl = Fleet::from_trace(flat.to_str().unwrap(), 10).unwrap();
        assert_eq!(fl.num_tiers(), 1);
        assert_eq!(fl.tier_name(0), "trace");
        assert!(fl.iter_profiles().all(|p| p.tier == 0));
        // two distinct bandwidth levels collapse to trace-lo / trace-hi
        let two = dir.join("fedselect_trace_two_level.txt");
        std::fs::write(
            &two,
            "1e6 1e5 1e9 0.5 1.0 0.0\n1e6 1e5 1e9 0.5 1.0 0.0\n2e7 5e6 1e10 1.0 1.0 0.0\n",
        )
        .unwrap();
        let fl2 = Fleet::from_trace(two.to_str().unwrap(), 9).unwrap();
        assert_eq!(fl2.num_tiers(), 2, "{:?}", fl2.tier_sizes());
        assert_eq!(fl2.tier_name(0), "trace-lo");
        assert_eq!(fl2.tier_name(1), "trace-hi");
        let sizes = fl2.tier_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
        for p in fl2.iter_profiles() {
            assert_eq!(p.tier, usize::from(p.down_bps > 1e6));
        }
    }

    #[test]
    fn trace_fleet_rejects_malformed_files() {
        assert!(Fleet::from_trace("no/such/file.txt", 8).is_err());
        let dir = std::env::temp_dir();
        let bad_cols = dir.join("fedselect_trace_bad_cols.txt");
        std::fs::write(&bad_cols, "1e6 1e5 1e9 0.5\n").unwrap();
        let err = Fleet::from_trace(bad_cols.to_str().unwrap(), 8).unwrap_err();
        assert!(err.to_string().contains("6 columns"), "{err}");
        let bad_range = dir.join("fedselect_trace_bad_range.txt");
        std::fs::write(&bad_range, "1e6 1e5 1e9 0.5 1.0 1.5\n").unwrap();
        assert!(Fleet::from_trace(bad_range.to_str().unwrap(), 8).is_err());
        let empty = dir.join("fedselect_trace_empty.txt");
        std::fs::write(&empty, "# only comments\n\n").unwrap();
        let err = Fleet::from_trace(empty.to_str().unwrap(), 8).unwrap_err();
        assert!(err.to_string().contains("no profile lines"), "{err}");
    }
}

//! Sparse per-client scheduler state: entries exist only for clients the
//! scheduler has ever selected.
//!
//! The eager scheduler kept `last_selected: Vec<i64>` and
//! `signals: Vec<f32>` sized to the fleet — O(fleet) resident bytes even
//! when a 10M-client run only ever touches a few thousand devices. The
//! [`TouchedState`] replaces both with one compact hash map keyed by client
//! id; a client absent from the map reads as the legacy defaults
//! (`last_selected = -1`, `signal = 0.0`), so the selection policies see
//! exactly the state they saw before. The invariant
//! `clients_touched() ≤ clients ever selected` is test-enforced
//! (`tests/fleet_scale.rs`) and exported as the `fleet.clients_touched` /
//! `fleet.resident_bytes` gauges.

use std::collections::HashMap;

/// Scheduler state for one ever-selected client.
#[derive(Clone, Copy, Debug)]
pub struct ClientTouch {
    /// Last round this client was selected (`-1` before any selection —
    /// the legacy dense-vector default).
    pub last_selected: i64,
    /// Last observed update norm (the loss-weighted policy's signal;
    /// `0.0` until the client first completes a round).
    pub signal: f32,
}

impl Default for ClientTouch {
    fn default() -> Self {
        ClientTouch {
            last_selected: -1,
            signal: 0.0,
        }
    }
}

/// Sparse map of per-client scheduler state. Memory is O(clients ever
/// selected), independent of fleet size.
#[derive(Clone, Debug, Default)]
pub struct TouchedState {
    entries: HashMap<usize, ClientTouch>,
}

impl TouchedState {
    pub fn new() -> Self {
        TouchedState::default()
    }

    /// Number of clients with resident state (ever selected).
    pub fn clients_touched(&self) -> usize {
        self.entries.len()
    }

    /// Approximate resident bytes of the store (entries × slot size; the
    /// map's load-factor overhead is bounded by a constant factor).
    pub fn resident_bytes(&self) -> u64 {
        (self.entries.len() * (std::mem::size_of::<usize>() + std::mem::size_of::<ClientTouch>()))
            as u64
    }

    /// Last round `ci` was selected; `-1` if never.
    pub fn last_selected(&self, ci: usize) -> i64 {
        self.entries.get(&ci).map_or(-1, |t| t.last_selected)
    }

    /// Last observed update-norm signal for `ci`; `0.0` if never observed.
    pub fn signal(&self, ci: usize) -> f32 {
        self.entries.get(&ci).map_or(0.0, |t| t.signal)
    }

    /// Whether `ci` has ever been selected.
    pub fn contains(&self, ci: usize) -> bool {
        self.entries.contains_key(&ci)
    }

    /// Record a selection: `ci` was picked in `round`.
    pub fn mark_selected(&mut self, ci: usize, round: i64) {
        self.entries.entry(ci).or_default().last_selected = round;
    }

    /// Record an observed update norm for `ci`. Only called for cohort
    /// members (already marked selected), so it never grows the map past
    /// the ever-selected set.
    pub fn set_signal(&mut self, ci: usize, signal: f32) {
        self.entries.entry(ci).or_default().signal = signal;
    }

    /// Touched client ids in ascending order — the deterministic iteration
    /// order every sparse sampling path uses (hash-map order is not
    /// seed-stable).
    pub fn sorted_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.entries.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// `(id, state)` pairs in ascending id order.
    pub fn sorted_entries(&self) -> Vec<(usize, ClientTouch)> {
        let mut out: Vec<(usize, ClientTouch)> =
            self.entries.iter().map(|(&k, &v)| (k, v)).collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absent_clients_read_the_legacy_defaults() {
        let ts = TouchedState::new();
        assert_eq!(ts.last_selected(42), -1);
        assert_eq!(ts.signal(42), 0.0);
        assert_eq!(ts.clients_touched(), 0);
        assert_eq!(ts.resident_bytes(), 0);
        assert!(!ts.contains(42));
    }

    #[test]
    fn state_grows_only_with_touched_clients() {
        let mut ts = TouchedState::new();
        ts.mark_selected(7, 0);
        ts.mark_selected(1_000_000, 0);
        ts.mark_selected(7, 3); // re-selection updates in place
        ts.set_signal(7, 2.5);
        assert_eq!(ts.clients_touched(), 2);
        assert_eq!(ts.last_selected(7), 3);
        assert_eq!(ts.signal(7), 2.5);
        assert_eq!(ts.last_selected(1_000_000), 0);
        assert_eq!(ts.signal(1_000_000), 0.0);
        assert!(ts.resident_bytes() > 0);
        assert_eq!(ts.sorted_ids(), vec![7, 1_000_000]);
    }

    #[test]
    fn sorted_entries_are_ascending_and_complete() {
        let mut ts = TouchedState::new();
        for &ci in &[9usize, 2, 5, 100] {
            ts.mark_selected(ci, 1);
        }
        let ids: Vec<usize> = ts.sorted_entries().iter().map(|&(k, _)| k).collect();
        assert_eq!(ids, vec![2, 5, 9, 100]);
    }
}

//! Million-client fleet engine: lazy deterministic profiles, sparse
//! touched-state, stratified sampling, and scale-only scenarios.
//!
//! The paper's setting is planet-scale cross-device federated learning —
//! selection policies, availability waves, churn, and outages only get
//! interesting over populations far larger than any per-client state the
//! simulator could afford to materialize. This subsystem makes simulation
//! cost **O(active), not O(fleet)**:
//!
//! - [`profiles`]: a [`Fleet`] no longer stores per-client
//!   [`DeviceProfile`]s. [`Fleet::profile`] recomputes them on demand as a
//!   pure function of `(run seed, client id, fleet kind)` — bit-stable
//!   across calls and call orders — so a 10M-client fleet holds zero
//!   resident bytes (trace fleets keep only the loaded row table).
//!   [`Fleet::materialize`] is the eager shim for tests and small tools.
//! - [`touched`]: [`TouchedState`] keeps scheduler signals and staleness
//!   counters only for clients ever selected; absent clients read the
//!   legacy dense-vector defaults. Client caches grow the same way
//!   (`FleetCaches` allocates a client's cache on first commit).
//! - [`sampling`]: past [`sampling::SPARSE_SCAN_THRESHOLD`] clients the
//!   selection policies switch from their legacy dense scans (kept
//!   bit-for-bit at seed sizes) to rejection / two-stratum sampling that
//!   costs O(cohort + touched) per round.
//! - [`scenario`]: churn, regional outages, and diurnal availability
//!   waves as closed-form sim-time processes feeding `PlanCtx`
//!   eligibility, with per-round arrival/departure/outage counts ledgered
//!   in `RoundRecord` and the trace schema.

pub mod profiles;
pub mod sampling;
pub mod scenario;
pub mod touched;

pub use profiles::{DeviceProfile, Fleet, FleetKind};
pub use sampling::SPARSE_SCAN_THRESHOLD;
pub use scenario::{
    ChurnSpec, EligibilityView, OutageSpec, Scenario, ScenarioConfig, WaveSpec,
};
pub use touched::{ClientTouch, TouchedState};

//! Fleet scenarios: churn, regional outages, and availability waves over
//! the simulated clock.
//!
//! These only matter at scale — a 24-client run has no "regions" and no
//! meaningful arrival process — so they live in the fleet subsystem and
//! are **closed-form in sim time**: eligibility of client `ci` at sim time
//! `t` is a pure O(1) predicate, and per-round ledger counts (eligible
//! population, arrivals, departures, outage-excluded) are computed by
//! interval decomposition in O(1), never by an O(fleet) scan. That keeps
//! `plan_round` at 10M clients in the milliseconds the subsystem promises.
//!
//! Three processes compose (a client must pass all active ones):
//!
//! - **Churn** (`--churn RATE[:WIDTH]`): the eligible population is a
//!   circular window of `WIDTH × fleet` ids that slides through the id
//!   space at `RATE × fleet` clients per simulated hour. Ids ahead of the
//!   window have not "installed the app" yet; ids behind it have churned
//!   out. Every slide step departs the oldest client and arrives a new
//!   one — a deterministic arrival/departure process.
//! - **Regional outage** (`--outage START:DUR:FRAC`): ids `[0, FRAC ×
//!   fleet)` — one contiguous "region" of the id space — are blacked out
//!   between sim hours `START` and `START+DUR`.
//! - **Availability wave** (`--wave DUTY`): a 24-hour diurnal wave; client
//!   `ci` is awake when `(ci + floor(t_hours)) mod 24 < DUTY × 24`, so at
//!   any instant a `DUTY` fraction of ids is eligible and the awake set
//!   rolls through the population hour by hour. Unlike the per-profile
//!   `avail_*` fields (which gate by *round index*), the wave runs on the
//!   simulated clock, so multi-day horizons see realistic day/night
//!   cycles even when rounds take variable sim time.
//!
//! `--horizon HOURS` bounds the run by simulated time instead of round
//! count; the coordinator stops at the first round close past it.

use crate::error::{Error, Result};

/// Deterministic arrival/departure process: a sliding eligibility window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnSpec {
    /// Window slide rate, in fleet fractions per simulated hour (0.01 =
    /// 1% of the fleet arrives, and 1% departs, per hour).
    pub rate_per_h: f64,
    /// Eligible window width as a fleet fraction, in (0, 1].
    pub width_frac: f64,
}

impl ChurnSpec {
    /// Parse `RATE` or `RATE:WIDTH` (width defaults to 0.9).
    pub fn parse(s: &str) -> Result<ChurnSpec> {
        let bad = |m: &str| Error::Config(format!("bad --churn {s:?}: {m}"));
        let (rate_s, width_s) = match s.split_once(':') {
            Some((r, w)) => (r, Some(w)),
            None => (s, None),
        };
        let rate_per_h: f64 = rate_s
            .parse()
            .map_err(|_| bad("RATE must be a number (fleet fraction per hour)"))?;
        let width_frac: f64 = match width_s {
            Some(w) => w.parse().map_err(|_| bad("WIDTH must be a number"))?,
            None => 0.9,
        };
        if !(rate_per_h > 0.0) || !rate_per_h.is_finite() {
            return Err(bad("RATE must be positive and finite"));
        }
        if !(width_frac > 0.0 && width_frac <= 1.0) {
            return Err(bad("WIDTH must be in (0, 1]"));
        }
        Ok(ChurnSpec {
            rate_per_h,
            width_frac,
        })
    }
}

/// A blackout window over one contiguous region of the id space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OutageSpec {
    /// Outage start, simulated hours since run start.
    pub start_h: f64,
    /// Outage duration, simulated hours.
    pub dur_h: f64,
    /// Fraction of the fleet (ids `[0, frac × n)`) that goes dark.
    pub frac: f64,
}

impl OutageSpec {
    /// Parse `START:DUR:FRAC` (hours, hours, fleet fraction).
    pub fn parse(s: &str) -> Result<OutageSpec> {
        let bad = |m: &str| Error::Config(format!("bad --outage {s:?}: {m}"));
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            return Err(bad("want START:DUR:FRAC"));
        }
        let start_h: f64 = parts[0].parse().map_err(|_| bad("START must be a number"))?;
        let dur_h: f64 = parts[1].parse().map_err(|_| bad("DUR must be a number"))?;
        let frac: f64 = parts[2].parse().map_err(|_| bad("FRAC must be a number"))?;
        if start_h < 0.0 || !start_h.is_finite() {
            return Err(bad("START must be ≥ 0"));
        }
        if !(dur_h > 0.0) || !dur_h.is_finite() {
            return Err(bad("DUR must be positive"));
        }
        if !(frac > 0.0 && frac <= 1.0) {
            return Err(bad("FRAC must be in (0, 1]"));
        }
        Ok(OutageSpec {
            start_h,
            dur_h,
            frac,
        })
    }
}

/// A 24-hour diurnal availability wave on the simulated clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WaveSpec {
    /// Fraction of each 24-hour cycle a client is awake, in (0, 1).
    pub duty: f64,
}

impl WaveSpec {
    pub fn parse(s: &str) -> Result<WaveSpec> {
        let duty: f64 = s
            .parse()
            .map_err(|_| Error::Config(format!("bad --wave {s:?}: DUTY must be a number")))?;
        if !(duty > 0.0 && duty < 1.0) {
            return Err(Error::Config(format!(
                "bad --wave {s:?}: DUTY must be in (0, 1)"
            )));
        }
        Ok(WaveSpec { duty })
    }
}

/// The scenario knobs, as carried in `TrainConfig`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioConfig {
    pub churn: Option<ChurnSpec>,
    pub outage: Option<OutageSpec>,
    pub wave: Option<WaveSpec>,
    /// Stop training at the first round close past this many simulated
    /// hours; 0 = unbounded (round count governs).
    pub horizon_h: f64,
}

impl ScenarioConfig {
    /// Whether any eligibility-shaping process is active (horizon alone
    /// does not shape eligibility).
    pub fn shapes_eligibility(&self) -> bool {
        self.churn.is_some() || self.outage.is_some() || self.wave.is_some()
    }

    /// Range-check every spec — the CLI parsers enforce the same bounds,
    /// but configs can also be built programmatically.
    pub fn validate(&self) -> Result<()> {
        if let Some(c) = &self.churn {
            if !(c.rate_per_h > 0.0) || !c.rate_per_h.is_finite() {
                return Err(Error::Config("churn rate must be positive and finite".into()));
            }
            if !(c.width_frac > 0.0 && c.width_frac <= 1.0) {
                return Err(Error::Config("churn width must be in (0, 1]".into()));
            }
        }
        if let Some(o) = &self.outage {
            if o.start_h < 0.0 || !o.start_h.is_finite() {
                return Err(Error::Config("outage start must be ≥ 0".into()));
            }
            if !(o.dur_h > 0.0) || !o.dur_h.is_finite() {
                return Err(Error::Config("outage duration must be positive".into()));
            }
            if !(o.frac > 0.0 && o.frac <= 1.0) {
                return Err(Error::Config("outage fraction must be in (0, 1]".into()));
            }
        }
        if let Some(w) = &self.wave {
            if !(w.duty > 0.0 && w.duty < 1.0) {
                return Err(Error::Config("wave duty must be in (0, 1)".into()));
            }
        }
        if self.horizon_h < 0.0 || !self.horizon_h.is_finite() {
            return Err(Error::Config("horizon must be ≥ 0 hours".into()));
        }
        Ok(())
    }
}

/// Wave slots per day: eligibility is resolved on whole sim-hours.
const WAVE_PERIOD: u64 = 24;

/// The scenario processes bound to a fleet size.
#[derive(Clone, Debug)]
pub struct Scenario {
    cfg: ScenarioConfig,
    n: usize,
}

impl Scenario {
    /// `None` when the config shapes no eligibility (pure `--horizon`
    /// runs skip the scenario plumbing entirely — legacy byte-identity).
    pub fn new(cfg: &ScenarioConfig, n: usize) -> Option<Scenario> {
        if cfg.shapes_eligibility() && n > 0 {
            Some(Scenario {
                cfg: cfg.clone(),
                n,
            })
        } else {
            None
        }
    }

    /// Unwrapped churn-window offset at sim time `t_h` (monotone in `t`);
    /// the window's low edge is this mod `n`. Exposed so the scheduler can
    /// ledger arrivals/departures as the offset delta between rounds.
    pub fn churn_offset_raw(&self, t_h: f64) -> u64 {
        match self.cfg.churn {
            Some(c) => (c.rate_per_h * self.n as f64 * t_h.max(0.0)).floor() as u64,
            None => 0,
        }
    }

    /// Freeze eligibility at sim time `t_h` into an O(1)-sized view.
    pub fn view(&self, t_h: f64) -> EligibilityView {
        let n = self.n;
        let (churn_lo, churn_w) = match self.cfg.churn {
            Some(c) => {
                let w = ((c.width_frac * n as f64).round() as usize).clamp(1, n);
                let lo = (self.churn_offset_raw(t_h) % n as u64) as usize;
                (lo, w)
            }
            None => (0, n),
        };
        let outage_cut = match self.cfg.outage {
            Some(o) if t_h >= o.start_h && t_h < o.start_h + o.dur_h => {
                ((o.frac * n as f64).round() as usize).min(n)
            }
            _ => 0,
        };
        let (wave_duty_slots, wave_phase) = match self.cfg.wave {
            Some(w) => {
                // ceil'd so a fractional duty never rounds to "nobody awake"
                let slots = ((w.duty * WAVE_PERIOD as f64).ceil() as u64).clamp(1, WAVE_PERIOD);
                (slots, (t_h.max(0.0).floor() as u64) % WAVE_PERIOD)
            }
            None => (WAVE_PERIOD, 0),
        };
        EligibilityView {
            n,
            churn_lo,
            churn_w,
            outage_cut,
            wave_duty_slots,
            wave_phase,
        }
    }
}

/// Eligibility at one instant: an O(1) predicate over the id space plus
/// closed-form population counts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EligibilityView {
    n: usize,
    /// Churn window low edge (id space is circular).
    churn_lo: usize,
    /// Churn window width; `n` when churn is off.
    churn_w: usize,
    /// Ids `[0, outage_cut)` are blacked out; 0 when no outage is active.
    outage_cut: usize,
    /// Awake slots per 24-hour cycle; 24 when the wave is off.
    wave_duty_slots: u64,
    /// Current hour-of-day phase.
    wave_phase: u64,
}

impl EligibilityView {
    /// Whether churn actually constrains membership (a full-width window
    /// slides without anyone arriving or departing).
    pub fn churn_active(&self) -> bool {
        self.churn_w < self.n
    }

    /// Whether client `ci` may be selected at this instant. O(1).
    pub fn eligible(&self, ci: usize) -> bool {
        if ci >= self.n {
            return false;
        }
        self.in_churn_window(ci) && !self.in_outage(ci) && self.wave_awake(ci)
    }

    fn in_churn_window(&self, ci: usize) -> bool {
        ((ci + self.n - self.churn_lo) % self.n) < self.churn_w
    }

    fn in_outage(&self, ci: usize) -> bool {
        ci < self.outage_cut
    }

    fn wave_awake(&self, ci: usize) -> bool {
        (ci as u64 + self.wave_phase) % WAVE_PERIOD < self.wave_duty_slots
    }

    /// The churn window as 1–2 linear id intervals `[a, b)`.
    fn churn_intervals(&self) -> [(usize, usize); 2] {
        let (lo, w, n) = (self.churn_lo, self.churn_w, self.n);
        if lo + w <= n {
            [(lo, lo + w), (0, 0)]
        } else {
            [(lo, n), (0, lo + w - n)]
        }
    }

    /// Count of x in `[a, b)` with `(x + phase) % 24 < duty_slots` —
    /// closed form over full cycles plus a ≤ 24-step remainder.
    fn wave_count(&self, a: usize, b: usize) -> usize {
        if a >= b {
            return 0;
        }
        let len = b - a;
        let cycles = len / WAVE_PERIOD as usize;
        let mut count = cycles * self.wave_duty_slots as usize;
        for x in a + cycles * WAVE_PERIOD as usize..b {
            if self.wave_awake(x) {
                count += 1;
            }
        }
        count
    }

    /// How many clients are eligible right now. O(1) (≤ 2 intervals × a
    /// ≤ 24-step remainder each), no fleet scan.
    pub fn eligible_count(&self) -> usize {
        self.churn_intervals()
            .iter()
            .map(|&(a, b)| {
                // drop the blacked-out prefix, then count awake ids
                let a = a.max(self.outage_cut.min(b));
                self.wave_count(a, b)
            })
            .sum()
    }

    /// How many clients the outage is excluding right now — clients that
    /// pass churn and wave but sit in the dark region. O(1).
    pub fn outage_excluded_count(&self) -> usize {
        if self.outage_cut == 0 {
            return 0;
        }
        self.churn_intervals()
            .iter()
            .map(|&(a, b)| self.wave_count(a, b.min(self.outage_cut).max(a)))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn brute_eligible(v: &EligibilityView, n: usize) -> Vec<usize> {
        (0..n).filter(|&ci| v.eligible(ci)).collect()
    }

    #[test]
    fn parsing_accepts_good_specs_and_rejects_bad_ones() {
        let c = ChurnSpec::parse("0.02").unwrap();
        assert_eq!((c.rate_per_h, c.width_frac), (0.02, 0.9));
        let c = ChurnSpec::parse("0.5:0.75").unwrap();
        assert_eq!((c.rate_per_h, c.width_frac), (0.5, 0.75));
        assert!(ChurnSpec::parse("-1").is_err());
        assert!(ChurnSpec::parse("0.1:1.5").is_err());
        assert!(ChurnSpec::parse("x").is_err());
        let o = OutageSpec::parse("4:2:0.3").unwrap();
        assert_eq!((o.start_h, o.dur_h, o.frac), (4.0, 2.0, 0.3));
        assert!(OutageSpec::parse("4:2").is_err());
        assert!(OutageSpec::parse("4:-1:0.3").is_err());
        assert!(OutageSpec::parse("4:2:0").is_err());
        let w = WaveSpec::parse("0.5").unwrap();
        assert_eq!(w.duty, 0.5);
        assert!(WaveSpec::parse("1.0").is_err());
        assert!(WaveSpec::parse("0").is_err());
    }

    #[test]
    fn no_shaping_config_builds_no_scenario() {
        let cfg = ScenarioConfig {
            horizon_h: 5.0,
            ..ScenarioConfig::default()
        };
        assert!(!cfg.shapes_eligibility());
        assert!(Scenario::new(&cfg, 100).is_none());
    }

    #[test]
    fn churn_window_slides_deterministically() {
        let cfg = ScenarioConfig {
            churn: Some(ChurnSpec {
                rate_per_h: 0.1,
                width_frac: 0.5,
            }),
            ..ScenarioConfig::default()
        };
        let sc = Scenario::new(&cfg, 100).unwrap();
        // t=0: window [0, 50)
        let v0 = sc.view(0.0);
        assert!(v0.eligible(0) && v0.eligible(49) && !v0.eligible(50));
        assert_eq!(v0.eligible_count(), 50);
        // after 1h at 10 clients/h the window is [10, 60): 0..10 churned
        // out (departures), 50..60 arrived
        let v1 = sc.view(1.0);
        assert!(!v1.eligible(9) && v1.eligible(10) && v1.eligible(59) && !v1.eligible(60));
        assert_eq!(sc.churn_offset_raw(1.0) - sc.churn_offset_raw(0.0), 10);
        // the window wraps the id space without losing clients
        let v9 = sc.view(9.0);
        assert_eq!(v9.eligible_count(), 50);
        assert!(v9.eligible(95) && v9.eligible(5) && !v9.eligible(50));
        // same time, same view: pure in t
        assert_eq!(sc.view(9.0), v9);
    }

    #[test]
    fn outage_blacks_out_the_region_only_during_the_window() {
        let cfg = ScenarioConfig {
            outage: Some(OutageSpec {
                start_h: 4.0,
                dur_h: 2.0,
                frac: 0.3,
            }),
            ..ScenarioConfig::default()
        };
        let sc = Scenario::new(&cfg, 100).unwrap();
        assert_eq!(sc.view(3.9).eligible_count(), 100);
        let during = sc.view(4.0);
        assert_eq!(during.eligible_count(), 70);
        assert_eq!(during.outage_excluded_count(), 30);
        assert!(!during.eligible(0) && !during.eligible(29) && during.eligible(30));
        // half-open window: over at start + dur
        assert_eq!(sc.view(6.0).eligible_count(), 100);
        assert_eq!(sc.view(6.0).outage_excluded_count(), 0);
    }

    #[test]
    fn wave_rolls_a_duty_fraction_through_the_population() {
        let cfg = ScenarioConfig {
            wave: Some(WaveSpec { duty: 0.5 }),
            ..ScenarioConfig::default()
        };
        let sc = Scenario::new(&cfg, 240).unwrap();
        let v0 = sc.view(0.0);
        // duty 0.5 → 12 of every 24 ids awake
        assert_eq!(v0.eligible_count(), 120);
        assert!(v0.eligible(0) && v0.eligible(11) && !v0.eligible(12));
        // an hour later the awake set has rolled by one id
        let v1 = sc.view(1.0);
        assert!(!v1.eligible(11) && v1.eligible(23));
        // fractional hours resolve to the floor hour
        assert_eq!(sc.view(1.7), v1);
    }

    #[test]
    fn closed_form_counts_match_a_brute_force_scan() {
        // all three processes at once, across wrap-around and the outage
        // boundary — the O(1) counts must equal an O(n) scan
        let cfg = ScenarioConfig {
            churn: Some(ChurnSpec {
                rate_per_h: 0.07,
                width_frac: 0.6,
            }),
            outage: Some(OutageSpec {
                start_h: 2.0,
                dur_h: 5.0,
                frac: 0.25,
            }),
            wave: Some(WaveSpec { duty: 0.4 }),
            horizon_h: 0.0,
        };
        let n = 173; // deliberately not a multiple of 24
        let sc = Scenario::new(&cfg, n).unwrap();
        for t in [0.0, 1.5, 2.0, 3.25, 6.9, 7.0, 13.0, 40.5] {
            let v = sc.view(t);
            let brute = brute_eligible(&v, n);
            assert_eq!(v.eligible_count(), brute.len(), "t={t}");
            let brute_outage: usize = (0..n)
                .filter(|&ci| {
                    v.in_churn_window(ci) && v.wave_awake(ci) && v.in_outage(ci)
                })
                .count();
            assert_eq!(v.outage_excluded_count(), brute_outage, "t={t}");
        }
    }

    #[test]
    fn same_seed_same_times_give_identical_sequences() {
        let cfg = ScenarioConfig {
            churn: Some(ChurnSpec {
                rate_per_h: 0.2,
                width_frac: 0.8,
            }),
            outage: Some(OutageSpec {
                start_h: 1.0,
                dur_h: 3.0,
                frac: 0.5,
            }),
            ..ScenarioConfig::default()
        };
        let a = Scenario::new(&cfg, 1000).unwrap();
        let b = Scenario::new(&cfg, 1000).unwrap();
        for i in 0..20 {
            let t = i as f64 * 0.37;
            assert_eq!(a.view(t), b.view(t), "t={t}");
            assert_eq!(a.churn_offset_raw(t), b.churn_offset_raw(t));
        }
    }
}

//! # fedselect
//!
//! A production-shaped reproduction of *Federated Select: A Primitive for
//! Communication- and Memory-Efficient Federated Learning* (Charles et al.,
//! 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) is the federated coordinator: the `FEDSELECT`
//! primitive and its three system implementations, sparse deselection
//! aggregation (plain / secure-masked / IBLT), server optimizers, the round
//! driver of the paper's Algorithm 2 with an event-driven round engine
//! (pluggable synchronous / over-select / buffered-async aggregation on the
//! simulated clock), a pipelined round executor ([`exec`]: per-client
//! fetch→train→merge tasks over a bounded worker pool, `--exec strict|fast`
//! merge-order contract, key-striped sharded aggregation),
//! a cohort [`scheduler`] (device-profile and trace-driven
//! fleets, pluggable selection policies, simulated round wall-time), a
//! cross-round client slice [`cache`] (versioned pieces, delta fetch
//! plans, budgeted on-device caches), a multi-tenant [`tenancy`]
//! coordinator (N concurrent jobs arbitrated over one shared fleet, CDN,
//! and client cache budget),
//! synthetic federated datasets, a CDN substrate with a PIR cost model, and
//! the experiment harness regenerating every table and figure of the
//! paper's §5.
//!
//! Layers 2 and 1 (JAX models and Pallas kernels) are compiled once at build
//! time (`make artifacts`) into HLO-text artifacts which [`runtime`] loads
//! and executes through the PJRT C API. Python is never on the request path.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use fedselect::prelude::*;
//!
//! let cfg = TrainConfig::logreg_default(512, 64);
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final recall@5 = {:.3}", report.final_eval.metric);
//! ```

pub mod aggregation;
pub mod baselines;
pub mod cache;
pub mod cdn;
pub mod clients;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod fedselect;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod native;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod scheduler;
pub mod tenancy;
pub mod tensor;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::aggregation::{
        AggMode, Aggregator, ShardedAccumulator, SparseAccumulator, TouchedKeys,
    };
    pub use crate::cache::{CacheShare, ClientCache, EvictPolicy, FleetCaches, VersionClock};
    pub use crate::clients::Engine;
    pub use crate::config::{DatasetConfig, EngineKind, EvalConfig, TrainConfig};
    pub use crate::coordinator::{
        AggregationMode, RoundEngine, RoundRecord, TrainReport, Trainer,
    };
    pub use crate::data::FederatedDataset;
    pub use crate::error::{Error, Result};
    pub use crate::exec::ExecMode;
    pub use crate::fedselect::{
        ClientKeys, KeyPolicy, RoundSession, SliceBundle, SliceImpl, SliceService,
    };
    pub use crate::fleet::{
        ChurnSpec, OutageSpec, Scenario, ScenarioConfig, TouchedState, WaveSpec,
    };
    pub use crate::model::{ModelArch, ParamStore, SelectSpec};
    pub use crate::obs::{
        HealthConfig, HealthMonitor, HealthReport, Incident, LogLevel, MetricsRegistry,
        NullRecorder, ObsConfig, Recorder, SloRule, TraceEvent, TraceFormat,
    };
    pub use crate::optim::ServerOpt;
    pub use crate::scheduler::{
        CompletionEvent, DeviceProfile, Fleet, FleetKind, SchedPolicy, Scheduler,
        SelectionPolicy, SimClock,
    };
    pub use crate::tenancy::{
        ArbiterPolicy, Coordinator, FleetArbiter, JobRegistry, JobSpec, MultiReport,
    };
    pub use crate::tensor::rng::Rng;
}

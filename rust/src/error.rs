//! Crate-wide error type.

use std::fmt;

/// Unified error for the fedselect coordinator.
#[derive(Debug)]
pub enum Error {
    /// Configuration rejected by validation.
    Config(String),
    /// An artifact referenced by name is missing from the manifest, or the
    /// artifacts directory has not been built (`make artifacts`).
    Artifact(String),
    /// Shape/ordering mismatch between manifest and supplied buffers.
    Shape(String),
    /// PJRT / XLA failure.
    Xla(String),
    /// Dataset construction failure.
    Data(String),
    /// I/O failure.
    Io(std::io::Error),
    /// JSON (de)serialization failure.
    Json(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(e) => write!(f, "json error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[macro_export]
macro_rules! bail_config {
    ($($arg:tt)*) => { return Err($crate::error::Error::Config(format!($($arg)*))) };
}

//! Metrics plumbing: aggregate statistics, CSV emission, markdown tables
//! for EXPERIMENTS.md, and the per-tier fleet summary of a training run.
//!
//! Since the observability PR the summaries are *registry-backed*: the
//! trainer folds every [`RoundRecord`] into a [`MetricsRegistry`] as it
//! runs ([`record_round`]), and [`fleet_summary_from`] /
//! [`multitenant_summary_from`] render their tables by reading the
//! canonical [`keys`] back out instead of re-deriving the tallies from the
//! round ledgers. The ledger-walking entry points ([`fleet_summary`],
//! [`multitenant_summary`]) are kept as thin compositions — build the
//! registry, render from it — so both paths stay byte-identical by
//! construction (test-enforced below).

use std::fmt::Write as _;
use std::path::Path;

use crate::coordinator::RoundRecord;
use crate::error::Result;
use crate::obs::MetricsRegistry;
use crate::scheduler::Fleet;

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Human-readable byte count.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable byte rate.
pub fn human_rate(bps: f64) -> String {
    format!("{}/s", human_bytes(bps.max(0.0) as u64))
}

/// Canonical metric names. Everything the trainer and the multi-tenant
/// coordinator publish into their [`MetricsRegistry`] lives under these
/// keys; summaries, the bench harness, and `trace_report` read them back
/// instead of recomputing from ledgers.
pub mod keys {
    /// Counter: rounds folded into the registry.
    pub const ROUNDS: &str = "rounds";
    /// Counter: updates merged into the server model.
    pub const COMPLETED: &str = "clients.completed";
    /// Counter: post-fetch dropouts.
    pub const DROPPED: &str = "clients.dropped";
    /// Counter: computed updates never merged (over-selected stragglers,
    /// staleness-bound buffered discards).
    pub const DISCARDED: &str = "clients.discarded";
    /// Counter: landed updates pushed back in flight by `--committee-defer`.
    pub const DEFERRED: &str = "clients.deferred";
    /// Counter: server->client wire bytes (post-cache when `--cache`).
    pub const DOWN_BYTES: &str = "comm.down_bytes";
    /// Counter: client->server wire bytes.
    pub const UP_BYTES: &str = "comm.up_bytes";
    /// Counter: client-cache entries evicted under byte budgets.
    pub const CACHE_EVICTIONS: &str = "cache.evictions";
    /// Counter: version-fresh pieces refetched past `--max-stale-rounds`.
    pub const CACHE_STALE_REFRESHES: &str = "cache.stale_refreshes";
    /// Gauge: accumulated simulated fleet time (sum of `sim_round_s`).
    pub const SIM_TOTAL_S: &str = "sim.total_s";
    /// Gauge: clients eligible for selection in the latest round (fleet
    /// size minus scenario churn/outage exclusions).
    pub const FLEET_ELIGIBLE: &str = "fleet.eligible";
    /// Gauge: ever-selected clients with resident touched-state after the
    /// latest round.
    pub const FLEET_CLIENTS_TOUCHED: &str = "fleet.clients_touched";
    /// Gauge: resident scheduler-state bytes (touched entries + on-device
    /// caches + trace profile rows) after the latest round.
    pub const FLEET_RESIDENT_BYTES: &str = "fleet.resident_bytes";
    /// Counter: clients entering the eligible population across churn
    /// window boundaries.
    pub const FLEET_ARRIVALS: &str = "fleet.arrivals";
    /// Counter: clients leaving the eligible population across churn
    /// window boundaries.
    pub const FLEET_DEPARTURES: &str = "fleet.departures";
    /// Counter: client-rounds excluded by regional outage windows.
    pub const FLEET_OUTAGE_EXCLUDED: &str = "fleet.outage_excluded";
    /// Counter vec (index = fleet tier): merged updates.
    pub const TIER_COMPLETED: &str = "tier.completed";
    /// Counter vec (index = fleet tier): post-fetch dropouts.
    pub const TIER_DROPPED: &str = "tier.dropped";
    /// Counter vec (index = fleet tier): discarded updates.
    pub const TIER_DISCARDED: &str = "tier.discarded";
    /// Counter vec (index = fleet tier): download bytes.
    pub const TIER_DOWN_BYTES: &str = "tier.down_bytes";
    /// Counter vec (index = fleet tier): client-cache piece hits.
    pub const TIER_CACHE_HITS: &str = "tier.cache_hits";
    /// Counter vec (index = fleet tier): client-cache piece lookups.
    pub const TIER_CACHE_LOOKUPS: &str = "tier.cache_lookups";
    /// Counter vec (index = job): rounds run under the arbiter.
    pub const JOB_ROUNDS: &str = "job.rounds";
    /// Counter vec (index = job): download wire bytes.
    pub const JOB_DOWN_BYTES: &str = "job.down_bytes";
    /// Counter vec (index = job): upload wire bytes.
    pub const JOB_UP_BYTES: &str = "job.up_bytes";
    /// Counter vec (index = job): client-cache piece hits.
    pub const JOB_CACHE_HITS: &str = "job.cache_hits";
    /// Counter vec (index = job): client-cache piece lookups.
    pub const JOB_CACHE_LOOKUPS: &str = "job.cache_lookups";
    /// Counter: health-monitor incidents opened.
    pub const HEALTH_INCIDENTS: &str = "health.incidents";
    /// Counter: health-monitor incidents opened at `critical` severity
    /// (SLO breaches).
    pub const HEALTH_CRITICAL: &str = "health.critical";
    /// Counter: health-monitor incidents resolved (a clean round closed
    /// them).
    pub const HEALTH_RESOLVED: &str = "health.resolved";
    /// Counter: (incident, round) pairs in violation — every open/update
    /// lifecycle step.
    pub const HEALTH_VIOLATION_ROUNDS: &str = "health.violation_rounds";
    /// Gauge: incidents currently open after the latest round.
    pub const HEALTH_OPEN: &str = "health.open";

    /// Gauge vec (index = job): simulated device-seconds consumed on fleet
    /// tier `tier`.
    pub fn job_busy_key(tier: usize) -> String {
        format!("job.busy_s.t{tier}")
    }
}

/// Fold one round ledger into the registry under the canonical [`keys`].
/// The trainer calls this after every round; [`fleet_registry`] replays a
/// recorded trajectory through it.
pub fn record_round(reg: &mut MetricsRegistry, r: &RoundRecord) {
    reg.counter_add(keys::ROUNDS, 1);
    reg.counter_add(keys::COMPLETED, r.completed as u64);
    reg.counter_add(keys::DROPPED, r.dropped as u64);
    reg.counter_add(keys::DISCARDED, r.discarded_clients as u64);
    reg.counter_add(keys::DEFERRED, r.deferrals as u64);
    reg.counter_add(keys::DOWN_BYTES, r.comm.down_bytes);
    reg.counter_add(keys::UP_BYTES, r.up_bytes);
    reg.counter_add(keys::CACHE_EVICTIONS, r.cache_evictions);
    reg.counter_add(keys::CACHE_STALE_REFRESHES, r.cache_stale_refreshes);
    reg.gauge_add(keys::SIM_TOTAL_S, r.sim_round_s);
    reg.gauge_set(keys::FLEET_ELIGIBLE, r.eligible as f64);
    reg.gauge_set(keys::FLEET_CLIENTS_TOUCHED, r.clients_touched as f64);
    reg.gauge_set(keys::FLEET_RESIDENT_BYTES, r.resident_bytes as f64);
    reg.counter_add(keys::FLEET_ARRIVALS, r.arrivals as u64);
    reg.counter_add(keys::FLEET_DEPARTURES, r.departures as u64);
    reg.counter_add(keys::FLEET_OUTAGE_EXCLUDED, r.outage_excluded as u64);
    for (t, &v) in r.tier_completed.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_COMPLETED, t, v as u64);
    }
    for (t, &v) in r.tier_dropped.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_DROPPED, t, v as u64);
    }
    for (t, &v) in r.tier_discarded.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_DISCARDED, t, v as u64);
    }
    for (t, &v) in r.tier_down_bytes.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_DOWN_BYTES, t, v);
    }
    for (t, &v) in r.tier_cache_hits.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_CACHE_HITS, t, v);
    }
    for (t, &v) in r.tier_cache_lookups.iter().enumerate() {
        reg.counter_vec_add(keys::TIER_CACHE_LOOKUPS, t, v);
    }
}

/// Replay a recorded trajectory into a fresh registry (for summaries over
/// reports loaded without a live trainer).
pub fn fleet_registry(rounds: &[RoundRecord]) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    for r in rounds {
        record_round(&mut reg, r);
    }
    reg
}

/// Per-tier summary of a scheduled training run: population, device
/// characteristics, and selection/completion/download tallies across the
/// recorded rounds.
pub fn fleet_summary(fleet: &Fleet, rounds: &[RoundRecord]) -> Table {
    fleet_summary_from(fleet, &fleet_registry(rounds))
}

/// Render the fleet summary from a live registry (the trainer's own, via
/// `Trainer::metrics`) instead of re-walking round ledgers. Byte-identical
/// to [`fleet_summary`] over the same trajectory.
pub fn fleet_summary_from(fleet: &Fleet, reg: &MetricsRegistry) -> Table {
    let tiers = fleet.num_tiers();
    let sizes = fleet.tier_sizes();
    let at = |name: &str, t: usize| reg.counter_vec(name).get(t).copied().unwrap_or(0);
    let mut table = Table::new(
        &format!("Fleet summary ({})", fleet.kind),
        &[
            "tier", "clients", "mem_frac", "mean_down", "hazard", "selected", "completed",
            "dropped", "discarded", "down_total", "cache_hit%",
        ],
    );
    // One streaming pass over the lazy fleet: per-tier characteristic sums
    // accumulate in client order, so the means are bit-identical to the old
    // eager per-tier filter (same clients, same addition order) without
    // materializing a profile table. Rows then render in canonical
    // ascending-tier order — byte-stable regardless of fetch threading or
    // lazy/eager mode.
    let mut down_sum = vec![0.0f64; tiers];
    let mut mem_sum = vec![0.0f64; tiers];
    let mut hazard_sum = vec![0.0f64; tiers];
    for p in fleet.iter_profiles() {
        down_sum[p.tier] += p.down_bps;
        mem_sum[p.tier] += p.mem_frac;
        hazard_sum[p.tier] += p.hazard as f64;
    }
    for t in 0..tiers {
        let n = sizes[t].max(1) as f64;
        let mean_down = down_sum[t] / n;
        let mean_mem = mem_sum[t] / n;
        let mean_hazard = hazard_sum[t] / n;
        let completed = at(keys::TIER_COMPLETED, t);
        let dropped = at(keys::TIER_DROPPED, t);
        let discarded = at(keys::TIER_DISCARDED, t);
        let cache_hits = at(keys::TIER_CACHE_HITS, t);
        let cache_lookups = at(keys::TIER_CACHE_LOOKUPS, t);
        table.push(vec![
            fleet.tier_name(t).to_string(),
            sizes[t].to_string(),
            format!("{mean_mem:.2}"),
            human_rate(mean_down),
            format!("{mean_hazard:.3}"),
            // under buffered aggregation carried merges land in a later
            // round's tally, so this is an approximation there; exact for
            // sync and over-select
            (completed + dropped + discarded).to_string(),
            completed.to_string(),
            dropped.to_string(),
            discarded.to_string(),
            human_bytes(at(keys::TIER_DOWN_BYTES, t)),
            // per-tier client-cache hit rate; "-" when the run never looked
            // a piece up (cache off)
            if cache_lookups > 0 {
                format!("{:.1}", 100.0 * cache_hits as f64 / cache_lookups as f64)
            } else {
                "-".to_string()
            },
        ]);
    }
    table
}

/// Quantile companion to [`fleet_summary_from`]: one row per populated
/// histogram (the per-tier `fetch_latency_s.t*` family and
/// `staleness_rounds`) with p50/p95/p99 from
/// [`crate::obs::Histogram::quantile`]. Returns `None` when no histogram
/// holds observations — in particular for ledger-rebuilt registries
/// ([`fleet_registry`] — `RoundRecord`s carry no per-client latencies),
/// so the existing `fleet_summary` ⇔ `fleet_summary_from` byte-identity
/// is untouched: quantiles render only beside a *live* registry.
pub fn latency_summary_from(reg: &MetricsRegistry) -> Option<Table> {
    let mut table = Table::new(
        "Latency quantiles (simulated)",
        &["series", "n", "mean", "p50", "p95", "p99"],
    );
    for (name, hist) in reg.hists() {
        if hist.count() == 0 {
            continue;
        }
        table.push(vec![
            name.to_string(),
            hist.count().to_string(),
            format!("{:.3}", hist.mean()),
            format!("{:.3}", hist.quantile(0.50)),
            format!("{:.3}", hist.quantile(0.95)),
            format!("{:.3}", hist.quantile(0.99)),
        ]);
    }
    if table.rows.is_empty() {
        None
    } else {
        Some(table)
    }
}

/// Fold a multi-tenant report's per-job usage into a registry under the
/// `job.*` [`keys`] (vec index = position in `report.usage`).
pub fn multitenant_registry(report: &crate::tenancy::MultiReport) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    for (j, u) in report.usage.iter().enumerate() {
        reg.counter_vec_add(keys::JOB_ROUNDS, j, u.rounds as u64);
        reg.counter_vec_add(keys::JOB_DOWN_BYTES, j, u.down_bytes);
        reg.counter_vec_add(keys::JOB_UP_BYTES, j, u.up_bytes);
        reg.counter_vec_add(keys::JOB_CACHE_HITS, j, u.cache_hits);
        reg.counter_vec_add(keys::JOB_CACHE_LOOKUPS, j, u.cache_lookups);
        for (t, &b) in u.tier_busy_s.iter().enumerate() {
            reg.gauge_vec_add(&keys::job_busy_key(t), j, b);
        }
    }
    reg
}

/// Fleet-level rollup of a multi-tenant run: one row per job (rounds run,
/// per-tier simulated device-seconds, wire bytes, client-cache hit rate)
/// plus a fleet totals row; the title carries the tick count, the shared
/// wall-clock, and the overall device utilization.
pub fn multitenant_summary(report: &crate::tenancy::MultiReport) -> Table {
    multitenant_summary_from(report, &multitenant_registry(report))
}

/// Render the multi-tenant rollup from a registry (job names, tier names,
/// and run-shape fields still come from the report; every number comes
/// from the `job.*` keys). Byte-identical to [`multitenant_summary`].
pub fn multitenant_summary_from(
    report: &crate::tenancy::MultiReport,
    reg: &MetricsRegistry,
) -> Table {
    let tiers = &report.tier_names;
    let mut header: Vec<String> = vec!["job".to_string(), "rounds".to_string()];
    for t in tiers {
        header.push(format!("busy_s[{t}]"));
    }
    for col in ["down", "up", "cache_hit%"] {
        header.push(col.to_string());
    }
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!(
            "Fleet utilization ({} jobs / {} ticks / {:.1} sim-s / {:.1}% busy)",
            report.usage.len(),
            report.ticks,
            report.total_sim_s,
            100.0 * report.fleet_utilization,
        ),
        &refs,
    );
    let at = |name: &str, j: usize| reg.counter_vec(name).get(j).copied().unwrap_or(0);
    let mut tot_busy = vec![0.0f64; tiers.len()];
    let mut tot_rounds = 0u64;
    let (mut tot_down, mut tot_up) = (0u64, 0u64);
    let (mut tot_hits, mut tot_lookups) = (0u64, 0u64);
    let hit_pct = |hits: u64, lookups: u64| {
        if lookups > 0 {
            format!("{:.1}", 100.0 * hits as f64 / lookups as f64)
        } else {
            "-".to_string()
        }
    };
    for (j, u) in report.usage.iter().enumerate() {
        let rounds = at(keys::JOB_ROUNDS, j);
        let (down, up) = (at(keys::JOB_DOWN_BYTES, j), at(keys::JOB_UP_BYTES, j));
        let (hits, lookups) = (at(keys::JOB_CACHE_HITS, j), at(keys::JOB_CACHE_LOOKUPS, j));
        let mut row = vec![u.name.clone(), rounds.to_string()];
        for (t, tot) in tot_busy.iter_mut().enumerate() {
            let b = reg
                .gauge_vec(&keys::job_busy_key(t))
                .get(j)
                .copied()
                .unwrap_or(0.0);
            row.push(format!("{b:.1}"));
            *tot += b;
        }
        row.push(human_bytes(down));
        row.push(human_bytes(up));
        row.push(hit_pct(hits, lookups));
        table.push(row);
        tot_rounds += rounds;
        tot_down += down;
        tot_up += up;
        tot_hits += hits;
        tot_lookups += lookups;
    }
    let mut totals = vec!["(fleet)".to_string(), tot_rounds.to_string()];
    for b in &tot_busy {
        totals.push(format!("{b:.1}"));
    }
    totals.push(human_bytes(tot_down));
    totals.push(human_bytes(tot_up));
    totals.push(hit_pct(tot_hits, tot_lookups));
    table.push(totals);
    table
}

/// A simple table that renders to CSV and markdown.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row arity");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Write CSV to `results/<name>.csv` (creating the directory).
    pub fn write_csv(&self, dir: impl AsRef<Path>, name: &str) -> Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Render with aligned columns for terminal output.
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", fmt_row(&self.header, &widths));
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r, &widths));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        assert!(t.to_csv().starts_with("a,b\n1,2"));
        assert!(t.to_markdown().contains("| 1 | 2 |"));
        assert!(t.to_pretty().contains("demo"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into()]);
    }

    fn sample_record() -> RoundRecord {
        use crate::fedselect::RoundComm;
        RoundRecord {
            round: 1,
            completed: 5,
            dropped: 1,
            mode: crate::coordinator::AggregationMode::Synchronous,
            discarded_clients: 0,
            mean_staleness: 0.0,
            committees: 0,
            mean_committee_size: 0.0,
            min_committee_size: 0,
            comm: RoundComm::default(),
            up_bytes: 0,
            max_client_mem: 0,
            wall_ms: 0.0,
            merge_stall_ms: 0.0,
            exec_util: 1.0,
            sim_round_s: 2.0,
            tier_completed: vec![2, 2, 1],
            tier_dropped: vec![1, 0, 0],
            tier_discarded: vec![0, 1, 0],
            tier_down_bytes: vec![100, 200, 300],
            tier_cache_hits: vec![3, 0, 0],
            tier_cache_lookups: vec![4, 0, 0],
            cache_evictions: 0,
            cache_stale_refreshes: 0,
            deferrals: 0,
            eligible: 30,
            arrivals: 2,
            departures: 1,
            outage_excluded: 3,
            clients_touched: 6,
            resident_bytes: 480,
        }
    }

    #[test]
    fn fleet_summary_tallies_tiers() {
        use crate::scheduler::FleetKind;
        let fleet = Fleet::generate(FleetKind::Tiered3, 30, 7, 0.25).unwrap();
        let rec = sample_record();
        let t = fleet_summary(&fleet, &[rec.clone(), rec]);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][0], "low-end");
        assert_eq!(t.rows[0][6], "4"); // completed: 2 rounds x 2
        assert_eq!(t.rows[0][7], "2"); // dropped
        assert_eq!(t.rows[1][8], "2"); // discarded (mid tier)
        assert_eq!(t.rows[1][5], "6"); // selected = completed+dropped+discarded
        assert_eq!(t.rows[0][10], "75.0"); // cache hit%: 6 hits / 8 lookups
        assert_eq!(t.rows[1][10], "-"); // no lookups in this tier
        assert!(human_rate(2e6).ends_with("/s"));
    }

    #[test]
    fn record_round_folds_scalars_and_tiers() {
        let rec = sample_record();
        let mut reg = MetricsRegistry::new();
        record_round(&mut reg, &rec);
        record_round(&mut reg, &rec);
        assert_eq!(reg.counter(keys::ROUNDS), 2);
        assert_eq!(reg.counter(keys::COMPLETED), 10);
        assert_eq!(reg.counter(keys::DROPPED), 2);
        assert_eq!(reg.counter_vec(keys::TIER_DOWN_BYTES), &[200, 400, 600]);
        assert!((reg.gauge(keys::SIM_TOTAL_S) - 4.0).abs() < 1e-12);
        // fleet-scale gauges hold the latest round's value; arrival /
        // departure / outage tallies accumulate
        assert_eq!(reg.gauge(keys::FLEET_ELIGIBLE), 30.0);
        assert_eq!(reg.gauge(keys::FLEET_CLIENTS_TOUCHED), 6.0);
        assert_eq!(reg.gauge(keys::FLEET_RESIDENT_BYTES), 480.0);
        assert_eq!(reg.counter(keys::FLEET_ARRIVALS), 4);
        assert_eq!(reg.counter(keys::FLEET_DEPARTURES), 2);
        assert_eq!(reg.counter(keys::FLEET_OUTAGE_EXCLUDED), 6);
        // and the registry-rendered table matches the ledger-walking path
        use crate::scheduler::FleetKind;
        let fleet = Fleet::generate(FleetKind::Tiered3, 30, 7, 0.25).unwrap();
        let recs = [rec.clone(), rec];
        let a = fleet_summary(&fleet, &recs);
        let b = fleet_summary_from(&fleet, &fleet_registry(&recs));
        assert_eq!(a.to_pretty(), b.to_pretty());
    }

    #[test]
    fn fleet_summary_rows_follow_canonical_tier_order() {
        use crate::scheduler::FleetKind;
        // Rows must come out in ascending tier-index order and render
        // byte-identically on repeated calls, independent of how the fleet
        // was touched beforehand (lazy generation has no iteration-order
        // state to leak).
        let fleet = Fleet::generate(FleetKind::Tiered3, 60, 11, 0.25).unwrap();
        let _ = fleet.profile(59); // touch out of order
        let rec = sample_record();
        let a = fleet_summary(&fleet, &[rec.clone()]);
        for (t, row) in a.rows.iter().enumerate() {
            assert_eq!(row[0], fleet.tier_name(t));
        }
        let b = fleet_summary(&fleet, &[rec]);
        assert_eq!(a.to_pretty(), b.to_pretty());
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn latency_summary_renders_only_populated_histograms() {
        let mut reg = MetricsRegistry::new();
        assert!(latency_summary_from(&reg).is_none());
        reg.register_hist("fetch_latency_s.t0", &[1.0, 2.0]);
        // Registered but empty histograms render nothing.
        assert!(latency_summary_from(&reg).is_none());
        reg.observe("fetch_latency_s.t0", 0.5);
        reg.observe("fetch_latency_s.t0", 1.5);
        let t = latency_summary_from(&reg).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], "fetch_latency_s.t0");
        assert_eq!(t.rows[0][1], "2");
        assert_eq!(t.rows[0][3], "1.000"); // p50 at the first bucket edge
        // Ledger-rebuilt registries carry no histograms (RoundRecords have
        // no per-client latencies), so the fleet_summary byte-identity
        // with the ledger path is untouched by the quantile table.
        let ledger_reg = fleet_registry(&[sample_record()]);
        assert!(latency_summary_from(&ledger_reg).is_none());
    }

    fn sample_multireport() -> crate::tenancy::MultiReport {
        use crate::tenancy::{JobUsage, MultiReport};
        let usage = |name: &str, busy: [f64; 2], down: u64, hits: u64, lookups: u64| JobUsage {
            id: 0,
            name: name.to_string(),
            rounds: 4,
            tier_busy_s: busy.to_vec(),
            down_bytes: down,
            up_bytes: 10,
            cache_hits: hits,
            cache_lookups: lookups,
        };
        MultiReport {
            reports: Vec::new(),
            usage: vec![
                usage("a", [1.0, 2.0], 100, 3, 4),
                usage("b", [0.5, 0.25], 200, 0, 0),
            ],
            ticks: 4,
            grants: vec![4, 4],
            total_sim_s: 10.0,
            fleet_utilization: 0.5,
            tier_names: vec!["low".to_string(), "high".to_string()],
        }
    }

    #[test]
    fn multitenant_summary_rolls_up_jobs_and_fleet_totals() {
        let report = sample_multireport();
        let t = multitenant_summary(&report);
        assert_eq!(t.header[2], "busy_s[low]");
        assert_eq!(t.rows.len(), 3); // 2 jobs + fleet totals
        assert_eq!(t.rows[0][2], "1.0");
        assert_eq!(t.rows[2][0], "(fleet)");
        assert_eq!(t.rows[2][1], "8"); // total rounds
        assert_eq!(t.rows[2][2], "1.5"); // summed low-tier busy time
        assert_eq!(t.rows[0][6], "75.0");
        assert_eq!(t.rows[1][6], "-");
        assert_eq!(t.rows[2][6], "75.0"); // fleet-wide hit rate
        assert!(t.title.contains("50.0% busy"), "{}", t.title);
    }

    #[test]
    fn multitenant_registry_render_matches_ledger_path() {
        let report = sample_multireport();
        let reg = multitenant_registry(&report);
        assert_eq!(reg.counter_vec(keys::JOB_ROUNDS), &[4, 4]);
        assert_eq!(reg.gauge_vec(&keys::job_busy_key(1)), &[2.0, 0.25]);
        let a = multitenant_summary(&report);
        let b = multitenant_summary_from(&report, &reg);
        assert_eq!(a.to_pretty(), b.to_pretty());
    }
}

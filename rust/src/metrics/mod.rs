//! Metrics plumbing: aggregate statistics, CSV emission, markdown tables
//! for EXPERIMENTS.md, and the per-tier fleet summary of a training run.

use std::fmt::Write as _;
use std::path::Path;

use crate::coordinator::RoundRecord;
use crate::error::Result;
use crate::scheduler::Fleet;

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Human-readable byte count.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable byte rate.
pub fn human_rate(bps: f64) -> String {
    format!("{}/s", human_bytes(bps.max(0.0) as u64))
}

/// Per-tier summary of a scheduled training run: population, device
/// characteristics, and selection/completion/download tallies across the
/// recorded rounds.
pub fn fleet_summary(fleet: &Fleet, rounds: &[RoundRecord]) -> Table {
    let tiers = fleet.num_tiers();
    let sizes = fleet.tier_sizes();
    let mut completed = vec![0usize; tiers];
    let mut dropped = vec![0usize; tiers];
    let mut discarded = vec![0usize; tiers];
    let mut down = vec![0u64; tiers];
    let mut cache_hits = vec![0u64; tiers];
    let mut cache_lookups = vec![0u64; tiers];
    for r in rounds {
        for t in 0..tiers {
            completed[t] += r.tier_completed.get(t).copied().unwrap_or(0);
            dropped[t] += r.tier_dropped.get(t).copied().unwrap_or(0);
            discarded[t] += r.tier_discarded.get(t).copied().unwrap_or(0);
            down[t] += r.tier_down_bytes.get(t).copied().unwrap_or(0);
            cache_hits[t] += r.tier_cache_hits.get(t).copied().unwrap_or(0);
            cache_lookups[t] += r.tier_cache_lookups.get(t).copied().unwrap_or(0);
        }
    }
    let mut table = Table::new(
        &format!("Fleet summary ({})", fleet.kind),
        &[
            "tier", "clients", "mem_frac", "mean_down", "hazard", "selected", "completed",
            "dropped", "discarded", "down_total", "cache_hit%",
        ],
    );
    for t in 0..tiers {
        let profiles: Vec<_> = fleet.profiles.iter().filter(|p| p.tier == t).collect();
        let n = profiles.len().max(1) as f64;
        let mean_down = profiles.iter().map(|p| p.down_bps).sum::<f64>() / n;
        let mean_mem = profiles.iter().map(|p| p.mem_frac).sum::<f64>() / n;
        let mean_hazard = profiles.iter().map(|p| p.hazard as f64).sum::<f64>() / n;
        table.push(vec![
            fleet.tier_name(t).to_string(),
            sizes[t].to_string(),
            format!("{mean_mem:.2}"),
            human_rate(mean_down),
            format!("{mean_hazard:.3}"),
            // under buffered aggregation carried merges land in a later
            // round's tally, so this is an approximation there; exact for
            // sync and over-select
            (completed[t] + dropped[t] + discarded[t]).to_string(),
            completed[t].to_string(),
            dropped[t].to_string(),
            discarded[t].to_string(),
            human_bytes(down[t]),
            // per-tier client-cache hit rate; "-" when the run never looked
            // a piece up (cache off)
            if cache_lookups[t] > 0 {
                format!("{:.1}", 100.0 * cache_hits[t] as f64 / cache_lookups[t] as f64)
            } else {
                "-".to_string()
            },
        ]);
    }
    table
}

/// Fleet-level rollup of a multi-tenant run: one row per job (rounds run,
/// per-tier simulated device-seconds, wire bytes, client-cache hit rate)
/// plus a fleet totals row; the title carries the tick count, the shared
/// wall-clock, and the overall device utilization.
pub fn multitenant_summary(report: &crate::tenancy::MultiReport) -> Table {
    let tiers = &report.tier_names;
    let mut header: Vec<String> = vec!["job".to_string(), "rounds".to_string()];
    for t in tiers {
        header.push(format!("busy_s[{t}]"));
    }
    for col in ["down", "up", "cache_hit%"] {
        header.push(col.to_string());
    }
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!(
            "Fleet utilization ({} jobs / {} ticks / {:.1} sim-s / {:.1}% busy)",
            report.usage.len(),
            report.ticks,
            report.total_sim_s,
            100.0 * report.fleet_utilization,
        ),
        &refs,
    );
    let mut tot_busy = vec![0.0f64; tiers.len()];
    let mut tot_rounds = 0usize;
    let (mut tot_down, mut tot_up) = (0u64, 0u64);
    let (mut tot_hits, mut tot_lookups) = (0u64, 0u64);
    let hit_pct = |hits: u64, lookups: u64| {
        if lookups > 0 {
            format!("{:.1}", 100.0 * hits as f64 / lookups as f64)
        } else {
            "-".to_string()
        }
    };
    for u in &report.usage {
        let mut row = vec![u.name.clone(), u.rounds.to_string()];
        for (t, &b) in u.tier_busy_s.iter().enumerate() {
            row.push(format!("{b:.1}"));
            if t < tot_busy.len() {
                tot_busy[t] += b;
            }
        }
        row.push(human_bytes(u.down_bytes));
        row.push(human_bytes(u.up_bytes));
        row.push(hit_pct(u.cache_hits, u.cache_lookups));
        table.push(row);
        tot_rounds += u.rounds;
        tot_down += u.down_bytes;
        tot_up += u.up_bytes;
        tot_hits += u.cache_hits;
        tot_lookups += u.cache_lookups;
    }
    let mut totals = vec!["(fleet)".to_string(), tot_rounds.to_string()];
    for b in &tot_busy {
        totals.push(format!("{b:.1}"));
    }
    totals.push(human_bytes(tot_down));
    totals.push(human_bytes(tot_up));
    totals.push(hit_pct(tot_hits, tot_lookups));
    table.push(totals);
    table
}

/// A simple table that renders to CSV and markdown.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row arity");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Write CSV to `results/<name>.csv` (creating the directory).
    pub fn write_csv(&self, dir: impl AsRef<Path>, name: &str) -> Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Render with aligned columns for terminal output.
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", fmt_row(&self.header, &widths));
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r, &widths));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        assert!(t.to_csv().starts_with("a,b\n1,2"));
        assert!(t.to_markdown().contains("| 1 | 2 |"));
        assert!(t.to_pretty().contains("demo"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn fleet_summary_tallies_tiers() {
        use crate::fedselect::RoundComm;
        use crate::scheduler::FleetKind;
        let fleet = Fleet::generate(FleetKind::Tiered3, 30, 7, 0.25).unwrap();
        let rec = RoundRecord {
            round: 1,
            completed: 5,
            dropped: 1,
            mode: crate::coordinator::AggregationMode::Synchronous,
            discarded_clients: 0,
            mean_staleness: 0.0,
            committees: 0,
            mean_committee_size: 0.0,
            min_committee_size: 0,
            comm: RoundComm::default(),
            up_bytes: 0,
            max_client_mem: 0,
            wall_ms: 0.0,
            sim_round_s: 2.0,
            tier_completed: vec![2, 2, 1],
            tier_dropped: vec![1, 0, 0],
            tier_discarded: vec![0, 1, 0],
            tier_down_bytes: vec![100, 200, 300],
            tier_cache_hits: vec![3, 0, 0],
            tier_cache_lookups: vec![4, 0, 0],
            cache_evictions: 0,
            cache_stale_refreshes: 0,
            deferrals: 0,
        };
        let t = fleet_summary(&fleet, &[rec.clone(), rec]);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][0], "low-end");
        assert_eq!(t.rows[0][6], "4"); // completed: 2 rounds x 2
        assert_eq!(t.rows[0][7], "2"); // dropped
        assert_eq!(t.rows[1][8], "2"); // discarded (mid tier)
        assert_eq!(t.rows[1][5], "6"); // selected = completed+dropped+discarded
        assert_eq!(t.rows[0][10], "75.0"); // cache hit%: 6 hits / 8 lookups
        assert_eq!(t.rows[1][10], "-"); // no lookups in this tier
        assert!(human_rate(2e6).ends_with("/s"));
    }

    #[test]
    fn multitenant_summary_rolls_up_jobs_and_fleet_totals() {
        use crate::tenancy::{JobUsage, MultiReport};
        let usage = |name: &str, busy: [f64; 2], down: u64, hits: u64, lookups: u64| JobUsage {
            id: 0,
            name: name.to_string(),
            rounds: 4,
            tier_busy_s: busy.to_vec(),
            down_bytes: down,
            up_bytes: 10,
            cache_hits: hits,
            cache_lookups: lookups,
        };
        let report = MultiReport {
            reports: Vec::new(),
            usage: vec![
                usage("a", [1.0, 2.0], 100, 3, 4),
                usage("b", [0.5, 0.25], 200, 0, 0),
            ],
            ticks: 4,
            grants: vec![4, 4],
            total_sim_s: 10.0,
            fleet_utilization: 0.5,
            tier_names: vec!["low".to_string(), "high".to_string()],
        };
        let t = multitenant_summary(&report);
        assert_eq!(t.header[2], "busy_s[low]");
        assert_eq!(t.rows.len(), 3); // 2 jobs + fleet totals
        assert_eq!(t.rows[0][2], "1.0");
        assert_eq!(t.rows[2][0], "(fleet)");
        assert_eq!(t.rows[2][1], "8"); // total rounds
        assert_eq!(t.rows[2][2], "1.5"); // summed low-tier busy time
        assert_eq!(t.rows[0][6], "75.0");
        assert_eq!(t.rows[1][6], "-");
        assert_eq!(t.rows[2][6], "75.0"); // fleet-wide hit rate
        assert!(t.title.contains("50.0% busy"), "{}", t.title);
    }
}

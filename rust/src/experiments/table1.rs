//! Table 1 regeneration: dataset statistics (clients / examples per split)
//! for the synthetic Stack Overflow and EMNIST substitutes.

use crate::coordinator::build_dataset;
use crate::config::DatasetConfig;
use crate::data::bow::BowConfig;
use crate::data::images::ImageConfig;
use crate::data::text::TextConfig;
use crate::error::Result;
use crate::metrics::Table;

use super::ExpOptions;

pub fn run(opts: &ExpOptions) -> Result<Vec<Table>> {
    let scale = if opts.quick { 1 } else { 4 };
    let datasets = vec![
        DatasetConfig::Bow(
            BowConfig::new(8192, 50).with_clients(100 * scale, 10 * scale, 20 * scale),
        ),
        DatasetConfig::Image(ImageConfig::new(62).with_clients(75 * scale, 15 * scale)),
        DatasetConfig::Text(
            TextConfig::new(2048, 20).with_clients(75 * scale, 8 * scale, 15 * scale),
        ),
    ];
    let mut t = Table::new(
        "Dataset statistics (Table 1 analogue)",
        &[
            "dataset",
            "train_clients",
            "train_examples",
            "val_clients",
            "val_examples",
            "test_clients",
            "test_examples",
        ],
    );
    for d in &datasets {
        let s = build_dataset(d).stats();
        t.push(vec![
            s.name,
            s.train_clients.to_string(),
            s.train_examples.to_string(),
            s.val_clients.to_string(),
            s.val_examples.to_string(),
            s.test_clients.to_string(),
            s.test_examples.to_string(),
        ]);
    }
    Ok(vec![t])
}

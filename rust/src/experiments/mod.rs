//! Experiment registry: regenerate every table and figure of the paper's §5.
//!
//! `fedselect experiment --id <id> [--quick]` runs the workload and prints
//! the same rows/series the paper reports, writing CSVs to `results/`.
//! Absolute numbers differ (synthetic data, scaled dimensions — DESIGN.md
//! §4); the *shape* — who wins, by what factor, where curves cross — is the
//! reproduction target.
//!
//! | id | paper artifact |
//! |---|---|
//! | `table1` | dataset statistics |
//! | `fig2`   | tag-prediction recall@5 vs rounds, vary (n, m) |
//! | `fig3`   | final recall + relative model size vs (n, m) |
//! | `fig4`   | key-strategy ablation (Top / Random / RandomTop) |
//! | `fig5`   | EMNIST accuracy vs rounds (CNN + 2NN, random keys) |
//! | `table2` | CNN final accuracy ± std vs m |
//! | `table3` | 2NN final accuracy ± std vs m |
//! | `fig6`   | fixed-per-round vs independent random keys |
//! | `fig7`   | transformer: structured / random / mixed frontier |
//! | `sched`  | (beyond the paper) cohort-scheduler policy × fleet sweep |
//! | `async`  | (beyond the paper) aggregation-mode × fleet sweep on the round engine |
//! | `secagg` | (beyond the paper) secure-aggregation committee size × mode × fleet sweep |
//! | `cache`  | (beyond the paper) slice-cache eviction policy × budget × fleet sweep |
//! | `multitenant` | (beyond the paper) N concurrent jobs on one shared fleet vs isolated runs |
//! | `scale`  | (beyond the paper) lazy-fleet scale sweep 10k -> 10M clients + churn/outage tie-in |
//! | `health` | (beyond the paper) SLO/anomaly monitor vs injected outage/churn/flaky faults |

mod async_agg;
mod cache;
mod emnist;
mod health;
mod logreg;
mod multitenant;
mod scale;
mod scheduler;
mod secagg;
mod table1;
mod transformer;

use crate::config::EngineKind;
use crate::coordinator::{TrainReport, Trainer};
use crate::error::{Error, Result};
use crate::metrics::Table;

/// Shared knobs for a regeneration run.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub quick: bool,
    pub engine: EngineKind,
    pub out_dir: String,
    pub trials: usize,
}

impl ExpOptions {
    pub fn new(quick: bool, engine: EngineKind) -> Self {
        ExpOptions {
            quick,
            engine,
            out_dir: "results".to_string(),
            trials: if quick { 1 } else { 2 },
        }
    }
}

/// All known experiment ids.
pub const ALL_IDS: &[&str] = &[
    "table1", "fig2", "fig3", "fig4", "fig5", "table2", "table3", "fig6", "fig7", "sched",
    "async", "secagg", "cache", "multitenant", "scale", "health",
];

/// Run one experiment by id; returns the rendered tables (already written
/// as CSV to `opts.out_dir`).
pub fn run(id: &str, opts: &ExpOptions) -> Result<Vec<Table>> {
    let tables = match id {
        "table1" => table1::run(opts)?,
        "fig2" => logreg::fig2(opts)?,
        "fig3" => logreg::fig3(opts)?,
        "fig4" => logreg::fig4(opts)?,
        "fig5" => emnist::fig5(opts)?,
        "table2" => emnist::table2(opts)?,
        "table3" => emnist::table3(opts)?,
        "fig6" => emnist::fig6(opts)?,
        "fig7" => transformer::fig7(opts)?,
        "sched" => scheduler::sweep(opts)?,
        "async" => async_agg::sweep(opts)?,
        "secagg" => secagg::sweep(opts)?,
        "cache" => cache::sweep(opts)?,
        "multitenant" => multitenant::run(opts)?,
        "scale" => scale::run(opts)?,
        "health" => health::run(opts)?,
        other => {
            return Err(Error::Config(format!(
                "unknown experiment {other:?}; known: {}",
                ALL_IDS.join(", ")
            )))
        }
    };
    for t in &tables {
        let name = format!("{}_{}", id, slug(&t.title));
        t.write_csv(&opts.out_dir, &name)?;
    }
    Ok(tables)
}

fn slug(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect::<String>()
        .split('_')
        .filter(|p| !p.is_empty())
        .collect::<Vec<_>>()
        .join("_")
}

/// Run `trials` seeds of a config-producing closure; returns reports.
/// (Used by downstream sweeps and the examples; the figure modules manage
/// dataset reuse themselves via `Trainer::with_dataset`.)
pub fn run_trials(
    opts: &ExpOptions,
    mut make: impl FnMut(u64) -> crate::config::TrainConfig,
) -> Result<Vec<TrainReport>> {
    let mut out = Vec::with_capacity(opts.trials);
    for trial in 0..opts.trials {
        let cfg = make(1000 + trial as u64);
        let mut tr = Trainer::new(cfg)?;
        out.push(tr.run()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_an_error() {
        let opts = ExpOptions::new(true, EngineKind::Native);
        assert!(run("fig99", &opts).is_err());
    }

    #[test]
    fn slug_sanitizes() {
        assert_eq!(slug("Recall@5 vs rounds (n=512)"), "recall_5_vs_rounds_n_512");
    }

    #[test]
    fn table1_runs_quick() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_test_results")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = run("table1", &opts).unwrap();
        assert!(!tables.is_empty());
        assert!(!tables[0].rows.is_empty());
    }
}

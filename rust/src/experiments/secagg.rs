//! Secure-aggregation committee sweep: the §4.2 privacy strategy ("apply φ
//! at the client, then dense secure aggregation") composed with every round
//! engine close rule. The whole-cohort protocol only exists behind the
//! synchronous barrier; close-group committees re-key the pairwise masks
//! per goal-count close, so the sweep's axis is effectively *committee
//! size* (the buffered goal count / over-select survivor count) × mode ×
//! fleet. Expected shape: committee runs land within noise of plain
//! training on the model metric, pay the full-model masked-upload bytes the
//! paper's §4.2 predicts (16 B/coordinate here: masked update + masked
//! counts as u64 group elements), and keep the buffered/over-select
//! simulated-time win over the barrier.

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, AggregationMode, Trainer};
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::metrics::Table;
use crate::scheduler::FleetKind;

use super::ExpOptions;

/// One sweep row: display name, secure?, committee?, mode.
fn sweep_rows(cohort: usize) -> Vec<(&'static str, bool, bool, AggregationMode)> {
    vec![
        ("plain", false, false, AggregationMode::Synchronous),
        ("cohort-masks", true, false, AggregationMode::Synchronous),
        ("committee", true, true, AggregationMode::Synchronous),
        (
            "committee",
            true,
            true,
            AggregationMode::OverSelect { extra_frac: 0.5 },
        ),
        (
            "committee",
            true,
            true,
            AggregationMode::Buffered {
                goal_count: (cohort / 3).max(1),
                max_staleness: 4,
            },
        ),
        (
            "committee",
            true,
            true,
            AggregationMode::Buffered {
                goal_count: cohort.saturating_sub(2).max(1),
                max_staleness: 4,
            },
        ),
    ]
}

/// `--id secagg`: committee size × aggregation mode × fleet.
pub fn sweep(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (vocab, m) = (512usize, 128usize);
    let (rounds, cohort, n_clients) = if opts.quick { (8, 10, 60) } else { (16, 20, 120) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut t = Table::new(
        "Secure-aggregation committee sweep",
        &[
            "fleet",
            "mode",
            "secagg",
            "final_metric",
            "committees",
            "mean_committee_size",
            "discarded",
            "up_MB",
            "sim_total_s",
        ],
    );
    for fleet in [FleetKind::Tiered3, FleetKind::FlakyEdge] {
        for (secagg, secure, committee, mode) in sweep_rows(cohort) {
            let mut cfg = TrainConfig::logreg_default(vocab, m);
            cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
            cfg.engine = opts.engine.clone();
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
            cfg.fleet = fleet.clone();
            cfg.agg_mode = mode;
            cfg.secure_agg = secure;
            cfg.secure_committee = committee;
            cfg.seed = 4242;
            let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
            let report = tr.run()?;
            let committees: usize = report.rounds.iter().map(|r| r.committees).sum();
            let members: f64 = report
                .rounds
                .iter()
                .map(|r| r.mean_committee_size * r.committees as f64)
                .sum();
            let mean_size = if committees > 0 {
                members / committees as f64
            } else {
                0.0
            };
            t.push(vec![
                fleet.to_string(),
                mode.to_string(),
                secagg.to_string(),
                format!("{:.4}", report.final_eval.metric),
                committees.to_string(),
                format!("{mean_size:.1}"),
                report.total_discarded.to_string(),
                format!("{:.2}", report.total_up_bytes as f64 / 1e6),
                format!("{:.1}", report.total_sim_s),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    /// The acceptance shape of the secagg experiment: committee-keyed
    /// secure aggregation trains under every close rule, at near-plain
    /// model quality, paying the full-model masked-upload bytes.
    #[test]
    fn committee_secagg_composes_with_every_close_rule() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_secagg_sweep")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = sweep(&opts).unwrap();
        assert_eq!(tables.len(), 1);
        // 2 fleets x 6 rows
        assert_eq!(tables[0].rows.len(), 12);
        for fleet in ["tiered-3", "flaky-edge"] {
            let rows: Vec<&Vec<String>> =
                tables[0].rows.iter().filter(|r| r[0] == fleet).collect();
            assert_eq!(rows.len(), 6);
            let plain: &Vec<String> = rows.iter().find(|r| r[2] == "plain").copied().unwrap();
            let plain_metric: f64 = plain[3].parse().unwrap();
            for r in &rows {
                let gap = (r[3].parse::<f64>().unwrap() - plain_metric).abs();
                assert!(gap < 0.05, "{fleet}/{}/{}: metric gap {gap}", r[1], r[2]);
                if r[2] == "committee" {
                    assert!(
                        r[4].parse::<usize>().unwrap() > 0,
                        "{fleet}/{}: no committees keyed",
                        r[1]
                    );
                    assert!(r[5].parse::<f64>().unwrap() >= 1.0);
                    // masked full-model uploads dominate sliced ones
                    assert!(
                        r[7].parse::<f64>().unwrap() > plain[7].parse::<f64>().unwrap(),
                        "{fleet}/{}: committee up bytes not full-model-sized",
                        r[1]
                    );
                }
            }
        }
    }
}

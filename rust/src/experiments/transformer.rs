//! §5.4 regeneration (Fig. 7): transformer next-word prediction under
//! structured / random / mixed key selection — the accuracy vs client-model-
//! size frontier. Requires the PJRT artifacts (`tf_cu_*`, `tf_eval`).

use crate::config::{DatasetConfig, EngineKind, TrainConfig};
use crate::coordinator::{build_dataset, Trainer};
use crate::data::text::TextConfig;
use crate::error::{Error, Result};
use crate::fedselect::KeyPolicy;
use crate::metrics::{mean_std, Table};
use crate::model::ModelArch;

use super::ExpOptions;

/// The α grid: mv = vocab/α, dh = ffn/α (matches the AOT variant grid).
const ALPHAS: &[usize] = &[16, 8, 4, 2, 1];

pub fn fig7(opts: &ExpOptions) -> Result<Vec<Table>> {
    let dir = match &opts.engine {
        EngineKind::Pjrt { artifacts_dir } => artifacts_dir.clone(),
        EngineKind::Native => "artifacts".to_string(),
    };
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        return Err(Error::Artifact(
            "fig7 (transformer) requires artifacts; run `make artifacts`".into(),
        ));
    }
    let engine = EngineKind::Pjrt {
        artifacts_dir: dir,
    };

    let arch = ModelArch::transformer();
    let (vocab, seq, ffn) = match &arch {
        ModelArch::Transformer { shape, .. } => (shape.vocab, shape.seq, shape.ffn),
        _ => unreachable!(),
    };
    let text = if opts.quick {
        TextConfig::new(vocab, seq).with_clients(24, 4, 8)
    } else {
        TextConfig::new(vocab, seq).with_clients(150, 15, 30)
    };
    let dataset = build_dataset(&DatasetConfig::Text(text.clone()));
    let (rounds, cohort) = if opts.quick { (3, 4) } else { (20, 12) };
    let alphas: &[usize] = if opts.quick { &[4, 1] } else { ALPHAS };

    let mut t = Table::new(
        "Transformer NWP: accuracy vs client model size",
        &[
            "scheme",
            "alpha_inv",
            "mv",
            "dh",
            "rel_model_size",
            "accuracy_mean",
            "accuracy_std",
        ],
    );

    // (scheme, mv, dh) arms; alpha=1 is the shared no-selection point.
    let mut arms: Vec<(&str, usize, usize, usize)> = Vec::new();
    for &a in alphas {
        if a == 1 {
            arms.push(("none", 1, vocab, ffn));
        } else {
            arms.push(("structured", a, vocab / a, ffn));
            arms.push(("random", a, vocab, ffn / a));
            arms.push(("mixed", a, vocab / a, ffn / a));
        }
    }

    for (scheme, a, mv, dh) in arms {
        let mut finals = Vec::new();
        let mut rel = 0.0;
        for trial in 0..opts.trials {
            let mut cfg = TrainConfig::transformer_default(mv, dh);
            cfg.dataset = DatasetConfig::Text(text.clone());
            cfg.engine = engine.clone();
            cfg.policies = vec![
                if mv == vocab {
                    KeyPolicy::AllKeys
                } else {
                    KeyPolicy::TopFreq { m: mv }
                },
                if dh == ffn {
                    KeyPolicy::AllKeys
                } else {
                    KeyPolicy::RandomGlobal { m: dh }
                },
            ];
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = if opts.quick { 64 } else { 512 };
            cfg.seed = 3000 + trial as u64;
            let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
            rel = tr.rel_model_size();
            let report = tr.run()?;
            finals.push(report.final_eval.metric);
        }
        let (mean, std) = mean_std(&finals);
        t.push(vec![
            scheme.to_string(),
            a.to_string(),
            mv.to_string(),
            dh.to_string(),
            format!("{rel:.4}"),
            format!("{mean:.4}"),
            format!("{std:.4}"),
        ]);
    }
    Ok(vec![t])
}

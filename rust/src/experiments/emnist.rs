//! §5.3 regenerations: EMNIST-style image classification with random select
//! keys — Fig. 5 curves, Tables 2/3 final accuracies, Fig. 6 fixed-vs-
//! independent ablation.
//!
//! The 2NN family runs on either engine; the CNN family requires the PJRT
//! artifacts (conv backward lives in XLA). In `--quick` mode the CNN arms
//! are skipped unless the engine is PJRT.

use crate::config::{DatasetConfig, EngineKind, TrainConfig};
use crate::coordinator::{build_dataset, Trainer};
use crate::data::images::ImageConfig;
use crate::data::FederatedDataset;
use crate::error::Result;
use crate::fedselect::KeyPolicy;
use crate::metrics::{mean_std, Table};
use crate::model::ModelArch;

use super::ExpOptions;

fn image_cfg(quick: bool) -> ImageConfig {
    let c = ImageConfig::new(62);
    if quick {
        c.with_clients(30, 10)
    } else {
        c.with_clients(200, 40)
    }
}

struct Arm {
    model: &'static str,
    m: usize,
    fixed: bool,
}

fn run_arm(
    opts: &ExpOptions,
    arm: &Arm,
    rounds: usize,
    cohort: usize,
    eval_every: usize,
    dataset: &FederatedDataset,
    img: &ImageConfig,
) -> Result<(Vec<(usize, usize, f64)>, Vec<f64>, f64)> {
    let mut curves = Vec::new();
    let mut finals = Vec::new();
    let mut rel = 0.0;
    for trial in 0..opts.trials {
        let mut cfg = match arm.model {
            "cnn" => TrainConfig::cnn_default(arm.m),
            _ => TrainConfig::mlp_default(arm.m),
        };
        cfg.dataset = DatasetConfig::Image(img.clone());
        cfg.engine = if arm.model == "cnn" {
            match &opts.engine {
                EngineKind::Native => EngineKind::pjrt_default(),
                e => e.clone(),
            }
        } else {
            opts.engine.clone()
        };
        cfg.policies = vec![if arm.fixed {
            KeyPolicy::FixedPerRound { m: arm.m }
        } else {
            KeyPolicy::RandomGlobal { m: arm.m }
        }];
        cfg.rounds = rounds;
        cfg.cohort = cohort;
        cfg.eval.every = eval_every;
        cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
        cfg.seed = 2000 + trial as u64;
        let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
        rel = tr.rel_model_size();
        let report = tr.run()?;
        for e in &report.evals {
            curves.push((trial, e.round, e.metric));
        }
        finals.push(report.final_eval.metric);
    }
    Ok((curves, finals, rel))
}

fn cnn_available(opts: &ExpOptions) -> bool {
    // CNN arms need artifacts; probe for the manifest.
    let dir = match &opts.engine {
        EngineKind::Pjrt { artifacts_dir } => artifacts_dir.clone(),
        EngineKind::Native => "artifacts".to_string(),
    };
    std::path::Path::new(&dir).join("manifest.json").exists()
}

fn grids(quick: bool) -> (Vec<usize>, Vec<usize>, usize, usize, usize) {
    // (cnn_ms, mlp_ms, rounds, cohort, eval_every)
    if quick {
        (vec![16, 64], vec![50, 200], 5, 6, 2)
    } else {
        (
            vec![4, 8, 16, 32, 64],
            vec![10, 50, 100, 200],
            25,
            25,
            5,
        )
    }
}

/// Fig. 5: test accuracy across rounds for CNN and 2NN, random keys.
pub fn fig5(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (cnn_ms, mlp_ms, rounds, cohort, eval_every) = grids(opts.quick);
    let img = image_cfg(opts.quick);
    let dataset = build_dataset(&DatasetConfig::Image(img.clone()));
    let mut t = Table::new(
        "EMNIST test accuracy vs rounds (random keys)",
        &["model", "m", "trial", "round", "accuracy"],
    );
    let mut arms: Vec<Arm> = mlp_ms
        .iter()
        .map(|&m| Arm {
            model: "2nn",
            m,
            fixed: false,
        })
        .collect();
    if cnn_available(opts) {
        arms.extend(cnn_ms.iter().map(|&m| Arm {
            model: "cnn",
            m,
            fixed: false,
        }));
    } else {
        crate::obs_warn!("[fig5] artifacts missing: skipping CNN arms (run `make artifacts`)");
    }
    for arm in &arms {
        let (curves, _, _) = run_arm(opts, arm, rounds, cohort, eval_every, &dataset, &img)?;
        for (trial, round, acc) in curves {
            t.push(vec![
                arm.model.to_string(),
                arm.m.to_string(),
                trial.to_string(),
                round.to_string(),
                format!("{acc:.4}"),
            ]);
        }
    }
    Ok(vec![t])
}

fn final_table(
    opts: &ExpOptions,
    model: &'static str,
    ms: &[usize],
    rounds: usize,
    cohort: usize,
    title: &str,
) -> Result<Table> {
    let img = image_cfg(opts.quick);
    let dataset = build_dataset(&DatasetConfig::Image(img.clone()));
    let mut t = Table::new(title, &["m", "accuracy_mean", "accuracy_std", "rel_model_size"]);
    for &m in ms {
        let arm = Arm {
            model,
            m,
            fixed: false,
        };
        let (_, finals, rel) = run_arm(opts, &arm, rounds, cohort, 0, &dataset, &img)?;
        let (mean, std) = mean_std(&finals);
        t.push(vec![
            m.to_string(),
            format!("{:.4}", mean),
            format!("{:.4}", std),
            format!("{rel:.3}"),
        ]);
    }
    Ok(t)
}

/// Table 2: CNN final accuracy ± std and relative model size per m.
pub fn table2(opts: &ExpOptions) -> Result<Vec<Table>> {
    if !cnn_available(opts) {
        return Err(crate::error::Error::Artifact(
            "table2 (CNN) requires artifacts; run `make artifacts`".into(),
        ));
    }
    let (cnn_ms, _, rounds, cohort, _) = grids(opts.quick);
    Ok(vec![final_table(
        opts,
        "cnn",
        &cnn_ms,
        rounds,
        cohort,
        "CNN final accuracy vs m (random filter keys, Table 2 analogue)",
    )?])
}

/// Table 3: 2NN final accuracy ± std and relative model size per m.
pub fn table3(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (_, mlp_ms, rounds, cohort, _) = grids(opts.quick);
    Ok(vec![final_table(
        opts,
        "2nn",
        &mlp_ms,
        rounds,
        cohort,
        "2NN final accuracy vs m (random neuron keys, Table 3 analogue)",
    )?])
}

/// Fig. 6: fixed-per-round (shared) vs independently sampled random keys.
pub fn fig6(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (cnn_ms, mlp_ms, rounds, cohort, eval_every) = grids(opts.quick);
    let img = image_cfg(opts.quick);
    let dataset = build_dataset(&DatasetConfig::Image(img.clone()));
    let mut t = Table::new(
        "Fixed-per-round vs independent random keys",
        &["model", "m", "fixed", "trial", "round", "accuracy"],
    );
    let mut arms = Vec::new();
    let mid_mlp = mlp_ms[mlp_ms.len() / 2];
    for fixed in [false, true] {
        arms.push(Arm {
            model: "2nn",
            m: mid_mlp,
            fixed,
        });
    }
    if cnn_available(opts) {
        let mid_cnn = cnn_ms[cnn_ms.len() / 2];
        for fixed in [false, true] {
            arms.push(Arm {
                model: "cnn",
                m: mid_cnn,
                fixed,
            });
        }
    } else {
        crate::obs_warn!("[fig6] artifacts missing: skipping CNN arms");
    }
    for arm in &arms {
        let (curves, _, _) = run_arm(opts, arm, rounds, cohort, eval_every, &dataset, &img)?;
        for (trial, round, acc) in curves {
            t.push(vec![
                arm.model.to_string(),
                arm.m.to_string(),
                arm.fixed.to_string(),
                trial.to_string(),
                round.to_string(),
                format!("{acc:.4}"),
            ]);
        }
    }
    Ok(vec![t])
}

#[allow(dead_code)]
fn arch_sanity() -> (ModelArch, ModelArch) {
    (ModelArch::cnn(), ModelArch::mlp2nn())
}

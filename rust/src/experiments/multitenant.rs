//! Multi-tenant coordinator experiment: N heterogeneous jobs sharing one
//! tiered fleet vs the same jobs run in isolation, back to back.
//!
//! The acceptance shape: under the `fair-share` arbiter every job's
//! planner sees exactly the exclusion set it would see running alone, so
//! each job's *final metrics are string-identical* to its isolated run —
//! while the shared-fleet simulated wall-time **strictly beats** the sum
//! of the isolated runs, because the jobs' rounds overlap on the fleet
//! clock instead of queueing.

use crate::cache::CacheShare;
use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::fedselect::SliceImpl;
use crate::metrics::{multitenant_summary, Table};
use crate::scheduler::FleetKind;
use crate::tenancy::{ArbiterPolicy, Coordinator, JobRegistry, JobSpec};

use super::ExpOptions;

/// The heterogeneous job roster: same fleet (seed/kind/clients), different
/// models, key budgets, slice implementations, and cache settings.
fn jobs(opts: &ExpOptions) -> Vec<JobSpec> {
    let (rounds, n_clients) = if opts.quick { (2, 30) } else { (6, 48) };
    let make = |vocab: usize, m: usize, cohort: usize, imp: SliceImpl, cache: bool| {
        let mut cfg = TrainConfig::logreg_default(vocab, m);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(n_clients, 6, 8));
        cfg.engine = opts.engine.clone();
        cfg.rounds = rounds;
        cfg.cohort = cohort;
        cfg.eval.every = 0;
        cfg.eval.max_examples = if opts.quick { 256 } else { 1024 };
        cfg.fleet = FleetKind::Tiered3;
        cfg.slice_impl = imp;
        cfg.cache = cache;
        cfg.seed = 2025;
        cfg
    };
    let mut roster = vec![
        JobSpec::new(1, "tags-narrow", make(256, 32, 6, SliceImpl::OnDemand, false)),
        JobSpec::new(2, "tags-wide", make(512, 64, 8, SliceImpl::PregenCdn, true)).with_weight(2.0),
    ];
    if !opts.quick {
        roster.push(JobSpec::new(
            3,
            "tags-broadcast",
            make(256, 48, 6, SliceImpl::Broadcast, false),
        ));
    }
    roster
}

/// `--id multitenant`: shared-fleet concurrent jobs vs isolated sequential
/// runs, plus the fleet utilization rollup.
pub fn run(opts: &ExpOptions) -> Result<Vec<Table>> {
    let roster = jobs(opts);

    // isolated baselines: each job alone on its own (identical) fleet
    let mut isolated = Vec::with_capacity(roster.len());
    for spec in &roster {
        isolated.push(Trainer::new(spec.cfg.clone())?.run()?);
    }
    let isolated_total: f64 = isolated.iter().map(|r| r.total_sim_s).sum();

    let registry = JobRegistry::new(roster, CacheShare::Partitioned)?;
    let mut coord = Coordinator::new(registry, ArbiterPolicy::FairShare)?;
    let shared = coord.run()?;

    let mut per_job = Table::new(
        "Per-job metrics: shared fleet vs isolated",
        &[
            "job", "rounds", "metric_shared", "metric_isolated", "metric_match",
            "job_sim_s_shared", "job_sim_s_isolated",
        ],
    );
    for ((usage, srep), irep) in shared.usage.iter().zip(&shared.reports).zip(&isolated) {
        let ms = format!("{:.6}", srep.final_eval.metric);
        let mi = format!("{:.6}", irep.final_eval.metric);
        per_job.push(vec![
            usage.name.clone(),
            usage.rounds.to_string(),
            ms.clone(),
            mi.clone(),
            if ms == mi { "yes".into() } else { "NO".into() },
            format!("{:.1}", srep.total_sim_s),
            format!("{:.1}", irep.total_sim_s),
        ]);
    }

    let mut wall = Table::new(
        "Shared-fleet vs isolated simulated wall-time",
        &["mode", "jobs", "ticks", "sim_total_s", "speedup"],
    );
    wall.push(vec![
        "shared".into(),
        shared.reports.len().to_string(),
        shared.ticks.to_string(),
        format!("{:.1}", shared.total_sim_s),
        format!("{:.2}", isolated_total / shared.total_sim_s.max(1e-12)),
    ]);
    wall.push(vec![
        "isolated".into(),
        isolated.len().to_string(),
        "-".into(),
        format!("{isolated_total:.1}"),
        "1.00".into(),
    ]);

    Ok(vec![per_job, wall, multitenant_summary(&shared)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    /// The tentpole acceptance: shared-fleet total simulated wall-time
    /// strictly beats isolated sequential runs, at string-identical
    /// per-job final metrics.
    #[test]
    fn shared_fleet_beats_isolated_at_identical_metrics() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_multitenant_exp")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = run(&opts).unwrap();
        assert_eq!(tables.len(), 3);
        let per_job = &tables[0];
        assert_eq!(per_job.rows.len(), 2); // quick roster
        for r in &per_job.rows {
            assert_eq!(r[2], r[3], "{}: shared vs isolated metric diverged", r[0]);
            assert_eq!(r[4], "yes");
        }
        let wall = &tables[1];
        let shared_s: f64 = wall.rows[0][3].parse().unwrap();
        let isolated_s: f64 = wall.rows[1][3].parse().unwrap();
        assert!(
            shared_s < isolated_s,
            "shared {shared_s} !< isolated {isolated_s}"
        );
        // utilization rollup: one row per job + fleet totals
        assert_eq!(tables[2].rows.len(), 3);
    }
}

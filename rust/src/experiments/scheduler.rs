//! Cohort-scheduler policy sweep: the systems scenario the paper's §5–6
//! setup cannot express. One workload (logreg tag prediction), several
//! device fleets, all four selection policies — comparing model quality,
//! completion/dropout tallies, downloaded bytes, and *simulated* round
//! wall-time (the straggler-bound SimClock metric real deployments care
//! about, not host wall time).

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::build_dataset;
use crate::coordinator::Trainer;
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::metrics::{mean_std, Table};
use crate::scheduler::{FleetKind, SchedPolicy};

use super::ExpOptions;

/// `--id sched`: policy × fleet comparison table.
pub fn sweep(opts: &ExpOptions) -> Result<Vec<Table>> {
    // m chosen so the tiered fleet's low/mid memory caps genuinely clamp
    // (keyed floats at full budget exceed mem_cap_frac of the model)
    let (vocab, m) = (1024usize, 512usize);
    let (rounds, cohort, n_clients) = if opts.quick { (4, 10, 40) } else { (12, 20, 120) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut t = Table::new(
        "Cohort policy sweep (simulated device fleets)",
        &[
            "fleet",
            "policy",
            "final_metric",
            "completed",
            "dropped",
            "sim_round_s_mean",
            "sim_round_s_std",
            "sim_total_s",
            "down_MB",
        ],
    );
    for fleet in [FleetKind::Uniform, FleetKind::Tiered3, FleetKind::FlakyEdge] {
        for policy in SchedPolicy::ALL {
            let mut cfg = TrainConfig::logreg_default(vocab, m);
            cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
            cfg.engine = opts.engine.clone();
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
            cfg.fleet = fleet.clone();
            cfg.sched_policy = policy;
            cfg.mem_cap_frac = 0.25;
            cfg.seed = 1000;
            let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
            let report = tr.run()?;
            let sim_rounds: Vec<f64> = report.rounds.iter().map(|r| r.sim_round_s).collect();
            let (sim_mean, sim_std) = mean_std(&sim_rounds);
            t.push(vec![
                fleet.to_string(),
                policy.to_string(),
                format!("{:.4}", report.final_eval.metric),
                report
                    .rounds
                    .iter()
                    .map(|r| r.completed)
                    .sum::<usize>()
                    .to_string(),
                report
                    .rounds
                    .iter()
                    .map(|r| r.dropped)
                    .sum::<usize>()
                    .to_string(),
                format!("{sim_mean:.2}"),
                format!("{sim_std:.2}"),
                format!("{:.1}", report.total_sim_s),
                format!("{:.2}", report.total_down_bytes as f64 / 1e6),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    #[test]
    fn sweep_runs_quick_and_covers_every_cell() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_sched_sweep")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = sweep(&opts).unwrap();
        assert_eq!(tables.len(), 1);
        // 3 fleets x 5 policies
        assert_eq!(tables[0].rows.len(), 15);
        // memory-capped on tiered-3 downloads less than uniform on tiered-3
        let down = |fleet: &str, policy: &str| -> f64 {
            tables[0]
                .rows
                .iter()
                .find(|r| r[0] == fleet && r[1] == policy)
                .unwrap()[8]
                .parse()
                .unwrap()
        };
        assert!(down("tiered-3", "memory-capped") < down("tiered-3", "uniform"));
    }
}

//! Health-monitor validation: inject known faults, assert the SLO/anomaly
//! engine catches them — and stays silent on a quiet fleet.
//!
//! Four scenarios over the same small training workload (2k lazy fleet on
//! a 40-client dataset):
//!
//! - **quiet** — uniform, failure-free fleet; the SLO set holds every
//!   round and the detectors see only their own warm-up noise. Ground
//!   truth: zero incidents.
//! - **outage** — a standing regional outage excludes 50% of the fleet,
//!   so `eligible_frac` sits below the `ge:0.7` floor (hysteresis 2).
//! - **churn** — heavy churn keeps only ~60% of clients inside their
//!   availability window, violating `eligible_frac:ge:0.8`.
//! - **flaky** — a flaky-edge fleet with a 45% hazard floor pushes
//!   `dropped_frac` past the `le:0.2` ceiling.
//!
//! Ground-truth fault rounds are recomputed from the run's own round
//! ledger (the same [`sample`] the monitor used), so the table's
//! precision/recall scores the *detection logic*, not the fault injector.
//! Scenario-level recall must be 1.0 and the quiet fleet must stay at
//! zero incidents — both are asserted by the in-module tests.

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, TrainReport, Trainer};
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::fleet::{ChurnSpec, OutageSpec};
use crate::metrics::Table;
use crate::obs::health::sample;
use crate::obs::{Series, SloRule};
use crate::scheduler::FleetKind;

use super::ExpOptions;

/// One injected-fault scenario plus its ledger-side ground truth.
struct Scenario {
    name: &'static str,
    /// SLO rules active for the run (detectors are always on too).
    slos: &'static str,
    /// Whether this scenario injects a fault at all (quiet does not).
    faulty: bool,
    mutate: fn(&mut TrainConfig),
    /// Ledger predicate: was this round actually abnormal?
    fault: fn(&TrainConfig, &crate::coordinator::RoundRecord) -> bool,
}

const SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "quiet",
        slos: "eligible_frac:ge:0.7,dropped_frac:le:0.2",
        faulty: false,
        mutate: |_| {},
        fault: |_, _| false,
    },
    Scenario {
        name: "outage",
        slos: "eligible_frac:ge:0.7:2",
        faulty: true,
        mutate: |cfg| {
            cfg.scenario.outage = Some(OutageSpec { start_h: 0.0, dur_h: 1e6, frac: 0.5 });
        },
        fault: |_, rec| rec.outage_excluded > 0,
    },
    Scenario {
        name: "churn",
        slos: "eligible_frac:ge:0.8",
        faulty: true,
        mutate: |cfg| {
            cfg.scenario.churn = Some(ChurnSpec { rate_per_h: 2.0, width_frac: 0.6 });
        },
        fault: |cfg, rec| (rec.eligible as f64) < 0.8 * cfg.fleet_size as f64,
    },
    Scenario {
        name: "flaky",
        slos: "dropped_frac:le:0.2",
        faulty: true,
        mutate: |cfg| {
            cfg.fleet = FleetKind::FlakyEdge;
            cfg.dropout_rate = 0.45;
        },
        fault: |cfg, rec| {
            sample(Series::DroppedFrac, rec, cfg.fleet_size, cfg.cohort)
                .is_some_and(|x| x > 0.2)
        },
    },
];

/// `--id health`: fault-injection sweep scoring the monitor against the
/// run's own ledger.
pub fn run(opts: &ExpOptions) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Health monitor vs injected faults (2k fleet)",
        &[
            "scenario", "rounds", "incidents", "critical", "flagged", "fault_rounds",
            "precision", "recall",
        ],
    );
    for sc in SCENARIOS {
        let (cfg, report) = run_scenario(sc, opts)?;
        let fault_rounds: Vec<usize> = report
            .rounds
            .iter()
            .filter(|r| (sc.fault)(&cfg, r))
            .map(|r| r.round)
            .collect();
        let flagged = report.health.flagged_rounds();
        let hits = flagged.iter().filter(|r| fault_rounds.contains(r)).count();
        // round-level precision of the flags; scenario-level recall (did
        // an injected fault produce at least one incident?)
        let precision = if flagged.is_empty() {
            if sc.faulty { 0.0 } else { 1.0 }
        } else {
            hits as f64 / flagged.len() as f64
        };
        let recall = if !sc.faulty {
            if report.health.total() == 0 { 1.0 } else { 0.0 }
        } else if report.health.total() > 0 && hits > 0 {
            1.0
        } else {
            0.0
        };
        t.push(vec![
            sc.name.to_string(),
            report.rounds.len().to_string(),
            report.health.total().to_string(),
            report.health.critical_count().to_string(),
            flagged.len().to_string(),
            fault_rounds.len().to_string(),
            format!("{precision:.2}"),
            format!("{recall:.2}"),
        ]);
    }
    Ok(vec![t])
}

fn run_scenario(sc: &Scenario, opts: &ExpOptions) -> Result<(TrainConfig, TrainReport)> {
    let (vocab, m) = (256usize, 64usize);
    let ds_cfg = BowConfig::new(vocab, 20).with_clients(40, 6, 10);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut cfg = TrainConfig::logreg_default(vocab, m);
    cfg.dataset = DatasetConfig::Bow(ds_cfg);
    cfg.engine = opts.engine.clone();
    cfg.rounds = if opts.quick { 8 } else { 12 };
    cfg.cohort = 16;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 256;
    cfg.fleet_size = 2_000;
    cfg.seed = 1000;
    cfg.obs.health.slos = SloRule::parse_list(sc.slos)?;
    cfg.obs.health.detectors = true;
    // short runs: warm the detectors up faster than the default 8 rounds
    cfg.obs.health.warmup = 4;
    (sc.mutate)(&mut cfg);
    let mut tr = Trainer::with_dataset(cfg.clone(), dataset)?;
    let report = tr.run()?;
    Ok((cfg, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    #[test]
    fn injected_faults_are_detected_and_quiet_fleet_is_silent() {
        let opts = ExpOptions::new(true, EngineKind::Native);
        let t = run(&opts).unwrap();
        assert_eq!(t[0].rows.len(), SCENARIOS.len());
        for row in &t[0].rows {
            let recall: f64 = row[7].parse().unwrap();
            assert_eq!(recall, 1.0, "scenario-level recall must be 1.0: {row:?}");
            if row[0] == "quiet" {
                assert_eq!(row[2], "0", "quiet fleet must stay incident-free: {row:?}");
            } else {
                let incidents: usize = row[2].parse().unwrap();
                assert!(incidents > 0, "fault scenario must open incidents: {row:?}");
            }
        }
    }
}

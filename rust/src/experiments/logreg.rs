//! §5.2 regenerations: tag prediction with structured select keys
//! (Fig. 2 curves, Fig. 3 size/recall frontier, Fig. 4 key-strategy
//! ablation). Runs on the native engine by default — the logreg family has
//! a bit-faithful Rust mirror — or on PJRT artifacts via `--engine pjrt`.

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, Trainer};
use crate::data::bow::BowConfig;
use crate::data::FederatedDataset;
use crate::error::Result;
use crate::fedselect::KeyPolicy;
use crate::metrics::{mean_std, Table};
use crate::model::ModelArch;

use super::ExpOptions;

fn grid(quick: bool) -> (Vec<usize>, Vec<usize>, usize, usize, usize) {
    // (vocab sizes n, key counts m, rounds, cohort, eval_every)
    if quick {
        (vec![512, 2048], vec![64, 256], 6, 10, 2)
    } else {
        (vec![512, 2048, 8192], vec![64, 256, 1024, 8192], 25, 30, 5)
    }
}

fn base_cfg(n: usize, m: usize, opts: &ExpOptions, ds: &BowConfig) -> TrainConfig {
    let mut cfg = TrainConfig::logreg_default(n, m);
    cfg.dataset = DatasetConfig::Bow(ds.clone());
    cfg.engine = opts.engine.clone();
    cfg
}

fn dataset_cfg(n: usize, quick: bool) -> BowConfig {
    let c = BowConfig::new(n, 50);
    if quick {
        c.with_clients(40, 8, 12)
    } else {
        c.with_clients(300, 30, 60)
    }
}

/// One (n, m, policy) sweep cell: run trials, return (per-eval curves,
/// final metrics, rel size).
struct Cell {
    curves: Vec<(usize, usize, f64, f64)>, // (trial, round, recall, loss)
    finals: Vec<f64>,
    rel_size: f64,
}

fn run_cell(
    opts: &ExpOptions,
    n: usize,
    policy: KeyPolicy,
    rounds: usize,
    cohort: usize,
    eval_every: usize,
    dataset: &FederatedDataset,
    ds_cfg: &BowConfig,
) -> Result<Cell> {
    let mut curves = Vec::new();
    let mut finals = Vec::new();
    let mut rel_size = 0.0;
    for trial in 0..opts.trials {
        let mut cfg = base_cfg(n, policy.m(n), opts, ds_cfg);
        cfg.policies = vec![policy];
        cfg.rounds = rounds;
        cfg.cohort = cohort;
        cfg.eval.every = eval_every;
        cfg.eval.use_val = true;
        cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
        cfg.seed = 1000 + trial as u64;
        let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
        rel_size = tr.rel_model_size();
        let report = tr.run()?;
        for e in &report.evals {
            curves.push((trial, e.round, e.metric, e.loss));
        }
        finals.push(report.final_eval.metric);
    }
    Ok(Cell {
        curves,
        finals,
        rel_size,
    })
}

/// Fig. 2: validation recall@5 across rounds, varying n and m (Top keys).
pub fn fig2(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (ns, ms, rounds, cohort, eval_every) = grid(opts.quick);
    let mut t = Table::new(
        "Validation recall@5 vs rounds (FedAdagrad, Top-m keys)",
        &["n", "m", "trial", "round", "recall5", "loss"],
    );
    for &n in &ns {
        let ds_cfg = dataset_cfg(n, opts.quick);
        let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));
        for &m in &ms {
            if m > n {
                continue;
            }
            let cell = run_cell(
                opts,
                n,
                KeyPolicy::TopFreq { m },
                rounds,
                cohort,
                eval_every,
                &dataset,
                &ds_cfg,
            )?;
            for (trial, round, rec, loss) in cell.curves {
                t.push(vec![
                    n.to_string(),
                    m.to_string(),
                    trial.to_string(),
                    round.to_string(),
                    format!("{rec:.4}"),
                    format!("{loss:.4}"),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Fig. 3: relative model size and final test recall per (n, m).
pub fn fig3(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (ns, ms, rounds, cohort, _) = grid(opts.quick);
    let mut t = Table::new(
        "Relative model size and test recall (Top-m keys)",
        &["n", "m", "rel_model_size", "recall5_mean", "recall5_std"],
    );
    for &n in &ns {
        let ds_cfg = dataset_cfg(n, opts.quick);
        let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));
        for &m in &ms {
            if m > n {
                continue;
            }
            let cell = run_cell(
                opts,
                n,
                KeyPolicy::TopFreq { m },
                rounds,
                cohort,
                0,
                &dataset,
                &ds_cfg,
            )?;
            let (mean, std) = mean_std(&cell.finals);
            t.push(vec![
                n.to_string(),
                m.to_string(),
                format!("{:.4}", cell.rel_size),
                format!("{mean:.4}"),
                format!("{std:.4}"),
            ]);
        }
    }
    Ok(vec![t])
}

/// Fig. 4: key-selection strategy ablation at fixed m.
pub fn fig4(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (ns, rounds, cohort, eval_every, m) = if opts.quick {
        (vec![512], 6, 10, 2, 64)
    } else {
        (vec![2048, 8192], 25, 30, 5, 1024)
    };
    let mut t = Table::new(
        "Key selection strategies (m fixed)",
        &["n", "strategy", "trial", "round", "recall5"],
    );
    for &n in &ns {
        let ds_cfg = dataset_cfg(n, opts.quick);
        let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));
        for (name, policy) in [
            ("top", KeyPolicy::TopFreq { m }),
            ("random", KeyPolicy::RandomLocal { m }),
            ("random_top", KeyPolicy::RandomTopLocal { m }),
        ] {
            let cell = run_cell(
                opts, n, policy, rounds, cohort, eval_every, &dataset, &ds_cfg,
            )?;
            for (trial, round, rec, _) in cell.curves {
                t.push(vec![
                    n.to_string(),
                    name.to_string(),
                    trial.to_string(),
                    round.to_string(),
                    format!("{rec:.4}"),
                ]);
            }
        }
    }
    Ok(vec![t])
}

#[allow(dead_code)]
fn assert_arch_matches(n: usize) -> ModelArch {
    ModelArch::logreg(n)
}

//! Cross-round slice-cache sweep: eviction policy × cache budget × fleet
//! on a repeated-selection workload (stable `TopFreq` keys, staleness-fair
//! cycling so every client returns within one pass, tiered dropout so
//! fetched-but-never-merged key sets stay version-fresh). The headline is
//! **down-bytes saved** against the cache-off baseline of the same seed —
//! and because fresh cache entries are exact copies, every cached row has
//! the *byte-identical* model trajectory of its baseline (the final-metric
//! column must match the baseline row exactly).

use crate::cache::EvictPolicy;
use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, Trainer};
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::metrics::Table;
use crate::scheduler::{FleetKind, SchedPolicy};

use super::ExpOptions;

/// `--id cache`: eviction policy × budget fraction × fleet, with a
/// cache-off baseline row per fleet.
pub fn sweep(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (vocab, m) = (1024usize, 128usize);
    let (rounds, cohort, n_clients) = if opts.quick { (8, 8, 32) } else { (16, 12, 60) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut t = Table::new(
        "Slice-cache sweep (down-bytes saved vs cache-off baseline)",
        &[
            "fleet",
            "evict",
            "budget_frac",
            "hit_rate_pct",
            "down_MB",
            "saved_MB",
            "saved_pct",
            "evictions",
            "stale_refreshes",
            "final_metric",
            "sim_total_s",
        ],
    );
    for fleet in [FleetKind::Tiered3, FleetKind::FlakyEdge] {
        let make = |cache: Option<(EvictPolicy, f64)>| {
            let mut cfg = TrainConfig::logreg_default(vocab, m);
            cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
            cfg.engine = opts.engine.clone();
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
            cfg.fleet = fleet.clone();
            cfg.sched_policy = SchedPolicy::StalenessFair;
            cfg.dropout_rate = 0.3;
            cfg.seed = 2024;
            if let Some((evict, budget)) = cache {
                cfg.cache = true;
                cfg.cache_evict = evict;
                cfg.cache_budget_frac = budget;
            }
            cfg
        };
        // cache-off baseline of the same seed (identical trajectory)
        let base = Trainer::with_dataset(make(None), dataset.clone())?.run()?;
        let base_down = base.total_down_bytes as f64 / 1e6;
        t.push(vec![
            fleet.to_string(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{base_down:.2}"),
            "0.00".into(),
            "0.0".into(),
            "0".into(),
            "0".into(),
            format!("{:.4}", base.final_eval.metric),
            format!("{:.1}", base.total_sim_s),
        ]);
        for evict in EvictPolicy::ALL {
            for budget in [0.25f64, 1.0] {
                let report =
                    Trainer::with_dataset(make(Some((evict, budget))), dataset.clone())?.run()?;
                let down = report.total_down_bytes as f64 / 1e6;
                let hits: u64 = report.rounds.iter().map(|r| r.comm.client_cache_hits).sum();
                let lookups: u64 = report
                    .rounds
                    .iter()
                    .flat_map(|r| r.tier_cache_lookups.iter())
                    .sum();
                let evictions: u64 = report.rounds.iter().map(|r| r.cache_evictions).sum();
                let stale: u64 = report
                    .rounds
                    .iter()
                    .map(|r| r.cache_stale_refreshes)
                    .sum();
                t.push(vec![
                    fleet.to_string(),
                    evict.to_string(),
                    format!("{budget}"),
                    format!(
                        "{:.1}",
                        if lookups > 0 {
                            100.0 * hits as f64 / lookups as f64
                        } else {
                            0.0
                        }
                    ),
                    format!("{down:.2}"),
                    format!("{:.2}", base_down - down),
                    format!("{:.1}", 100.0 * (base_down - down) / base_down.max(1e-12)),
                    evictions.to_string(),
                    stale.to_string(),
                    format!("{:.4}", report.final_eval.metric),
                    format!("{:.1}", report.total_sim_s),
                ]);
            }
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    /// The acceptance shape of the cache experiment: every cached
    /// configuration strictly saves down-bytes at a byte-identical model
    /// trajectory, and tight budgets actually churn the caches.
    #[test]
    fn cache_sweep_saves_bytes_at_identical_metrics() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_cache_sweep")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = sweep(&opts).unwrap();
        assert_eq!(tables.len(), 1);
        // 2 fleets x (1 baseline + 3 evict x 2 budgets)
        assert_eq!(tables[0].rows.len(), 14);
        for fleet in ["tiered-3", "flaky-edge"] {
            let rows: Vec<&Vec<String>> =
                tables[0].rows.iter().filter(|r| r[0] == fleet).collect();
            assert_eq!(rows.len(), 7);
            let base = rows.iter().find(|r| r[1] == "-").unwrap();
            let base_down: f64 = base[4].parse().unwrap();
            for r in rows.iter().filter(|r| r[1] != "-") {
                let label = format!("{fleet}/{}/{}", r[1], r[2]);
                // strictly fewer wire bytes than the cache-off baseline
                assert!(r[6].parse::<f64>().unwrap() > 0.0, "{label}: nothing saved");
                assert!(r[4].parse::<f64>().unwrap() < base_down, "{label}");
                assert!(r[3].parse::<f64>().unwrap() > 0.0, "{label}: zero hit rate");
                // byte-identical trajectory: the metric matches the
                // baseline to the last printed digit
                assert_eq!(r[9], base[9], "{label}: trajectory diverged");
                // faster (or equal) simulated training: fewer bytes moved
                assert!(
                    r[10].parse::<f64>().unwrap() <= base[10].parse::<f64>().unwrap() + 1e-9,
                    "{label}: sim time rose"
                );
            }
            // the tight budget must churn at least one configuration
            if fleet == "tiered-3" {
                assert!(
                    rows.iter()
                        .filter(|r| r[2] == "0.25")
                        .any(|r| r[7].parse::<u64>().unwrap() > 0),
                    "tight budgets never evicted"
                );
            }
        }
    }
}

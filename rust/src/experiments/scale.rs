//! Fleet-scale sweep: the headline workload of the million-client fleet
//! engine. Profiles are lazy (a pure function of `(seed, client, kind)`),
//! scheduler state is sparse (touched clients only), and every selection
//! policy has a sub-linear sampling path — so `plan_round` over 10M
//! clients costs milliseconds and resident bytes stay proportional to the
//! cohort, not the fleet.
//!
//! Two tables:
//!
//! 1. **plan-only sweep** — fleet size × policy, driving
//!    [`Scheduler::plan_round`] directly: mean plan wall-time, planned
//!    clients/s, touched-state count, and resident scheduler bytes.
//! 2. **scenario tie-in** — a small end-to-end training run with an
//!    oversized fleet under churn + a regional outage, reporting the
//!    eligibility ledger of the final round.

use std::time::Instant;

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, Trainer};
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::fleet::{ChurnSpec, OutageSpec};
use crate::metrics::{human_bytes, Table};
use crate::scheduler::{FleetKind, SchedPolicy, Scheduler, SliceGeometry};
use crate::tensor::rng::Rng;

use super::ExpOptions;

/// Rounds of `plan_round` timed per (size, policy) cell.
const PLAN_ROUNDS: usize = 5;

/// `--id scale`: fleet 10k -> 10M sweep plus a churn/outage tie-in run.
pub fn run(opts: &ExpOptions) -> Result<Vec<Table>> {
    let sizes: &[usize] = if opts.quick {
        &[10_000, 1_000_000]
    } else {
        &[10_000, 1_000_000, 10_000_000]
    };
    let mut tables = vec![plan_sweep(sizes)?];
    tables.push(scenario_tie_in(opts)?);
    Ok(tables)
}

/// Drive the scheduler alone — no dataset, no model — so the numbers
/// isolate selection cost. The dataset-client count passed to
/// [`Scheduler::new`] is a stand-in; `--fleet-size` overrides it.
fn plan_sweep(sizes: &[usize]) -> Result<Table> {
    let geom = SliceGeometry {
        base_ms: vec![512],
        per_key_floats: vec![64],
        broadcast_floats: 64,
        server_floats: 4096 * 64 + 64,
    };
    let mut t = Table::new(
        "Fleet scale sweep (plan-only, tiered-3 fleet)",
        &[
            "fleet_size",
            "policy",
            "plan_ms_mean",
            "clients_per_s",
            "touched",
            "resident",
        ],
    );
    for &n in sizes {
        for policy in SchedPolicy::ALL {
            let mut cfg = TrainConfig::logreg_default(256, 64);
            cfg.fleet = FleetKind::Tiered3;
            cfg.fleet_size = n;
            cfg.sched_policy = policy;
            cfg.cohort = 100;
            cfg.mem_cap_frac = 0.25;
            cfg.seed = 7;
            let mut sched = Scheduler::new(&cfg, 100)?;
            let mut rng = Rng::new(cfg.seed, 0x5CA1E);
            let start = Instant::now();
            for round in 1..=PLAN_ROUNDS {
                let _plan = sched.plan_round(round, cfg.cohort, &geom, &mut rng, &[]);
            }
            let secs = start.elapsed().as_secs_f64().max(1e-9);
            let plan_ms = 1e3 * secs / PLAN_ROUNDS as f64;
            // population covered per second of planning: the capacity
            // metric — how fast a coordinator could re-plan the full fleet
            let clients_per_s = n as f64 * PLAN_ROUNDS as f64 / secs;
            t.push(vec![
                n.to_string(),
                policy.to_string(),
                format!("{plan_ms:.3}"),
                format!("{clients_per_s:.3e}"),
                sched.clients_touched().to_string(),
                human_bytes(sched.resident_state_bytes()),
            ]);
        }
    }
    Ok(t)
}

/// End-to-end check that scenarios flow through training: a 2,000-client
/// fleet over a 40-client dataset, hourly churn plus a regional outage.
fn scenario_tie_in(opts: &ExpOptions) -> Result<Table> {
    let (vocab, m) = (256usize, 64usize);
    let rounds = if opts.quick { 4 } else { 8 };
    let ds_cfg = BowConfig::new(vocab, 20).with_clients(40, 6, 10);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut cfg = TrainConfig::logreg_default(vocab, m);
    cfg.dataset = DatasetConfig::Bow(ds_cfg);
    cfg.engine = opts.engine.clone();
    cfg.rounds = rounds;
    cfg.cohort = 16;
    cfg.eval.every = 0;
    cfg.eval.max_examples = 256;
    cfg.fleet = FleetKind::Tiered3;
    cfg.fleet_size = 2_000;
    cfg.scenario.churn = Some(ChurnSpec { rate_per_h: 2.0, width_frac: 0.6 });
    cfg.scenario.outage = Some(OutageSpec { start_h: 0.0, dur_h: 1e6, frac: 0.25 });
    cfg.seed = 1000;
    let mut tr = Trainer::with_dataset(cfg, dataset)?;
    let report = tr.run()?;

    let mut t = Table::new(
        "Scenario tie-in (2k fleet, churn 2/h width 0.6, outage frac 0.25)",
        &[
            "round",
            "eligible",
            "arrivals",
            "departures",
            "outage_excl",
            "touched",
            "resident",
            "completed",
        ],
    );
    for r in &report.rounds {
        t.push(vec![
            r.round.to_string(),
            r.eligible.to_string(),
            r.arrivals.to_string(),
            r.departures.to_string(),
            r.outage_excluded.to_string(),
            r.clients_touched.to_string(),
            human_bytes(r.resident_bytes),
            r.completed.to_string(),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    #[test]
    fn plan_sweep_covers_dense_and_sparse_paths() {
        // 2k stays on the dense legacy paths; 70k crosses
        // SPARSE_SCAN_THRESHOLD and exercises the sub-linear samplers
        let t = plan_sweep(&[2_000, 70_000]).unwrap();
        assert_eq!(t.rows.len(), 2 * SchedPolicy::ALL.len());
        for row in &t.rows {
            let plan_ms: f64 = row[2].parse().unwrap();
            assert!(plan_ms.is_finite() && plan_ms >= 0.0);
            // every policy touched exactly the planned cohorts
            let touched: usize = row[4].parse().unwrap();
            assert!(touched <= 100 * PLAN_ROUNDS);
            assert!(touched > 0);
        }
    }

    #[test]
    fn scenario_tie_in_ledgers_eligibility() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_scale_tie_in")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let t = scenario_tie_in(&opts).unwrap();
        assert!(!t.rows.is_empty());
        for row in &t.rows {
            let eligible: usize = row[1].parse().unwrap();
            let outage: usize = row[4].parse().unwrap();
            // the standing outage removes a quarter of the 2k fleet; churn
            // shrinks the window further
            assert!(outage > 0, "outage must exclude clients: {row:?}");
            assert!(eligible < 2_000, "eligibility must be constrained: {row:?}");
            assert!(eligible >= 16, "cohort must remain satisfiable: {row:?}");
        }
    }
}

//! Round-engine mode sweep: the straggler scenario the paper's synchronous
//! Algorithm 2 cannot express. One workload (logreg tag prediction), the
//! two straggler-heavy fleets, all three aggregation modes — comparing
//! model quality, merge/discard tallies, staleness, and *simulated*
//! training time. The expected shape: `over-select` and `buffered` close
//! rounds at a goal count instead of the straggler, so `total_sim_s` drops
//! well below `sync` at (near-)matching final accuracy.

use crate::config::{DatasetConfig, TrainConfig};
use crate::coordinator::{build_dataset, AggregationMode, Trainer};
use crate::data::bow::BowConfig;
use crate::error::Result;
use crate::metrics::{mean_std, Table};
use crate::scheduler::FleetKind;

use super::ExpOptions;

/// The mode column of the sweep for a given cohort size: the barrier
/// baseline, 1.5× over-selection closed at the original cohort, and
/// buffered aggregation closed two updates short of the cohort.
pub fn sweep_modes(cohort: usize) -> [AggregationMode; 3] {
    [
        AggregationMode::Synchronous,
        AggregationMode::OverSelect { extra_frac: 0.5 },
        AggregationMode::Buffered {
            goal_count: cohort.saturating_sub(2).max(1),
            max_staleness: 4,
        },
    ]
}

/// `--id async`: aggregation-mode × fleet comparison table.
pub fn sweep(opts: &ExpOptions) -> Result<Vec<Table>> {
    let (vocab, m) = (1024usize, 256usize);
    let (rounds, cohort, n_clients) = if opts.quick { (10, 10, 60) } else { (20, 20, 150) };
    let ds_cfg = BowConfig::new(vocab, 50).with_clients(n_clients, 8, 12);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    let mut t = Table::new(
        "Aggregation-mode sweep (straggler fleets)",
        &[
            "fleet",
            "mode",
            "final_metric",
            "merged",
            "dropped",
            "discarded",
            "mean_staleness",
            "sim_round_s_mean",
            "sim_round_s_std",
            "sim_total_s",
            "down_MB",
        ],
    );
    for fleet in [FleetKind::Tiered3, FleetKind::FlakyEdge] {
        for mode in sweep_modes(cohort) {
            let mut cfg = TrainConfig::logreg_default(vocab, m);
            cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
            cfg.engine = opts.engine.clone();
            cfg.rounds = rounds;
            cfg.cohort = cohort;
            cfg.eval.every = 0;
            cfg.eval.max_examples = if opts.quick { 512 } else { 2048 };
            cfg.fleet = fleet.clone();
            cfg.agg_mode = mode;
            cfg.seed = 1000;
            let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
            let report = tr.run()?;
            let sim_rounds: Vec<f64> = report.rounds.iter().map(|r| r.sim_round_s).collect();
            let (sim_mean, sim_std) = mean_std(&sim_rounds);
            let stale: Vec<f64> = report.rounds.iter().map(|r| r.mean_staleness).collect();
            t.push(vec![
                fleet.to_string(),
                mode.to_string(),
                format!("{:.4}", report.final_eval.metric),
                report
                    .rounds
                    .iter()
                    .map(|r| r.completed)
                    .sum::<usize>()
                    .to_string(),
                report
                    .rounds
                    .iter()
                    .map(|r| r.dropped)
                    .sum::<usize>()
                    .to_string(),
                report.total_discarded.to_string(),
                format!("{:.2}", mean_std(&stale).0),
                format!("{sim_mean:.2}"),
                format!("{sim_std:.2}"),
                format!("{:.1}", report.total_sim_s),
                format!("{:.2}", report.total_down_bytes as f64 / 1e6),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    /// The acceptance shape of the async experiment: both non-barrier modes
    /// finish training in strictly less simulated time than `sync` on both
    /// straggler fleets, at near-matching final accuracy.
    #[test]
    fn async_modes_beat_the_barrier_on_simulated_time() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir()
                .join("fedselect_async_sweep")
                .to_string_lossy()
                .into_owned(),
            ..ExpOptions::new(true, EngineKind::Native)
        };
        let tables = sweep(&opts).unwrap();
        assert_eq!(tables.len(), 1);
        // 2 fleets x 3 modes
        assert_eq!(tables[0].rows.len(), 6);
        let cell = |fleet: &str, mode: &str, col: usize| -> f64 {
            tables[0]
                .rows
                .iter()
                .find(|r| r[0] == fleet && r[1].starts_with(mode))
                .unwrap()[col]
                .parse()
                .unwrap()
        };
        for fleet in ["tiered-3", "flaky-edge"] {
            let sync_sim = cell(fleet, "sync", 9);
            for mode in ["over-select", "buffered"] {
                let sim = cell(fleet, mode, 9);
                assert!(
                    sim < sync_sim,
                    "{fleet}/{mode}: sim {sim} !< sync {sync_sim}"
                );
                let gap = (cell(fleet, mode, 2) - cell(fleet, "sync", 2)).abs();
                assert!(gap < 0.05, "{fleet}/{mode}: metric gap {gap} too wide");
            }
            // over-selection pays for its straggler immunity in bytes
            assert!(cell(fleet, "over-select", 10) > cell(fleet, "sync", 10));
            assert!(cell(fleet, "over-select", 5) > 0.0, "no discards ledgered");
            assert!(
                cell(fleet, "buffered", 6) > 0.0,
                "buffered mode never saw a stale merge"
            );
        }
    }
}

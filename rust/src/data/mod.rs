//! Synthetic federated datasets (the paper's Stack Overflow / EMNIST
//! substitutes — see DESIGN.md §4 for the substitution rationale).
//!
//! A [`FederatedDataset`] is a train/val/test partition of [`ClientData`],
//! where each client holds raw [`Example`]s plus cached feature-frequency
//! statistics (what structured key selection operates on). Generators:
//!
//! * [`bow`]    — Zipfian bag-of-words tag-prediction corpus (§5.2),
//! * [`images`] — writer-styled 28×28 glyph classification (§5.3),
//! * [`text`]   — Markov-chain token corpus for next-word prediction (§5.4).

pub mod bow;
pub mod images;
pub mod text;

use crate::tensor::rng::Rng;

/// One training example, across all model families.
#[derive(Clone, Debug)]
pub enum Example {
    /// Sparse binary bag-of-words with a set of true tags.
    Bow { words: Vec<u32>, tags: Vec<u32> },
    /// Dense 28x28 grayscale image with a class label.
    Image { pixels: Vec<f32>, label: u32 },
    /// Token sequence of length seq+1 (inputs = [..seq], targets = [1..]).
    Text { tokens: Vec<u32> },
}

/// One client's local dataset.
#[derive(Clone, Debug)]
pub struct ClientData {
    pub id: u64,
    pub examples: Vec<Example>,
    /// Occurrence count per feature index (word / token), for structured key
    /// selection. Empty for image clients.
    pub feature_counts: Vec<(u32, u32)>,
}

impl ClientData {
    /// Feature indices sorted by descending local frequency (ties by index).
    pub fn features_by_frequency(&self) -> Vec<u32> {
        let mut fc = self.feature_counts.clone();
        fc.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        fc.into_iter().map(|(f, _)| f).collect()
    }

    pub fn num_examples(&self) -> usize {
        self.examples.len()
    }

    pub fn compute_feature_counts(examples: &[Example]) -> Vec<(u32, u32)> {
        let mut counts = std::collections::HashMap::new();
        for ex in examples {
            match ex {
                Example::Bow { words, .. } => {
                    for &w in words {
                        *counts.entry(w).or_insert(0u32) += 1;
                    }
                }
                Example::Text { tokens } => {
                    for &t in tokens {
                        *counts.entry(t).or_insert(0u32) += 1;
                    }
                }
                Example::Image { .. } => {}
            }
        }
        let mut v: Vec<(u32, u32)> = counts.into_iter().collect();
        v.sort_unstable();
        v
    }
}

/// Train/val/test client partition (paper Table 1 shape).
#[derive(Clone, Debug, Default)]
pub struct FederatedDataset {
    pub name: String,
    pub train: Vec<ClientData>,
    pub val: Vec<ClientData>,
    pub test: Vec<ClientData>,
}

/// Summary row for the Table 1 regeneration.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub name: String,
    pub train_clients: usize,
    pub train_examples: usize,
    pub val_clients: usize,
    pub val_examples: usize,
    pub test_clients: usize,
    pub test_examples: usize,
}

impl FederatedDataset {
    pub fn stats(&self) -> DatasetStats {
        let count = |cs: &[ClientData]| cs.iter().map(|c| c.num_examples()).sum();
        DatasetStats {
            name: self.name.clone(),
            train_clients: self.train.len(),
            train_examples: count(&self.train),
            val_clients: self.val.len(),
            val_examples: count(&self.val),
            test_clients: self.test.len(),
            test_examples: count(&self.test),
        }
    }

    /// Sample a cohort of `k` distinct train-client indices.
    pub fn sample_cohort(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        rng.sample_without_replacement(self.train.len(), k.min(self.train.len()))
    }
}

/// Log-normal example count, clamped — cross-device datasets are heavily
/// skewed in per-client quantity (paper §1's data heterogeneity).
pub(crate) fn skewed_count(rng: &mut Rng, mu: f32, sigma: f32, lo: usize, hi: usize) -> usize {
    (rng.lognormal(mu, sigma) as usize).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_counts_count_occurrences() {
        let exs = vec![
            Example::Bow {
                words: vec![3, 5, 3],
                tags: vec![0],
            },
            Example::Bow {
                words: vec![5],
                tags: vec![1],
            },
        ];
        let fc = ClientData::compute_feature_counts(&exs);
        assert_eq!(fc, vec![(3, 2), (5, 2)]);
    }

    #[test]
    fn frequency_ordering_breaks_ties_by_index() {
        let c = ClientData {
            id: 0,
            examples: vec![],
            feature_counts: vec![(9, 2), (1, 5), (4, 2)],
        };
        assert_eq!(c.features_by_frequency(), vec![1, 4, 9]);
    }

    #[test]
    fn skewed_count_respects_bounds() {
        let mut rng = Rng::new(2, 0);
        for _ in 0..200 {
            let n = skewed_count(&mut rng, 3.0, 1.0, 5, 50);
            assert!((5..=50).contains(&n));
        }
    }
}

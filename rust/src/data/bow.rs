//! Synthetic Stack-Overflow-like tag-prediction corpus.
//!
//! What FedSelect's §5.2 behaviour depends on, and what this generator
//! reproduces (DESIGN.md §4):
//!
//! 1. global word frequencies are Zipfian,
//! 2. clients are heterogeneous: each client's vocabulary is a topic-skewed,
//!    small subset of the global vocabulary,
//! 3. tags are predictable from word co-occurrence (a sparse ground-truth
//!    teacher), so a logistic model can actually learn.
//!
//! Each tag owns a set of indicator words; an example's tags are the tags
//! whose indicators sufficiently overlap its word set.

use super::{skewed_count, ClientData, Example, FederatedDataset};
use crate::tensor::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct BowConfig {
    pub vocab: usize,
    pub tags: usize,
    pub train_clients: usize,
    pub val_clients: usize,
    pub test_clients: usize,
    /// Latent topics driving client heterogeneity.
    pub topics: usize,
    /// Zipf exponent of the global word distribution.
    pub zipf_s: f64,
    /// Mean words per example (distinct).
    pub words_per_example: usize,
    /// Indicator words per tag in the teacher.
    pub indicators_per_tag: usize,
    pub seed: u64,
}

impl BowConfig {
    pub fn new(vocab: usize, tags: usize) -> Self {
        BowConfig {
            vocab,
            tags,
            train_clients: 400,
            val_clients: 40,
            test_clients: 80,
            topics: 16,
            zipf_s: 1.07,
            words_per_example: 24,
            indicators_per_tag: 16,
            seed: 17,
        }
    }

    pub fn with_clients(mut self, train: usize, val: usize, test: usize) -> Self {
        self.train_clients = train;
        self.val_clients = val;
        self.test_clients = test;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

struct Teacher {
    /// tag -> sorted indicator words
    indicators: Vec<Vec<u32>>,
}

impl Teacher {
    fn new(cfg: &BowConfig, rng: &mut Rng, zipf: &Zipf) -> Self {
        // never demand more distinct words than the vocabulary can provide
        let per_tag = cfg.indicators_per_tag.min(cfg.vocab / 3).max(2);
        let indicators = (0..cfg.tags)
            .map(|_| {
                let mut set = std::collections::BTreeSet::new();
                while set.len() < per_tag {
                    set.insert(zipf.sample(rng) as u32);
                }
                set.into_iter().collect()
            })
            .collect();
        Teacher { indicators }
    }

    /// Tags whose indicator overlap with `words` is >= 2, else the argmax tag.
    fn tags_for(&self, words: &[u32]) -> Vec<u32> {
        let wset: std::collections::HashSet<u32> = words.iter().copied().collect();
        let mut best = (0u32, 0usize);
        let mut out = Vec::new();
        for (t, ind) in self.indicators.iter().enumerate() {
            let ov = ind.iter().filter(|w| wset.contains(w)).count();
            if ov >= 2 {
                out.push(t as u32);
            }
            if ov > best.1 {
                best = (t as u32, ov);
            }
        }
        if out.is_empty() {
            out.push(best.0);
        }
        out.truncate(8);
        out
    }
}

/// Per-topic preferred word lists (client heterogeneity source).
fn topic_words(cfg: &BowConfig, rng: &mut Rng, zipf: &Zipf) -> Vec<Vec<u32>> {
    let per_topic = (cfg.vocab / cfg.topics).clamp(32, 4096).min(cfg.vocab / 2).max(2);
    (0..cfg.topics)
        .map(|_| {
            let mut set = std::collections::BTreeSet::new();
            while set.len() < per_topic {
                set.insert(zipf.sample(rng) as u32);
            }
            set.into_iter().collect()
        })
        .collect()
}

fn gen_client(
    id: u64,
    cfg: &BowConfig,
    rng: &mut Rng,
    zipf: &Zipf,
    topics: &[Vec<u32>],
    teacher: &Teacher,
) -> ClientData {
    let theta = rng.dirichlet(0.3, cfg.topics);
    let n_examples = skewed_count(rng, 3.0, 0.9, 4, 120);
    let mut examples = Vec::with_capacity(n_examples);
    for _ in 0..n_examples {
        // cap by vocab/3 so the distinct-word draw below always terminates
        let hi = (cfg.words_per_example * 3).min(cfg.vocab / 3).max(2);
        let n_words = skewed_count(rng, (cfg.words_per_example as f32).ln(), 0.4, 2.min(hi), hi);
        let mut words = std::collections::BTreeSet::new();
        while words.len() < n_words {
            if rng.f32() < 0.55 {
                // topic-conditioned draw
                let t = rng.categorical(&theta);
                let tw = &topics[t];
                words.insert(tw[rng.below(tw.len())]);
            } else {
                // global Zipf draw
                words.insert(zipf.sample(rng) as u32);
            }
        }
        let words: Vec<u32> = words.into_iter().collect();
        let tags = teacher.tags_for(&words);
        examples.push(Example::Bow { words, tags });
    }
    let feature_counts = ClientData::compute_feature_counts(&examples);
    ClientData {
        id,
        examples,
        feature_counts,
    }
}

/// Generate the full federated tag-prediction corpus.
pub fn generate(cfg: &BowConfig) -> FederatedDataset {
    let mut rng = Rng::new(cfg.seed, 1001);
    let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
    let teacher = Teacher::new(cfg, &mut rng, &zipf);
    let topics = topic_words(cfg, &mut rng, &zipf);
    let gen_split = |count: usize, salt: u64| -> Vec<ClientData> {
        (0..count)
            .map(|i| {
                let mut crng = Rng::new(cfg.seed ^ (salt << 32) ^ i as u64, salt * 7 + 3);
                gen_client(i as u64, cfg, &mut crng, &zipf, &topics, &teacher)
            })
            .collect()
    };
    FederatedDataset {
        name: format!("synth-stackoverflow(v={},t={})", cfg.vocab, cfg.tags),
        train: gen_split(cfg.train_clients, 1),
        val: gen_split(cfg.val_clients, 2),
        test: gen_split(cfg.test_clients, 3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FederatedDataset {
        generate(&BowConfig::new(256, 10).with_clients(20, 4, 6))
    }

    #[test]
    fn splits_have_requested_sizes() {
        let ds = small();
        assert_eq!(ds.train.len(), 20);
        assert_eq!(ds.val.len(), 4);
        assert_eq!(ds.test.len(), 6);
        assert!(ds.stats().train_examples > 20);
    }

    #[test]
    fn examples_are_valid_and_tagged() {
        let ds = small();
        for c in &ds.train {
            assert!(!c.examples.is_empty());
            for ex in &c.examples {
                match ex {
                    Example::Bow { words, tags } => {
                        assert!(!words.is_empty());
                        assert!(!tags.is_empty());
                        assert!(words.iter().all(|&w| (w as usize) < 256));
                        assert!(tags.iter().all(|&t| (t as usize) < 10));
                        // words are distinct & sorted (BTreeSet order)
                        assert!(words.windows(2).all(|w| w[0] < w[1]));
                    }
                    _ => panic!("wrong example kind"),
                }
            }
        }
    }

    #[test]
    fn client_vocab_is_much_smaller_than_global() {
        let ds = generate(&BowConfig::new(2048, 20).with_clients(10, 0, 0));
        for c in &ds.train {
            assert!(
                c.feature_counts.len() < 2048 / 2,
                "client vocab {} too large",
                c.feature_counts.len()
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = small();
        let b = small();
        for (ca, cb) in a.train.iter().zip(b.train.iter()) {
            assert_eq!(ca.feature_counts, cb.feature_counts);
        }
    }

    #[test]
    fn global_word_frequency_is_zipf_like() {
        let ds = generate(&BowConfig::new(512, 10).with_clients(60, 0, 0));
        let mut counts = vec![0u32; 512];
        for c in &ds.train {
            for &(w, n) in &c.feature_counts {
                counts[w as usize] += n;
            }
        }
        let head: u32 = counts[..32].iter().sum();
        let tail: u32 = counts[256..].iter().sum();
        assert!(head > tail, "head {head} should dominate tail {tail}");
    }
}

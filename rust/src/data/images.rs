//! Synthetic EMNIST-like federated image dataset.
//!
//! Classes are procedural 28×28 prototype glyphs (sums of seeded Gaussian
//! blobs); a client is a "writer" applying a consistent style — affine
//! jitter, intensity scaling, additive noise — to every glyph it produces.
//! Per-client class distributions are Dirichlet-skewed, reproducing the
//! writer heterogeneity that makes federated EMNIST non-IID.
//!
//! Random-key FedSelect behaviour (§5.3) depends on model redundancy, not on
//! pixel statistics, so this substitution preserves the CNN-vs-2NN contrast
//! the paper reports (DESIGN.md §4).

use super::{skewed_count, ClientData, Example, FederatedDataset};
use crate::tensor::rng::Rng;

pub const SIDE: usize = 28;
pub const PIXELS: usize = SIDE * SIDE;

#[derive(Clone, Debug)]
pub struct ImageConfig {
    pub classes: usize,
    pub train_clients: usize,
    pub test_clients: usize,
    /// Dirichlet concentration of per-client class mixtures.
    pub class_alpha: f64,
    pub seed: u64,
}

impl ImageConfig {
    pub fn new(classes: usize) -> Self {
        ImageConfig {
            classes,
            train_clients: 300,
            test_clients: 60,
            class_alpha: 0.3,
            seed: 29,
        }
    }

    pub fn with_clients(mut self, train: usize, test: usize) -> Self {
        self.train_clients = train;
        self.test_clients = test;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Class prototype: sum of `blobs` Gaussian bumps, normalized to [0, 1].
fn prototype(class: u32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xC1A55 ^ (class as u64) << 8, 5);
    let blobs = 4 + (class as usize % 4);
    let mut img = vec![0.0f32; PIXELS];
    for _ in 0..blobs {
        let cx = 4.0 + 20.0 * rng.f32();
        let cy = 4.0 + 20.0 * rng.f32();
        let sx = 1.5 + 3.0 * rng.f32();
        let sy = 1.5 + 3.0 * rng.f32();
        let amp = 0.5 + 0.5 * rng.f32();
        for i in 0..SIDE {
            for j in 0..SIDE {
                let dx = (j as f32 - cx) / sx;
                let dy = (i as f32 - cy) / sy;
                img[i * SIDE + j] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
            }
        }
    }
    let max = img.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    for v in &mut img {
        *v /= max;
    }
    img
}

/// A writer's consistent rendering style.
#[derive(Clone, Copy, Debug)]
struct Style {
    rot: f32,
    scale: f32,
    dx: f32,
    dy: f32,
    gain: f32,
    noise: f32,
}

impl Style {
    fn sample(rng: &mut Rng) -> Self {
        Style {
            rot: (rng.f32() - 0.5) * 0.5,
            scale: 0.9 + 0.2 * rng.f32(),
            dx: (rng.f32() - 0.5) * 4.0,
            dy: (rng.f32() - 0.5) * 4.0,
            gain: 0.7 + 0.6 * rng.f32(),
            noise: 0.02 + 0.08 * rng.f32(),
        }
    }
}

/// Bilinear sample of `img` at (x, y); zero outside.
fn bilinear(img: &[f32], x: f32, y: f32) -> f32 {
    if x < 0.0 || y < 0.0 || x > (SIDE - 1) as f32 || y > (SIDE - 1) as f32 {
        return 0.0;
    }
    let x0 = x.floor() as usize;
    let y0 = y.floor() as usize;
    let x1 = (x0 + 1).min(SIDE - 1);
    let y1 = (y0 + 1).min(SIDE - 1);
    let fx = x - x0 as f32;
    let fy = y - y0 as f32;
    let p = |yy: usize, xx: usize| img[yy * SIDE + xx];
    p(y0, x0) * (1.0 - fx) * (1.0 - fy)
        + p(y0, x1) * fx * (1.0 - fy)
        + p(y1, x0) * (1.0 - fx) * fy
        + p(y1, x1) * fx * fy
}

fn render(proto: &[f32], style: &Style, rng: &mut Rng) -> Vec<f32> {
    let c = (SIDE / 2) as f32;
    let (s, co) = style.rot.sin_cos();
    let inv_scale = 1.0 / style.scale;
    let mut out = vec![0.0f32; PIXELS];
    for i in 0..SIDE {
        for j in 0..SIDE {
            // inverse affine: output (j, i) -> source coords
            let xr = (j as f32 - c - style.dx) * inv_scale;
            let yr = (i as f32 - c - style.dy) * inv_scale;
            let xs = co * xr + s * yr + c;
            let ys = -s * xr + co * yr + c;
            let v = bilinear(proto, xs, ys) * style.gain + style.noise * rng.normal();
            out[i * SIDE + j] = v.clamp(0.0, 1.0);
        }
    }
    out
}

fn gen_client(id: u64, cfg: &ImageConfig, protos: &[Vec<f32>], rng: &mut Rng) -> ClientData {
    let style = Style::sample(rng);
    let mix = rng.dirichlet(cfg.class_alpha, cfg.classes);
    let n = skewed_count(rng, 3.4, 0.7, 10, 150);
    let examples = (0..n)
        .map(|_| {
            let label = rng.categorical(&mix) as u32;
            Example::Image {
                pixels: render(&protos[label as usize], &style, rng),
                label,
            }
        })
        .collect::<Vec<_>>();
    ClientData {
        id,
        examples,
        feature_counts: Vec::new(),
    }
}

pub fn generate(cfg: &ImageConfig) -> FederatedDataset {
    let protos: Vec<Vec<f32>> = (0..cfg.classes as u32)
        .map(|c| prototype(c, cfg.seed))
        .collect();
    let split = |count: usize, salt: u64| -> Vec<ClientData> {
        (0..count)
            .map(|i| {
                let mut rng = Rng::new(cfg.seed ^ (salt << 40) ^ i as u64, salt * 11 + 1);
                gen_client(i as u64, cfg, &protos, &mut rng)
            })
            .collect()
    };
    FederatedDataset {
        name: format!("synth-emnist(c={})", cfg.classes),
        train: split(cfg.train_clients, 1),
        val: Vec::new(),
        test: split(cfg.test_clients, 3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_are_distinct_and_bounded() {
        let a = prototype(0, 1);
        let b = prototype(1, 1);
        assert!(a.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "prototypes too similar: {diff}");
    }

    #[test]
    fn clients_have_consistent_style_but_varied_labels() {
        let ds = generate(&ImageConfig::new(10).with_clients(8, 2));
        for c in &ds.train {
            assert!(c.examples.len() >= 10);
            let labels: std::collections::HashSet<u32> = c
                .examples
                .iter()
                .map(|e| match e {
                    Example::Image { label, .. } => *label,
                    _ => panic!(),
                })
                .collect();
            assert!(!labels.is_empty());
        }
    }

    #[test]
    fn pixels_in_range() {
        let ds = generate(&ImageConfig::new(5).with_clients(3, 1));
        for c in ds.train.iter().chain(ds.test.iter()) {
            for e in &c.examples {
                if let Example::Image { pixels, label } = e {
                    assert_eq!(pixels.len(), PIXELS);
                    assert!((*label as usize) < 5);
                    assert!(pixels.iter().all(|&v| (0.0..=1.0).contains(&v)));
                }
            }
        }
    }

    #[test]
    fn class_distributions_are_skewed_across_clients() {
        let ds = generate(&ImageConfig::new(10).with_clients(12, 0));
        // with alpha=0.3, different clients should have different modal classes
        let modal: std::collections::HashSet<u32> = ds
            .train
            .iter()
            .map(|c| {
                let mut counts = [0u32; 10];
                for e in &c.examples {
                    if let Example::Image { label, .. } = e {
                        counts[*label as usize] += 1;
                    }
                }
                counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &n)| n)
                    .unwrap()
                    .0 as u32
            })
            .collect();
        assert!(modal.len() >= 3, "modal classes {modal:?}");
    }
}

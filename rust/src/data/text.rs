//! Synthetic token corpus for next-word prediction (§5.4 substitute).
//!
//! A single *global* stochastic bigram process (learnable by any LM) with
//! per-client topic skew (so structured vocabulary selection helps):
//!
//! * transition: with prob `p_det` the next token is `succ(cur)` under a
//!   fixed global permutation-ish successor map (the learnable structure);
//!   otherwise a fresh draw from the client's topic-skewed Zipf mixture.
//! * Client token distributions concentrate on a topic band of the
//!   vocabulary, so the most frequent local tokens (structured select keys)
//!   cover most of the client's text.
//!
//! Token 0 is a reserved UNK: structured selection always includes it, and
//! tokens outside a client's selected slice are mapped onto it.

use super::{skewed_count, ClientData, Example, FederatedDataset};
use crate::tensor::rng::{Rng, Zipf};

pub const UNK: u32 = 0;

#[derive(Clone, Debug)]
pub struct TextConfig {
    pub vocab: usize,
    /// Sequence length of each example (tokens per example = seq + 1).
    pub seq: usize,
    pub train_clients: usize,
    pub val_clients: usize,
    pub test_clients: usize,
    pub topics: usize,
    pub zipf_s: f64,
    /// Probability the bigram successor map fires (learnable signal).
    pub p_det: f32,
    pub seed: u64,
}

impl TextConfig {
    pub fn new(vocab: usize, seq: usize) -> Self {
        TextConfig {
            vocab,
            seq,
            train_clients: 300,
            val_clients: 30,
            test_clients: 60,
            topics: 12,
            zipf_s: 1.05,
            p_det: 0.65,
            seed: 41,
        }
    }

    pub fn with_clients(mut self, train: usize, val: usize, test: usize) -> Self {
        self.train_clients = train;
        self.val_clients = val;
        self.test_clients = test;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Fixed global successor map: a seeded pseudo-permutation biased toward
/// frequent tokens so successors are themselves Zipf-plausible.
fn successor(cur: u32, vocab: usize, seed: u64) -> u32 {
    let h = (cur as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(seed)
        .rotate_left(23)
        .wrapping_mul(0xBF58476D1CE4E5B9);
    // bias toward the Zipf head: square the uniform variate
    let u = (h >> 11) as f64 / (1u64 << 53) as f64;
    let r = (u * u * (vocab as f64 - 1.0)) as u32 + 1;
    r.min(vocab as u32 - 1)
}

fn gen_client(
    id: u64,
    cfg: &TextConfig,
    zipf: &Zipf,
    topic_bands: &[(usize, usize)],
    rng: &mut Rng,
) -> ClientData {
    // Client draws fresh tokens from a mixture of global Zipf and its topic
    // band (a contiguous rank range, i.e. a coherent subset of vocabulary).
    let topic = rng.below(cfg.topics);
    let (lo, hi) = topic_bands[topic];
    let n = skewed_count(rng, 2.8, 0.8, 4, 80);
    let mut examples = Vec::with_capacity(n);
    let mut cur = zipf.sample(rng) as u32;
    for _ in 0..n {
        let mut tokens = Vec::with_capacity(cfg.seq + 1);
        for _ in 0..cfg.seq + 1 {
            tokens.push(cur);
            cur = if rng.f32() < cfg.p_det {
                successor(cur, cfg.vocab, cfg.seed)
            } else if rng.f32() < 0.6 {
                (lo + rng.below(hi - lo)) as u32
            } else {
                zipf.sample(rng) as u32
            };
        }
        examples.push(Example::Text { tokens });
    }
    let feature_counts = ClientData::compute_feature_counts(&examples);
    ClientData {
        id,
        examples,
        feature_counts,
    }
}

pub fn generate(cfg: &TextConfig) -> FederatedDataset {
    let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
    // Topic bands: overlapping rank ranges, denser near the head.
    let bands: Vec<(usize, usize)> = (0..cfg.topics)
        .map(|t| {
            let span = (cfg.vocab / 4).max(16);
            let lo = 1 + (t * (cfg.vocab - span - 1)) / cfg.topics.max(1);
            (lo, (lo + span).min(cfg.vocab))
        })
        .collect();
    let split = |count: usize, salt: u64| -> Vec<ClientData> {
        (0..count)
            .map(|i| {
                let mut rng = Rng::new(cfg.seed ^ (salt << 36) ^ i as u64, salt * 13 + 5);
                gen_client(i as u64, cfg, &zipf, &bands, &mut rng)
            })
            .collect()
    };
    FederatedDataset {
        name: format!("synth-textcorpus(v={},L={})", cfg.vocab, cfg.seq),
        train: split(cfg.train_clients, 1),
        val: split(cfg.val_clients, 2),
        test: split(cfg.test_clients, 3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_right_length_and_range() {
        let cfg = TextConfig::new(512, 20).with_clients(10, 2, 3);
        let ds = generate(&cfg);
        for c in &ds.train {
            for e in &c.examples {
                if let Example::Text { tokens } = e {
                    assert_eq!(tokens.len(), 21);
                    assert!(tokens.iter().all(|&t| (t as usize) < 512));
                } else {
                    panic!("wrong kind");
                }
            }
        }
    }

    #[test]
    fn successor_map_is_deterministic_and_in_range() {
        for cur in 0..100u32 {
            let a = successor(cur, 512, 7);
            let b = successor(cur, 512, 7);
            assert_eq!(a, b);
            assert!((1..512).contains(&(a as usize)));
        }
    }

    #[test]
    fn bigram_structure_is_present() {
        // successor(cur) must appear after cur far more often than chance
        let cfg = TextConfig::new(256, 20).with_clients(30, 0, 0);
        let ds = generate(&cfg);
        let mut hits = 0usize;
        let mut total = 0usize;
        for c in &ds.train {
            for e in &c.examples {
                if let Example::Text { tokens } = e {
                    for w in tokens.windows(2) {
                        total += 1;
                        if w[1] == successor(w[0], cfg.vocab, cfg.seed) {
                            hits += 1;
                        }
                    }
                }
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.4, "deterministic-successor rate {rate}");
    }

    #[test]
    fn clients_concentrate_on_topic_bands() {
        let cfg = TextConfig::new(2048, 20).with_clients(10, 0, 0);
        let ds = generate(&cfg);
        for c in &ds.train {
            let total: u32 = c.feature_counts.iter().map(|&(_, n)| n).sum();
            let top_m: u32 = {
                let mut f = c.features_by_frequency();
                f.truncate(256);
                let set: std::collections::HashSet<u32> = f.into_iter().collect();
                c.feature_counts
                    .iter()
                    .filter(|(w, _)| set.contains(w))
                    .map(|&(_, n)| n)
                    .sum()
            };
            // top-256 of 2048 tokens should cover most of the client's text
            assert!(
                top_m as f64 / total as f64 > 0.5,
                "coverage {}",
                top_m as f64 / total as f64
            );
        }
    }
}

//! Minimal JSON parser/serializer (offline substitute for serde_json).
//!
//! Parses the subset of JSON that `artifacts/manifest.json` uses — objects,
//! arrays, strings (with escapes), numbers, booleans, null — into a [`Json`]
//! value tree. Strict enough to reject malformed documents; small enough to
//! audit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(s, "{}", *n as i64);
                } else {
                    let _ = write!(s, "{n}");
                }
            }
            Json::Str(v) => write_escaped(s, v),
            Json::Arr(v) => {
                s.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    x.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_escaped(s, k);
                    s.push(':');
                    x.write(s);
                }
                s.push('}');
            }
        }
    }
}

fn write_escaped(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "bad utf8 in string")?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"name": "a", "shape": [64, 50], "dtype": "f32", "ok": true,
             "meta": {"m": 64}, "note": "line\nbreak é"}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("a"));
        assert_eq!(
            a.get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![64, 50]
        );
        assert_eq!(a.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(a.get("note").unwrap().as_str(), Some("line\nbreak é"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "01a", "\"x", "{} extra"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y","c":null,"d":false}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn handles_empty_containers_and_numbers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
    }
}

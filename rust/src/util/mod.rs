//! Small self-contained utilities (the offline build ships its own JSON and
//! CLI parsing — see Cargo.toml).

pub mod cli;
pub mod json;

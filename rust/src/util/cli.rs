//! Tiny `--flag value` argument parser (offline substitute for clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and a leading
//! subcommand word. Unknown flags are an error (catches typos in sweeps).

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (not including argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut it = raw.into_iter().peekable();
        let mut a = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(stripped) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {tok:?}"));
            };
            if let Some((k, v)) = stripped.split_once('=') {
                a.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                a.flags.insert(stripped.to_string(), it.next().unwrap());
            } else {
                a.flags.insert(stripped.to_string(), "true".to_string());
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Whether any `--flag` was given at all (used to tell a bare
    /// `fedselect` info invocation from a flags-only training run).
    pub fn has_flags(&self) -> bool {
        !self.flags.is_empty()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| format!("bad value for --{key}: {e}")),
        }
    }

    /// Error on any flag never queried (typo detection). Call last.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flag(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--rounds", "20", "--quick", "--m=64"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.parse_or("rounds", 0usize).unwrap(), 20);
        assert!(a.flag("quick"));
        assert_eq!(a.str_or("m", "0"), "64");
        assert_eq!(a.parse_or("missing", 5usize).unwrap(), 5);
        a.reject_unknown().unwrap();
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let a = parse(&["--oops", "1"]);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn has_flags_distinguishes_bare_invocations() {
        assert!(!parse(&[]).has_flags());
        assert!(!parse(&["info"]).has_flags());
        assert!(parse(&["--fleet", "tiered-3"]).has_flags());
    }

    #[test]
    fn positional_after_flags_is_error() {
        assert!(Args::parse(vec!["--a".into(), "--b".into(), "stray2".into(),]).is_ok());
        assert!(Args::parse(vec!["cmd".into(), "stray".into()]).is_err());
    }

    #[test]
    fn bad_numeric_is_error() {
        let a = parse(&["--rounds", "abc"]);
        assert!(a.parse_or("rounds", 0usize).is_err());
    }
}

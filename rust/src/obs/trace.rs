//! File-backed trace sinks and trace-file utilities.
//!
//! # JSONL schema (`fedselect-trace-v1`)
//!
//! Line 1 is the header `{"schema":"fedselect-trace-v1","t":"header"}`;
//! every following line is one event object whose `"t"` field names the
//! [`TraceEvent`] variant (`run_start`, `round_start`, `span`, `task`,
//! `client`, `round_close`, `eval`, `incident`, `tick`, `log`, `run_end`;
//! `task` and `incident` are v1-additive families — per-slot executor
//! tasks, and health-monitor incident open/update/resolve steps). Keys
//! are emitted in
//! sorted order and numbers use the crate's deterministic formatter, so
//! the sim-clock content of two same-seed traces is byte-identical; the
//! only nondeterministic fields are named `wall_ms`, which
//! [`strip_nondeterministic`] removes before [`diff_traces`] compares.
//!
//! # Chrome export
//!
//! [`ChromeRecorder`] writes the Chrome trace-event JSON array format
//! (open in `chrome://tracing` or Perfetto): phase spans become `"X"`
//! complete events on the wall clock, everything else becomes `"i"`
//! instant events. The closing `]` is intentionally never written — the
//! format explicitly tolerates an unterminated array, which keeps the sink
//! crash-safe.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::sync::Mutex;
use std::time::Instant;

use super::{Recorder, TraceEvent};
use crate::util::json::Json;

/// Versioned schema tag written on the header line of every JSONL trace.
pub const TRACE_SCHEMA: &str = "fedselect-trace-v1";

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn uint(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Encode one event as the JSON object written to a JSONL trace line.
pub fn encode_event(ev: &TraceEvent) -> Json {
    let tag = Json::Str(ev.tag().to_string());
    match ev {
        TraceEvent::RunStart { ns, seed, rounds, cohort, mode } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("seed", uint(*seed)),
            ("rounds", uint(*rounds as u64)),
            ("cohort", uint(*cohort as u64)),
            ("mode", Json::Str(mode.clone())),
        ]),
        TraceEvent::RoundStart { ns, round, sim_start_s } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("sim_start_s", num(*sim_start_s)),
        ]),
        TraceEvent::Span { ns, round, phase, wall_ms, sim_s } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("phase", Json::Str(phase.name().to_string())),
            ("wall_ms", num(*wall_ms)),
            ("sim_s", num(*sim_s)),
        ]),
        TraceEvent::Task { ns, round, client, tier, wall_ms, sim_s } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("client", uint(*client as u64)),
            ("tier", uint(*tier as u64)),
            ("wall_ms", num(*wall_ms)),
            ("sim_s", num(*sim_s)),
        ]),
        TraceEvent::Client { ns, round, client, tier, stage } => {
            let mut pairs = vec![
                ("t", tag),
                ("ns", uint(*ns as u64)),
                ("round", uint(*round as u64)),
                ("client", uint(*client as u64)),
                (
                    "tier",
                    match tier {
                        Some(t) => uint(*t as u64),
                        None => Json::Null,
                    },
                ),
                ("stage", Json::Str(stage.name().to_string())),
            ];
            match *stage {
                super::ClientStage::Fetched { down_bytes, cache_hit_pieces } => {
                    pairs.push(("down_bytes", uint(down_bytes)));
                    pairs.push(("cache_hit_pieces", uint(cache_hit_pieces)));
                }
                super::ClientStage::Computed { up_bytes } => {
                    pairs.push(("up_bytes", uint(up_bytes)));
                }
                super::ClientStage::Merged { staleness, weight } => {
                    pairs.push(("staleness", uint(staleness as u64)));
                    pairs.push(("weight", num(weight as f64)));
                }
                super::ClientStage::CommitteeKeyed { committee, submitter } => {
                    pairs.push(("committee", uint(committee as u64)));
                    pairs.push(("submitter", Json::Bool(submitter)));
                }
                _ => {}
            }
            obj(pairs)
        }
        TraceEvent::RoundClose {
            ns,
            round,
            completed,
            dropped,
            discarded,
            deferred,
            committees,
            close_s,
            sim_round_s,
            sim_total_s,
            down_bytes,
            up_bytes,
            eligible,
            arrivals,
            departures,
            outage_excluded,
            clients_touched,
            resident_bytes,
        } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("completed", uint(*completed as u64)),
            ("dropped", uint(*dropped as u64)),
            ("discarded", uint(*discarded as u64)),
            ("deferred", uint(*deferred as u64)),
            ("committees", uint(*committees as u64)),
            ("close_s", num(*close_s)),
            ("sim_round_s", num(*sim_round_s)),
            ("sim_total_s", num(*sim_total_s)),
            ("down_bytes", uint(*down_bytes)),
            ("up_bytes", uint(*up_bytes)),
            ("eligible", uint(*eligible as u64)),
            ("arrivals", uint(*arrivals as u64)),
            ("departures", uint(*departures as u64)),
            ("outage_excluded", uint(*outage_excluded as u64)),
            ("clients_touched", uint(*clients_touched as u64)),
            ("resident_bytes", uint(*resident_bytes)),
        ]),
        TraceEvent::Eval { ns, round, loss, metric, examples, wall_ms } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("loss", num(*loss)),
            ("metric", num(*metric)),
            ("examples", uint(*examples as u64)),
            ("wall_ms", num(*wall_ms)),
        ]),
        TraceEvent::Incident {
            ns,
            round,
            id,
            action,
            severity,
            rule,
            series,
            observed,
            expected,
            sim_s,
        } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("round", uint(*round as u64)),
            ("id", uint(*id as u64)),
            ("action", Json::Str(action.name().to_string())),
            ("severity", Json::Str(severity.name().to_string())),
            ("rule", Json::Str(rule.clone())),
            ("series", Json::Str(series.clone())),
            ("observed", num(*observed)),
            ("expected", num(*expected)),
            ("sim_s", num(*sim_s)),
        ]),
        TraceEvent::Tick { tick, granted } => obj(vec![
            ("t", tag),
            ("tick", uint(*tick)),
            (
                "granted",
                Json::Arr(granted.iter().map(|&j| uint(j as u64)).collect()),
            ),
        ]),
        TraceEvent::Log { level, msg } => obj(vec![
            ("t", tag),
            ("level", Json::Str(level.name().to_string())),
            ("msg", Json::Str(msg.clone())),
        ]),
        TraceEvent::RunEnd { ns, rounds, sim_total_s } => obj(vec![
            ("t", tag),
            ("ns", uint(*ns as u64)),
            ("rounds", uint(*rounds as u64)),
            ("sim_total_s", num(*sim_total_s)),
        ]),
    }
}

/// JSONL sink: one event per line behind a buffered writer.
pub struct JsonlRecorder {
    w: Mutex<BufWriter<File>>,
}

impl JsonlRecorder {
    /// Create (truncate) `path` and write the schema header line.
    pub fn create(path: &str) -> io::Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        let header = obj(vec![
            ("schema", Json::Str(TRACE_SCHEMA.to_string())),
            ("t", Json::Str("header".to_string())),
        ]);
        writeln!(w, "{}", header.dump())?;
        Ok(JsonlRecorder { w: Mutex::new(w) })
    }
}

impl Recorder for JsonlRecorder {
    fn record(&self, ev: &TraceEvent) {
        let line = encode_event(ev).dump();
        if let Ok(mut w) = self.w.lock() {
            let _ = writeln!(w, "{line}");
        }
    }

    fn flush(&self) {
        if let Ok(mut w) = self.w.lock() {
            let _ = w.flush();
        }
    }
}

struct ChromeInner {
    w: BufWriter<File>,
    first: bool,
}

/// Chrome trace-event sink. `pid` carries the job namespace, `tid` the
/// round, so multi-tenant phase waterfalls separate per job.
pub struct ChromeRecorder {
    inner: Mutex<ChromeInner>,
    epoch: Instant,
}

impl ChromeRecorder {
    /// Create (truncate) `path` and open the trace-event array.
    pub fn create(path: &str) -> io::Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        write!(w, "[")?;
        Ok(ChromeRecorder {
            inner: Mutex::new(ChromeInner { w, first: true }),
            epoch: Instant::now(),
        })
    }

    fn write_record(&self, record: Json) {
        if let Ok(mut inner) = self.inner.lock() {
            let sep = if inner.first { "\n" } else { ",\n" };
            inner.first = false;
            let line = record.dump();
            let _ = write!(inner.w, "{sep}{line}");
        }
    }
}

impl Recorder for ChromeRecorder {
    fn record(&self, ev: &TraceEvent) {
        let now_us = self.epoch.elapsed().as_micros() as u64;
        let (ns, round) = match ev {
            TraceEvent::RunStart { ns, .. }
            | TraceEvent::RunEnd { ns, .. } => (*ns, 0),
            TraceEvent::RoundStart { ns, round, .. }
            | TraceEvent::Span { ns, round, .. }
            | TraceEvent::Task { ns, round, .. }
            | TraceEvent::Client { ns, round, .. }
            | TraceEvent::RoundClose { ns, round, .. }
            | TraceEvent::Eval { ns, round, .. }
            | TraceEvent::Incident { ns, round, .. } => (*ns, *round),
            TraceEvent::Tick { .. } | TraceEvent::Log { .. } => (0, 0),
        };
        let record = match ev {
            // per-slot tasks render as overlapping complete events on the
            // round's row, named by client — the executor waterfall
            TraceEvent::Task { client, wall_ms, sim_s, .. } => {
                let dur_us = (wall_ms * 1e3).max(0.0) as u64;
                obj(vec![
                    ("name", Json::Str(format!("task c{client}"))),
                    ("ph", Json::Str("X".to_string())),
                    ("pid", uint(ns as u64)),
                    ("tid", uint(round as u64)),
                    ("ts", uint(now_us.saturating_sub(dur_us))),
                    ("dur", uint(dur_us)),
                    ("args", obj(vec![("sim_s", num(*sim_s))])),
                ])
            }
            TraceEvent::Span { phase, wall_ms, sim_s, .. } => {
                let dur_us = (wall_ms * 1e3).max(0.0) as u64;
                obj(vec![
                    ("name", Json::Str(phase.name().to_string())),
                    ("ph", Json::Str("X".to_string())),
                    ("pid", uint(ns as u64)),
                    ("tid", uint(round as u64)),
                    ("ts", uint(now_us.saturating_sub(dur_us))),
                    ("dur", uint(dur_us)),
                    ("args", obj(vec![("sim_s", num(*sim_s))])),
                ])
            }
            other => obj(vec![
                ("name", Json::Str(other.tag().to_string())),
                ("ph", Json::Str("i".to_string())),
                ("s", Json::Str("t".to_string())),
                ("pid", uint(ns as u64)),
                ("tid", uint(round as u64)),
                ("ts", uint(now_us)),
                ("args", encode_event(other)),
            ]),
        };
        self.write_record(record);
    }

    fn flush(&self) {
        if let Ok(mut inner) = self.inner.lock() {
            let _ = inner.w.flush();
        }
    }
}

/// Required keys per event type, used by [`validate_trace_line`].
fn required_keys(tag: &str) -> Option<&'static [&'static str]> {
    Some(match tag {
        "header" => &["schema"],
        "run_start" => &["ns", "seed", "rounds", "cohort", "mode"],
        "round_start" => &["ns", "round", "sim_start_s"],
        "span" => &["ns", "round", "phase", "wall_ms", "sim_s"],
        "task" => &["ns", "round", "client", "tier", "wall_ms", "sim_s"],
        "client" => &["ns", "round", "client", "tier", "stage"],
        "round_close" => &[
            "ns",
            "round",
            "completed",
            "dropped",
            "discarded",
            "deferred",
            "committees",
            "close_s",
            "sim_round_s",
            "sim_total_s",
            "down_bytes",
            "up_bytes",
            "eligible",
            "arrivals",
            "departures",
            "outage_excluded",
            "clients_touched",
            "resident_bytes",
        ],
        "eval" => &["ns", "round", "loss", "metric", "examples", "wall_ms"],
        "incident" => &[
            "ns", "round", "id", "action", "severity", "rule", "series", "observed",
            "expected", "sim_s",
        ],
        "tick" => &["tick", "granted"],
        "log" => &["level", "msg"],
        "run_end" => &["ns", "rounds", "sim_total_s"],
        _ => return None,
    })
}

/// Validate one JSONL trace line against schema v1: parseable JSON object,
/// known `"t"` tag, all required keys present.
pub fn validate_trace_line(line: &str) -> Result<(), String> {
    let json = Json::parse(line)?;
    let Json::Obj(_) = &json else {
        return Err("trace line is not a JSON object".to_string());
    };
    let tag = json
        .get("t")
        .and_then(|t| t.as_str())
        .ok_or_else(|| "trace line has no string 't' tag".to_string())?;
    let keys =
        required_keys(tag).ok_or_else(|| format!("unknown trace event type '{tag}'"))?;
    for k in keys {
        if json.get(k).is_none() {
            return Err(format!("'{tag}' line is missing required key '{k}'"));
        }
    }
    Ok(())
}

/// Recursively remove every `wall_ms` field — the only nondeterministic
/// content of a JSONL trace — so same-seed traces compare byte-identical.
pub fn strip_nondeterministic(json: &mut Json) {
    match json {
        Json::Obj(map) => {
            map.remove("wall_ms");
            for v in map.values_mut() {
                strip_nondeterministic(v);
            }
        }
        Json::Arr(items) => {
            for v in items.iter_mut() {
                strip_nondeterministic(v);
            }
        }
        _ => {}
    }
}

/// Compare the deterministic content of two JSONL traces. Returns `None`
/// when they agree, else a description of the first divergence. `log`
/// lines are skipped (log text may carry host-dependent paths); `wall_ms`
/// fields are stripped; everything else — every sim-clock timestamp, byte
/// count, client event, and close decision — must match exactly.
pub fn diff_traces(a: &str, b: &str) -> Option<String> {
    let canon = |text: &str| -> Vec<(usize, String)> {
        text.lines()
            .enumerate()
            .filter(|(_, l)| !l.trim().is_empty())
            .filter_map(|(i, l)| match Json::parse(l) {
                Ok(mut j) => {
                    if j.get("t").and_then(|t| t.as_str()) == Some("log") {
                        None
                    } else {
                        strip_nondeterministic(&mut j);
                        Some((i + 1, j.dump()))
                    }
                }
                Err(e) => Some((i + 1, format!("<unparseable: {e}>"))),
            })
            .collect()
    };
    let (la, lb) = (canon(a), canon(b));
    for (ea, eb) in la.iter().zip(lb.iter()) {
        if ea.1 != eb.1 {
            return Some(format!(
                "first divergence at line {} vs line {}:\n  a: {}\n  b: {}",
                ea.0, eb.0, ea.1, eb.1
            ));
        }
    }
    if la.len() != lb.len() {
        return Some(format!(
            "traces differ in length: {} vs {} deterministic lines",
            la.len(),
            lb.len()
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ClientStage, LogLevel, Phase};

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::RunStart {
                ns: 0,
                seed: 7,
                rounds: 2,
                cohort: 4,
                mode: "sync".to_string(),
            },
            TraceEvent::RoundStart { ns: 0, round: 1, sim_start_s: 0.0 },
            TraceEvent::Client {
                ns: 0,
                round: 1,
                client: 3,
                tier: Some(1),
                stage: ClientStage::Fetched { down_bytes: 1024, cache_hit_pieces: 2 },
            },
            TraceEvent::Span {
                ns: 0,
                round: 1,
                phase: Phase::Fetch,
                wall_ms: 1.25,
                sim_s: 3.5,
            },
            TraceEvent::Task {
                ns: 0,
                round: 1,
                client: 3,
                tier: 1,
                wall_ms: 0.75,
                sim_s: 3.5,
            },
            TraceEvent::RoundClose {
                ns: 0,
                round: 1,
                completed: 4,
                dropped: 0,
                discarded: 0,
                deferred: 0,
                committees: 1,
                close_s: 12.0,
                sim_round_s: 13.0,
                sim_total_s: 13.0,
                down_bytes: 4096,
                up_bytes: 2048,
                eligible: 8,
                arrivals: 1,
                departures: 1,
                outage_excluded: 0,
                clients_touched: 6,
                resident_bytes: 512,
            },
            TraceEvent::Incident {
                ns: 0,
                round: 1,
                id: 0,
                action: crate::obs::IncidentAction::Open,
                severity: crate::obs::Severity::Critical,
                rule: "slo:eligible_frac:ge:0.8".to_string(),
                series: "eligible_frac".to_string(),
                observed: 0.5,
                expected: 0.8,
                sim_s: 13.0,
            },
            TraceEvent::Log { level: LogLevel::Info, msg: "hello".to_string() },
            TraceEvent::RunEnd { ns: 0, rounds: 2, sim_total_s: 26.0 },
        ]
    }

    #[test]
    fn encoded_events_validate_against_the_schema() {
        for ev in sample_events() {
            let line = encode_event(&ev).dump();
            validate_trace_line(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        }
        assert!(validate_trace_line("{\"t\":\"martian\"}").is_err());
        assert!(validate_trace_line("{\"no_tag\":1}").is_err());
        assert!(validate_trace_line("{\"t\":\"span\",\"ns\":0}").is_err());
    }

    #[test]
    fn encoding_is_deterministic() {
        for ev in sample_events() {
            assert_eq!(encode_event(&ev).dump(), encode_event(&ev).dump());
        }
    }

    #[test]
    fn strip_removes_only_wall_clock_fields() {
        let mut j = Json::parse(
            "{\"t\":\"span\",\"wall_ms\":3.25,\"sim_s\":1.5,\"nested\":{\"wall_ms\":1}}",
        )
        .unwrap();
        strip_nondeterministic(&mut j);
        let dumped = j.dump();
        assert!(!dumped.contains("wall_ms"));
        assert!(dumped.contains("sim_s"));
    }

    #[test]
    fn diff_ignores_wall_clock_and_log_lines_but_flags_sim_divergence() {
        let a = "{\"t\":\"span\",\"ns\":0,\"round\":1,\"phase\":\"plan\",\"wall_ms\":1.0,\"sim_s\":2.0}\n{\"t\":\"log\",\"level\":\"info\",\"msg\":\"from host a\"}\n";
        let b = "{\"t\":\"span\",\"ns\":0,\"round\":1,\"phase\":\"plan\",\"wall_ms\":9.0,\"sim_s\":2.0}\n{\"t\":\"log\",\"level\":\"info\",\"msg\":\"from host b\"}\n";
        assert_eq!(diff_traces(a, b), None);
        let c = b.replace("\"sim_s\":2.0", "\"sim_s\":3.0");
        let msg = diff_traces(a, &c).expect("sim divergence must be flagged");
        assert!(msg.contains("divergence"));
        let d = format!("{a}{{\"t\":\"run_end\",\"ns\":0,\"rounds\":1,\"sim_total_s\":2.0}}\n");
        assert!(diff_traces(a, &d).unwrap().contains("length"));
    }

    #[test]
    fn diff_treats_incident_lines_as_content_not_log_noise() {
        let inc = "{\"t\":\"incident\",\"ns\":0,\"round\":2,\"id\":0,\"action\":\"open\",\"severity\":\"critical\",\"rule\":\"slo:eligible_frac:ge:0.8\",\"series\":\"eligible_frac\",\"observed\":0.5,\"expected\":0.8,\"sim_s\":26.0}\n";
        assert_eq!(diff_traces(inc, inc), None);
        let mutated = inc.replace("\"observed\":0.5", "\"observed\":0.25");
        let msg = diff_traces(inc, &mutated).expect("incident divergence must be flagged");
        assert!(msg.contains("divergence"));
        // Dropping the incident line entirely is a length divergence —
        // unlike `log` lines, incidents are never skipped.
        assert!(diff_traces(inc, "").unwrap().contains("length"));
    }

    #[test]
    fn jsonl_recorder_writes_header_and_events() {
        let path = std::env::temp_dir().join("fedselect_obs_trace_unit.jsonl");
        let path = path.to_str().unwrap().to_string();
        {
            let rec = JsonlRecorder::create(&path).unwrap();
            for ev in sample_events() {
                rec.record(&ev);
            }
            rec.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sample_events().len() + 1);
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.get("schema").and_then(|s| s.as_str()), Some(TRACE_SCHEMA));
        for line in &lines {
            validate_trace_line(line).unwrap();
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chrome_recorder_emits_a_trace_event_array() {
        let path = std::env::temp_dir().join("fedselect_obs_trace_unit.chrome.json");
        let path = path.to_str().unwrap().to_string();
        {
            let rec = ChromeRecorder::create(&path).unwrap();
            for ev in sample_events() {
                rec.record(&ev);
            }
            rec.flush();
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('['));
        // the array is intentionally unterminated (crash-safe); close it
        // the way chrome://tracing's parser effectively does
        text.push(']');
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.as_arr().unwrap();
        assert_eq!(events.len(), sample_events().len());
        assert_eq!(
            events[3].get("ph").and_then(|p| p.as_str()),
            Some("X"),
            "span events are complete events"
        );
        let _ = std::fs::remove_file(&path);
    }
}

//! Leveled logging routed through the telemetry layer.
//!
//! The `obs_error!` / `obs_warn!` / `obs_info!` / `obs_debug!` macros
//! replace the ad-hoc `println!`/`eprintln!` sites: `info`/`debug` go to
//! stdout, `warn`/`error` to stderr, so stdout is byte-identical to the
//! pre-telemetry binary at the default `info` level. When a trace sink is
//! installed ([`set_sink`]), every printed line is also recorded as a
//! [`TraceEvent::Log`] event — log lines may carry host-dependent text, so
//! the trace differ skips them (`trace::diff_traces`).

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

use super::{Recorder, TraceEvent};

/// Log threshold, most to least severe. `--log-level` sets it; `--quiet`
/// maps to `Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    /// Stable lowercase name used in trace output.
    pub fn name(self) -> &'static str {
        match self {
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Self {
        match v {
            0 => LogLevel::Error,
            1 => LogLevel::Warn,
            3 => LogLevel::Debug,
            _ => LogLevel::Info,
        }
    }
}

impl fmt::Display for LogLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl FromStr for LogLevel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "error" => Ok(LogLevel::Error),
            "warn" => Ok(LogLevel::Warn),
            "info" => Ok(LogLevel::Info),
            "debug" => Ok(LogLevel::Debug),
            other => Err(format!(
                "unknown log level '{other}' (expected error|warn|info|debug)"
            )),
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Info as u8);
static SINK: Mutex<Option<Arc<dyn Recorder>>> = Mutex::new(None);

/// Set the process-wide log threshold.
pub fn set_level(level: LogLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log threshold.
pub fn level() -> LogLevel {
    LogLevel::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether messages at `l` currently print.
pub fn enabled(l: LogLevel) -> bool {
    l <= level()
}

/// Install (or clear) the recorder that mirrors printed log lines into the
/// trace stream.
pub fn set_sink(rec: Option<Arc<dyn Recorder>>) {
    if let Ok(mut guard) = SINK.lock() {
        *guard = rec;
    }
}

/// Print one leveled line and mirror it to the trace sink. Prefer the
/// `obs_*!` macros over calling this directly.
pub fn emit(level: LogLevel, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let msg = args.to_string();
    match level {
        LogLevel::Error | LogLevel::Warn => eprintln!("{msg}"),
        LogLevel::Info | LogLevel::Debug => println!("{msg}"),
    }
    if let Ok(guard) = SINK.lock() {
        if let Some(rec) = guard.as_ref() {
            if rec.enabled() {
                rec.record(&TraceEvent::Log { level, msg });
            }
        }
    }
}

/// Log at `error` level (stderr; always printed).
#[macro_export]
macro_rules! obs_error {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::LogLevel::Error, format_args!($($arg)*))
    };
}

/// Log at `warn` level (stderr).
#[macro_export]
macro_rules! obs_warn {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::LogLevel::Warn, format_args!($($arg)*))
    };
}

/// Log at `info` level (stdout; the default threshold).
#[macro_export]
macro_rules! obs_info {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::LogLevel::Info, format_args!($($arg)*))
    };
}

/// Log at `debug` level (stdout; off by default).
#[macro_export]
macro_rules! obs_debug {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::LogLevel::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_round_trip() {
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
        for l in [LogLevel::Error, LogLevel::Warn, LogLevel::Info, LogLevel::Debug] {
            assert_eq!(l.to_string().parse::<LogLevel>().unwrap(), l);
        }
        assert!("verbose".parse::<LogLevel>().is_err());
    }

    #[test]
    fn threshold_gates_emission() {
        let before = level();
        set_level(LogLevel::Error);
        assert!(enabled(LogLevel::Error));
        assert!(!enabled(LogLevel::Warn));
        assert!(!enabled(LogLevel::Info));
        set_level(LogLevel::Debug);
        assert!(enabled(LogLevel::Debug));
        set_level(before);
    }
}

//! Structured telemetry: deterministic round tracing, leveled logging, and
//! a metrics registry shared by the trainer, the summaries, and the bench
//! harness.
//!
//! # Architecture
//!
//! Every instrumented layer (coordinator, round engine, scheduler, cache
//! commit, SecAgg committees, tenancy arbiter) reports through one
//! [`Recorder`]. Events are plain enums ([`TraceEvent`]) carrying **both**
//! clocks:
//!
//! - *wall-clock* fields (always named `wall_ms`) measure host time and are
//!   nondeterministic by nature;
//! - *sim-clock* fields (`sim_*`, `close_s`, staleness, byte counts) are
//!   produced by the deterministic simulation and must be byte-identical
//!   across same-seed runs.
//!
//! Three sinks implement the trait:
//!
//! | sink | selected by | cost |
//! |---|---|---|
//! | [`NullRecorder`] | default | none: `enabled()` is `false`, so call sites skip event construction entirely — zero allocation on the hot path |
//! | [`JsonlRecorder`] | `--trace-out PATH` | one JSON line per event, schema [`TRACE_SCHEMA`] |
//! | [`ChromeRecorder`] | `--trace-out PATH --trace-format chrome` | `chrome://tracing` / Perfetto trace-event array |
//!
//! # Determinism contract
//!
//! Telemetry observes, never steers: no recorder may touch an RNG, reorder
//! work, or feed anything back into the trajectory. `tests/obs.rs` enforces
//! that a traced run and a [`NullRecorder`] run produce identical
//! `RoundRecord`s (every field but the wall clock) at 1 and 4 fetch
//! threads, and that two same-seed JSONL traces are byte-identical after
//! stripping `wall_ms` fields ([`trace::diff_traces`]).

pub mod health;
pub mod log;
pub mod registry;
pub mod slo;
pub mod trace;

pub use health::{
    HealthConfig, HealthMonitor, HealthReport, HealthRollup, Incident, IncidentAction,
    IncidentEvent, Severity,
};
pub use log::{set_level, LogLevel};
pub use registry::{Histogram, MetricsRegistry};
pub use slo::{Series, SloOp, SloRule};
pub use trace::{
    diff_traces, validate_trace_line, ChromeRecorder, JsonlRecorder, TRACE_SCHEMA,
};

use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

use crate::error::{Error, Result};

/// The five spans of one training round, in execution order. `Eval` runs
/// outside the round proper (see `RoundRecord::wall_ms`, which covers
/// `Plan..=Close` only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Cohort selection, in-flight exclusion, and per-client key choice.
    Plan,
    /// Slice/delta fetches through the `RoundSession` plus cache commit.
    Fetch,
    /// Local training over the cohort slots.
    Compute,
    /// Scheduler events, engine close, aggregation, and the sim-clock tick.
    Close,
    /// Held-out evaluation (only on eval rounds).
    Eval,
}

impl Phase {
    /// Stable lowercase name used in trace output.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Fetch => "fetch",
            Phase::Compute => "compute",
            Phase::Close => "close",
            Phase::Eval => "eval",
        }
    }
}

/// Per-client lifecycle stage within a round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClientStage {
    /// Planned into the cohort this round.
    Selected,
    /// Downlink served: bytes over the wire and pieces answered by the
    /// on-device cache (0 when the cache is off).
    Fetched { down_bytes: u64, cache_hit_pieces: u64 },
    /// Dropped out mid-round (hazard coin); bytes already spent.
    Dropped,
    /// Finished local training and uploaded `up_bytes`.
    Computed { up_bytes: u64 },
    /// Update merged at this close, with its staleness class and weight.
    Merged { staleness: usize, weight: f32 },
    /// Computed update aged out / over-selected past the close — bytes
    /// spent, never merged.
    Discarded,
    /// Held back by the merge-deferral committee floor; returns to flight.
    Deferred,
    /// Keyed into a SecAgg committee (`submitter: false` = dropout whose
    /// mask is reconstructed).
    CommitteeKeyed { committee: usize, submitter: bool },
}

impl ClientStage {
    /// Stable lowercase name used in trace output.
    pub fn name(&self) -> &'static str {
        match self {
            ClientStage::Selected => "selected",
            ClientStage::Fetched { .. } => "fetched",
            ClientStage::Dropped => "dropped",
            ClientStage::Computed { .. } => "computed",
            ClientStage::Merged { .. } => "merged",
            ClientStage::Discarded => "discarded",
            ClientStage::Deferred => "deferred",
            ClientStage::CommitteeKeyed { .. } => "committee_keyed",
        }
    }
}

/// One telemetry event. Variants are cheap to construct, but call sites
/// must still guard construction with [`Recorder::enabled`] so the default
/// [`NullRecorder`] path allocates nothing.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// Run header, emitted once before the first round.
    RunStart {
        ns: u32,
        seed: u64,
        rounds: usize,
        cohort: usize,
        mode: String,
    },
    /// A round began; `sim_start_s` is the sim clock before the round.
    RoundStart { ns: u32, round: usize, sim_start_s: f64 },
    /// One phase span of a round. `wall_ms` is host time; `sim_s` is the
    /// deterministic sim-clock span attributed to the phase (for `Fetch` /
    /// `Compute` the slowest client's leg, for `Close` the close time;
    /// 0 where the phase has no simulated extent).
    Span {
        ns: u32,
        round: usize,
        phase: Phase,
        wall_ms: f64,
        sim_s: f64,
    },
    /// One cohort slot's fetch→train task through the pipelined executor
    /// (`fedselect-trace-v1`, additive): `wall_ms` is the task body's host
    /// time on whichever worker ran it, `sim_s` the slot's simulated
    /// completion point. Emitted per surviving slot in cohort order —
    /// deliberately *not* tagged `"span"`, so the per-round phase-span
    /// count is unchanged. Tasks overlap on the host; phase spans stay
    /// envelopes.
    Task {
        ns: u32,
        round: usize,
        client: usize,
        tier: usize,
        wall_ms: f64,
        sim_s: f64,
    },
    /// A per-client lifecycle event. `tier` is `None` when the stage does
    /// not know the device tier (committee dropouts keyed from a past
    /// close).
    Client {
        ns: u32,
        round: usize,
        client: usize,
        tier: Option<usize>,
        stage: ClientStage,
    },
    /// Round footer: the engine's close decision, the sim-clock tick, and
    /// the fleet-scale gauges (eligibility under churn/outage scenarios,
    /// arrivals/departures across the churn window boundary, and the
    /// touched-state footprint of the lazy fleet).
    RoundClose {
        ns: u32,
        round: usize,
        completed: usize,
        dropped: usize,
        discarded: usize,
        deferred: usize,
        committees: usize,
        close_s: f64,
        sim_round_s: f64,
        sim_total_s: f64,
        down_bytes: u64,
        up_bytes: u64,
        eligible: usize,
        arrivals: usize,
        departures: usize,
        outage_excluded: usize,
        clients_touched: usize,
        resident_bytes: u64,
    },
    /// Held-out evaluation result.
    Eval {
        ns: u32,
        round: usize,
        loss: f64,
        metric: f64,
        examples: usize,
        wall_ms: f64,
    },
    /// Health-monitor incident lifecycle step (`fedselect-trace-v1`,
    /// additive). Emitted after `round_close`, in deterministic rule/series
    /// order; all fields are sim-side for sim-side rules, so same-seed
    /// incident ledgers are byte-identical (and `trace_report --diff`
    /// compares them as content, unlike `log` lines).
    Incident {
        ns: u32,
        round: usize,
        id: u32,
        action: IncidentAction,
        severity: Severity,
        rule: String,
        series: String,
        observed: f64,
        expected: f64,
        sim_s: f64,
    },
    /// Multi-tenant arbiter tick: which job namespaces were granted.
    Tick { tick: u64, granted: Vec<u32> },
    /// A leveled log line routed through the recorder sink.
    Log { level: LogLevel, msg: String },
    /// Run footer, emitted by `finish_report`.
    RunEnd { ns: u32, rounds: usize, sim_total_s: f64 },
}

impl TraceEvent {
    /// Stable type tag used as the `"t"` field of trace lines.
    pub fn tag(&self) -> &'static str {
        match self {
            TraceEvent::RunStart { .. } => "run_start",
            TraceEvent::RoundStart { .. } => "round_start",
            TraceEvent::Span { .. } => "span",
            TraceEvent::Task { .. } => "task",
            TraceEvent::Client { .. } => "client",
            TraceEvent::RoundClose { .. } => "round_close",
            TraceEvent::Eval { .. } => "eval",
            TraceEvent::Incident { .. } => "incident",
            TraceEvent::Tick { .. } => "tick",
            TraceEvent::Log { .. } => "log",
            TraceEvent::RunEnd { .. } => "run_end",
        }
    }
}

/// A telemetry sink. Implementations must be `Send + Sync`: the trainer is
/// shared-referenced by fetch worker threads while the recorder is live.
pub trait Recorder: Send + Sync {
    /// Whether events should be built at all. Call sites guard event
    /// construction with this so the null sink costs nothing.
    fn enabled(&self) -> bool {
        true
    }
    /// Consume one event.
    fn record(&self, ev: &TraceEvent);
    /// Flush buffered output (end of run).
    fn flush(&self) {}
}

/// The default sink: drops everything and reports `enabled() == false`, so
/// instrumented code never constructs events. Trajectories with this sink
/// are byte-identical to pre-telemetry builds (test-enforced).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&self, _ev: &TraceEvent) {}
}

/// On-disk trace encoding selected by `--trace-format`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceFormat {
    /// One JSON object per line, schema [`TRACE_SCHEMA`] (default).
    #[default]
    Jsonl,
    /// Chrome trace-event array for `chrome://tracing` / Perfetto.
    Chrome,
}

impl fmt::Display for TraceFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceFormat::Jsonl => write!(f, "jsonl"),
            TraceFormat::Chrome => write!(f, "chrome"),
        }
    }
}

impl FromStr for TraceFormat {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "jsonl" => Ok(TraceFormat::Jsonl),
            "chrome" => Ok(TraceFormat::Chrome),
            other => Err(format!(
                "unknown trace format '{other}' (expected jsonl|chrome)"
            )),
        }
    }
}

/// Telemetry knobs carried by `TrainConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Stdout/stderr log threshold (`--log-level`, `--quiet`).
    pub log_level: LogLevel,
    /// Trace sink path (`--trace-out`); `None` selects [`NullRecorder`].
    pub trace_out: Option<String>,
    /// Trace encoding (`--trace-format`).
    pub trace_format: TraceFormat,
    /// Health monitor: SLO rules (`--slo`) + anomaly detectors
    /// (`--detect`, `--detect-warmup`). The default is fully off — the
    /// trainer then builds no [`HealthMonitor`] at all.
    pub health: HealthConfig,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            log_level: LogLevel::Info,
            trace_out: None,
            trace_format: TraceFormat::Jsonl,
            health: HealthConfig::default(),
        }
    }
}

/// Build the recorder an `ObsConfig` asks for: the null sink when no trace
/// path is set, otherwise a file-backed JSONL or Chrome recorder.
pub fn build_recorder(cfg: &ObsConfig) -> Result<Arc<dyn Recorder>> {
    match &cfg.trace_out {
        None => Ok(Arc::new(NullRecorder)),
        Some(path) => match cfg.trace_format {
            TraceFormat::Jsonl => Ok(Arc::new(
                JsonlRecorder::create(path).map_err(Error::Io)?,
            )),
            TraceFormat::Chrome => Ok(Arc::new(
                ChromeRecorder::create(path).map_err(Error::Io)?,
            )),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_format_round_trips() {
        for f in [TraceFormat::Jsonl, TraceFormat::Chrome] {
            assert_eq!(f.to_string().parse::<TraceFormat>().unwrap(), f);
        }
        assert!("perfetto".parse::<TraceFormat>().is_err());
    }

    #[test]
    fn null_recorder_is_disabled() {
        let r = NullRecorder;
        assert!(!r.enabled());
        r.record(&TraceEvent::RoundStart {
            ns: 0,
            round: 1,
            sim_start_s: 0.0,
        });
        r.flush();
    }

    #[test]
    fn default_obs_config_selects_the_null_sink() {
        let cfg = ObsConfig::default();
        assert_eq!(cfg.log_level, LogLevel::Info);
        let rec = build_recorder(&cfg).unwrap();
        assert!(!rec.enabled());
    }

    #[test]
    fn phase_and_stage_names_are_stable() {
        assert_eq!(Phase::Plan.name(), "plan");
        assert_eq!(Phase::Close.name(), "close");
        assert_eq!(ClientStage::Selected.name(), "selected");
        assert_eq!(
            ClientStage::CommitteeKeyed { committee: 0, submitter: true }.name(),
            "committee_keyed"
        );
    }
}

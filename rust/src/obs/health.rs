//! Deterministic fleet-health monitor: SLO evaluation + anomaly
//! detection over the per-round ledger, with a first-class incident
//! ledger.
//!
//! The [`HealthMonitor`] consumes each [`RoundRecord`] right after the
//! trainer folds it into the metrics registry and evaluates two rule
//! families:
//!
//! - **SLOs** ([`SloRule`]): declarative thresholds with `FOR_ROUNDS`
//!   hysteresis — an incident opens only after the rule has been violated
//!   for that many *consecutive* rounds, so one-round blips never page.
//!   SLO incidents are `critical`.
//! - **Anomaly detectors** (`--detect`): per-series EWMA mean/variance
//!   z-score and a windowed level-shift test, both gated behind a warm-up
//!   of `--detect-warmup` rounds (no incident can open before the window
//!   fills). Detector incidents are `warn`.
//!
//! Everything is computed from sim-clock quantities (detectors skip the
//! host-wall series entirely; see [`Series::sim_side`]), with fixed
//! constants and no RNG — two same-seed runs produce byte-identical
//! incident ledgers, and the ledger rides the trace as the additive
//! `incident` event family. The monitor *observes* the round ledger and
//! never steers the trajectory: with no SLOs and detectors off,
//! [`HealthMonitor::new`] returns `None` and the trainer carries no
//! monitor at all (byte-identity test-enforced in `tests/obs.rs`).

use std::collections::VecDeque;
use std::fmt;

use crate::coordinator::RoundRecord;
use crate::error::{Error, Result};
use crate::obs::slo::{Series, SloOp, SloRule, ALL_SERIES};

/// EWMA smoothing factor for the z-score detector.
const EWMA_LAMBDA: f64 = 0.25;
/// Std-deviation floor as a fraction of |mean|: a near-constant series
/// must move by at least `z_thresh × this × |mean|` to fire.
const STD_FLOOR_FRAC: f64 = 0.05;

/// Health-monitor configuration, carried by
/// [`crate::obs::ObsConfig::health`] (so every `TrainConfig` constructor
/// inherits the fully-off default).
#[derive(Clone, Debug, PartialEq)]
pub struct HealthConfig {
    /// Declarative threshold rules (`--slo KEY:OP:VALUE[:FOR_ROUNDS]`,
    /// comma-separated; per-job via `JobSpec::with_slos`).
    pub slos: Vec<SloRule>,
    /// Enable the EWMA z-score + level-shift anomaly detectors over all
    /// sim-side series (`--detect`).
    pub detectors: bool,
    /// Rounds of history a detector needs before it may open an incident
    /// (`--detect-warmup`, default 8).
    pub warmup: usize,
    /// |z| threshold for the EWMA detector (also scales the level-shift
    /// noise band).
    pub z_thresh: f64,
    /// Minimum level shift as a fraction of the old window mean.
    pub shift_frac: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            slos: Vec::new(),
            detectors: false,
            warmup: 8,
            z_thresh: 4.0,
            shift_frac: 0.2,
        }
    }
}

impl HealthConfig {
    /// Whether any monitoring is configured at all; when false the
    /// trainer does not construct a monitor (the fully-off contract).
    pub fn is_active(&self) -> bool {
        !self.slos.is_empty() || self.detectors
    }

    pub fn validate(&self) -> Result<()> {
        if self.detectors && self.warmup == 0 {
            return Err(Error::Config(
                "--detect-warmup must be >= 1 when detectors are on".into(),
            ));
        }
        if !(self.z_thresh.is_finite() && self.z_thresh > 0.0) {
            return Err(Error::Config("health z_thresh must be > 0".into()));
        }
        if !(self.shift_frac.is_finite() && self.shift_frac > 0.0) {
            return Err(Error::Config("health shift_frac must be > 0".into()));
        }
        for rule in &self.slos {
            if rule.for_rounds == 0 {
                return Err(Error::Config(format!(
                    "SLO rule {rule} has FOR_ROUNDS == 0"
                )));
            }
            if !rule.value.is_finite() {
                return Err(Error::Config(format!(
                    "SLO rule {rule} has a non-finite threshold"
                )));
            }
            // host-clock series would make the incident ledger vary run to
            // run, breaking the byte-identical same-seed contract
            if !rule.series.sim_side() {
                return Err(Error::Config(format!(
                    "SLO rule {rule} targets host-clock series {}; pick a \
                     sim-side series to keep the incident ledger deterministic",
                    rule.series
                )));
            }
        }
        Ok(())
    }
}

/// Incident severity: SLO breaches are `critical` (an explicit contract
/// was broken), detector anomalies are `warn` (statistically unusual).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warn,
    Critical,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Critical => "critical",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Lifecycle step an [`IncidentEvent`] reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IncidentAction {
    Open,
    Update,
    Resolve,
}

impl IncidentAction {
    pub fn name(&self) -> &'static str {
        match self {
            IncidentAction::Open => "open",
            IncidentAction::Update => "update",
            IncidentAction::Resolve => "resolve",
        }
    }
}

impl fmt::Display for IncidentAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One entry of the incident ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct Incident {
    /// Sequential per-run id (deterministic: rules are evaluated in
    /// declaration order, detectors in series order).
    pub id: u32,
    pub severity: Severity,
    /// Canonical rule label: `slo:<series>:<op>:<value>[:<for>]`,
    /// `ewma_z:<series>`, or `level_shift:<series>`.
    pub rule: String,
    pub series: Series,
    /// Round at which the incident opened (for SLOs with hysteresis this
    /// is the round the streak reached `FOR_ROUNDS`).
    pub opened_round: usize,
    /// Round of the first clean sample, `None` while still open at run
    /// end.
    pub resolved_round: Option<usize>,
    /// Last round observed in violation.
    pub last_round: usize,
    /// Violating rounds covered (for SLOs this includes the pre-open
    /// hysteresis streak).
    pub rounds: usize,
    /// Observed value when the incident opened.
    pub observed: f64,
    /// What the rule expected: the SLO threshold, or the detector
    /// baseline (EWMA mean / old-window mean) at open.
    pub expected: f64,
    /// Most deviant observed value over the incident's lifetime.
    pub worst: f64,
}

impl Incident {
    pub fn is_open(&self) -> bool {
        self.resolved_round.is_none()
    }
}

/// One incident lifecycle step, returned by
/// [`HealthMonitor::observe_round`] so the trainer can mirror it into
/// the metrics registry (`health.*`) and the trace (`incident` events).
#[derive(Clone, Debug)]
pub struct IncidentEvent {
    pub action: IncidentAction,
    pub id: u32,
    pub severity: Severity,
    pub rule: String,
    pub series: Series,
    pub round: usize,
    pub observed: f64,
    pub expected: f64,
}

/// End-of-run health rollup, carried by
/// [`crate::coordinator::TrainReport::health`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HealthReport {
    /// The full incident ledger, in open order.
    pub incidents: Vec<Incident>,
    /// Rounds the monitor observed (0 when the monitor was off).
    pub rounds_observed: usize,
    /// SLO rules that were active.
    pub rules: usize,
    /// Whether the anomaly detectors were on.
    pub detectors: bool,
}

impl HealthReport {
    pub fn total(&self) -> usize {
        self.incidents.len()
    }

    pub fn open_count(&self) -> usize {
        self.incidents.iter().filter(|i| i.is_open()).count()
    }

    pub fn critical_count(&self) -> usize {
        self.incidents
            .iter()
            .filter(|i| i.severity == Severity::Critical)
            .count()
    }

    /// Rounds covered by at least one incident (for precision/recall
    /// against scenario ground truth): every round in
    /// `[opened_round - pre_open_streak, last_round]` per incident.
    pub fn flagged_rounds(&self) -> Vec<usize> {
        let mut flagged: Vec<usize> = Vec::new();
        for inc in &self.incidents {
            let pre = inc.rounds.saturating_sub(
                inc.last_round.saturating_sub(inc.opened_round) + 1,
            );
            let start = inc.opened_round.saturating_sub(pre);
            for r in start..=inc.last_round {
                flagged.push(r);
            }
        }
        flagged.sort_unstable();
        flagged.dedup();
        flagged
    }

    /// One-line exit summary (printed by the CLI only when the monitor
    /// is active, preserving legacy stdout byte-for-byte otherwise).
    pub fn summary(&self) -> String {
        if self.incidents.is_empty() {
            return format!(
                "health: 0 incidents over {} round(s) ({} SLO rule(s), detectors {})",
                self.rounds_observed,
                self.rules,
                if self.detectors { "on" } else { "off" },
            );
        }
        format!(
            "health: {} incident(s) ({} critical, {} still open) over {} round(s)",
            self.total(),
            self.critical_count(),
            self.open_count(),
            self.rounds_observed,
        )
    }
}

/// Aggregate over per-job [`HealthReport`]s (multi-tenant rollup).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthRollup {
    pub incidents: usize,
    pub critical: usize,
    pub open: usize,
}

impl HealthRollup {
    pub fn fold<'a>(reports: impl Iterator<Item = &'a HealthReport>) -> HealthRollup {
        let mut out = HealthRollup::default();
        for r in reports {
            out.incidents += r.total();
            out.critical += r.critical_count();
            out.open += r.open_count();
        }
        out
    }
}

/// Extract one series sample from a round ledger entry; `None` means the
/// series is absent this round (no cache lookups, no keyed committee, or
/// a zero denominator).
pub fn sample(series: Series, rec: &RoundRecord, fleet_n: usize, cohort: usize) -> Option<f64> {
    let frac = |num: usize| {
        if cohort == 0 {
            None
        } else {
            Some(num as f64 / cohort as f64)
        }
    };
    match series {
        Series::SimRoundS => Some(rec.sim_round_s),
        Series::EligibleFrac => {
            if fleet_n == 0 {
                None
            } else {
                Some(rec.eligible as f64 / fleet_n as f64)
            }
        }
        Series::CompletedFrac => frac(rec.completed),
        Series::DroppedFrac => frac(rec.dropped),
        Series::DiscardedFrac => frac(rec.discarded_clients),
        Series::DeferredFrac => frac(rec.deferrals),
        Series::CacheHitRate => {
            let lookups: u64 = rec.tier_cache_lookups.iter().sum();
            if lookups == 0 {
                None
            } else {
                let hits: u64 = rec.tier_cache_hits.iter().sum();
                Some(hits as f64 / lookups as f64)
            }
        }
        Series::MeanStaleness => Some(rec.mean_staleness),
        Series::MinCommitteeSize => {
            if rec.committees == 0 {
                None
            } else {
                Some(rec.min_committee_size as f64)
            }
        }
        Series::MergeStallMs => Some(rec.merge_stall_ms),
        Series::ExecUtil => Some(rec.exec_util),
    }
}

/// Per-SLO-rule evaluation state.
struct SloState {
    rule: SloRule,
    label: String,
    /// Consecutive violating rounds so far (resets on any clean or
    /// absent sample — the hysteresis counter).
    streak: usize,
    /// Index into `HealthMonitor::incidents` while open.
    open: Option<usize>,
}

/// Which detector a [`DetectorState`] incident slot belongs to.
#[derive(Clone, Copy, PartialEq, Eq)]
enum DetectorKind {
    EwmaZ,
    LevelShift,
}

impl DetectorKind {
    fn label(&self, series: Series) -> String {
        match self {
            DetectorKind::EwmaZ => format!("ewma_z:{series}"),
            DetectorKind::LevelShift => format!("level_shift:{series}"),
        }
    }
}

/// Per-series anomaly-detector state (EWMA + level-shift window).
struct DetectorState {
    series: Series,
    /// Samples folded into the EWMA so far (warm-up gate).
    n: usize,
    mean: f64,
    var: f64,
    /// Trailing window for the level-shift test; capacity
    /// `2 × half_window`.
    window: VecDeque<f64>,
    open_z: Option<usize>,
    open_shift: Option<usize>,
}

/// The monitor itself: owned by the trainer, fed every round, drained
/// into a [`HealthReport`] by [`HealthMonitor::finish`].
pub struct HealthMonitor {
    warmup: usize,
    z_thresh: f64,
    shift_frac: f64,
    fleet_n: usize,
    cohort: usize,
    slos: Vec<SloState>,
    detectors: Vec<DetectorState>,
    incidents: Vec<Incident>,
    rounds: usize,
    next_id: u32,
}

/// Which direction makes an observed value "worse" for a given rule
/// (tracked into [`Incident::worst`]).
#[derive(Clone, Copy)]
enum WorstDir {
    /// Lower is worse (ge/gt requirements: violations sit below the
    /// threshold).
    Low,
    /// Higher is worse (le/lt requirements).
    High,
    /// Farther from the expected baseline is worse (detectors).
    Far,
}

impl HealthMonitor {
    /// `None` when the config enables nothing — the trainer then carries
    /// no monitor and the round loop is exactly the pre-monitor code.
    pub fn new(cfg: &HealthConfig, fleet_n: usize, cohort: usize) -> Option<HealthMonitor> {
        if !cfg.is_active() {
            return None;
        }
        let slos = cfg
            .slos
            .iter()
            .map(|rule| SloState {
                label: rule.label(),
                rule: rule.clone(),
                streak: 0,
                open: None,
            })
            .collect();
        let detectors = if cfg.detectors {
            ALL_SERIES
                .iter()
                .filter(|s| s.sim_side())
                .map(|&series| DetectorState {
                    series,
                    n: 0,
                    mean: 0.0,
                    var: 0.0,
                    window: VecDeque::new(),
                    open_z: None,
                    open_shift: None,
                })
                .collect()
        } else {
            Vec::new()
        };
        Some(HealthMonitor {
            warmup: cfg.warmup.max(1),
            z_thresh: cfg.z_thresh,
            shift_frac: cfg.shift_frac,
            fleet_n,
            cohort,
            slos,
            detectors,
            incidents: Vec::new(),
            rounds: 0,
            next_id: 0,
        })
    }

    /// Currently-open incidents (the `health.open` gauge).
    pub fn open_incidents(&self) -> usize {
        self.incidents.iter().filter(|i| i.is_open()).count()
    }

    /// Half-width of the level-shift window (the full window is
    /// `2 × half`, so the test cannot fire before ~`warmup` rounds).
    fn half_window(&self) -> usize {
        ((self.warmup + 1) / 2).max(2)
    }

    /// Append a freshly-built incident (its `id` already assigned) and
    /// return its index plus the `open` lifecycle event.
    fn push_incident(incidents: &mut Vec<Incident>, inc: Incident) -> (usize, IncidentEvent) {
        let ev = IncidentEvent {
            action: IncidentAction::Open,
            id: inc.id,
            severity: inc.severity,
            rule: inc.rule.clone(),
            series: inc.series,
            round: inc.opened_round,
            observed: inc.observed,
            expected: inc.expected,
        };
        incidents.push(inc);
        (incidents.len() - 1, ev)
    }

    fn touch_incident(
        incidents: &mut [Incident],
        idx: usize,
        round: usize,
        observed: f64,
        dir: WorstDir,
    ) -> IncidentEvent {
        let inc = &mut incidents[idx];
        inc.last_round = round;
        inc.rounds += 1;
        let worse = match dir {
            WorstDir::Low => observed < inc.worst,
            WorstDir::High => observed > inc.worst,
            WorstDir::Far => {
                (observed - inc.expected).abs() > (inc.worst - inc.expected).abs()
            }
        };
        if worse {
            inc.worst = observed;
        }
        IncidentEvent {
            action: IncidentAction::Update,
            id: inc.id,
            severity: inc.severity,
            rule: inc.rule.clone(),
            series: inc.series,
            round,
            observed,
            expected: inc.expected,
        }
    }

    fn resolve_incident(
        incidents: &mut [Incident],
        idx: usize,
        round: usize,
        observed: f64,
    ) -> IncidentEvent {
        let inc = &mut incidents[idx];
        inc.resolved_round = Some(round);
        IncidentEvent {
            action: IncidentAction::Resolve,
            id: inc.id,
            severity: inc.severity,
            rule: inc.rule.clone(),
            series: inc.series,
            round,
            observed,
            expected: inc.expected,
        }
    }

    /// Feed one round ledger entry; returns the incident lifecycle steps
    /// it produced, in deterministic (rule order, then series order)
    /// order.
    pub fn observe_round(&mut self, rec: &RoundRecord) -> Vec<IncidentEvent> {
        self.rounds += 1;
        let round = rec.round;
        let mut events = Vec::new();

        // SLO rules, in declaration order.
        for st in &mut self.slos {
            let sampled = sample(st.rule.series, rec, self.fleet_n, self.cohort);
            match sampled {
                Some(x) if st.rule.violated(x) => {
                    st.streak += 1;
                    if let Some(idx) = st.open {
                        let dir = match st.rule.op {
                            SloOp::Ge | SloOp::Gt => WorstDir::Low,
                            SloOp::Le | SloOp::Lt => WorstDir::High,
                        };
                        events.push(Self::touch_incident(
                            &mut self.incidents,
                            idx,
                            round,
                            x,
                            dir,
                        ));
                    } else if st.streak >= st.rule.for_rounds {
                        let id = self.next_id;
                        self.next_id += 1;
                        let (idx, ev) = Self::push_incident(
                            &mut self.incidents,
                            Incident {
                                id,
                                severity: Severity::Critical,
                                rule: st.label.clone(),
                                series: st.rule.series,
                                opened_round: round,
                                resolved_round: None,
                                last_round: round,
                                rounds: st.streak,
                                observed: x,
                                expected: st.rule.value,
                                worst: x,
                            },
                        );
                        st.open = Some(idx);
                        events.push(ev);
                    }
                }
                other => {
                    // Clean sample, or series absent this round: the
                    // streak resets and any open incident resolves.
                    // Absent samples report the threshold itself as the
                    // "observed" value (never NaN — it must serialize).
                    st.streak = 0;
                    if let Some(idx) = st.open.take() {
                        let observed = other.unwrap_or(st.rule.value);
                        events.push(Self::resolve_incident(
                            &mut self.incidents,
                            idx,
                            round,
                            observed,
                        ));
                    }
                }
            }
        }

        // Anomaly detectors, in series order. Absent samples are skipped
        // entirely (no state update, open incidents held).
        let warmup = self.warmup;
        let z_thresh = self.z_thresh;
        let shift_frac = self.shift_frac;
        let half = self.half_window();
        for det in &mut self.detectors {
            let Some(x) = sample(det.series, rec, self.fleet_n, self.cohort) else {
                continue;
            };

            // EWMA z-score against the pre-update baseline.
            if det.n >= warmup {
                let std = det.var.max(0.0).sqrt();
                let denom = std.max(STD_FLOOR_FRAC * det.mean.abs()).max(1e-9);
                let z = (x - det.mean) / denom;
                if z.abs() > z_thresh {
                    match det.open_z {
                        Some(idx) => events.push(Self::touch_incident(
                            &mut self.incidents,
                            idx,
                            round,
                            x,
                            WorstDir::Far,
                        )),
                        None => {
                            let id = self.next_id;
                            self.next_id += 1;
                            let (idx, ev) = Self::push_incident(
                                &mut self.incidents,
                                Incident {
                                    id,
                                    severity: Severity::Warn,
                                    rule: DetectorKind::EwmaZ.label(det.series),
                                    series: det.series,
                                    opened_round: round,
                                    resolved_round: None,
                                    last_round: round,
                                    rounds: 1,
                                    observed: x,
                                    expected: det.mean,
                                    worst: x,
                                },
                            );
                            det.open_z = Some(idx);
                            events.push(ev);
                        }
                    }
                } else if let Some(idx) = det.open_z.take() {
                    events.push(Self::resolve_incident(&mut self.incidents, idx, round, x));
                }
            }
            let diff = x - det.mean;
            let incr = if det.n == 0 { diff } else { EWMA_LAMBDA * diff };
            det.mean += incr;
            if det.n > 0 {
                det.var = (1.0 - EWMA_LAMBDA) * (det.var + diff * incr);
            }
            det.n += 1;

            // Windowed level shift: mean of the newest half vs the
            // oldest half, against the old half's noise band.
            det.window.push_back(x);
            if det.window.len() > 2 * half {
                det.window.pop_front();
            }
            if det.window.len() == 2 * half {
                let mean_old = det.window.iter().take(half).sum::<f64>() / half as f64;
                let mean_new = det.window.iter().skip(half).sum::<f64>() / half as f64;
                let var_old = det
                    .window
                    .iter()
                    .take(half)
                    .map(|v| (v - mean_old) * (v - mean_old))
                    .sum::<f64>()
                    / half as f64;
                let delta = (mean_new - mean_old).abs();
                let band =
                    2.0 * var_old.max(0.0).sqrt() + shift_frac * mean_old.abs() + 1e-9;
                if delta > band {
                    match det.open_shift {
                        Some(idx) => events.push(Self::touch_incident(
                            &mut self.incidents,
                            idx,
                            round,
                            mean_new,
                            WorstDir::Far,
                        )),
                        None => {
                            let id = self.next_id;
                            self.next_id += 1;
                            let (idx, ev) = Self::push_incident(
                                &mut self.incidents,
                                Incident {
                                    id,
                                    severity: Severity::Warn,
                                    rule: DetectorKind::LevelShift.label(det.series),
                                    series: det.series,
                                    opened_round: round,
                                    resolved_round: None,
                                    last_round: round,
                                    rounds: 1,
                                    observed: mean_new,
                                    expected: mean_old,
                                    worst: mean_new,
                                },
                            );
                            det.open_shift = Some(idx);
                            events.push(ev);
                        }
                    }
                } else if let Some(idx) = det.open_shift.take() {
                    events.push(Self::resolve_incident(
                        &mut self.incidents,
                        idx,
                        round,
                        mean_new,
                    ));
                }
            }
        }

        events
    }

    /// Drain the ledger into the end-of-run report (incidents still open
    /// stay open — `resolved_round == None`).
    pub fn finish(&mut self) -> HealthReport {
        HealthReport {
            incidents: std::mem::take(&mut self.incidents),
            rounds_observed: self.rounds,
            rules: self.slos.len(),
            detectors: !self.detectors.is_empty(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AggregationMode;
    use crate::fedselect::RoundComm;

    fn rec(round: usize, eligible: usize, sim_round_s: f64) -> RoundRecord {
        RoundRecord {
            round,
            completed: 10,
            dropped: 0,
            mode: AggregationMode::Synchronous,
            discarded_clients: 0,
            mean_staleness: 0.0,
            committees: 0,
            mean_committee_size: 0.0,
            min_committee_size: 0,
            comm: RoundComm::default(),
            up_bytes: 0,
            max_client_mem: 0,
            wall_ms: 0.0,
            merge_stall_ms: 0.0,
            exec_util: 1.0,
            sim_round_s,
            tier_completed: vec![10],
            tier_dropped: vec![0],
            tier_discarded: vec![0],
            tier_down_bytes: vec![0],
            tier_cache_hits: vec![0],
            tier_cache_lookups: vec![0],
            cache_evictions: 0,
            cache_stale_refreshes: 0,
            deferrals: 0,
            eligible,
            arrivals: 0,
            departures: 0,
            outage_excluded: 0,
            clients_touched: 0,
            resident_bytes: 0,
        }
    }

    fn slo_cfg(rules: &str) -> HealthConfig {
        HealthConfig {
            slos: SloRule::parse_list(rules).unwrap(),
            ..HealthConfig::default()
        }
    }

    #[test]
    fn inactive_config_builds_no_monitor() {
        assert!(HealthMonitor::new(&HealthConfig::default(), 100, 10).is_none());
        assert!(HealthMonitor::new(&slo_cfg("eligible_frac:ge:0.8"), 100, 10).is_some());
    }

    #[test]
    fn slo_opens_updates_and_resolves() {
        let mut mon = HealthMonitor::new(&slo_cfg("eligible_frac:ge:0.8"), 100, 10).unwrap();
        assert!(mon.observe_round(&rec(1, 90, 1.0)).is_empty());
        let evs = mon.observe_round(&rec(2, 50, 1.0));
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].action, IncidentAction::Open);
        assert_eq!(evs[0].severity, Severity::Critical);
        assert_eq!(evs[0].rule, "slo:eligible_frac:ge:0.8");
        assert_eq!(evs[0].observed, 0.5);
        assert_eq!(evs[0].expected, 0.8);
        let evs = mon.observe_round(&rec(3, 40, 1.0));
        assert_eq!(evs[0].action, IncidentAction::Update);
        let evs = mon.observe_round(&rec(4, 95, 1.0));
        assert_eq!(evs[0].action, IncidentAction::Resolve);
        let report = mon.finish();
        assert_eq!(report.total(), 1);
        let inc = &report.incidents[0];
        assert_eq!(inc.opened_round, 2);
        assert_eq!(inc.resolved_round, Some(4));
        assert_eq!(inc.last_round, 3);
        assert_eq!(inc.rounds, 2);
        assert_eq!(inc.worst, 0.4); // lowest eligible_frac seen
        assert_eq!(report.flagged_rounds(), vec![2, 3]);
    }

    #[test]
    fn for_rounds_hysteresis_ignores_one_round_blips() {
        let mut mon =
            HealthMonitor::new(&slo_cfg("eligible_frac:ge:0.8:3"), 100, 10).unwrap();
        // One- and two-round blips: streak never reaches 3.
        assert!(mon.observe_round(&rec(1, 50, 1.0)).is_empty());
        assert!(mon.observe_round(&rec(2, 90, 1.0)).is_empty());
        assert!(mon.observe_round(&rec(3, 50, 1.0)).is_empty());
        assert!(mon.observe_round(&rec(4, 50, 1.0)).is_empty());
        assert!(mon.observe_round(&rec(5, 90, 1.0)).is_empty());
        // Sustained breach opens on the third consecutive violation and
        // the ledger back-dates the streak into `rounds`.
        assert!(mon.observe_round(&rec(6, 50, 1.0)).is_empty());
        assert!(mon.observe_round(&rec(7, 50, 1.0)).is_empty());
        let evs = mon.observe_round(&rec(8, 50, 1.0));
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].action, IncidentAction::Open);
        let report = mon.finish();
        assert_eq!(report.total(), 1);
        assert_eq!(report.incidents[0].opened_round, 8);
        assert_eq!(report.incidents[0].rounds, 3);
        assert!(report.incidents[0].is_open());
        assert_eq!(report.flagged_rounds(), vec![6, 7, 8]);
    }

    #[test]
    fn detector_warmup_gates_incidents() {
        let det_cfg = HealthConfig {
            detectors: true,
            warmup: 8,
            ..HealthConfig::default()
        };
        let mut mon = HealthMonitor::new(&det_cfg, 100, 10).unwrap();
        // A massive spike inside the warm-up window: no incident.
        for r in 1..=4 {
            assert!(mon.observe_round(&rec(r, 100, 10.0)).is_empty());
        }
        assert!(mon.observe_round(&rec(5, 100, 500.0)).is_empty());
        assert_eq!(mon.finish().total(), 0);

        // Same spike after the window fills: EWMA z fires.
        let mut mon = HealthMonitor::new(&det_cfg, 100, 10).unwrap();
        for r in 1..=10 {
            assert!(mon.observe_round(&rec(r, 100, 10.0)).is_empty());
        }
        let evs = mon.observe_round(&rec(11, 100, 500.0));
        assert!(evs
            .iter()
            .any(|e| e.action == IncidentAction::Open && e.rule == "ewma_z:sim_round_s"));
        assert!(evs.iter().all(|e| e.severity == Severity::Warn));
    }

    #[test]
    fn level_shift_detects_sustained_step_and_resolves() {
        let det_cfg = HealthConfig {
            detectors: true,
            warmup: 8,
            ..HealthConfig::default()
        };
        let mut mon = HealthMonitor::new(&det_cfg, 100, 10).unwrap();
        for r in 1..=10 {
            mon.observe_round(&rec(r, 100, 10.0));
        }
        // Eligibility halves and stays there (an outage): some detector
        // opens, and once the EWMA/window adapt to the new level the
        // incident resolves.
        let mut opened = false;
        let mut resolved = false;
        for r in 11..=40 {
            for e in mon.observe_round(&rec(r, 50, 10.0)) {
                if e.series == Series::EligibleFrac {
                    opened |= e.action == IncidentAction::Open;
                    resolved |= e.action == IncidentAction::Resolve;
                }
            }
        }
        assert!(opened, "eligibility collapse never detected");
        assert!(resolved, "detector never adapted to the new level");
        let report = mon.finish();
        assert!(report.total() >= 1);
        // Constant series elsewhere: no incidents outside eligibility.
        assert!(report
            .incidents
            .iter()
            .all(|i| i.series == Series::EligibleFrac));
    }

    #[test]
    fn absent_series_resets_slo_streaks() {
        // min_committee_size is absent when no committee was keyed; the
        // rule must not fire on absent rounds.
        let mut mon =
            HealthMonitor::new(&slo_cfg("min_committee_size:ge:3"), 100, 10).unwrap();
        for r in 1..=5 {
            assert!(mon.observe_round(&rec(r, 100, 1.0)).is_empty());
        }
        assert_eq!(mon.finish().total(), 0);
    }

    #[test]
    fn quiet_constant_fleet_produces_zero_incidents() {
        let cfg = HealthConfig {
            slos: SloRule::parse_list("eligible_frac:ge:0.5,sim_round_s:le:100").unwrap(),
            detectors: true,
            warmup: 8,
            ..HealthConfig::default()
        };
        let mut mon = HealthMonitor::new(&cfg, 100, 10).unwrap();
        for r in 1..=50 {
            // Mild noise well inside every band.
            let jitter = 1.0 + 0.01 * ((r % 3) as f64);
            assert!(mon.observe_round(&rec(r, 98 + (r % 3), jitter)).is_empty());
        }
        let report = mon.finish();
        assert_eq!(report.total(), 0);
        assert_eq!(report.rounds_observed, 50);
    }

    #[test]
    fn rollup_folds_reports() {
        let mut a = HealthReport::default();
        a.incidents.push(Incident {
            id: 0,
            severity: Severity::Critical,
            rule: "slo:x".into(),
            series: Series::SimRoundS,
            opened_round: 1,
            resolved_round: None,
            last_round: 2,
            rounds: 2,
            observed: 1.0,
            expected: 0.5,
            worst: 1.5,
        });
        let b = HealthReport::default();
        let roll = HealthRollup::fold([&a, &b].into_iter());
        assert_eq!(roll.incidents, 1);
        assert_eq!(roll.critical, 1);
        assert_eq!(roll.open, 1);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut cfg = HealthConfig {
            detectors: true,
            ..HealthConfig::default()
        };
        cfg.warmup = 0;
        assert!(cfg.validate().is_err());
        cfg.warmup = 8;
        assert!(cfg.validate().is_ok());
        cfg.z_thresh = 0.0;
        assert!(cfg.validate().is_err());
        cfg.z_thresh = 4.0;
        // host-clock series parse but cannot back an SLO: the incident
        // ledger must stay deterministic
        cfg.slos = SloRule::parse_list("merge_stall_ms:le:100").unwrap();
        assert!(cfg.validate().is_err());
        cfg.slos = SloRule::parse_list("sim_round_s:le:100").unwrap();
        assert!(cfg.validate().is_ok());
    }
}

//! Declarative SLO rules over per-round health series.
//!
//! A rule states a *requirement* on one sim-side series of the per-round
//! ledger (`KEY:OP:VALUE[:FOR_ROUNDS]`, e.g. `eligible_frac:ge:0.8:3`):
//! the round is *in violation* when the requirement does not hold, and an
//! incident opens only after `FOR_ROUNDS` consecutive violating rounds
//! (hysteresis, default 1). Host-wall series (`merge_stall_ms`,
//! `exec_util`) parse but are rejected by
//! [`HealthConfig::validate`](crate::obs::HealthConfig::validate) —
//! same-seed ledger byte-identity only holds for sim-side rules (see
//! [`Series::sim_side`]).

use std::fmt;

use crate::error::{Error, Result};

/// A per-round health series an SLO rule or anomaly detector can watch.
///
/// All values are derived from [`crate::coordinator::RoundRecord`] fields;
/// fractions are normalized against the fleet size (`eligible_frac`) or
/// the configured cohort (the other `*_frac` series). A series can be
/// *absent* for a round (e.g. `cache_hit_rate` with no cache lookups,
/// `min_committee_size` when no committee was keyed) — absent samples
/// reset SLO violation streaks and are skipped by detectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Series {
    /// Simulated round duration, seconds (`sim_round_s`).
    SimRoundS,
    /// Eligible clients / fleet size.
    EligibleFrac,
    /// Merged updates / configured cohort.
    CompletedFrac,
    /// Post-fetch dropouts / configured cohort.
    DroppedFrac,
    /// Discarded (computed-but-never-merged) updates / configured cohort.
    DiscardedFrac,
    /// Committee-defer pushbacks / configured cohort.
    DeferredFrac,
    /// Client-cache piece hits / lookups this round (absent when no
    /// lookups happened).
    CacheHitRate,
    /// Mean rounds-of-staleness over merged updates.
    MeanStaleness,
    /// Smallest keyed-committee submitter count (absent when no committee
    /// was keyed this round).
    MinCommitteeSize,
    /// Host wall time serialized in the merge (**non-deterministic**).
    MergeStallMs,
    /// Executor pool utilization in [0, 1] (**non-deterministic**).
    ExecUtil,
}

/// All series, in declaration order (error messages, detector loops).
pub const ALL_SERIES: [Series; 11] = [
    Series::SimRoundS,
    Series::EligibleFrac,
    Series::CompletedFrac,
    Series::DroppedFrac,
    Series::DiscardedFrac,
    Series::DeferredFrac,
    Series::CacheHitRate,
    Series::MeanStaleness,
    Series::MinCommitteeSize,
    Series::MergeStallMs,
    Series::ExecUtil,
];

impl Series {
    pub fn name(&self) -> &'static str {
        match self {
            Series::SimRoundS => "sim_round_s",
            Series::EligibleFrac => "eligible_frac",
            Series::CompletedFrac => "completed_frac",
            Series::DroppedFrac => "dropped_frac",
            Series::DiscardedFrac => "discarded_frac",
            Series::DeferredFrac => "deferred_frac",
            Series::CacheHitRate => "cache_hit_rate",
            Series::MeanStaleness => "mean_staleness",
            Series::MinCommitteeSize => "min_committee_size",
            Series::MergeStallMs => "merge_stall_ms",
            Series::ExecUtil => "exec_util",
        }
    }

    /// Whether the series is computed purely from sim-clock quantities.
    /// Same-seed incident-ledger byte-identity only covers sim-side
    /// series; detectors skip host-wall ones entirely.
    pub fn sim_side(&self) -> bool {
        !matches!(self, Series::MergeStallMs | Series::ExecUtil)
    }

    pub fn parse(s: &str) -> Result<Series> {
        for series in ALL_SERIES {
            if series.name() == s {
                return Ok(series);
            }
        }
        let names: Vec<&str> = ALL_SERIES.iter().map(|s| s.name()).collect();
        Err(Error::Config(format!(
            "unknown SLO series {s:?}; one of: {}",
            names.join(", ")
        )))
    }
}

impl fmt::Display for Series {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Comparison the *requirement* asserts (violation = requirement false).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloOp {
    Lt,
    Le,
    Gt,
    Ge,
}

impl SloOp {
    pub fn name(&self) -> &'static str {
        match self {
            SloOp::Lt => "lt",
            SloOp::Le => "le",
            SloOp::Gt => "gt",
            SloOp::Ge => "ge",
        }
    }

    fn parse(s: &str) -> Result<SloOp> {
        match s {
            "lt" | "<" => Ok(SloOp::Lt),
            "le" | "<=" => Ok(SloOp::Le),
            "gt" | ">" => Ok(SloOp::Gt),
            "ge" | ">=" => Ok(SloOp::Ge),
            _ => Err(Error::Config(format!(
                "unknown SLO op {s:?}; one of: lt, le, gt, ge"
            ))),
        }
    }

    pub fn holds(&self, observed: f64, threshold: f64) -> bool {
        match self {
            SloOp::Lt => observed < threshold,
            SloOp::Le => observed <= threshold,
            SloOp::Gt => observed > threshold,
            SloOp::Ge => observed >= threshold,
        }
    }
}

impl fmt::Display for SloOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One declarative threshold rule: `SERIES:OP:VALUE[:FOR_ROUNDS]`.
#[derive(Clone, Debug, PartialEq)]
pub struct SloRule {
    pub series: Series,
    pub op: SloOp,
    pub value: f64,
    /// Consecutive violating rounds required before an incident opens
    /// (hysteresis); a shorter blip never opens one. Always ≥ 1.
    pub for_rounds: usize,
}

impl SloRule {
    pub fn new(series: Series, op: SloOp, value: f64) -> SloRule {
        SloRule {
            series,
            op,
            value,
            for_rounds: 1,
        }
    }

    pub fn for_rounds(mut self, rounds: usize) -> SloRule {
        self.for_rounds = rounds.max(1);
        self
    }

    /// Parse one `KEY:OP:VALUE[:FOR_ROUNDS]` rule.
    pub fn parse(s: &str) -> Result<SloRule> {
        let bad = |m: &str| Error::Config(format!("bad --slo rule {s:?}: {m}"));
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 && parts.len() != 4 {
            return Err(bad("want KEY:OP:VALUE[:FOR_ROUNDS]"));
        }
        let series = Series::parse(parts[0])?;
        let op = SloOp::parse(parts[1])?;
        let value: f64 = parts[2]
            .parse()
            .map_err(|_| bad("VALUE must be a number"))?;
        if !value.is_finite() {
            return Err(bad("VALUE must be finite"));
        }
        let for_rounds = match parts.get(3) {
            None => 1,
            Some(fr) => {
                let n: usize = fr
                    .parse()
                    .map_err(|_| bad("FOR_ROUNDS must be a positive integer"))?;
                if n == 0 {
                    return Err(bad("FOR_ROUNDS must be >= 1"));
                }
                n
            }
        };
        Ok(SloRule {
            series,
            op,
            value,
            for_rounds,
        })
    }

    /// Parse a comma-separated rule list (the `--slo` flag takes one
    /// occurrence; repeated flags would overwrite each other).
    pub fn parse_list(s: &str) -> Result<Vec<SloRule>> {
        let mut rules = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            rules.push(SloRule::parse(part)?);
        }
        if rules.is_empty() {
            return Err(Error::Config(format!("bad --slo {s:?}: no rules")));
        }
        Ok(rules)
    }

    /// True when this round's sample *violates* the requirement.
    pub fn violated(&self, observed: f64) -> bool {
        !self.op.holds(observed, self.value)
    }

    /// Canonical rule label used in incident ledgers and trace events.
    pub fn label(&self) -> String {
        if self.for_rounds > 1 {
            format!(
                "slo:{}:{}:{}:{}",
                self.series, self.op, self.value, self.for_rounds
            )
        } else {
            format!("slo:{}:{}:{}", self.series, self.op, self.value)
        }
    }
}

impl fmt::Display for SloRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_and_short_rules() {
        let r = SloRule::parse("eligible_frac:ge:0.8:3").unwrap();
        assert_eq!(r.series, Series::EligibleFrac);
        assert_eq!(r.op, SloOp::Ge);
        assert_eq!(r.value, 0.8);
        assert_eq!(r.for_rounds, 3);

        let r = SloRule::parse("sim_round_s:le:120").unwrap();
        assert_eq!(r.series, Series::SimRoundS);
        assert_eq!(r.for_rounds, 1);
        assert_eq!(r.label(), "slo:sim_round_s:le:120");
    }

    #[test]
    fn parses_comma_separated_lists() {
        let rules =
            SloRule::parse_list("eligible_frac:ge:0.8, dropped_frac:le:0.3:2").unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[1].series, Series::DroppedFrac);
        assert_eq!(rules[1].for_rounds, 2);
    }

    #[test]
    fn rejects_malformed_rules() {
        assert!(SloRule::parse("eligible_frac").is_err());
        assert!(SloRule::parse("bogus_series:ge:0.5").is_err());
        assert!(SloRule::parse("eligible_frac:between:0.5").is_err());
        assert!(SloRule::parse("eligible_frac:ge:lots").is_err());
        assert!(SloRule::parse("eligible_frac:ge:0.5:0").is_err());
        assert!(SloRule::parse("eligible_frac:ge:inf").is_err());
        assert!(SloRule::parse_list(" , ").is_err());
    }

    #[test]
    fn violation_is_requirement_negated() {
        let r = SloRule::parse("eligible_frac:ge:0.8").unwrap();
        assert!(!r.violated(0.8));
        assert!(!r.violated(0.9));
        assert!(r.violated(0.79));

        let r = SloRule::parse("sim_round_s:lt:100").unwrap();
        assert!(!r.violated(99.0));
        assert!(r.violated(100.0));
    }

    #[test]
    fn sim_side_split_matches_docs() {
        assert!(Series::SimRoundS.sim_side());
        assert!(Series::CacheHitRate.sim_side());
        assert!(!Series::MergeStallMs.sim_side());
        assert!(!Series::ExecUtil.sim_side());
        // Every series name round-trips through the parser.
        for s in ALL_SERIES {
            assert_eq!(Series::parse(s.name()).unwrap(), s);
        }
    }
}

//! The metrics registry: counters, gauges, per-tier/per-job counter and
//! gauge vectors, and fixed-bucket histograms.
//!
//! The trainer folds every round into its live registry as it runs, and
//! `metrics::fleet_registry` rebuilds the same registry from recorded
//! `RoundRecord`s — both paths share one fold (`metrics::record_round`), so
//! the summary tables render identically from either source
//! (test-enforced). Updates are plain arithmetic on pre-registered keys:
//! steady-state updates never allocate and never touch an RNG, so the
//! registry is always on without perturbing the trajectory.

use std::collections::BTreeMap;

/// A fixed-bucket histogram: `bounds[i]` is the inclusive upper bound of
/// bucket `i`; one final bucket catches everything above the last bound.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
}

impl Histogram {
    /// A histogram with the given ascending bucket upper bounds.
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            n: 0,
        }
    }

    /// Count one observation.
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of all observed values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Quantile estimate by linear interpolation within the fixed
    /// buckets (Prometheus `histogram_quantile` semantics): the target
    /// rank `q × n` is located in the cumulative bucket counts, then
    /// placed proportionally between that bucket's bounds. The first
    /// bucket interpolates from 0, and ranks landing in the overflow
    /// bucket clamp to the last bound (there is no upper edge to
    /// interpolate toward). Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * self.n as f64;
        let mut cum = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            let prev = cum;
            cum += c as f64;
            if cum >= rank && c > 0 {
                if i >= self.bounds.len() {
                    // Overflow bucket: clamp to the last finite bound.
                    return self.bounds.last().copied().unwrap_or(self.mean());
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = ((rank - prev) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
        }
        self.bounds.last().copied().unwrap_or(self.mean())
    }

    /// Bucket upper bounds (the overflow bucket is implicit).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; `len() == bounds().len() + 1` (last = overflow).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }
}

/// Named counters, gauges, indexed vectors, and histograms. Keys are
/// `&str` at every call site; lookups on existing keys do not allocate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    counter_vecs: BTreeMap<String, Vec<u64>>,
    gauge_vecs: BTreeMap<String, Vec<f64>>,
    hists: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    // Mutators do one `get_mut` lookup on the hot (existing-key) path —
    // never the old `contains_key` + `get_mut` double walk — and fall
    // back to `entry` only on first use: `entry` must own its key, so
    // taking it unconditionally would allocate a `String` per update.

    /// Add `v` to the counter `name` (created at 0 on first use).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        if let Some(c) = self.counters.get_mut(name) {
            *c += v;
            return;
        }
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Current value of counter `name` (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// All counters, sorted by name.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Set gauge `name` to `v`.
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        if let Some(g) = self.gauges.get_mut(name) {
            *g = v;
            return;
        }
        *self.gauges.entry(name.to_string()).or_insert(0.0) = v;
    }

    /// Add `v` to gauge `name` (created at 0 on first use).
    pub fn gauge_add(&mut self, name: &str, v: f64) {
        if let Some(g) = self.gauges.get_mut(name) {
            *g += v;
            return;
        }
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Current value of gauge `name` (0 when absent).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// All gauges, sorted by name.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Add `v` at index `idx` of counter vector `name`, growing the vector
    /// with zeros as needed (index = tier or job ordinal).
    pub fn counter_vec_add(&mut self, name: &str, idx: usize, v: u64) {
        if let Some(vec) = self.counter_vecs.get_mut(name) {
            if vec.len() <= idx {
                vec.resize(idx + 1, 0);
            }
            vec[idx] += v;
            return;
        }
        let vec = self.counter_vecs.entry(name.to_string()).or_default();
        vec.resize(idx + 1, 0);
        vec[idx] += v;
    }

    /// Counter vector `name` (empty slice when absent).
    pub fn counter_vec(&self, name: &str) -> &[u64] {
        self.counter_vecs.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Add `v` at index `idx` of gauge vector `name`.
    pub fn gauge_vec_add(&mut self, name: &str, idx: usize, v: f64) {
        if let Some(vec) = self.gauge_vecs.get_mut(name) {
            if vec.len() <= idx {
                vec.resize(idx + 1, 0.0);
            }
            vec[idx] += v;
            return;
        }
        let vec = self.gauge_vecs.entry(name.to_string()).or_default();
        vec.resize(idx + 1, 0.0);
        vec[idx] += v;
    }

    /// Gauge vector `name` (empty slice when absent).
    pub fn gauge_vec(&self, name: &str) -> &[f64] {
        self.gauge_vecs.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Create histogram `name` with the given bucket bounds (no-op when it
    /// already exists). Pre-register hot-path histograms so `observe` never
    /// allocates in steady state.
    pub fn register_hist(&mut self, name: &str, bounds: &[f64]) {
        if !self.hists.contains_key(name) {
            self.hists.insert(name.to_string(), Histogram::new(bounds));
        }
    }

    /// Count one observation into histogram `name`, creating it with
    /// [`DEFAULT_HIST_BOUNDS`] when absent.
    pub fn observe(&mut self, name: &str, v: f64) {
        if let Some(h) = self.hists.get_mut(name) {
            h.observe(v);
            return;
        }
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(&DEFAULT_HIST_BOUNDS))
            .observe(v);
    }

    /// Histogram `name`, if registered.
    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// All histograms, sorted by name.
    pub fn hists(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }
}

/// Decade buckets used when a histogram is observed without being
/// registered first.
pub const DEFAULT_HIST_BOUNDS: [f64; 7] = [0.001, 0.01, 0.1, 1.0, 10.0, 100.0, 1000.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut reg = MetricsRegistry::new();
        reg.counter_add("rounds", 1);
        reg.counter_add("rounds", 2);
        assert_eq!(reg.counter("rounds"), 3);
        assert_eq!(reg.counter("absent"), 0);
        reg.gauge_add("sim_s", 1.5);
        reg.gauge_add("sim_s", 2.5);
        assert_eq!(reg.gauge("sim_s"), 4.0);
        reg.gauge_set("sim_s", 0.5);
        assert_eq!(reg.gauge("sim_s"), 0.5);
    }

    #[test]
    fn counter_vecs_grow_on_demand() {
        let mut reg = MetricsRegistry::new();
        reg.counter_vec_add("tier.completed", 2, 5);
        reg.counter_vec_add("tier.completed", 0, 1);
        assert_eq!(reg.counter_vec("tier.completed"), &[1, 0, 5]);
        assert_eq!(reg.counter_vec("absent"), &[] as &[u64]);
        reg.gauge_vec_add("job.busy", 1, 2.0);
        assert_eq!(reg.gauge_vec("job.busy"), &[0.0, 2.0]);
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let mut h = Histogram::new(&[1.0, 10.0]);
        h.observe(0.5);
        h.observe(1.0); // inclusive upper bound
        h.observe(5.0);
        h.observe(50.0); // overflow bucket
        assert_eq!(h.bucket_counts(), &[2, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 56.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let mut h = Histogram::new(&[1.0, 2.0, 4.0]);
        assert_eq!(h.quantile(0.5), 0.0); // empty
        for _ in 0..10 {
            h.observe(0.5); // 10 obs in (0, 1]
        }
        for _ in 0..10 {
            h.observe(1.5); // 10 obs in (1, 2]
        }
        // p50: rank 10 lands exactly at the end of the first bucket.
        assert!((h.quantile(0.5) - 1.0).abs() < 1e-12);
        // p75: rank 15 is halfway through the (1, 2] bucket.
        assert!((h.quantile(0.75) - 1.5).abs() < 1e-12);
        // p100 clamps to the top of the last occupied bucket.
        assert!((h.quantile(1.0) - 2.0).abs() < 1e-12);
        // Overflow observations clamp to the last bound.
        h.observe(100.0);
        assert!((h.quantile(1.0) - 4.0).abs() < 1e-12);
        // q is clamped into [0, 1].
        assert!((h.quantile(-1.0) - h.quantile(0.0)).abs() < 1e-12);
    }

    #[test]
    fn registry_histograms_use_default_bounds_when_unregistered() {
        let mut reg = MetricsRegistry::new();
        reg.observe("lat", 0.05);
        assert_eq!(reg.hist("lat").unwrap().bounds(), &DEFAULT_HIST_BOUNDS);
        reg.register_hist("lat2", &[1.0]);
        reg.observe("lat2", 2.0);
        assert_eq!(reg.hist("lat2").unwrap().bucket_counts(), &[0, 1]);
    }
}

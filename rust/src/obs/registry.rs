//! The metrics registry: counters, gauges, per-tier/per-job counter and
//! gauge vectors, and fixed-bucket histograms.
//!
//! The trainer folds every round into its live registry as it runs, and
//! `metrics::fleet_registry` rebuilds the same registry from recorded
//! `RoundRecord`s — both paths share one fold (`metrics::record_round`), so
//! the summary tables render identically from either source
//! (test-enforced). Updates are plain arithmetic on pre-registered keys:
//! steady-state updates never allocate and never touch an RNG, so the
//! registry is always on without perturbing the trajectory.

use std::collections::BTreeMap;

/// A fixed-bucket histogram: `bounds[i]` is the inclusive upper bound of
/// bucket `i`; one final bucket catches everything above the last bound.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
}

impl Histogram {
    /// A histogram with the given ascending bucket upper bounds.
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            n: 0,
        }
    }

    /// Count one observation.
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of all observed values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Bucket upper bounds (the overflow bucket is implicit).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; `len() == bounds().len() + 1` (last = overflow).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }
}

/// Named counters, gauges, indexed vectors, and histograms. Keys are
/// `&str` at every call site; lookups on existing keys do not allocate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    counter_vecs: BTreeMap<String, Vec<u64>>,
    gauge_vecs: BTreeMap<String, Vec<f64>>,
    hists: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to the counter `name` (created at 0 on first use).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        if !self.counters.contains_key(name) {
            self.counters.insert(name.to_string(), 0);
        }
        *self.counters.get_mut(name).expect("just inserted") += v;
    }

    /// Current value of counter `name` (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// All counters, sorted by name.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Set gauge `name` to `v`.
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        if !self.gauges.contains_key(name) {
            self.gauges.insert(name.to_string(), 0.0);
        }
        *self.gauges.get_mut(name).expect("just inserted") = v;
    }

    /// Add `v` to gauge `name` (created at 0 on first use).
    pub fn gauge_add(&mut self, name: &str, v: f64) {
        if !self.gauges.contains_key(name) {
            self.gauges.insert(name.to_string(), 0.0);
        }
        *self.gauges.get_mut(name).expect("just inserted") += v;
    }

    /// Current value of gauge `name` (0 when absent).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// All gauges, sorted by name.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Add `v` at index `idx` of counter vector `name`, growing the vector
    /// with zeros as needed (index = tier or job ordinal).
    pub fn counter_vec_add(&mut self, name: &str, idx: usize, v: u64) {
        if !self.counter_vecs.contains_key(name) {
            self.counter_vecs.insert(name.to_string(), Vec::new());
        }
        let vec = self.counter_vecs.get_mut(name).expect("just inserted");
        if vec.len() <= idx {
            vec.resize(idx + 1, 0);
        }
        vec[idx] += v;
    }

    /// Counter vector `name` (empty slice when absent).
    pub fn counter_vec(&self, name: &str) -> &[u64] {
        self.counter_vecs.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Add `v` at index `idx` of gauge vector `name`.
    pub fn gauge_vec_add(&mut self, name: &str, idx: usize, v: f64) {
        if !self.gauge_vecs.contains_key(name) {
            self.gauge_vecs.insert(name.to_string(), Vec::new());
        }
        let vec = self.gauge_vecs.get_mut(name).expect("just inserted");
        if vec.len() <= idx {
            vec.resize(idx + 1, 0.0);
        }
        vec[idx] += v;
    }

    /// Gauge vector `name` (empty slice when absent).
    pub fn gauge_vec(&self, name: &str) -> &[f64] {
        self.gauge_vecs.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Create histogram `name` with the given bucket bounds (no-op when it
    /// already exists). Pre-register hot-path histograms so `observe` never
    /// allocates in steady state.
    pub fn register_hist(&mut self, name: &str, bounds: &[f64]) {
        if !self.hists.contains_key(name) {
            self.hists.insert(name.to_string(), Histogram::new(bounds));
        }
    }

    /// Count one observation into histogram `name`, creating it with
    /// [`DEFAULT_HIST_BOUNDS`] when absent.
    pub fn observe(&mut self, name: &str, v: f64) {
        if !self.hists.contains_key(name) {
            self.hists
                .insert(name.to_string(), Histogram::new(&DEFAULT_HIST_BOUNDS));
        }
        self.hists.get_mut(name).expect("just inserted").observe(v);
    }

    /// Histogram `name`, if registered.
    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// All histograms, sorted by name.
    pub fn hists(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }
}

/// Decade buckets used when a histogram is observed without being
/// registered first.
pub const DEFAULT_HIST_BOUNDS: [f64; 7] = [0.001, 0.01, 0.1, 1.0, 10.0, 100.0, 1000.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut reg = MetricsRegistry::new();
        reg.counter_add("rounds", 1);
        reg.counter_add("rounds", 2);
        assert_eq!(reg.counter("rounds"), 3);
        assert_eq!(reg.counter("absent"), 0);
        reg.gauge_add("sim_s", 1.5);
        reg.gauge_add("sim_s", 2.5);
        assert_eq!(reg.gauge("sim_s"), 4.0);
        reg.gauge_set("sim_s", 0.5);
        assert_eq!(reg.gauge("sim_s"), 0.5);
    }

    #[test]
    fn counter_vecs_grow_on_demand() {
        let mut reg = MetricsRegistry::new();
        reg.counter_vec_add("tier.completed", 2, 5);
        reg.counter_vec_add("tier.completed", 0, 1);
        assert_eq!(reg.counter_vec("tier.completed"), &[1, 0, 5]);
        assert_eq!(reg.counter_vec("absent"), &[] as &[u64]);
        reg.gauge_vec_add("job.busy", 1, 2.0);
        assert_eq!(reg.gauge_vec("job.busy"), &[0.0, 2.0]);
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let mut h = Histogram::new(&[1.0, 10.0]);
        h.observe(0.5);
        h.observe(1.0); // inclusive upper bound
        h.observe(5.0);
        h.observe(50.0); // overflow bucket
        assert_eq!(h.bucket_counts(), &[2, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 56.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn registry_histograms_use_default_bounds_when_unregistered() {
        let mut reg = MetricsRegistry::new();
        reg.observe("lat", 0.05);
        assert_eq!(reg.hist("lat").unwrap().bounds(), &DEFAULT_HIST_BOUNDS);
        reg.register_hist("lat2", &[1.0]);
        reg.observe("lat2", 2.0);
        assert_eq!(reg.hist("lat2").unwrap().bucket_counts(), &[0, 1]);
    }
}

//! Cohort scheduler: who trains this round, with what budget, and how long
//! the round takes on real devices.
//!
//! The paper's coordinator samples cohorts uniformly and injects failures
//! with one scalar post-fetch dropout rate (§5.1, §6). FedSelect's central
//! promise — data-dependent slices that *fit heterogeneous devices* — only
//! pays off when who is selected and how much each device can hold is
//! modeled per client. This subsystem makes that first-class:
//!
//! * [`Fleet`] / [`DeviceProfile`] ([`crate::fleet`]) — a lazy
//!   device-population model (bandwidth, compute, memory cap, availability
//!   trace, failure hazard); profiles are recomputed on demand as a pure
//!   function of `(run seed, client id)`, so fleets of millions cost no
//!   resident memory, and per-client scheduler state lives in a sparse
//!   [`TouchedState`] keyed only by ever-selected clients;
//! * [`SelectionPolicy`] ([`policy`]) — pluggable cohort selection:
//!   [`policy::Uniform`] (byte-identical to the pre-scheduler coordinator),
//!   [`policy::AvailabilityAware`], [`policy::MemoryCapped`] (clamps each
//!   client's select budget `m_i` to what its profile can hold, feeding the
//!   per-client [`crate::fedselect::KeyPolicy`] budgets), and
//!   [`policy::StalenessFair`] (least-recently-selected first);
//! * [`SimClock`] ([`simclock`]) — converts the per-client byte ledgers the
//!   round already produces into simulated round wall-time (cohort
//!   completion = the straggler's download + compute + upload), with
//!   profile-driven dropouts replacing the old scalar coin flip.
//!
//! The trainer's phase 0 is [`Scheduler::plan_round`]; after phase 3 it
//! calls [`Scheduler::complete_round`] with per-client byte/compute stats
//! and gets back the round's simulated duration and per-tier completion
//! counts, which land in `RoundRecord`.
//!
//! **Determinism contract.** `plan_round` consumes the round RNG exactly
//! once per policy decision, and the `uniform` fleet + `Uniform` policy
//! path performs the *identical* `sample_without_replacement` call (and no
//! other draw) the pre-scheduler coordinator made — property-tested
//! byte-for-byte in `tests/scheduler_determinism.rs`.

pub mod policy;
pub mod simclock;

// The fleet moved to its own subsystem (`crate::fleet`) when it went lazy;
// these re-exports keep the scheduler's public surface (and the prelude)
// stable for existing users.
pub use crate::fleet::{DeviceProfile, Fleet, FleetKind};
pub use policy::{PlanCtx, Selection, SelectionPolicy};
pub use simclock::{ClientTiming, CompletionEvent, SimClock, ROUND_OVERHEAD_S};

use crate::cache::{BudgetSource, FleetCaches};
use crate::config::TrainConfig;
use crate::error::Result;
use crate::fleet::{Scenario, TouchedState};
use crate::tensor::rng::Rng;

/// Which built-in selection policy to instantiate (config-level knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    Uniform,
    AvailabilityAware,
    MemoryCapped,
    StalenessFair,
    LossWeighted,
}

impl SchedPolicy {
    pub fn build(self) -> Box<dyn SelectionPolicy> {
        match self {
            SchedPolicy::Uniform => Box::new(policy::Uniform),
            SchedPolicy::AvailabilityAware => Box::new(policy::AvailabilityAware),
            SchedPolicy::MemoryCapped => Box::new(policy::MemoryCapped),
            SchedPolicy::StalenessFair => Box::new(policy::StalenessFair),
            SchedPolicy::LossWeighted => Box::new(policy::LossWeighted),
        }
    }

    pub const ALL: [SchedPolicy; 5] = [
        SchedPolicy::Uniform,
        SchedPolicy::AvailabilityAware,
        SchedPolicy::MemoryCapped,
        SchedPolicy::StalenessFair,
        SchedPolicy::LossWeighted,
    ];
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SchedPolicy::Uniform => "uniform",
            SchedPolicy::AvailabilityAware => "availability-aware",
            SchedPolicy::MemoryCapped => "memory-capped",
            SchedPolicy::StalenessFair => "staleness-fair",
            SchedPolicy::LossWeighted => "loss-weighted",
        })
    }
}

impl std::str::FromStr for SchedPolicy {
    type Err = String;
    /// Case-insensitive; accepts the canonical `Display` names plus
    /// underscore/short aliases. Note: the key-policy namespace (`top:m`,
    /// `random-global:m`, …) is disjoint, which is what lets the CLI accept
    /// both through one `--policy` flag.
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Ok(SchedPolicy::Uniform),
            "availability-aware" | "availability_aware" | "availability" | "avail" => {
                Ok(SchedPolicy::AvailabilityAware)
            }
            "memory-capped" | "memory_capped" | "mem-capped" | "memcap" => {
                Ok(SchedPolicy::MemoryCapped)
            }
            "staleness-fair" | "staleness_fair" | "staleness" | "lru" => {
                Ok(SchedPolicy::StalenessFair)
            }
            "loss-weighted" | "loss_weighted" | "loss" | "importance" => {
                Ok(SchedPolicy::LossWeighted)
            }
            other => Err(format!(
                "unknown scheduler policy {other:?} (want {}, {}, {}, {} or {})",
                SchedPolicy::Uniform,
                SchedPolicy::AvailabilityAware,
                SchedPolicy::MemoryCapped,
                SchedPolicy::StalenessFair,
                SchedPolicy::LossWeighted
            )),
        }
    }
}

/// Slice-size geometry the scheduler needs to turn memory caps into key
/// budgets; computed once by the trainer from the model's `SelectSpec`.
#[derive(Clone, Debug)]
pub struct SliceGeometry {
    /// Configured key count per keyspace (the `KeyPolicy` budgets).
    pub base_ms: Vec<usize>,
    /// Floats one key selects, per keyspace.
    pub per_key_floats: Vec<usize>,
    /// Floats broadcast to every client regardless of keys.
    pub broadcast_floats: usize,
    /// Full server model float count.
    pub server_floats: usize,
}

/// Phase 0 output: the cohort, per-slot failure hazards, and optional
/// per-slot key budgets.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub round: usize,
    /// Train-client indices, in selection order.
    pub cohort: Vec<usize>,
    /// Post-fetch dropout probability per cohort slot (the profile hazard;
    /// the deprecated scalar `dropout_rate` is already baked in as a floor
    /// at [`Scheduler::new`]).
    pub hazards: Vec<f32>,
    /// Per cohort slot, per keyspace: key budget override (`None` = use the
    /// configured policies as-is; guaranteed `None` under
    /// [`SchedPolicy::Uniform`], preserving byte-identity).
    pub key_budgets: Option<Vec<Vec<usize>>>,
    /// Clients eligible for selection this round (fleet minus scenario
    /// ineligibility minus the in-flight exclusion set).
    pub eligible: usize,
    /// Clients that churned into the population since the last plan.
    pub arrivals: usize,
    /// Clients that churned out of the population since the last plan.
    pub departures: usize,
    /// Clients a regional outage is excluding right now (would otherwise
    /// be eligible).
    pub outage_excluded: usize,
}

/// What one cohort slot actually did this round, reported back by the
/// trainer for simulated-time accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientRoundStats {
    pub down_bytes: u64,
    pub up_bytes: u64,
    /// Slice-floats × local examples (the `SimClock` compute model).
    pub compute_units: f64,
    /// ℓ2 norm of the client's update — the training signal the
    /// `loss-weighted` policy samples on (0 for dropped clients).
    pub update_norm: f32,
    pub dropped: bool,
}

/// Simulated-systems summary of one round.
#[derive(Clone, Debug, Default)]
pub struct RoundSim {
    /// Simulated round duration (straggler + overhead), seconds.
    pub sim_round_s: f64,
    /// Simulated time since the start of training, seconds.
    pub sim_total_s: f64,
    /// Completing clients per fleet tier.
    pub tier_completed: Vec<usize>,
    /// Post-fetch dropouts per fleet tier.
    pub tier_dropped: Vec<usize>,
    /// Download bytes per fleet tier (dropped clients included — their
    /// download was wasted, which is the point of the §6 pattern).
    pub tier_down_bytes: Vec<u64>,
    /// Tier of the straggler that gated the round, if anyone completed.
    pub straggler_tier: Option<usize>,
}

/// The cohort scheduler: owns the fleet, the selection policy, the
/// staleness + training-signal state, and the simulated clock.
pub struct Scheduler {
    fleet: Fleet,
    policy_kind: SchedPolicy,
    policy: Box<dyn SelectionPolicy>,
    clock: SimClock,
    /// Sparse per-client scheduler state (staleness counters + training
    /// signals), resident only for ever-selected clients.
    touched: TouchedState,
    /// Cross-round on-device slice caches — device state like the
    /// profiles, so it lives with the fleet; a client's cache is allocated
    /// on its first commit ([`Scheduler::ensure_cache`]). Installed by
    /// the trainer (which knows the model geometry the budgets derive
    /// from) when `--cache` is on; `None` otherwise.
    caches: Option<FleetCaches>,
    /// Churn / outage / wave processes; `None` when no scenario knob is
    /// set (the legacy, bit-exact path).
    scenario: Option<Scenario>,
    /// Churn window offset at the previous plan, for arrival/departure
    /// ledger deltas.
    churn_prev_raw: Option<u64>,
}

impl Scheduler {
    /// Build from a training config: the fleet is generated from
    /// `cfg.seed`/`cfg.fleet`/`cfg.mem_cap_frac` (trace fleets load their
    /// file here, the only fallible step), the policy from
    /// `cfg.sched_policy`. The deprecated scalar `cfg.dropout_rate` is baked
    /// into the profiles as a hazard floor (a fleet-wide flaky-edge-style
    /// hazard), so reporting over the fleet shows the hazards the run
    /// actually used.
    pub fn new(cfg: &TrainConfig, n_train_clients: usize) -> Result<Self> {
        // `--fleet-size 0` (the default) sizes the fleet to the dataset;
        // a larger fleet maps client ids onto dataset clients modulo
        // n_train at fetch time (coordinator), so selection runs over the
        // full population.
        let fleet_n = if cfg.fleet_size > 0 {
            cfg.fleet_size
        } else {
            n_train_clients
        };
        let mut fleet = Fleet::generate(cfg.fleet.clone(), fleet_n, cfg.seed, cfg.mem_cap_frac)?;
        if cfg.dropout_rate > 0.0 {
            fleet.set_hazard_floor(cfg.dropout_rate);
        }
        let scenario = Scenario::new(&cfg.scenario, fleet_n);
        Ok(Scheduler {
            fleet,
            policy_kind: cfg.sched_policy,
            policy: cfg.sched_policy.build(),
            clock: SimClock::new(),
            touched: TouchedState::new(),
            caches: None,
            scenario,
            churn_prev_raw: None,
        })
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// The sparse per-client scheduler state (ever-selected clients only).
    pub fn touched(&self) -> &TouchedState {
        &self.touched
    }

    /// Clients with any resident scheduler state — by construction, the
    /// clients ever selected.
    pub fn clients_touched(&self) -> usize {
        self.touched.clients_touched()
    }

    /// Approximate resident bytes of all per-client state: touched-state
    /// entries, allocated client caches, and the fleet's trace rows.
    /// Proportional to touched clients, independent of fleet size — the
    /// `fleet.resident_bytes` gauge.
    pub fn resident_state_bytes(&self) -> u64 {
        self.touched.resident_bytes()
            + self.caches.as_ref().map_or(0, |c| c.resident_bytes())
            + self.fleet.resident_bytes()
    }

    /// Attach the cross-round client caches (one per train client). Called
    /// by the trainer after construction — the per-client byte budgets
    /// derive from the model size, which only the trainer knows.
    pub fn install_caches(&mut self, caches: FleetCaches) {
        self.caches = Some(caches);
    }

    /// The fleet's client caches, when `--cache` is on.
    pub fn caches(&self) -> Option<&FleetCaches> {
        self.caches.as_ref()
    }

    pub fn caches_mut(&mut self) -> Option<&mut FleetCaches> {
        self.caches.as_mut()
    }

    /// Detach and return the fleet caches (leaving `None` installed). The
    /// multi-tenant coordinator's contended cache share swaps one pooled
    /// [`FleetCaches`] between jobs with this + [`Self::install_caches`]
    /// around each job's round, so every job contends for the same device
    /// bytes without sharing ownership.
    pub fn take_caches(&mut self) -> Option<FleetCaches> {
        self.caches.take()
    }

    /// The byte budget client `ci`'s cache would get, from the installed
    /// caches' budget source (explicit table, or derived from the device
    /// profile). `None` when no caches are installed.
    pub fn cache_budget_of(&self, ci: usize) -> Option<u64> {
        let caches = self.caches.as_ref()?;
        Some(match caches.budget_source() {
            BudgetSource::Table(t) => t.get(ci).copied().unwrap_or(0),
            BudgetSource::Derived { server_bytes, frac } => {
                (self.fleet.profile(ci).mem_bytes(*server_bytes) as f64 * frac) as u64
            }
        })
    }

    /// Allocate client `ci`'s cache if absent (first commit), at the
    /// budget its device profile derives. No-op without installed caches.
    pub fn ensure_cache(&mut self, ci: usize) {
        let Some(budget) = self.cache_budget_of(ci) else {
            return;
        };
        if let Some(caches) = self.caches.as_mut() {
            caches.ensure(ci, budget);
        }
    }

    pub fn policy_kind(&self) -> SchedPolicy {
        self.policy_kind
    }

    /// Simulated seconds since the start of training.
    pub fn sim_total_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Phase 0: choose the round's cohort, hazards, and key budgets.
    ///
    /// `rng` is the round RNG; under [`SchedPolicy::Uniform`] with an empty
    /// `exclude` set exactly one `sample_without_replacement(n, cohort)` is
    /// drawn from it — the same draw the pre-scheduler coordinator made.
    ///
    /// `exclude` lists train-client indices that may not be selected this
    /// round: the round engine's in-flight set under buffered aggregation
    /// (FedBuff caps per-client concurrency at one — a client whose update
    /// has not landed is not re-selected). Outside buffered mode it is
    /// empty and every policy keeps its legacy RNG consumption bit-exact.
    pub fn plan_round(
        &mut self,
        round: usize,
        cohort: usize,
        geom: &SliceGeometry,
        rng: &mut Rng,
        exclude: &[usize],
    ) -> RoundPlan {
        let n = self.fleet.len();
        let mut excluded: Vec<usize> = exclude.iter().copied().filter(|&ci| ci < n).collect();
        excluded.sort_unstable();
        excluded.dedup();
        // Scenario eligibility is frozen at the round's sim-time start;
        // ledger counts are closed-form (no fleet scan).
        let t_h = self.clock.now_s() / 3600.0;
        let view = self.scenario.as_ref().map(|s| s.view(t_h));
        let (arrivals, departures) = match (&self.scenario, &view) {
            (Some(s), Some(v)) if v.churn_active() => {
                let raw = s.churn_offset_raw(t_h);
                let prev = self.churn_prev_raw.replace(raw).unwrap_or(raw);
                let d = raw.saturating_sub(prev).min(n as u64) as usize;
                (d, d)
            }
            _ => (0, 0),
        };
        let outage_excluded = view.as_ref().map_or(0, |v| v.outage_excluded_count());
        let eligible = match &view {
            Some(v) => {
                let in_view = excluded.iter().filter(|&&ci| v.eligible(ci)).count();
                v.eligible_count().saturating_sub(in_view)
            }
            None => n - excluded.len(),
        };
        let ctx = PlanCtx {
            round,
            cohort,
            fleet: &self.fleet,
            touched: &self.touched,
            excluded: &excluded,
            scenario: view.as_ref(),
            geom,
        };
        let sel = self.policy.select(&ctx, rng);
        for &ci in &sel.cohort {
            self.touched.mark_selected(ci, round as i64);
        }
        let hazards = sel
            .cohort
            .iter()
            .map(|&ci| self.fleet.profile(ci).hazard)
            .collect();
        RoundPlan {
            round,
            cohort: sel.cohort,
            hazards,
            key_budgets: sel.key_budgets,
            eligible,
            arrivals,
            departures,
            outage_excluded,
        }
    }

    /// Per-client completion events for one round, in completion order
    /// (ties broken by cohort slot). Dropped clients never report and are
    /// excluded; their download still lands in the tier ledgers at
    /// [`Scheduler::complete_round_at`]. This is the ordering the round
    /// engine's aggregation modes consume.
    pub fn events(&self, plan: &RoundPlan, stats: &[ClientRoundStats]) -> Vec<CompletionEvent> {
        debug_assert_eq!(plan.cohort.len(), stats.len());
        let mut ev: Vec<CompletionEvent> = plan
            .cohort
            .iter()
            .zip(stats.iter())
            .enumerate()
            .filter(|(_, (_, st))| !st.dropped)
            .map(|(slot, (&ci, st))| {
                let p = self.fleet.profile(ci);
                let timing =
                    SimClock::client_timing(&p, st.down_bytes, st.up_bytes, st.compute_units);
                CompletionEvent {
                    slot,
                    client: ci,
                    tier: p.tier,
                    at_s: timing.total_s(),
                    timing,
                }
            })
            .collect();
        ev.sort_by(|a, b| {
            a.at_s
                .partial_cmp(&b.at_s)
                .expect("client timings are finite")
                .then(a.slot.cmp(&b.slot))
        });
        ev
    }

    /// After phase 3, synchronous barrier: the round closes at the
    /// straggler (the last completion event). Every non-dropped cohort slot
    /// counts as completed. `stats` is aligned with `plan.cohort`.
    pub fn complete_round(&mut self, plan: &RoundPlan, stats: &[ClientRoundStats]) -> RoundSim {
        let events = self.events(plan, stats);
        let close_s = events.last().map_or(0.0, |e| e.at_s);
        let merged_tiers: Vec<usize> = events.iter().map(|e| e.tier).collect();
        self.complete_round_at(plan, stats, &events, close_s, &merged_tiers)
    }

    /// After phase 3, event-driven close: the round engine decided the
    /// round closed at `close_s` (relative to round start — the goal-count
    /// completion under over-selection / buffered aggregation) and merged
    /// the updates whose fleet tiers are `merged_tiers` (which may include
    /// updates launched in earlier rounds under buffered aggregation).
    /// `events` is this round's [`Scheduler::events`] output, passed back in
    /// so it is computed once per round. Tier drop/download tallies always
    /// cover this round's whole cohort — a discarded straggler's download
    /// is spent regardless — and each non-dropped client's `update_norm` is
    /// recorded as its selection signal. Advances the simulated clock by
    /// `close_s` plus the fixed server overhead.
    pub fn complete_round_at(
        &mut self,
        plan: &RoundPlan,
        stats: &[ClientRoundStats],
        events: &[CompletionEvent],
        close_s: f64,
        merged_tiers: &[usize],
    ) -> RoundSim {
        debug_assert_eq!(plan.cohort.len(), stats.len());
        let tiers = self.fleet.num_tiers();
        let mut sim = RoundSim {
            tier_completed: vec![0; tiers],
            tier_dropped: vec![0; tiers],
            tier_down_bytes: vec![0; tiers],
            ..RoundSim::default()
        };
        for (&ci, st) in plan.cohort.iter().zip(stats.iter()) {
            let p = self.fleet.profile(ci);
            sim.tier_down_bytes[p.tier] += st.down_bytes;
            if st.dropped {
                sim.tier_dropped[p.tier] += 1;
            } else {
                // cohort members are already marked selected, so this
                // never grows the touched set past ever-selected clients
                self.touched.set_signal(ci, st.update_norm);
            }
        }
        for &t in merged_tiers {
            sim.tier_completed[t] += 1;
        }
        // the this-round client whose completion closed the round; None when
        // nobody reported, or when a carried in-flight landing closed it
        // (buffered mode) before any fresh completion
        sim.straggler_tier = events
            .iter()
            .rev()
            .find(|e| e.at_s <= close_s)
            .map(|e| e.tier);
        sim.sim_round_s = self.clock.advance_round_to(close_s);
        sim.sim_total_s = self.clock.now_s();
        sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn cfg(fleet: FleetKind, policy: SchedPolicy) -> TrainConfig {
        let mut cfg = TrainConfig::logreg_default(128, 32);
        cfg.fleet = fleet;
        cfg.sched_policy = policy;
        cfg
    }

    fn geom() -> SliceGeometry {
        SliceGeometry {
            base_ms: vec![32],
            per_key_floats: vec![50],
            broadcast_floats: 50,
            server_floats: 128 * 50 + 50,
        }
    }

    #[test]
    fn sched_policy_display_round_trips_case_insensitively() {
        for p in SchedPolicy::ALL {
            let shown = p.to_string();
            assert_eq!(shown.parse::<SchedPolicy>().unwrap(), p);
            assert_eq!(shown.to_uppercase().parse::<SchedPolicy>().unwrap(), p);
            assert_eq!(p.build().name(), shown);
        }
        assert_eq!(
            "mem-capped".parse::<SchedPolicy>().unwrap(),
            SchedPolicy::MemoryCapped
        );
        let err = "bogus".parse::<SchedPolicy>().unwrap_err();
        assert!(err.contains("uniform") && err.contains("staleness-fair"), "{err}");
    }

    #[test]
    fn uniform_plan_consumes_exactly_the_legacy_draw() {
        let mut s = Scheduler::new(&cfg(FleetKind::Uniform, SchedPolicy::Uniform), 40).unwrap();
        let mut rng = Rng::new(7, 1);
        let mut legacy = rng.clone();
        let plan = s.plan_round(1, 10, &geom(), &mut rng, &[]);
        assert_eq!(plan.cohort, legacy.sample_without_replacement(40, 10));
        // nothing else was drawn: subsequent values coincide
        assert_eq!(rng.next_u64(), legacy.next_u64());
        assert!(plan.key_budgets.is_none());
        assert!(plan.hazards.iter().all(|&h| h == 0.0));
    }

    #[test]
    fn dropout_rate_floors_every_hazard() {
        let mut c = cfg(FleetKind::Uniform, SchedPolicy::Uniform);
        c.dropout_rate = 0.3;
        let mut s = Scheduler::new(&c, 20).unwrap();
        let plan = s.plan_round(1, 5, &geom(), &mut Rng::new(1, 1), &[]);
        assert!(plan.hazards.iter().all(|&h| (h - 0.3).abs() < 1e-9));
    }

    #[test]
    fn complete_round_tallies_tiers_and_advances_the_clock() {
        let mut s = Scheduler::new(&cfg(FleetKind::Tiered3, SchedPolicy::Uniform), 60).unwrap();
        let mut rng = Rng::new(3, 2);
        let plan = s.plan_round(1, 12, &geom(), &mut rng, &[]);
        let stats: Vec<ClientRoundStats> = (0..plan.cohort.len())
            .map(|i| ClientRoundStats {
                down_bytes: 100_000,
                up_bytes: 50_000,
                compute_units: 1e7,
                dropped: i % 4 == 0,
                ..ClientRoundStats::default()
            })
            .collect();
        let sim = s.complete_round(&plan, &stats);
        assert_eq!(sim.tier_completed.len(), 3);
        assert_eq!(
            sim.tier_completed.iter().sum::<usize>()
                + sim.tier_dropped.iter().sum::<usize>(),
            12
        );
        assert!(sim.sim_round_s > 0.0);
        assert!((sim.sim_total_s - s.sim_total_s()).abs() < 1e-12);
        assert!(sim.straggler_tier.is_some());
        assert_eq!(sim.tier_down_bytes.iter().sum::<u64>(), 12 * 100_000);
        // a second round accumulates
        let plan2 = s.plan_round(2, 12, &geom(), &mut rng, &[]);
        let sim2 = s.complete_round(&plan2, &stats);
        assert!(sim2.sim_total_s > sim.sim_total_s);
    }

    #[test]
    fn events_are_sorted_and_exclude_dropped_clients() {
        let mut s = Scheduler::new(&cfg(FleetKind::Tiered3, SchedPolicy::Uniform), 60).unwrap();
        let mut rng = Rng::new(9, 4);
        let plan = s.plan_round(1, 10, &geom(), &mut rng, &[]);
        let stats: Vec<ClientRoundStats> = (0..plan.cohort.len())
            .map(|i| ClientRoundStats {
                down_bytes: 200_000,
                up_bytes: 80_000,
                compute_units: 1e7,
                dropped: i % 5 == 0,
                ..ClientRoundStats::default()
            })
            .collect();
        let ev = s.events(&plan, &stats);
        assert_eq!(ev.len(), stats.iter().filter(|s| !s.dropped).count());
        for w in ev.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "events out of order");
        }
        for e in &ev {
            assert!(!stats[e.slot].dropped);
            assert_eq!(e.client, plan.cohort[e.slot]);
            assert!((e.at_s - e.timing.total_s()).abs() < 1e-12);
        }
        // an early close is strictly cheaper than the barrier, ledgers the
        // whole cohort's downloads, and counts only the merged tiers
        let mid = ev[ev.len() / 2];
        let sim = s.complete_round_at(&plan, &stats, &ev, mid.at_s, &[mid.tier]);
        assert!((sim.sim_round_s - (mid.at_s + ROUND_OVERHEAD_S)).abs() < 1e-12);
        assert_eq!(sim.tier_completed.iter().sum::<usize>(), 1);
        assert_eq!(
            sim.tier_down_bytes.iter().sum::<u64>(),
            plan.cohort.len() as u64 * 200_000
        );
        assert_eq!(sim.straggler_tier, Some(mid.tier));
    }

    #[test]
    fn plan_round_exclusion_set_is_honored_and_empty_set_is_bit_exact() {
        let c = cfg(FleetKind::Uniform, SchedPolicy::Uniform);
        // an in-flight exclusion set keeps those clients out of the cohort
        let mut s = Scheduler::new(&c, 20).unwrap();
        let exclude = [2usize, 5, 11, 19];
        let plan = s.plan_round(1, 8, &geom(), &mut Rng::new(3, 1), &exclude);
        assert_eq!(plan.cohort.len(), 8);
        for &ci in &plan.cohort {
            assert!(!exclude.contains(&ci), "excluded client {ci} selected");
        }
        // the empty exclusion set consumes exactly the legacy draw
        let mut s2 = Scheduler::new(&c, 20).unwrap();
        let mut rng = Rng::new(3, 1);
        let mut legacy = rng.clone();
        let plan2 = s2.plan_round(1, 8, &geom(), &mut rng, &[]);
        assert_eq!(plan2.cohort, legacy.sample_without_replacement(20, 8));
        assert_eq!(rng.next_u64(), legacy.next_u64());
        // out-of-range exclusion entries are ignored, not a panic
        let mut s3 = Scheduler::new(&c, 20).unwrap();
        let plan3 = s3.plan_round(1, 8, &geom(), &mut Rng::new(3, 1), &[999]);
        assert_eq!(plan3.cohort.len(), 8);
    }

    #[test]
    fn staleness_state_feeds_the_fair_policy() {
        let mut s = Scheduler::new(&cfg(FleetKind::Uniform, SchedPolicy::StalenessFair), 12)
            .unwrap();
        let mut rng = Rng::new(5, 3);
        let g = geom();
        let mut seen = std::collections::HashSet::new();
        for round in 1..=3 {
            let plan = s.plan_round(round, 4, &g, &mut rng, &[]);
            for &ci in &plan.cohort {
                assert!(seen.insert(ci), "repeat before full pass");
            }
        }
        assert_eq!(seen.len(), 12);
    }
}

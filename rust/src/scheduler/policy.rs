//! Pluggable cohort-selection policies.
//!
//! A [`SelectionPolicy`] turns the round context (fleet, staleness state,
//! slice geometry) plus the round RNG into a cohort and, optionally,
//! per-client select-key budgets. [`Uniform`] is byte-identical to the
//! pre-scheduler coordinator's inline sampling at the same seed: it makes
//! exactly one `sample_without_replacement(n, k)` call on the round RNG and
//! nothing else consumes entropy on that path.

use crate::scheduler::{Fleet, SliceGeometry};
use crate::tensor::rng::Rng;

/// Everything a policy may condition on when choosing a round's cohort.
pub struct PlanCtx<'a> {
    /// 1-based round number (matches `Trainer::run_round`).
    pub round: usize,
    /// Requested cohort size.
    pub cohort: usize,
    pub fleet: &'a Fleet,
    /// Per train client: last round it was selected, or -1 if never.
    pub last_selected: &'a [i64],
    /// Per train client: update norm from its last participation, or 0 if
    /// it never participated — the [`LossWeighted`] importance signal.
    pub signals: &'a [f32],
    /// Per train client: `true` = may not be selected this round. The round
    /// engine excludes clients with an update still in flight (FedBuff caps
    /// per-client concurrency at one); all-`false` outside buffered mode,
    /// and every policy must fall back to its exact legacy RNG consumption
    /// in that case (the byte-identity contract).
    pub excluded: &'a [bool],
    pub geom: &'a SliceGeometry,
}

impl PlanCtx<'_> {
    /// The selectable client indices, or `None` when nobody is excluded (the
    /// legacy full-population path — policies must keep its RNG consumption
    /// bit-exact).
    pub fn eligible(&self) -> Option<Vec<usize>> {
        if self.excluded.iter().any(|&e| e) {
            Some(
                (0..self.fleet.len())
                    .filter(|&i| !self.excluded[i])
                    .collect(),
            )
        } else {
            None
        }
    }
}

/// A policy's output: the cohort (train-client indices) and optional
/// per-cohort-slot, per-keyspace key budgets (`None` = the configured
/// [`crate::fedselect::KeyPolicy`] budgets apply unchanged).
pub struct Selection {
    pub cohort: Vec<usize>,
    pub key_budgets: Option<Vec<Vec<usize>>>,
}

/// A cohort-selection strategy. Implementations must be deterministic given
/// (`ctx`, the RNG state): the scheduler proptests re-run every policy at a
/// fixed seed and require identical cohorts.
pub trait SelectionPolicy: Send {
    fn name(&self) -> &'static str;
    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection;
}

fn uniform_cohort(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    rng.sample_without_replacement(n, k.min(n))
}

/// Uniform draw over the eligible pool: the exact legacy
/// `sample_without_replacement` when nobody is excluded (the byte-identity
/// contract), an index-remapped draw over the eligible list otherwise.
/// Shared by every policy whose cohort draw is uniform.
fn uniform_eligible(ctx: &PlanCtx, rng: &mut Rng) -> Vec<usize> {
    match ctx.eligible() {
        None => uniform_cohort(ctx.fleet.len(), ctx.cohort, rng),
        Some(el) => uniform_cohort(el.len(), ctx.cohort, rng)
            .into_iter()
            .map(|j| el[j])
            .collect(),
    }
}

/// §5.1 uniform sampling without replacement — the paper's baseline and the
/// pre-scheduler coordinator's behavior, bit for bit.
pub struct Uniform;

impl SelectionPolicy for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        Selection {
            cohort: uniform_eligible(ctx, rng),
            key_budgets: None,
        }
    }
}

/// Sample uniformly among the clients whose availability trace says they are
/// online this round; if none are (degenerate trace), fall back to the full
/// population rather than running an empty round.
pub struct AvailabilityAware;

impl SelectionPolicy for AvailabilityAware {
    fn name(&self) -> &'static str {
        "availability-aware"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        let avail: Vec<usize> = (0..ctx.fleet.len())
            .filter(|&i| ctx.fleet.profiles[i].available(ctx.round) && !ctx.excluded[i])
            .collect();
        let cohort = if avail.is_empty() {
            uniform_eligible(ctx, rng)
        } else {
            uniform_cohort(avail.len(), ctx.cohort, rng)
                .into_iter()
                .map(|j| avail[j])
                .collect()
        };
        Selection {
            cohort,
            key_budgets: None,
        }
    }
}

/// Uniform sampling (same RNG draw as [`Uniform`], so cohorts coincide at a
/// fixed seed), plus per-client select budgets clamped so each client's
/// sub-model fits its device's memory cap.
pub struct MemoryCapped;

impl MemoryCapped {
    /// Largest per-keyspace key counts whose slice fits `mem_frac` of the
    /// full server model: broadcast floats are fixed cost, keyed floats are
    /// scaled down proportionally across keyspaces. Never below 1 key.
    pub fn budget_for(profile_mem_frac: f64, geom: &SliceGeometry) -> Vec<usize> {
        let cap = (profile_mem_frac * geom.server_floats as f64) as usize;
        let keyed: usize = geom
            .base_ms
            .iter()
            .zip(geom.per_key_floats.iter())
            .map(|(&m, &pk)| m * pk)
            .sum();
        if keyed == 0 {
            return geom.base_ms.clone();
        }
        let avail = cap.saturating_sub(geom.broadcast_floats);
        if avail >= keyed {
            return geom.base_ms.clone();
        }
        let s = avail as f64 / keyed as f64;
        geom.base_ms
            .iter()
            .map(|&m| ((m as f64 * s) as usize).max(1).min(m.max(1)))
            .collect()
    }
}

impl SelectionPolicy for MemoryCapped {
    fn name(&self) -> &'static str {
        "memory-capped"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        let cohort = uniform_eligible(ctx, rng);
        let budgets = cohort
            .iter()
            .map(|&ci| Self::budget_for(ctx.fleet.profiles[ci].mem_frac, ctx.geom))
            .collect();
        Selection {
            cohort,
            key_budgets: Some(budgets),
        }
    }
}

/// Prioritize the clients selected longest ago (never-selected first), with
/// random tie-breaking: a shuffle followed by a stable sort on
/// last-selected round. Over `ceil(n / cohort)` rounds every client is
/// visited at least once.
pub struct StalenessFair;

impl SelectionPolicy for StalenessFair {
    fn name(&self) -> &'static str {
        "staleness-fair"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        // with no exclusions this filter is the identity, so the shuffle
        // consumes exactly the legacy draws
        let mut idx: Vec<usize> = (0..ctx.fleet.len())
            .filter(|&i| !ctx.excluded[i])
            .collect();
        rng.shuffle(&mut idx);
        idx.sort_by_key(|&i| ctx.last_selected[i]);
        idx.truncate(ctx.cohort.min(idx.len()));
        Selection {
            cohort: idx,
            key_budgets: None,
        }
    }
}

/// Importance-based sampling: clients whose last participation produced a
/// large update (a proxy for high local loss / gradient norm — the signal
/// the client-selection literature weights on) are proportionally more
/// likely to be drawn. Never-selected clients get the mean observed signal
/// as an optimistic prior, and the policy degrades to plain [`Uniform`]
/// (same single RNG draw) until anyone has reported a signal at all.
/// Sampling is without replacement via successive categorical draws on the
/// remaining weights, so it stays deterministic in the round RNG.
pub struct LossWeighted;

impl SelectionPolicy for LossWeighted {
    fn name(&self) -> &'static str {
        "loss-weighted"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        // the eligible pool is the whole population when nobody is excluded
        // — the identity mapping, keeping legacy RNG consumption bit-exact
        let pool: Vec<usize> = match ctx.eligible() {
            None => (0..ctx.fleet.len()).collect(),
            Some(el) => el,
        };
        let n = pool.len();
        let k = ctx.cohort.min(n);
        let observed: Vec<f64> = pool
            .iter()
            .map(|&ci| {
                let s = ctx.signals[ci] as f64;
                if s.is_finite() && s > 0.0 {
                    s
                } else {
                    0.0
                }
            })
            .collect();
        let n_pos = observed.iter().filter(|&&s| s > 0.0).count();
        if n_pos == 0 {
            return Selection {
                cohort: uniform_cohort(n, k, rng).into_iter().map(|j| pool[j]).collect(),
                key_budgets: None,
            };
        }
        let prior = observed.iter().sum::<f64>() / n_pos as f64;
        let mut w: Vec<f64> = observed
            .iter()
            .map(|&s| if s > 0.0 { s } else { prior })
            .collect();
        let mut cohort = Vec::with_capacity(k);
        for _ in 0..k {
            let mut i = rng.categorical(&w);
            if w[i] == 0.0 {
                // float-rounding tail of the categorical sampler can land on
                // an exhausted index; fall forward to the next live one
                i = (0..n)
                    .map(|d| (i + d) % n)
                    .find(|&j| w[j] > 0.0)
                    .expect("k <= n leaves a live weight");
            }
            cohort.push(pool[i]);
            w[i] = 0.0;
        }
        Selection {
            cohort,
            key_budgets: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::FleetKind;

    fn ctx_parts(
        kind: FleetKind,
        n: usize,
    ) -> (Fleet, Vec<i64>, Vec<f32>, Vec<bool>, SliceGeometry) {
        let fleet = Fleet::generate(kind, n, 7, 0.25).unwrap();
        let last = vec![-1i64; n];
        let signals = vec![0.0f32; n];
        let excluded = vec![false; n];
        // full-budget slice == the whole keyed segment, so tier mem caps
        // below 1.0 genuinely clamp
        let geom = SliceGeometry {
            base_ms: vec![2048],
            per_key_floats: vec![50],
            broadcast_floats: 50,
            server_floats: 2048 * 50 + 50,
        };
        (fleet, last, signals, excluded, geom)
    }

    #[test]
    fn uniform_matches_the_raw_sampler_draw() {
        let (fleet, last, sigs, excl, geom) = ctx_parts(FleetKind::Uniform, 30);
        let ctx = PlanCtx {
            round: 1,
            cohort: 8,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &excl,
            geom: &geom,
        };
        let mut a = Rng::new(5, 1);
        let mut b = a.clone();
        let sel = Uniform.select(&ctx, &mut a);
        assert_eq!(sel.cohort, b.sample_without_replacement(30, 8));
        assert!(sel.key_budgets.is_none());
    }

    #[test]
    fn availability_aware_only_picks_online_clients() {
        let (fleet, last, sigs, excl, geom) = ctx_parts(FleetKind::Diurnal, 40);
        for round in [0usize, 6, 12, 18] {
            let ctx = PlanCtx {
                round,
                cohort: 5,
                fleet: &fleet,
                last_selected: &last,
                signals: &sigs,
                excluded: &excl,
                geom: &geom,
            };
            let mut rng = Rng::new(3, 2);
            let sel = AvailabilityAware.select(&ctx, &mut rng);
            assert!(!sel.cohort.is_empty());
            for &ci in &sel.cohort {
                assert!(
                    fleet.profiles[ci].available(round),
                    "round {round}: client {ci} offline"
                );
            }
        }
    }

    #[test]
    fn memory_capped_budgets_fit_the_device() {
        let (fleet, last, sigs, excl, geom) = ctx_parts(FleetKind::Tiered3, 60);
        let ctx = PlanCtx {
            round: 1,
            cohort: 20,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &excl,
            geom: &geom,
        };
        let mut rng = Rng::new(9, 3);
        let sel = MemoryCapped.select(&ctx, &mut rng);
        let budgets = sel.key_budgets.unwrap();
        assert_eq!(budgets.len(), sel.cohort.len());
        for (&ci, ms) in sel.cohort.iter().zip(budgets.iter()) {
            let p = &fleet.profiles[ci];
            let floats: usize = geom.broadcast_floats
                + ms.iter()
                    .zip(geom.per_key_floats.iter())
                    .map(|(&m, &pk)| m * pk)
                    .sum::<usize>();
            let cap = (p.mem_frac * geom.server_floats as f64) as usize;
            // either the base budget already fits, or the clamp brought the
            // slice within the cap (±1 key of rounding slack)
            assert!(
                floats <= cap + geom.per_key_floats[0] || ms == &geom.base_ms,
                "client {ci}: {floats} floats vs cap {cap}"
            );
            assert!(ms[0] >= 1 && ms[0] <= geom.base_ms[0]);
        }
        // the uniform-memory high tier keeps the full budget
        assert!(sel
            .cohort
            .iter()
            .zip(budgets.iter())
            .filter(|(&ci, _)| fleet.profiles[ci].tier == 2)
            .all(|(_, ms)| ms == &geom.base_ms));
    }

    #[test]
    fn memory_capped_cohort_equals_uniform_cohort_at_same_seed() {
        let (fleet, last, sigs, excl, geom) = ctx_parts(FleetKind::Tiered3, 60);
        let ctx = PlanCtx {
            round: 1,
            cohort: 12,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &excl,
            geom: &geom,
        };
        let mut a = Rng::new(4, 4);
        let mut b = a.clone();
        assert_eq!(
            MemoryCapped.select(&ctx, &mut a).cohort,
            Uniform.select(&ctx, &mut b).cohort
        );
    }

    #[test]
    fn staleness_fair_visits_everyone_before_repeating() {
        let (fleet, mut last, sigs, excl, geom) = ctx_parts(FleetKind::Uniform, 24);
        let mut rng = Rng::new(1, 5);
        let mut seen = std::collections::HashSet::new();
        for round in 1..=4usize {
            let ctx = PlanCtx {
                round,
                cohort: 6,
                fleet: &fleet,
                last_selected: &last,
                signals: &sigs,
                excluded: &excl,
                geom: &geom,
            };
            let cohort = StalenessFair.select(&ctx, &mut rng).cohort;
            assert_eq!(cohort.len(), 6);
            for &ci in &cohort {
                assert!(seen.insert(ci), "client {ci} repeated before full pass");
                last[ci] = round as i64;
            }
        }
        assert_eq!(seen.len(), 24);
    }

    #[test]
    fn loss_weighted_without_history_is_exactly_uniform() {
        let (fleet, last, sigs, excl, geom) = ctx_parts(FleetKind::Uniform, 30);
        let ctx = PlanCtx {
            round: 1,
            cohort: 8,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &excl,
            geom: &geom,
        };
        let mut a = Rng::new(5, 1);
        let mut b = a.clone();
        assert_eq!(
            LossWeighted.select(&ctx, &mut a).cohort,
            Uniform.select(&ctx, &mut b).cohort
        );
        // and nothing beyond the uniform draw was consumed
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn loss_weighted_prefers_high_signal_clients() {
        let (fleet, last, mut sigs, excl, geom) = ctx_parts(FleetKind::Uniform, 20);
        for s in sigs.iter_mut() {
            *s = 1.0;
        }
        sigs[3] = 50.0; // one client with a huge training signal
        sigs[7] = 0.0; // one that never participated (gets the mean prior)
        let ctx = PlanCtx {
            round: 1,
            cohort: 4,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &excl,
            geom: &geom,
        };
        let mut rng = Rng::new(11, 6);
        let mut hot = 0usize;
        let mut cold = 0usize;
        for _ in 0..300 {
            let cohort = LossWeighted.select(&ctx, &mut rng).cohort;
            assert_eq!(cohort.len(), 4);
            let distinct: std::collections::HashSet<_> = cohort.iter().collect();
            assert_eq!(distinct.len(), 4, "sampling must be without replacement");
            hot += usize::from(cohort.contains(&3));
            cold += usize::from(cohort.contains(&12));
        }
        // client 3 carries ~50/72 of the weight mass: near-certain pick
        assert!(hot > 280, "hot client picked {hot}/300");
        assert!(cold < hot / 2, "baseline client picked {cold} vs {hot}");
    }

    #[test]
    fn every_policy_respects_the_exclusion_set() {
        let (fleet, last, mut sigs, _, geom) = ctx_parts(FleetKind::Uniform, 16);
        sigs[2] = 3.0; // give loss-weighted a live signal path too
        let mut excl = vec![false; 16];
        for i in [0usize, 3, 7, 11, 15] {
            excl[i] = true;
        }
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(Uniform),
            Box::new(AvailabilityAware),
            Box::new(MemoryCapped),
            Box::new(StalenessFair),
            Box::new(LossWeighted),
        ];
        for p in &policies {
            let ctx = PlanCtx {
                round: 1,
                cohort: 8,
                fleet: &fleet,
                last_selected: &last,
                signals: &sigs,
                excluded: &excl,
                geom: &geom,
            };
            let mut rng = Rng::new(21, 9);
            let sel = p.select(&ctx, &mut rng);
            assert_eq!(sel.cohort.len(), 8, "{}", p.name());
            for &ci in &sel.cohort {
                assert!(!excl[ci], "{}: excluded client {ci} selected", p.name());
            }
            let distinct: std::collections::HashSet<_> = sel.cohort.iter().collect();
            assert_eq!(distinct.len(), 8, "{}: duplicate selections", p.name());
        }
        // exclusion shrinking the pool below the cohort clamps, not panics
        let all_but_two: Vec<bool> = (0..16).map(|i| i >= 2).collect();
        let ctx = PlanCtx {
            round: 1,
            cohort: 8,
            fleet: &fleet,
            last_selected: &last,
            signals: &sigs,
            excluded: &all_but_two,
            geom: &geom,
        };
        for p in &policies {
            let mut rng = Rng::new(22, 9);
            let sel = p.select(&ctx, &mut rng);
            assert!(sel.cohort.len() <= 2, "{}", p.name());
            assert!(sel.cohort.iter().all(|&ci| ci < 2), "{}", p.name());
        }
    }
}

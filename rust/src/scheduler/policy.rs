//! Pluggable cohort-selection policies.
//!
//! A [`SelectionPolicy`] turns the round context (fleet, sparse touched
//! state, scenario eligibility, slice geometry) plus the round RNG into a
//! cohort and, optionally, per-client select-key budgets. [`Uniform`] is
//! byte-identical to the pre-scheduler coordinator's inline sampling at
//! the same seed: it makes exactly one `sample_without_replacement(n, k)`
//! call on the round RNG and nothing else consumes entropy on that path.
//!
//! **Dense vs sparse.** At fleet sizes up to
//! [`SPARSE_SCAN_THRESHOLD`] every policy runs its legacy dense scan —
//! bit-for-bit the pre-lazy behavior (the byte-identity contract,
//! property-tested in `tests/scheduler_determinism.rs`). Larger fleets
//! switch to the stratified samplers in [`crate::fleet::sampling`], which
//! cost O(cohort + touched) instead of O(fleet). Sparse cohorts are
//! deterministic in the seed but consume the RNG differently from the
//! dense scans — the threshold pins every seed-size config to the dense
//! path, so nothing the byte-identity suite locks ever crosses over.

use crate::fleet::sampling::{rejection_sample, TwoStratumSampler, SPARSE_SCAN_THRESHOLD};
use crate::fleet::{EligibilityView, TouchedState};
use crate::scheduler::{Fleet, SliceGeometry};
use crate::tensor::rng::Rng;

/// Everything a policy may condition on when choosing a round's cohort.
pub struct PlanCtx<'a> {
    /// 1-based round number (matches `Trainer::run_round`).
    pub round: usize,
    /// Requested cohort size.
    pub cohort: usize,
    pub fleet: &'a Fleet,
    /// Sparse per-client scheduler state: staleness counters and training
    /// signals for ever-selected clients (legacy defaults for the rest).
    pub touched: &'a TouchedState,
    /// Sorted, deduped client ids that may not be selected this round. The
    /// round engine excludes clients with an update still in flight
    /// (FedBuff caps per-client concurrency at one); empty outside
    /// buffered mode, and every policy must fall back to its exact legacy
    /// RNG consumption in that case (the byte-identity contract).
    pub excluded: &'a [usize],
    /// Scenario eligibility (churn/outage/wave) frozen at this round's
    /// sim time; `None` when no scenario is active (the legacy path).
    pub scenario: Option<&'a EligibilityView>,
    pub geom: &'a SliceGeometry,
}

impl PlanCtx<'_> {
    /// Last round `ci` was selected, or -1 if never.
    pub fn last_selected(&self, ci: usize) -> i64 {
        self.touched.last_selected(ci)
    }

    /// Update norm from `ci`'s last participation, or 0 if it never
    /// participated — the [`LossWeighted`] importance signal.
    pub fn signal(&self, ci: usize) -> f32 {
        self.touched.signal(ci)
    }

    /// Whether `ci` may not be selected this round (in-flight exclusion
    /// or scenario ineligibility). O(log |excluded|).
    pub fn is_excluded(&self, ci: usize) -> bool {
        self.excluded.binary_search(&ci).is_ok()
            || self.scenario.is_some_and(|v| !v.eligible(ci))
    }

    /// Whether anything constrains the selectable pool.
    fn constrained(&self) -> bool {
        !self.excluded.is_empty() || self.scenario.is_some()
    }

    /// Whether this fleet is past the dense-scan threshold.
    fn sparse(&self) -> bool {
        self.fleet.len() > SPARSE_SCAN_THRESHOLD
    }

    /// The selectable client indices, or `None` when the pool is
    /// unconstrained (the legacy full-population path — policies must keep
    /// its RNG consumption bit-exact). Dense path only: O(fleet).
    pub fn eligible(&self) -> Option<Vec<usize>> {
        if self.constrained() {
            Some(
                (0..self.fleet.len())
                    .filter(|&i| !self.is_excluded(i))
                    .collect(),
            )
        } else {
            None
        }
    }
}

/// A policy's output: the cohort (client indices) and optional
/// per-cohort-slot, per-keyspace key budgets (`None` = the configured
/// [`crate::fedselect::KeyPolicy`] budgets apply unchanged).
pub struct Selection {
    pub cohort: Vec<usize>,
    pub key_budgets: Option<Vec<Vec<usize>>>,
}

/// A cohort-selection strategy. Implementations must be deterministic given
/// (`ctx`, the RNG state): the scheduler proptests re-run every policy at a
/// fixed seed and require identical cohorts.
pub trait SelectionPolicy: Send {
    fn name(&self) -> &'static str;
    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection;
}

fn uniform_cohort(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    rng.sample_without_replacement(n, k.min(n))
}

/// Uniform draw over the eligible pool. Unconstrained: the exact legacy
/// `sample_without_replacement` (the byte-identity contract — and already
/// O(cohort) at huge n, so the sparse path shares it). Constrained dense:
/// an index-remapped draw over the eligible list. Constrained sparse:
/// bounded rejection sampling — never an O(fleet) scan.
fn uniform_eligible(ctx: &PlanCtx, rng: &mut Rng) -> Vec<usize> {
    if !ctx.constrained() {
        return uniform_cohort(ctx.fleet.len(), ctx.cohort, rng);
    }
    if ctx.sparse() {
        return rejection_sample(rng, ctx.fleet.len(), ctx.cohort, |ci| !ctx.is_excluded(ci));
    }
    let el = ctx.eligible().expect("constrained");
    uniform_cohort(el.len(), ctx.cohort, rng)
        .into_iter()
        .map(|j| el[j])
        .collect()
}

/// §5.1 uniform sampling without replacement — the paper's baseline and the
/// pre-scheduler coordinator's behavior, bit for bit.
pub struct Uniform;

impl SelectionPolicy for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        Selection {
            cohort: uniform_eligible(ctx, rng),
            key_budgets: None,
        }
    }
}

/// Sample uniformly among the clients whose availability trace says they are
/// online this round; if none are (degenerate trace), fall back to the full
/// population rather than running an empty round.
pub struct AvailabilityAware;

impl SelectionPolicy for AvailabilityAware {
    fn name(&self) -> &'static str {
        "availability-aware"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        if ctx.sparse() {
            // availability is closed-form per profile, so rejection probes
            // it in O(1) without enumerating the online set
            let picks = rejection_sample(rng, ctx.fleet.len(), ctx.cohort, |ci| {
                ctx.fleet.profile(ci).available(ctx.round) && !ctx.is_excluded(ci)
            });
            let cohort = if picks.is_empty() {
                uniform_eligible(ctx, rng)
            } else {
                picks
            };
            return Selection {
                cohort,
                key_budgets: None,
            };
        }
        let avail: Vec<usize> = (0..ctx.fleet.len())
            .filter(|&i| ctx.fleet.profile(i).available(ctx.round) && !ctx.is_excluded(i))
            .collect();
        let cohort = if avail.is_empty() {
            uniform_eligible(ctx, rng)
        } else {
            uniform_cohort(avail.len(), ctx.cohort, rng)
                .into_iter()
                .map(|j| avail[j])
                .collect()
        };
        Selection {
            cohort,
            key_budgets: None,
        }
    }
}

/// Uniform sampling (same RNG draw as [`Uniform`], so cohorts coincide at a
/// fixed seed), plus per-client select budgets clamped so each client's
/// sub-model fits its device's memory cap.
pub struct MemoryCapped;

impl MemoryCapped {
    /// Largest per-keyspace key counts whose slice fits `mem_frac` of the
    /// full server model: broadcast floats are fixed cost, keyed floats are
    /// scaled down proportionally across keyspaces. Never below 1 key.
    pub fn budget_for(profile_mem_frac: f64, geom: &SliceGeometry) -> Vec<usize> {
        let cap = (profile_mem_frac * geom.server_floats as f64) as usize;
        let keyed: usize = geom
            .base_ms
            .iter()
            .zip(geom.per_key_floats.iter())
            .map(|(&m, &pk)| m * pk)
            .sum();
        if keyed == 0 {
            return geom.base_ms.clone();
        }
        let avail = cap.saturating_sub(geom.broadcast_floats);
        if avail >= keyed {
            return geom.base_ms.clone();
        }
        let s = avail as f64 / keyed as f64;
        geom.base_ms
            .iter()
            .map(|&m| ((m as f64 * s) as usize).max(1).min(m.max(1)))
            .collect()
    }
}

impl SelectionPolicy for MemoryCapped {
    fn name(&self) -> &'static str {
        "memory-capped"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        let cohort = uniform_eligible(ctx, rng);
        let budgets = cohort
            .iter()
            .map(|&ci| Self::budget_for(ctx.fleet.profile(ci).mem_frac, ctx.geom))
            .collect();
        Selection {
            cohort,
            key_budgets: Some(budgets),
        }
    }
}

/// Prioritize the clients selected longest ago (never-selected first), with
/// random tie-breaking. Dense: a shuffle followed by a stable sort on
/// last-selected round — over `ceil(n / cohort)` rounds every client is
/// visited at least once. Sparse: never-touched clients (staleness -1, the
/// overwhelming majority at scale) are drawn by rejection; any remaining
/// slots fill from the touched set in ascending `(last_selected, id)`
/// order — O(cohort + touched log touched), no fleet scan.
pub struct StalenessFair;

impl SelectionPolicy for StalenessFair {
    fn name(&self) -> &'static str {
        "staleness-fair"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        if ctx.sparse() {
            let mut cohort = rejection_sample(rng, ctx.fleet.len(), ctx.cohort, |ci| {
                !ctx.touched.contains(ci) && !ctx.is_excluded(ci)
            });
            if cohort.len() < ctx.cohort {
                // nearly everyone has been touched: fall back to the
                // compact staleness order over the touched set
                let mut stale: Vec<(i64, usize)> = ctx
                    .touched
                    .sorted_entries()
                    .into_iter()
                    .map(|(ci, t)| (t.last_selected, ci))
                    .collect();
                stale.sort_unstable();
                for (_, ci) in stale {
                    if cohort.len() >= ctx.cohort {
                        break;
                    }
                    if !ctx.is_excluded(ci) && !cohort.contains(&ci) {
                        cohort.push(ci);
                    }
                }
            }
            return Selection {
                cohort,
                key_budgets: None,
            };
        }
        // with no exclusions this filter is the identity, so the shuffle
        // consumes exactly the legacy draws
        let mut idx: Vec<usize> = (0..ctx.fleet.len())
            .filter(|&i| !ctx.is_excluded(i))
            .collect();
        rng.shuffle(&mut idx);
        idx.sort_by_key(|&i| ctx.last_selected(i));
        idx.truncate(ctx.cohort.min(idx.len()));
        Selection {
            cohort: idx,
            key_budgets: None,
        }
    }
}

/// Importance-based sampling: clients whose last participation produced a
/// large update (a proxy for high local loss / gradient norm — the signal
/// the client-selection literature weights on) are proportionally more
/// likely to be drawn. Never-selected clients get the mean observed signal
/// as an optimistic prior, and the policy degrades to plain [`Uniform`]
/// (same single RNG draw) until anyone has reported a signal at all.
/// Dense: sampling without replacement via successive categorical draws on
/// the remaining weights. Sparse: the hierarchical
/// [`TwoStratumSampler`] — observed-signal clients form a compact weighted
/// stratum, everyone else a uniform prior-weighted stratum resolved by
/// rejection — O(cohort × touched) instead of O(fleet).
pub struct LossWeighted;

impl SelectionPolicy for LossWeighted {
    fn name(&self) -> &'static str {
        "loss-weighted"
    }

    fn select(&self, ctx: &PlanCtx, rng: &mut Rng) -> Selection {
        if ctx.sparse() {
            return Selection {
                cohort: self.select_sparse(ctx, rng),
                key_budgets: None,
            };
        }
        // the eligible pool is the whole population when nobody is excluded
        // — the identity mapping, keeping legacy RNG consumption bit-exact
        let pool: Vec<usize> = match ctx.eligible() {
            None => (0..ctx.fleet.len()).collect(),
            Some(el) => el,
        };
        let n = pool.len();
        let k = ctx.cohort.min(n);
        let observed: Vec<f64> = pool
            .iter()
            .map(|&ci| {
                let s = ctx.signal(ci) as f64;
                if s.is_finite() && s > 0.0 {
                    s
                } else {
                    0.0
                }
            })
            .collect();
        let n_pos = observed.iter().filter(|&&s| s > 0.0).count();
        if n_pos == 0 {
            return Selection {
                cohort: uniform_cohort(n, k, rng).into_iter().map(|j| pool[j]).collect(),
                key_budgets: None,
            };
        }
        let prior = observed.iter().sum::<f64>() / n_pos as f64;
        let mut w: Vec<f64> = observed
            .iter()
            .map(|&s| if s > 0.0 { s } else { prior })
            .collect();
        let mut cohort = Vec::with_capacity(k);
        for _ in 0..k {
            let mut i = rng.categorical(&w);
            if w[i] == 0.0 {
                // float-rounding tail of the categorical sampler can land on
                // an exhausted index; fall forward to the next live one
                i = (0..n)
                    .map(|d| (i + d) % n)
                    .find(|&j| w[j] > 0.0)
                    .expect("k <= n leaves a live weight");
            }
            cohort.push(pool[i]);
            w[i] = 0.0;
        }
        Selection {
            cohort,
            key_budgets: None,
        }
    }
}

impl LossWeighted {
    fn select_sparse(&self, ctx: &PlanCtx, rng: &mut Rng) -> Vec<usize> {
        let n = ctx.fleet.len();
        // the observed-signal stratum: compact, ascending id order
        let hot: Vec<(usize, f64)> = ctx
            .touched
            .sorted_entries()
            .into_iter()
            .filter(|&(ci, t)| {
                let s = t.signal as f64;
                s.is_finite() && s > 0.0 && !ctx.is_excluded(ci)
            })
            .map(|(ci, t)| (ci, t.signal as f64))
            .collect();
        if hot.is_empty() {
            return uniform_eligible(ctx, rng);
        }
        let prior = hot.iter().map(|&(_, w)| w).sum::<f64>() / hot.len() as f64;
        let untouched = n.saturating_sub(hot.len());
        let mut sampler = TwoStratumSampler::new(hot, untouched, prior, n);
        let mut cohort: Vec<usize> = Vec::with_capacity(ctx.cohort);
        while cohort.len() < ctx.cohort {
            let picked_so_far = cohort.clone();
            match sampler.draw(rng, |ci| {
                !ctx.is_excluded(ci) && !picked_so_far.contains(&ci)
            }) {
                Some(ci) => cohort.push(ci),
                None => break,
            }
        }
        cohort
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{ChurnSpec, Scenario, ScenarioConfig};
    use crate::scheduler::FleetKind;

    fn ctx_parts(kind: FleetKind, n: usize) -> (Fleet, TouchedState, SliceGeometry) {
        let fleet = Fleet::generate(kind, n, 7, 0.25).unwrap();
        let touched = TouchedState::new();
        // full-budget slice == the whole keyed segment, so tier mem caps
        // below 1.0 genuinely clamp
        let geom = SliceGeometry {
            base_ms: vec![2048],
            per_key_floats: vec![50],
            broadcast_floats: 50,
            server_floats: 2048 * 50 + 50,
        };
        (fleet, touched, geom)
    }

    fn ctx<'a>(
        round: usize,
        cohort: usize,
        fleet: &'a Fleet,
        touched: &'a TouchedState,
        excluded: &'a [usize],
        geom: &'a SliceGeometry,
    ) -> PlanCtx<'a> {
        PlanCtx {
            round,
            cohort,
            fleet,
            touched,
            excluded,
            scenario: None,
            geom,
        }
    }

    #[test]
    fn uniform_matches_the_raw_sampler_draw() {
        let (fleet, touched, geom) = ctx_parts(FleetKind::Uniform, 30);
        let c = ctx(1, 8, &fleet, &touched, &[], &geom);
        let mut a = Rng::new(5, 1);
        let mut b = a.clone();
        let sel = Uniform.select(&c, &mut a);
        assert_eq!(sel.cohort, b.sample_without_replacement(30, 8));
        assert!(sel.key_budgets.is_none());
    }

    #[test]
    fn availability_aware_only_picks_online_clients() {
        let (fleet, touched, geom) = ctx_parts(FleetKind::Diurnal, 40);
        for round in [0usize, 6, 12, 18] {
            let c = ctx(round, 5, &fleet, &touched, &[], &geom);
            let mut rng = Rng::new(3, 2);
            let sel = AvailabilityAware.select(&c, &mut rng);
            assert!(!sel.cohort.is_empty());
            for &ci in &sel.cohort {
                assert!(
                    fleet.profile(ci).available(round),
                    "round {round}: client {ci} offline"
                );
            }
        }
    }

    #[test]
    fn memory_capped_budgets_fit_the_device() {
        let (fleet, touched, geom) = ctx_parts(FleetKind::Tiered3, 60);
        let c = ctx(1, 20, &fleet, &touched, &[], &geom);
        let mut rng = Rng::new(9, 3);
        let sel = MemoryCapped.select(&c, &mut rng);
        let budgets = sel.key_budgets.unwrap();
        assert_eq!(budgets.len(), sel.cohort.len());
        for (&ci, ms) in sel.cohort.iter().zip(budgets.iter()) {
            let p = fleet.profile(ci);
            let floats: usize = geom.broadcast_floats
                + ms.iter()
                    .zip(geom.per_key_floats.iter())
                    .map(|(&m, &pk)| m * pk)
                    .sum::<usize>();
            let cap = (p.mem_frac * geom.server_floats as f64) as usize;
            // either the base budget already fits, or the clamp brought the
            // slice within the cap (±1 key of rounding slack)
            assert!(
                floats <= cap + geom.per_key_floats[0] || ms == &geom.base_ms,
                "client {ci}: {floats} floats vs cap {cap}"
            );
            assert!(ms[0] >= 1 && ms[0] <= geom.base_ms[0]);
        }
        // the uniform-memory high tier keeps the full budget
        assert!(sel
            .cohort
            .iter()
            .zip(budgets.iter())
            .filter(|(&ci, _)| fleet.profile(ci).tier == 2)
            .all(|(_, ms)| ms == &geom.base_ms));
    }

    #[test]
    fn memory_capped_cohort_equals_uniform_cohort_at_same_seed() {
        let (fleet, touched, geom) = ctx_parts(FleetKind::Tiered3, 60);
        let c = ctx(1, 12, &fleet, &touched, &[], &geom);
        let mut a = Rng::new(4, 4);
        let mut b = a.clone();
        assert_eq!(
            MemoryCapped.select(&c, &mut a).cohort,
            Uniform.select(&c, &mut b).cohort
        );
    }

    #[test]
    fn staleness_fair_visits_everyone_before_repeating() {
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, 24);
        let mut rng = Rng::new(1, 5);
        let mut seen = std::collections::HashSet::new();
        for round in 1..=4usize {
            let c = ctx(round, 6, &fleet, &touched, &[], &geom);
            let cohort = StalenessFair.select(&c, &mut rng).cohort;
            assert_eq!(cohort.len(), 6);
            for &ci in &cohort {
                assert!(seen.insert(ci), "client {ci} repeated before full pass");
            }
            for &ci in &cohort {
                touched.mark_selected(ci, round as i64);
            }
        }
        assert_eq!(seen.len(), 24);
    }

    #[test]
    fn loss_weighted_without_history_is_exactly_uniform() {
        let (fleet, touched, geom) = ctx_parts(FleetKind::Uniform, 30);
        let c = ctx(1, 8, &fleet, &touched, &[], &geom);
        let mut a = Rng::new(5, 1);
        let mut b = a.clone();
        assert_eq!(
            LossWeighted.select(&c, &mut a).cohort,
            Uniform.select(&c, &mut b).cohort
        );
        // and nothing beyond the uniform draw was consumed
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn loss_weighted_prefers_high_signal_clients() {
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, 20);
        for ci in 0..20 {
            touched.mark_selected(ci, 0);
            touched.set_signal(ci, 1.0);
        }
        touched.set_signal(3, 50.0); // one client with a huge training signal
        touched.set_signal(7, 0.0); // no observed signal (gets the mean prior)
        let c = ctx(1, 4, &fleet, &touched, &[], &geom);
        let mut rng = Rng::new(11, 6);
        let mut hot = 0usize;
        let mut cold = 0usize;
        for _ in 0..300 {
            let cohort = LossWeighted.select(&c, &mut rng).cohort;
            assert_eq!(cohort.len(), 4);
            let distinct: std::collections::HashSet<_> = cohort.iter().collect();
            assert_eq!(distinct.len(), 4, "sampling must be without replacement");
            hot += usize::from(cohort.contains(&3));
            cold += usize::from(cohort.contains(&12));
        }
        // client 3 carries ~50/72 of the weight mass: near-certain pick
        assert!(hot > 280, "hot client picked {hot}/300");
        assert!(cold < hot / 2, "baseline client picked {cold} vs {hot}");
    }

    #[test]
    fn every_policy_respects_the_exclusion_set() {
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, 16);
        touched.mark_selected(2, 0);
        touched.set_signal(2, 3.0); // give loss-weighted a live signal path too
        let excl = [0usize, 3, 7, 11, 15];
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(Uniform),
            Box::new(AvailabilityAware),
            Box::new(MemoryCapped),
            Box::new(StalenessFair),
            Box::new(LossWeighted),
        ];
        for p in &policies {
            let c = ctx(1, 8, &fleet, &touched, &excl, &geom);
            let mut rng = Rng::new(21, 9);
            let sel = p.select(&c, &mut rng);
            assert_eq!(sel.cohort.len(), 8, "{}", p.name());
            for &ci in &sel.cohort {
                assert!(!excl.contains(&ci), "{}: excluded client {ci} selected", p.name());
            }
            let distinct: std::collections::HashSet<_> = sel.cohort.iter().collect();
            assert_eq!(distinct.len(), 8, "{}: duplicate selections", p.name());
        }
        // exclusion shrinking the pool below the cohort clamps, not panics
        let all_but_two: Vec<usize> = (2..16).collect();
        let c = ctx(1, 8, &fleet, &touched, &all_but_two, &geom);
        for p in &policies {
            let mut rng = Rng::new(22, 9);
            let sel = p.select(&c, &mut rng);
            assert!(sel.cohort.len() <= 2, "{}", p.name());
            assert!(sel.cohort.iter().all(|&ci| ci < 2), "{}", p.name());
        }
    }

    #[test]
    fn scenario_eligibility_gates_every_policy() {
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, 100);
        touched.mark_selected(60, 0);
        touched.set_signal(60, 2.0);
        // churn window [0, 50) at t=0: ids ≥ 50 have not arrived yet
        let scfg = ScenarioConfig {
            churn: Some(ChurnSpec {
                rate_per_h: 0.1,
                width_frac: 0.5,
            }),
            ..ScenarioConfig::default()
        };
        let sc = Scenario::new(&scfg, 100).unwrap();
        let view = sc.view(0.0);
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(Uniform),
            Box::new(AvailabilityAware),
            Box::new(MemoryCapped),
            Box::new(StalenessFair),
            Box::new(LossWeighted),
        ];
        for p in &policies {
            let c = PlanCtx {
                round: 1,
                cohort: 10,
                fleet: &fleet,
                touched: &touched,
                excluded: &[],
                scenario: Some(&view),
                geom: &geom,
            };
            let mut rng = Rng::new(13, 3);
            let sel = p.select(&c, &mut rng);
            assert_eq!(sel.cohort.len(), 10, "{}", p.name());
            for &ci in &sel.cohort {
                assert!(view.eligible(ci), "{}: ineligible client {ci}", p.name());
                assert!(ci < 50, "{}", p.name());
            }
        }
    }

    #[test]
    fn sparse_policies_are_deterministic_and_respect_constraints() {
        // past the threshold every policy must stay deterministic, skip
        // excluded ids, and return a full distinct cohort
        let n = SPARSE_SCAN_THRESHOLD + 10_000;
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Tiered3, n);
        for ci in (0..200).step_by(7) {
            touched.mark_selected(ci, 1);
            touched.set_signal(ci, (ci % 5) as f32 + 0.5);
        }
        let excl: Vec<usize> = (0..50).collect();
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(Uniform),
            Box::new(AvailabilityAware),
            Box::new(MemoryCapped),
            Box::new(StalenessFair),
            Box::new(LossWeighted),
        ];
        for p in &policies {
            let run = || {
                let c = ctx(2, 40, &fleet, &touched, &excl, &geom);
                let mut rng = Rng::new(31, 4);
                p.select(&c, &mut rng).cohort
            };
            let cohort = run();
            assert_eq!(cohort.len(), 40, "{}", p.name());
            assert_eq!(cohort, run(), "{}: nondeterministic", p.name());
            let distinct: std::collections::HashSet<_> = cohort.iter().collect();
            assert_eq!(distinct.len(), 40, "{}: duplicates", p.name());
            for &ci in &cohort {
                assert!(ci >= 50 && ci < n, "{}: bad pick {ci}", p.name());
            }
        }
    }

    #[test]
    fn sparse_staleness_fair_prefers_untouched_clients() {
        let n = SPARSE_SCAN_THRESHOLD + 1;
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, n);
        for ci in 0..1000 {
            touched.mark_selected(ci, 3);
        }
        let c = ctx(4, 20, &fleet, &touched, &[], &geom);
        let mut rng = Rng::new(8, 8);
        let cohort = StalenessFair.select(&c, &mut rng).cohort;
        assert_eq!(cohort.len(), 20);
        assert!(
            cohort.iter().all(|&ci| !touched.contains(ci)),
            "untouched majority must fill the cohort"
        );
    }

    #[test]
    fn sparse_loss_weighted_samples_hot_clients_more() {
        let n = SPARSE_SCAN_THRESHOLD + 1;
        let (fleet, mut touched, geom) = ctx_parts(FleetKind::Uniform, n);
        // ten observed clients carrying almost all the weight
        for ci in 0..10 {
            touched.mark_selected(ci, 1);
            touched.set_signal(ci, 1e6);
        }
        let c = ctx(2, 8, &fleet, &touched, &[], &geom);
        let mut rng = Rng::new(17, 5);
        let mut hot_picks = 0usize;
        for _ in 0..50 {
            let cohort = LossWeighted.select(&c, &mut rng).cohort;
            assert_eq!(cohort.len(), 8);
            hot_picks += cohort.iter().filter(|&&ci| ci < 10).count();
        }
        // the hot stratum has ~10 × 1e6 weight vs ~(n-10) × 1e6 prior —
        // hot clients should appear far above their 10/n base rate
        assert!(hot_picks > 0, "hot stratum never sampled");
    }
}
